"""L2: GPT-style decoder in JAX, calling the L1 Pallas kernels.

The model is the AI payload that Lattica moves around: the trainer node
steps `train_step`, publishes the flat parameter list as CID-addressed
blocks, and inference clusters execute `embed` / `layer_fwd` / `logits`
artifacts shard-by-shard over RPC streams.

Parameters are a FLAT LIST of arrays in a deterministic order (see
`param_names`); the Rust runtime treats them as an opaque ordered list
described by artifacts/manifest.json.

Scale note (recorded in DESIGN.md §3): the paper's workloads are data-center
models; on this CPU-only testbed we train a ~1M-parameter decoder so the
end-to-end example finishes in minutes. Every code path (kernels, AOT,
sharded serving, checkpoint distribution) is identical at larger widths —
`ModelConfig` scales d_model/n_layer without touching the stack.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.attention import attention as _attention_fwd
from .kernels.ffn import ffn as _ffn_fwd


# Pallas interpret-mode calls are not differentiable (no JVP rule for
# scratch + control flow); we attach the reference implementation's VJP so
# `train_step` can backprop while every forward pass — including inside the
# training graph — still runs the L1 kernel.


@jax.custom_vjp
def attention(q, k, v):
    return _attention_fwd(q, k, v, causal=True)


def _attn_fwd_rule(q, k, v):
    return _attention_fwd(q, k, v, causal=True), (q, k, v)


def _attn_bwd_rule(res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: kref.attention_ref(q, k, v, causal=True), q, k, v)
    return vjp(g)


attention.defvjp(_attn_fwd_rule, _attn_bwd_rule)


@jax.custom_vjp
def ffn(x, w1, b1, w2, b2):
    return _ffn_fwd(x, w1, b1, w2, b2)


def _ffn_fwd_rule(x, w1, b1, w2, b2):
    return _ffn_fwd(x, w1, b1, w2, b2), (x, w1, b1, w2, b2)


def _ffn_bwd_rule(res, g):
    _, vjp = jax.vjp(kref.ffn_ref, *res)
    return vjp(g)


ffn.defvjp(_ffn_fwd_rule, _ffn_bwd_rule)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    d_model: int = 128
    n_head: int = 4
    n_layer: int = 4
    d_ff: int = 512
    seq_len: int = 64
    batch: int = 4
    # Adam
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_head


LAYER_PARAMS = [
    "ln1_g",
    "ln1_b",
    "wq",
    "wk",
    "wv",
    "wo",
    "ln2_g",
    "ln2_b",
    "w1",
    "b1",
    "w2",
    "b2",
]

N_LAYER_PARAMS = len(LAYER_PARAMS)


def param_names(cfg: ModelConfig):
    names = ["wte", "wpe"]
    for i in range(cfg.n_layer):
        names += [f"l{i}.{n}" for n in LAYER_PARAMS]
    names += ["lnf_g", "lnf_b", "wout"]
    return names


def param_shapes(cfg: ModelConfig):
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    shapes = [(v, d), (s, d)]
    for _ in range(cfg.n_layer):
        shapes += [
            (d,),
            (d,),
            (d, d),
            (d, d),
            (d, d),
            (d, d),
            (d,),
            (d,),
            (d, f),
            (f,),
            (f, d),
            (d,),
        ]
    shapes += [(d,), (d,), (d, v)]
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic initialization, returned as the flat list."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in zip(param_names(cfg), param_shapes(cfg)):
        key, sub = jax.random.split(key)
        leaf = name.split(".")[-1]
        if leaf in ("ln1_g", "ln2_g", "lnf_g"):
            params.append(jnp.ones(shape, jnp.float32))
        elif leaf in ("ln1_b", "ln2_b", "lnf_b", "b1", "b2"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if name in ("wte", "wpe") else (2.0 / fan_in) ** 0.5 * 0.5
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def layer_param_slice(cfg: ModelConfig, layer: int):
    """(start, end) indices of layer `layer` in the flat list."""
    start = 2 + layer * N_LAYER_PARAMS
    return start, start + N_LAYER_PARAMS


def _layernorm(x, g, b):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def layer_fwd(hidden, lp, cfg: ModelConfig):
    """One transformer block over hidden (B, S, D). `lp` = 12 tensors."""
    (ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2) = lp
    b, s, d = hidden.shape
    h, dh = cfg.n_head, cfg.d_head

    x = _layernorm(hidden, ln1_g, ln1_b)
    x2 = x.reshape(b * s, d)
    q = (x2 @ wq).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (x2 @ wk).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = (x2 @ wv).reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    # L1 Pallas kernel, vmapped over the batch.
    att = jax.vmap(attention)(q, k, v)
    att = att.transpose(0, 2, 1, 3).reshape(b * s, d)
    hidden = hidden + (att @ wo).reshape(b, s, d)

    x = _layernorm(hidden, ln2_g, ln2_b)
    # L1 fused FFN kernel over flattened rows.
    y = ffn(x.reshape(b * s, d), w1, b1, w2, b2)
    return hidden + y.reshape(b, s, d)


def embed(tokens, wte, wpe):
    """tokens (B, S) int32 → hidden (B, S, D)."""
    s = tokens.shape[1]
    return wte[tokens] + wpe[None, :s, :]


def logits_head(hidden, lnf_g, lnf_b, wout):
    x = _layernorm(hidden, lnf_g, lnf_b)
    return x @ wout


def forward(params, tokens, cfg: ModelConfig):
    """Full forward pass → logits (B, S, V)."""
    hidden = embed(tokens, params[0], params[1])
    for i in range(cfg.n_layer):
        a, b = layer_param_slice(cfg, i)
        hidden = layer_fwd(hidden, params[a:b], cfg)
    return logits_head(hidden, params[-3], params[-2], params[-1])


def loss_fn(params, tokens_in, tokens_out, cfg: ModelConfig):
    """Mean next-token cross entropy."""
    logits = forward(params, tokens_in, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tokens_out[..., None], axis=-1)[..., 0]
    return nll.mean()


def train_step(params, m, v, step, batch, cfg: ModelConfig):
    """One Adam step. `batch` is (B, S+1) int32; returns updated state + loss.

    All state flows through arguments/results so the Rust trainer holds the
    optimizer state as plain literals between steps.
    """
    tokens_in = batch[:, :-1]
    tokens_out = batch[:, 1:]
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens_in, tokens_out, cfg)
    step = step + 1
    t = step.astype(jnp.float32)
    b1, b2 = cfg.beta1, cfg.beta2
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * (g * g)
        mhat = mi / (1 - b1**t)
        vhat = vi / (1 - b2**t)
        new_params.append(p - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, step, loss


def eval_loss(params, batch, cfg: ModelConfig):
    return loss_fn(params, batch[:, :-1], batch[:, 1:], cfg)


# ---------------------------------------------------------------------------
# AOT entry points (fixed shapes; see aot.py)
# ---------------------------------------------------------------------------


def make_entry_points(cfg: ModelConfig):
    """Callables + example argument shapes for every artifact we ship."""
    d = cfg.d_model
    f32 = jnp.float32
    i32 = jnp.int32

    def spec(shape, dt=f32):
        return jax.ShapeDtypeStruct(shape, dt)

    lp_specs = [
        spec((d,)),
        spec((d,)),
        spec((d, d)),
        spec((d, d)),
        spec((d, d)),
        spec((d, d)),
        spec((d,)),
        spec((d,)),
        spec((d, cfg.d_ff)),
        spec((cfg.d_ff,)),
        spec((cfg.d_ff, d)),
        spec((d,)),
    ]

    param_specs = [spec(s) for s in param_shapes(cfg)]

    # Serving entry points use batch=1.
    def embed_b1(tokens, wte, wpe):
        return (embed(tokens, wte, wpe),)

    def layer_b1(hidden, *lp):
        return (layer_fwd(hidden, list(lp), cfg),)

    def logits_b1(hidden, lnf_g, lnf_b, wout):
        out = logits_head(hidden, lnf_g, lnf_b, wout)
        return (out[:, -1, :],)  # next-token logits only

    def train(*args):
        n = len(param_specs)
        params = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        step = args[3 * n]
        batch = args[3 * n + 1]
        new_p, new_m, new_v, step, loss = train_step(params, m, v, step, batch, cfg)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (step, loss)

    def evaluate(*args):
        n = len(param_specs)
        params = list(args[:n])
        batch = args[n]
        return (eval_loss(params, batch, cfg),)

    return {
        "embed": (
            embed_b1,
            [spec((1, cfg.seq_len), i32), spec((cfg.vocab, d)), spec((cfg.seq_len, d))],
        ),
        "layer_fwd": (layer_b1, [spec((1, cfg.seq_len, d))] + lp_specs),
        "logits": (
            logits_b1,
            [spec((1, cfg.seq_len, d)), spec((d,)), spec((d,)), spec((d, cfg.vocab))],
        ),
        "train_step": (
            train,
            param_specs * 3
            + [spec((), i32), spec((cfg.batch, cfg.seq_len + 1), i32)],
        ),
        "eval_loss": (
            evaluate,
            param_specs + [spec((cfg.batch, cfg.seq_len + 1), i32)],
        ),
    }
