"""AOT lowering: JAX → HLO text artifacts + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust `xla` crate) rejects; the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Run once via `make artifacts`; Python never runs on the request path.
"""

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelConfig, init_params, make_entry_points, param_names, param_shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-head", type=int, default=4)
    ap.add_argument("--n-layer", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ModelConfig(
        vocab=args.vocab,
        d_model=args.d_model,
        n_head=args.n_head,
        n_layer=args.n_layer,
        d_ff=args.d_ff,
        seq_len=args.seq_len,
        batch=args.batch,
    )
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = {}
    for name, (fn, specs) in make_entry_points(cfg).items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        artifacts[name] = {
            "path": path,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
        }
        print(f"wrote {path} ({len(text)} chars, {len(specs)} inputs)")

    # Initial parameters, saved as raw little-endian f32 for the trainer.
    params = init_params(cfg, seed=args.seed)
    blob_path = os.path.join(args.out_dir, "init_params.bin")
    with open(blob_path, "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype=np.float32).tobytes())
    print(f"wrote init_params.bin ({os.path.getsize(blob_path)} bytes)")

    manifest = {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_head": cfg.n_head,
            "n_layer": cfg.n_layer,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
        },
        "params": [
            {"name": n, "shape": list(s)}
            for n, s in zip(param_names(cfg), param_shapes(cfg))
        ],
        "n_layer_params": 12,
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
