"""Flash-style causal attention as a Pallas kernel.

TPU adaptation of the flash-attention tiling (DESIGN.md §4): Q blocks x KV
blocks form the grid; the online-softmax running state (m, l, acc) lives in
VMEM scratch carried across the KV grid dimension; the two matmuls per tile
(QK^T and PV) are shaped to feed the MXU. ``interpret=True`` everywhere: the
CPU PJRT client cannot execute Mosaic custom-calls, and interpret mode
lowers to plain HLO so the AOT artifact runs in the Rust runtime.

VMEM budget per (block_q, block_k) tile at d = head_dim:
    q:   block_q * d * 4 B          k,v: block_k * d * 4 B each
    acc: block_q * d * 4 B          m,l: block_q * 4 B each
Defaults (128, 128, d <= 128) stay under ~256 KiB, far below the ~16 MiB
VMEM of a TPU core, leaving room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, block_q, block_k, causal
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0].astype(jnp.float32)  # (block_k, d)

    # QK^T on the MXU.
    s = jnp.dot(q, k.T) * scale  # (block_q, block_k)

    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]  # (block_q,)
    l_prev = l_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    correction = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])  # (block_q, block_k)
    l_cur = l_prev * correction + p.sum(axis=-1)

    # PV on the MXU, accumulated in VMEM scratch.
    acc_ref[...] = acc_ref[...] * correction[:, None] + jnp.dot(p, v)
    m_ref[...] = m_cur
    l_ref[...] = l_cur

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
):
    """Causal attention over (heads, seq, head_dim) arrays."""
    h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q != 0 or s % block_k != 0:
        # Fall back to a single block covering the sequence (small shapes).
        block_q = block_k = s
    scale = 1.0 / (d**0.5)
    grid = (h, s // block_q, s // block_k)
    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, qq, kk: (hh, qq, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, qq, kk: (hh, kk, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, qq, kk: (hh, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hh, qq, kk: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)


def vmem_bytes(block_q: int, block_k: int, d: int) -> int:
    """Estimated VMEM footprint of one grid step (see module docstring)."""
    return 4 * (block_q * d * 2 + block_k * d * 2 + 2 * block_q)
