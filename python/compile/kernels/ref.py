"""Pure-jnp reference oracle for the Pallas kernels.

Every kernel in this package has an exact (up to float tolerance) reference
here; pytest + hypothesis compare them across shapes and dtypes.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """Scaled dot-product attention over (heads, seq, dim) arrays."""
    _, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=jnp.float32))
    logits = (
        jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    )
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, :, :], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hqk,hkd->hqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ffn_ref(x, w1, b1, w2, b2):
    """Fused feed-forward: GELU(x @ w1 + b1) @ w2 + b2 (tanh GELU)."""
    x32 = x.astype(jnp.float32)
    h = x32 @ w1.astype(jnp.float32) + b1.astype(jnp.float32)
    h = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    out = h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
    return out.astype(x.dtype)
