"""Fused feed-forward (matmul + GELU + matmul) as a tiled Pallas kernel.

Grid: (rows / block_m) x (d_ff / block_f). Each step computes a
(block_m, block_f) tile of the hidden activation H = GELU(x @ w1 + b1) and
immediately contracts it with the matching (block_f, d_model) slice of w2,
accumulating the output tile in VMEM scratch — the hidden activation never
round-trips to HBM, which is the fusion the paper's serving stack would
want on a real TPU.

VMEM per step (f32): block_m*d + block_m*block_f + block_f*d (+ w1 slice
d*block_f). With block_m=128, block_f=512, d=512: ~2.6 MiB — comfortable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_F = 512


def _gelu(h):
    return 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, acc_ref):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)        # (block_m, d)
    w1 = w1_ref[...].astype(jnp.float32)      # (d, block_f)
    b1 = b1_ref[...].astype(jnp.float32)      # (block_f,)
    w2 = w2_ref[...].astype(jnp.float32)      # (block_f, d)

    h = _gelu(x @ w1 + b1[None, :])           # (block_m, block_f)
    acc_ref[...] += h @ w2                    # (block_m, d)

    @pl.when(fi == pl.num_programs(1) - 1)
    def _finalize():
        b2 = b2_ref[...].astype(jnp.float32)  # (d,)
        o_ref[...] = (acc_ref[...] + b2[None, :]).astype(o_ref.dtype)


def ffn(
    x,
    w1,
    b1,
    w2,
    b2,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_f: int = DEFAULT_BLOCK_F,
):
    """Fused GELU MLP over x: (rows, d_model); w1: (d, f); w2: (f, d)."""
    m, d = x.shape
    f = w1.shape[1]
    block_m = min(block_m, m)
    block_f = min(block_f, f)
    if m % block_m != 0:
        block_m = m
    if f % block_f != 0:
        block_f = f
    grid = (m // block_m, f // block_f)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda mm, ff: (mm, 0)),
            pl.BlockSpec((d, block_f), lambda mm, ff: (0, ff)),
            pl.BlockSpec((block_f,), lambda mm, ff: (ff,)),
            pl.BlockSpec((block_f, d), lambda mm, ff: (ff, 0)),
            pl.BlockSpec((d,), lambda mm, ff: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda mm, ff: (mm, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        interpret=True,
    )(x, w1, b1, w2, b2)


def vmem_bytes(block_m: int, block_f: int, d: int) -> int:
    """Estimated VMEM footprint of one grid step."""
    return 4 * (block_m * d * 2 + block_m * block_f + block_f * d * 2 + block_f + d)
