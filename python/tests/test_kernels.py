"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; fixed cases pin the defaults.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, vmem_bytes as attn_vmem
from compile.kernels.ffn import ffn, vmem_bytes as ffn_vmem
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


class TestAttention:
    def test_matches_ref_default(self):
        key = jax.random.PRNGKey(0)
        kq, kk, kv = jax.random.split(key, 3)
        q, k, v = (rand(kq, (4, 64, 32)), rand(kk, (4, 64, 32)), rand(kv, (4, 64, 32)))
        got = attention(q, k, v)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_multi_block_grid(self):
        key = jax.random.PRNGKey(1)
        kq, kk, kv = jax.random.split(key, 3)
        # seq 256 with block 64 → 4x4 KV grid, exercises online softmax.
        q = rand(kq, (2, 256, 16))
        k = rand(kk, (2, 256, 16))
        v = rand(kv, (2, 256, 16))
        got = attention(q, k, v, block_q=64, block_k=64)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_non_causal(self):
        key = jax.random.PRNGKey(2)
        kq, kk, kv = jax.random.split(key, 3)
        q, k, v = (rand(kq, (1, 32, 8)), rand(kk, (1, 32, 8)), rand(kv, (1, 32, 8)))
        got = attention(q, k, v, causal=False)
        want = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_causality_enforced(self):
        # Future positions must not influence earlier outputs.
        key = jax.random.PRNGKey(3)
        kq, kk, kv = jax.random.split(key, 3)
        q, k, v = (rand(kq, (1, 16, 8)), rand(kk, (1, 16, 8)), rand(kv, (1, 16, 8)))
        out1 = attention(q, k, v)
        v2 = v.at[:, -1, :].set(99.0)
        k2 = k.at[:, -1, :].set(99.0)
        out2 = attention(q, k2, v2)
        np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], rtol=1e-5, atol=1e-5)

    def test_softmax_stability_large_logits(self):
        key = jax.random.PRNGKey(4)
        kq, kk, kv = jax.random.split(key, 3)
        q = rand(kq, (1, 32, 8), scale=30.0)
        k = rand(kk, (1, 32, 8), scale=30.0)
        v = rand(kv, (1, 32, 8))
        got = attention(q, k, v)
        assert np.isfinite(np.asarray(got)).all()
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        heads=st.sampled_from([1, 2, 4]),
        seq=st.sampled_from([8, 16, 32, 64, 96]),
        dim=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
        causal=st.booleans(),
    )
    def test_hypothesis_shapes(self, heads, seq, dim, seed, causal):
        key = jax.random.PRNGKey(seed)
        kq, kk, kv = jax.random.split(key, 3)
        q = rand(kq, (heads, seq, dim))
        k = rand(kk, (heads, seq, dim))
        v = rand(kv, (heads, seq, dim))
        got = attention(q, k, v, causal=causal, block_q=32, block_k=32)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_vmem_estimate_within_budget(self):
        # Default blocks must fit VMEM with double-buffering headroom.
        assert attn_vmem(128, 128, 128) < 2 * 1024 * 1024


class TestFfn:
    def test_matches_ref_default(self):
        key = jax.random.PRNGKey(10)
        ks = jax.random.split(key, 5)
        x = rand(ks[0], (64, 32))
        w1 = rand(ks[1], (32, 128), scale=0.3)
        b1 = rand(ks[2], (128,), scale=0.1)
        w2 = rand(ks[3], (128, 32), scale=0.3)
        b2 = rand(ks[4], (32,), scale=0.1)
        got = ffn(x, w1, b1, w2, b2)
        want = ref.ffn_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_tiled_grid_matches(self):
        key = jax.random.PRNGKey(11)
        ks = jax.random.split(key, 5)
        x = rand(ks[0], (256, 64))
        w1 = rand(ks[1], (64, 512), scale=0.2)
        b1 = rand(ks[2], (512,), scale=0.1)
        w2 = rand(ks[3], (512, 64), scale=0.2)
        b2 = rand(ks[4], (64,), scale=0.1)
        got = ffn(x, w1, b1, w2, b2, block_m=64, block_f=128)
        want = ref.ffn_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        rows=st.sampled_from([4, 16, 64, 100]),
        d=st.sampled_from([8, 32, 64]),
        f=st.sampled_from([16, 64, 256]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, rows, d, f, seed):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 5)
        x = rand(ks[0], (rows, d))
        w1 = rand(ks[1], (d, f), scale=0.3)
        b1 = rand(ks[2], (f,), scale=0.1)
        w2 = rand(ks[3], (f, d), scale=0.3)
        b2 = rand(ks[4], (d,), scale=0.1)
        got = ffn(x, w1, b1, w2, b2, block_m=32, block_f=64)
        want = ref.ffn_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_vmem_estimate_within_budget(self):
        assert ffn_vmem(128, 512, 512) < 4 * 1024 * 1024
