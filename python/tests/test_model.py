"""L2 model tests: shapes, loss behaviour, training convergence, AOT parity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    ModelConfig,
    eval_loss,
    forward,
    init_params,
    layer_param_slice,
    make_entry_points,
    param_names,
    param_shapes,
    train_step,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(
    vocab=64, d_model=32, n_head=2, n_layer=2, d_ff=64, seq_len=16, batch=2, lr=3e-3
)


def synthetic_batch(cfg, key):
    """Learnable synthetic task: arithmetic sequences mod vocab."""
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (cfg.batch, 1), 0, cfg.vocab)
    delta = jax.random.randint(k2, (cfg.batch, 1), 1, 5)
    idx = jnp.arange(cfg.seq_len + 1)[None, :]
    return (start + delta * idx) % cfg.vocab


def test_param_layout_consistent():
    names = param_names(CFG)
    shapes = param_shapes(CFG)
    assert len(names) == len(shapes)
    assert names[0] == "wte" and names[-1] == "wout"
    assert len(names) == 2 + CFG.n_layer * 12 + 3
    a, b = layer_param_slice(CFG, 1)
    assert names[a] == "l1.ln1_g" and names[b - 1] == "l1.b2"


def test_forward_shapes():
    params = init_params(CFG)
    tokens = jnp.zeros((CFG.batch, CFG.seq_len), jnp.int32)
    logits = forward(params, tokens, CFG)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_initial_loss_near_uniform():
    params = init_params(CFG)
    batch = synthetic_batch(CFG, jax.random.PRNGKey(0))
    loss = eval_loss(params, batch, CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_training_reduces_loss():
    params = init_params(CFG)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jnp.array(0, jnp.int32)
    key = jax.random.PRNGKey(1)
    jit_step = jax.jit(lambda p, m, v, s, b: train_step(p, m, v, s, b, CFG))
    losses = []
    for i in range(60):
        key, sub = jax.random.split(key)
        batch = synthetic_batch(CFG, sub)
        params, m, v, step, loss = jit_step(params, m, v, step, batch)
        losses.append(float(loss))
    tail = sum(losses[-5:]) / 5
    assert tail < losses[0] * 0.8, f"no learning: {losses[0]:.3f} → {tail:.3f}"
    assert int(step) == 60


def test_entry_points_execute_with_example_shapes():
    eps = make_entry_points(CFG)
    assert set(eps) == {"embed", "layer_fwd", "logits", "train_step", "eval_loss"}
    for name, (fn, specs) in eps.items():
        args = [
            jnp.zeros(s.shape, s.dtype)
            if s.dtype != jnp.int32
            else jnp.zeros(s.shape, jnp.int32)
            for s in specs
        ]
        out = jax.jit(fn)(*args)
        assert isinstance(out, tuple) and len(out) >= 1, name


def test_sharded_forward_equals_monolithic():
    """embed → layer_fwd per layer → logits == forward() (the serving path)."""
    eps = make_entry_points(CFG)
    params = init_params(CFG)
    tokens = synthetic_batch(CFG, jax.random.PRNGKey(3))[:1, :-1]

    embed_fn = eps["embed"][0]
    layer_fn = eps["layer_fwd"][0]
    logits_fn = eps["logits"][0]

    (hidden,) = embed_fn(tokens, params[0], params[1])
    for i in range(CFG.n_layer):
        a, b = layer_param_slice(CFG, i)
        (hidden,) = layer_fn(hidden, *params[a:b])
    (next_logits,) = logits_fn(hidden, params[-3], params[-2], params[-1])

    full = forward(params, tokens, CFG)
    np.testing.assert_allclose(next_logits, full[:, -1, :], rtol=1e-4, atol=1e-4)


def test_init_deterministic():
    p1 = init_params(CFG, seed=7)
    p2 = init_params(CFG, seed=7)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
