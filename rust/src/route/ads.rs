//! Layer advertisement: how shard nodes tell the mesh which model layers
//! they host.
//!
//! Two channels, mirroring the relay tier's split (DESIGN.md §Inference
//! plane):
//!
//! * **DHT provider records** keyed by [`bucket_key`] `(model, layer-bucket)`
//!   — durable discovery with TTL/republish riding the existing kad
//!   machinery; a cold client walks the buckets of `[0, n_layer)` to find
//!   holders.
//! * **Gossip fast path** on [`LAYER_ADS_TOPIC`] — every [`AD_INTERVAL`] a
//!   shard floods its current [`LayerAd`] (capacity, load, measured RTTs to
//!   other holders), so routers re-score chains within seconds of load or
//!   placement shifts. Ads expire after [`AD_TTL`].
//!
//! Ads carry the advertiser's own peer-to-peer RTT samples so a client can
//! cost *inter-stage* edges it can never measure itself.

use crate::content::Cid;
use crate::identity::PeerId;
use crate::multiaddr::{Multiaddr, Proto, SimAddr};
use crate::netsim::Time;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

/// Gossip topic for layer-ad refresh.
pub const LAYER_ADS_TOPIC: &str = "lattica:layer-ads";
/// Gossip refresh cadence.
pub const AD_INTERVAL: Time = 2 * crate::netsim::SECOND;
/// An ad not refreshed for this long is dropped from the book.
pub const AD_TTL: Time = 10 * crate::netsim::SECOND;
/// Layer-range granularity of the DHT key space: one provider bucket per
/// `LAYER_BUCKET` consecutive layers.
pub const LAYER_BUCKET: u32 = 8;
/// Cap on piggybacked RTT samples per ad.
pub const MAX_AD_RTTS: usize = 32;
/// Sanity cap on advertised layer indices.
pub const MAX_LAYERS: u32 = 4096;

/// One node's claim: "I host layers `[layers.0, layers.1)` of `model`,
/// reachable at `host:port`, with this much session capacity and current
/// load." `rtts` are the advertiser's EWMA RTTs to other holders (ns).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerAd {
    pub peer: PeerId,
    pub host: u32,
    pub port: u16,
    pub model: String,
    pub layers: (u32, u32),
    /// Topology hint from [`crate::netsim::TopologyBuilder`] regions; used
    /// as the cost estimate when no measured RTT exists for an edge.
    pub region: u32,
    /// Max resident KV entries (capacity accounting unit of `KvStore`).
    pub capacity: u32,
    /// Utilization percent 0–100 (resident entries / capacity).
    pub load: u32,
    pub rtts: Vec<(PeerId, u64)>,
}

/// Nested pb entry for one RTT sample.
#[derive(Clone, Debug, Default, PartialEq)]
struct RttEntry {
    peer: Vec<u8>,
    rtt: u64,
}

impl Message for RttEntry {
    fn encode_to(&self, w: &mut PbWriter) {
        w.bytes(1, &self.peer);
        w.uint(2, self.rtt);
    }

    fn decode(buf: &[u8]) -> Result<RttEntry> {
        let mut m = RttEntry::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.peer = f.as_bytes()?.to_vec(),
                2 => m.rtt = f.as_u64(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

impl Message for LayerAd {
    fn encode_to(&self, w: &mut PbWriter) {
        w.bytes(1, &self.peer.0);
        w.uint(2, self.host as u64);
        w.uint(3, self.port as u64);
        w.string(4, &self.model);
        w.uint(5, self.layers.0 as u64);
        w.uint(6, self.layers.1 as u64);
        w.uint(7, self.region as u64);
        w.uint(8, self.capacity as u64);
        w.uint(9, self.load as u64);
        let entries: Vec<RttEntry> = self
            .rtts
            .iter()
            .take(MAX_AD_RTTS)
            .map(|(p, r)| RttEntry { peer: p.0.to_vec(), rtt: *r })
            .collect();
        w.messages(10, &entries);
    }

    fn decode(buf: &[u8]) -> Result<LayerAd> {
        let mut peer = Vec::new();
        let mut host = 0u32;
        let mut port = 0u64;
        let mut model = String::new();
        let mut start = 0u64;
        let mut end = 0u64;
        let mut region = 0u32;
        let mut capacity = 0u32;
        let mut load = 0u32;
        let mut entries: Vec<RttEntry> = Vec::new();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => peer = f.as_bytes()?.to_vec(),
                2 => host = f.as_u32(),
                3 => port = f.as_u64(),
                4 => model = f.as_string()?,
                5 => start = f.as_u64(),
                6 => end = f.as_u64(),
                7 => region = f.as_u32(),
                8 => capacity = f.as_u32(),
                9 => load = f.as_u32(),
                10 => {
                    if entries.len() < MAX_AD_RTTS {
                        entries.push(f.as_message()?);
                    }
                }
                _ => {}
            }
            Ok(())
        })?;
        ensure!(peer.len() == 32, "layer ad peer id must be 32 bytes");
        ensure!(port <= u16::MAX as u64, "layer ad port out of range");
        ensure!(
            start < end && end <= MAX_LAYERS as u64,
            "layer ad range [{start}, {end}) invalid"
        );
        ensure!(model.len() <= crate::route::MAX_MODEL_ID, "layer ad model id too long");
        let mut id = [0u8; 32];
        id.copy_from_slice(&peer);
        let mut rtts = Vec::with_capacity(entries.len());
        for e in entries {
            if e.peer.len() == 32 {
                let mut rid = [0u8; 32];
                rid.copy_from_slice(&e.peer);
                rtts.push((PeerId(rid), e.rtt));
            }
        }
        Ok(LayerAd {
            peer: PeerId(id),
            host,
            port: port as u16,
            model,
            layers: (start as u32, end as u32),
            region,
            capacity,
            load: load.min(100),
            rtts,
        })
    }
}

impl LayerAd {
    pub fn multiaddr(&self) -> Multiaddr {
        Multiaddr::direct(SimAddr::new(self.host, self.port), Proto::QuicLike).with_peer(self.peer)
    }

    /// The advertiser's measured RTT to `peer`, if it piggybacked one.
    pub fn rtt_to(&self, peer: &PeerId) -> Option<u64> {
        self.rtts.iter().find(|(p, _)| p == peer).map(|(_, r)| *r)
    }
}

/// DHT provider key for `(model, layer-bucket)`.
pub fn bucket_key(model: &str, bucket: u32) -> [u8; 32] {
    let mut seed = Vec::with_capacity(model.len() + 24);
    seed.extend_from_slice(b"lattica:layer-bucket:");
    seed.extend_from_slice(model.as_bytes());
    seed.push(b':');
    seed.extend_from_slice(&bucket.to_le_bytes());
    Cid::of(&seed).to_key()
}

/// The buckets a layer range `[a, b)` belongs to.
pub fn buckets(layers: (u32, u32)) -> impl Iterator<Item = u32> {
    (layers.0 / LAYER_BUCKET)..=(layers.1.saturating_sub(1) / LAYER_BUCKET)
}

/// Everything a node currently believes about layer holders: the merged
/// view of gossip ads (and `describe` replies), with TTL expiry. BTreeMap
/// keying gives deterministic iteration for routing.
#[derive(Default)]
pub struct AdBook {
    ads: BTreeMap<PeerId, (LayerAd, Time)>,
}

impl AdBook {
    pub fn new() -> AdBook {
        AdBook::default()
    }

    /// Ingest a decoded ad observed at `now`.
    pub fn ingest(&mut self, now: Time, ad: LayerAd) {
        self.ads.insert(ad.peer, (ad, now + AD_TTL));
    }

    /// Ingest raw gossip payload; malformed ads are dropped.
    pub fn ingest_bytes(&mut self, now: Time, data: &[u8]) {
        if let Ok(ad) = LayerAd::decode(data) {
            self.ingest(now, ad);
        }
    }

    pub fn prune(&mut self, now: Time) {
        self.ads.retain(|_, (_, exp)| *exp > now);
    }

    pub fn get(&self, peer: &PeerId) -> Option<&LayerAd> {
        self.ads.get(peer).map(|(ad, _)| ad)
    }

    pub fn len(&self) -> usize {
        self.ads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ads.is_empty()
    }

    /// All live ads for `model`, in peer-id order (deterministic).
    pub fn ads_for(&self, model: &str) -> impl Iterator<Item = &LayerAd> {
        self.ads.values().map(|(ad, _)| ad).filter(move |ad| ad.model == model)
    }

    /// Live ads for `model` whose range starts exactly at `layer` — chain
    /// assembly candidates for the next uncovered layer.
    pub fn holders_starting_at(&self, model: &str, layer: u32) -> Vec<&LayerAd> {
        self.ads_for(model).filter(|ad| ad.layers.0 == layer).collect()
    }

    /// Peers worth probing for RTT (every holder of any model).
    pub fn peers(&self) -> Vec<PeerId> {
        self.ads.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    fn ad(seed: u64, layers: (u32, u32)) -> LayerAd {
        LayerAd {
            peer: Keypair::from_seed(seed).peer_id(),
            host: seed as u32 + 10,
            port: 4001,
            model: "sim-tiny".into(),
            layers,
            region: (seed % 3) as u32,
            capacity: 4096,
            load: (seed % 100) as u32,
            rtts: vec![(Keypair::from_seed(seed + 1).peer_id(), 5_000_000 + seed)],
        }
    }

    #[test]
    fn ad_roundtrips() {
        let a = ad(3, (4, 8));
        let dec = LayerAd::decode(&a.encode()).unwrap();
        assert_eq!(dec, a);
        assert_eq!(dec.rtt_to(&Keypair::from_seed(4).peer_id()), Some(5_000_003));
    }

    #[test]
    fn hostile_ads_rejected() {
        // Empty peer id.
        assert!(LayerAd::decode(&[]).is_err());
        // Inverted layer range.
        let mut bad = ad(1, (4, 8));
        bad.layers = (8, 4);
        assert!(LayerAd::decode(&bad.encode()).is_err());
        // Port overflow survives encode (u16 field) but a forged wire value fails.
        let mut w = PbWriter::new();
        w.bytes(1, &[7u8; 32]);
        w.uint(3, 1 << 20);
        w.uint(5, 0);
        w.uint(6, 4);
        assert!(LayerAd::decode(&w.finish()).is_err());
    }

    #[test]
    fn bucket_math() {
        assert_eq!(buckets((0, 8)).collect::<Vec<_>>(), vec![0]);
        assert_eq!(buckets((0, 9)).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(buckets((8, 16)).collect::<Vec<_>>(), vec![1]);
        assert_ne!(bucket_key("m", 0), bucket_key("m", 1));
        assert_ne!(bucket_key("a", 0), bucket_key("b", 0));
    }

    #[test]
    fn book_expiry_and_lookup() {
        let mut book = AdBook::new();
        book.ingest(0, ad(1, (0, 4)));
        book.ingest(0, ad(2, (4, 8)));
        book.ingest(9 * crate::netsim::SECOND, ad(3, (4, 8)));
        assert_eq!(book.holders_starting_at("sim-tiny", 4).len(), 2);
        book.prune(11 * crate::netsim::SECOND);
        assert_eq!(book.len(), 1);
        assert_eq!(book.holders_starting_at("sim-tiny", 4).len(), 1);
    }
}
