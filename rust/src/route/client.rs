//! Client side of the inference plane: owns the routed chain for each
//! request and drives token-level pipelining.
//!
//! Per request, a [`ChainClient`]:
//!
//! 1. assembles a chain via [`LayerRouter`] (or uses a fixed chain in
//!    [`RouteMode::Static`] — the pre-router baseline);
//! 2. opens one `route` stream to the head, sends `Open` + the whole
//!    context as `Token` frames back-to-back (pipelined prefill: position
//!    `t + 1` is on the wire while `t` is still propagating down the
//!    chain);
//! 3. consumes `Emit` frames on the tail's `emit` stream, acks each token
//!    and feeds it back to the head as the next `Token`;
//! 4. on a `Fault` frame, head-stream death, or stall: quarantines the
//!    dead hop, splices a repaired chain ([`LayerRouter::repair`]), bumps
//!    the generation and re-opens with `n_prompt = prompt + acked` — the
//!    replay resumes from the last acked token by construction.
//!
//! The client is event-driven: the embedding scenario drains its node's
//! events into [`ChainClient::on_event`] and calls [`ChainClient::tick`]
//! periodically.

use super::ads::{AdBook, LAYER_ADS_TOPIC};
use super::model::SimModel;
use super::router::{LayerRouter, RttTable};
use super::shard::{PROBE_INTERVAL, ROUTE_SERVICE};
use super::wire::{Hop, OpenFrame, RouteFrame};
use crate::identity::PeerId;
use crate::metrics::InferenceStats;
use crate::netsim::{Net, Time, SECOND};
use crate::node::{LatticaNode, NodeEvent};
use crate::protocols::gossip::GossipEvent;
use crate::protocols::Ctx;
use crate::rpc::{RpcEvent, StreamHandle};
use std::collections::HashMap;

/// How long without progress before a request assumes its chain is dead
/// and repairs without a fault report (backstop for silent losses).
pub const STALL_TIMEOUT: Time = 4 * SECOND;

/// Chain selection policy.
pub enum RouteMode {
    /// Latency-aware routing over live ads (the tentpole path).
    Routed,
    /// A fixed, hand-assigned chain — the placement-blind baseline the
    /// bench's naive arm measures.
    Static(Vec<Hop>),
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completed {
    pub request: u64,
    pub tokens: Vec<u32>,
    pub started: Time,
    pub finished: Time,
    /// Time-to-first-token (first acked emit, across repairs).
    pub ttft: Time,
    pub repairs: u32,
}

struct Req {
    prompt: Vec<u32>,
    acked: Vec<u32>,
    gen_len: usize,
    chain: Vec<Hop>,
    generation: u64,
    head: Option<StreamHandle>,
    dialing: bool,
    started: Time,
    first_emit: Option<Time>,
    last_activity: Time,
    repairs: u32,
}

/// See module docs.
pub struct ChainClient {
    pub model: SimModel,
    pub router: LayerRouter,
    pub book: AdBook,
    mode: RouteMode,
    reqs: HashMap<u64, Req>,
    next_req: u64,
    head_streams: HashMap<StreamHandle, u64>,
    /// Tail-opened emit streams; bound to a request by their first Emit.
    emit_streams: HashMap<StreamHandle, Option<u64>>,
    pub stats: InferenceStats,
    pub completed: Vec<Completed>,
    pub stall_timeout: Time,
    probe_rr: usize,
    last_probe: Time,
}

impl ChainClient {
    /// Subscribes `node` to the layer-ads topic and returns a client for
    /// `model`. `my_region` seeds unmeasured-edge cost estimates.
    pub fn new(
        node: &mut LatticaNode,
        net: &mut Net,
        model: SimModel,
        my_region: u32,
        mode: RouteMode,
    ) -> ChainClient {
        let mut ctx = Ctx::new(&mut node.swarm, net);
        node.gossip.subscribe(&mut ctx, LAYER_ADS_TOPIC);
        let router = LayerRouter::new(&model.model_id, model.n_layer, my_region);
        ChainClient {
            model,
            router,
            book: AdBook::new(),
            mode,
            reqs: HashMap::new(),
            next_req: 1,
            head_streams: HashMap::new(),
            emit_streams: HashMap::new(),
            stats: InferenceStats::default(),
            completed: Vec::new(),
            stall_timeout: STALL_TIMEOUT,
            probe_rr: 0,
            last_probe: 0,
        }
    }

    /// Begin a request; returns its id. The chain opens as soon as the ad
    /// book can cover the layer range (immediately, if it already can).
    pub fn start(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        prompt: Vec<u32>,
        gen_len: usize,
    ) -> u64 {
        assert!(!prompt.is_empty() && gen_len > 0);
        let now = net.now();
        let id = self.next_req;
        self.next_req += 1;
        self.reqs.insert(
            id,
            Req {
                prompt,
                acked: Vec::new(),
                gen_len,
                chain: Vec::new(),
                generation: 1,
                head: None,
                dialing: false,
                started: now,
                first_emit: None,
                last_activity: now,
                repairs: 0,
            },
        );
        self.try_open(node, net, id, None);
        id
    }

    /// Requests neither completed nor abandoned.
    pub fn in_flight(&self) -> usize {
        self.reqs.len()
    }

    /// In-flight requests that have acked at least one token — "mid-stream"
    /// from the kill scenario's point of view.
    pub fn partially_acked(&self) -> usize {
        self.reqs.values().filter(|r| !r.acked.is_empty()).count()
    }

    /// The peers on `request`'s current chain (empty if unopened).
    pub fn chain_of(&self, request: u64) -> Vec<PeerId> {
        self.reqs
            .get(&request)
            .map(|r| r.chain.iter().map(|h| h.peer).collect())
            .unwrap_or_default()
    }

    /// Feed one node event. Returns true if the event belonged to the
    /// inference plane and was consumed.
    pub fn on_event(&mut self, node: &mut LatticaNode, net: &mut Net, ev: &NodeEvent) -> bool {
        match ev {
            NodeEvent::Gossip(GossipEvent::Received { topic, data, .. })
                if topic == LAYER_ADS_TOPIC =>
            {
                self.book.ingest_bytes(net.now(), data);
                true
            }
            NodeEvent::Rpc(RpcEvent::StreamOpened { service, method, handle, .. })
                if service == ROUTE_SERVICE && method == "emit" =>
            {
                self.emit_streams.insert(*handle, None);
                true
            }
            NodeEvent::Rpc(RpcEvent::StreamItem { handle, payload, .. }) => {
                if self.emit_streams.contains_key(handle) {
                    if let Ok(RouteFrame::Emit { request, pos, token }) =
                        RouteFrame::decode(payload.as_slice())
                    {
                        self.emit_streams.insert(*handle, Some(request));
                        self.ack(node, net, request, pos, token);
                    }
                    return true;
                }
                if let Some(&request) = self.head_streams.get(handle) {
                    if let Ok(RouteFrame::Fault { request: fr, hop_index, .. }) =
                        RouteFrame::decode(payload.as_slice())
                    {
                        if fr == request {
                            let dead = self
                                .reqs
                                .get(&request)
                                .and_then(|r| r.chain.get(hop_index as usize))
                                .map(|h| h.peer);
                            self.repair(node, net, request, dead);
                        }
                    }
                    return true;
                }
                false
            }
            NodeEvent::Rpc(RpcEvent::StreamEnded { handle }) => {
                if let Some(bound) = self.emit_streams.remove(handle) {
                    // Old-generation emit streams end during repair; live
                    // tail death is reported by the stage above it (Fault)
                    // or caught by the stall backstop.
                    let _ = bound;
                    return true;
                }
                if let Some(request) = self.head_streams.remove(handle) {
                    if let Some(r) = self.reqs.get(&request) {
                        if r.head == Some(*handle) {
                            // Head died under us mid-stream.
                            let dead = r.chain.first().map(|h| h.peer);
                            self.repair(node, net, request, dead);
                        }
                    }
                    return true;
                }
                false
            }
            NodeEvent::Rpc(RpcEvent::CreditsAvailable { handle, .. }) => {
                self.head_streams.contains_key(handle)
            }
            NodeEvent::PeerConnected { peer, .. } => {
                let waiting: Vec<u64> = self
                    .reqs
                    .iter()
                    .filter(|(_, r)| r.dialing && r.chain.first().map(|h| h.peer) == Some(*peer))
                    .map(|(id, _)| *id)
                    .collect();
                for id in waiting {
                    self.open_head(node, net, id);
                }
                false // others may care about connectivity too
            }
            _ => false,
        }
    }

    /// Periodic drive: ad expiry, RTT probes, dial retries, stall repair.
    pub fn tick(&mut self, node: &mut LatticaNode, net: &mut Net) {
        let now = net.now();
        self.book.prune(now);
        if now.saturating_sub(self.last_probe) >= PROBE_INTERVAL {
            self.last_probe = now;
            let peers = self.book.peers();
            if !peers.is_empty() {
                let p = peers[self.probe_rr % peers.len()];
                self.probe_rr = self.probe_rr.wrapping_add(1);
                if let Some(ad) = self.book.get(&p) {
                    node.swarm.peerstore.add_address(p, ad.multiaddr());
                }
                if node.swarm.is_connected(&p) {
                    let mut ctx = Ctx::new(&mut node.swarm, net);
                    let _ = node.ping.ping(&mut ctx, &p);
                } else {
                    let mut ctx = Ctx::new(&mut node.swarm, net);
                    let _ = ctx.ensure_connected(&p);
                }
            }
        }
        let ids: Vec<u64> = self.reqs.keys().copied().collect();
        for id in ids {
            let (needs_chain, dialing, has_head, stalled) = {
                let r = &self.reqs[&id];
                (
                    r.chain.is_empty(),
                    r.dialing,
                    r.head.is_some(),
                    now.saturating_sub(r.last_activity) >= self.stall_timeout,
                )
            };
            if needs_chain {
                self.try_open(node, net, id, None);
            } else if dialing || !has_head {
                self.open_head(node, net, id);
            } else if stalled {
                self.repair(node, net, id, None);
            }
        }
    }

    /// Assemble (or re-assemble) a chain for `id` and open it. `dead` is
    /// the hop being routed around, if known — splice-repair keeps the
    /// surviving hops (and their resident KV state relevance) intact.
    fn try_open(&mut self, node: &mut LatticaNode, net: &mut Net, id: u64, dead: Option<PeerId>) {
        let now = net.now();
        let old_chain = match self.reqs.get(&id) {
            Some(r) => r.chain.clone(),
            None => return,
        };
        let chain = match (&self.mode, dead) {
            (RouteMode::Static(c), _) => Some(c.clone()),
            (RouteMode::Routed, Some(d)) if !old_chain.is_empty() => self
                .router
                .repair(now, &self.book, &node.rtt, &old_chain, &d)
                .or_else(|| self.router.assemble(now, &self.book, &node.rtt)),
            (RouteMode::Routed, _) => self.router.assemble(now, &self.book, &node.rtt),
        };
        let Some(chain) = chain else {
            // Can't cover the layer range yet; tick retries as ads arrive.
            if let Some(r) = self.reqs.get_mut(&id) {
                r.chain.clear();
            }
            return;
        };
        if let Some(r) = self.reqs.get_mut(&id) {
            r.chain = chain;
        }
        self.open_head(node, net, id);
    }

    /// Dial/open the head stream and replay the full context into it.
    fn open_head(&mut self, node: &mut LatticaNode, net: &mut Net, id: u64) {
        let now = net.now();
        let Some(r) = self.reqs.get(&id) else { return };
        if r.head.is_some() || r.chain.is_empty() {
            return;
        }
        let head = r.chain[0];
        node.swarm.peerstore.add_address(head.peer, head.multiaddr());
        if !node.swarm.is_connected(&head.peer) {
            let mut ctx = Ctx::new(&mut node.swarm, net);
            let _ = ctx.ensure_connected(&head.peer);
            if let Some(r) = self.reqs.get_mut(&id) {
                r.dialing = true;
            }
            return;
        }
        let opened = {
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.rpc.open_rpc_stream_method(&mut ctx, &head.peer, ROUTE_SERVICE, "open")
        };
        let Ok(h) = opened else {
            if let Some(r) = self.reqs.get_mut(&id) {
                r.dialing = true;
            }
            return;
        };
        let client_hop = Hop {
            peer: node.peer_id(),
            host: node.swarm.local_addr.host,
            port: node.swarm.local_addr.port,
            layers: (0, 0),
        };
        let (open_frame, context) = {
            let r = self.reqs.get_mut(&id).expect("checked above");
            r.head = Some(h);
            r.dialing = false;
            r.last_activity = now;
            let context: Vec<u32> = r.prompt.iter().chain(r.acked.iter()).copied().collect();
            let o = OpenFrame {
                request: id,
                generation: r.generation,
                model: self.model.model_id.clone(),
                hop_index: 0,
                n_prompt: context.len() as u64,
                client: client_hop,
                chain: r.chain.clone(),
            };
            (o, context)
        };
        self.head_streams.insert(h, id);
        {
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.rpc.send_item(&mut ctx, h, RouteFrame::Open(open_frame).encode());
        }
        // Pipelined prefill/replay: every context position goes out
        // back-to-back; stream credits buffer the burst.
        for (pos, token) in context.into_iter().enumerate() {
            let frame = RouteFrame::Token { request: id, pos: pos as u64, token }.encode();
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.rpc.send_item(&mut ctx, h, frame);
        }
    }

    /// Accept an emitted token if it is exactly the next one this request
    /// needs; stale (pre-repair) emits fall out here.
    fn ack(&mut self, node: &mut LatticaNode, net: &mut Net, request: u64, pos: u64, token: u32) {
        let now = net.now();
        let (first, done, head) = {
            let Some(r) = self.reqs.get_mut(&request) else { return };
            let expect_ctx = (r.prompt.len() + r.acked.len()) as u64;
            if pos + 1 != expect_ctx {
                return; // duplicate from a pre-repair generation (or gap)
            }
            r.acked.push(token);
            r.last_activity = now;
            let first = r.first_emit.is_none();
            if first {
                r.first_emit = Some(now);
            }
            (first, r.acked.len() >= r.gen_len, r.head)
        };
        if first {
            let started = self.reqs[&request].started;
            self.stats.ttft.record(now.saturating_sub(started));
        }
        self.stats.tokens_streamed += 1;
        if done {
            let r = self.reqs.remove(&request).expect("present");
            if let Some(h) = r.head {
                self.head_streams.remove(&h);
                let mut ctx = Ctx::new(&mut node.swarm, net);
                node.rpc.end_stream(&mut ctx, h);
            }
            self.completed.push(Completed {
                request,
                tokens: r.acked,
                started: r.started,
                finished: now,
                ttft: r.first_emit.unwrap_or(now).saturating_sub(r.started),
                repairs: r.repairs,
            });
            return;
        }
        // Feed the accepted token back as the next context position.
        if let Some(h) = head {
            let frame = RouteFrame::Token { request, pos: pos + 1, token }.encode();
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.rpc.send_item(&mut ctx, h, frame);
        }
    }

    /// Splice around `dead` (or re-assemble when unknown) and replay.
    fn repair(&mut self, node: &mut LatticaNode, net: &mut Net, request: u64, dead: Option<PeerId>) {
        let now = net.now();
        let Some(r) = self.reqs.get_mut(&request) else { return };
        r.repairs += 1;
        r.generation += 1;
        r.dialing = false;
        r.last_activity = now;
        let old_head = r.head.take();
        self.stats.repairs += 1;
        if let Some(p) = dead {
            self.router.mark_dead(p, now);
        }
        // Unbind this request's emit stream so its eventual end (the old
        // chain tearing down) isn't mistaken for a fresh failure.
        for bound in self.emit_streams.values_mut() {
            if *bound == Some(request) {
                *bound = None;
            }
        }
        if let Some(h) = old_head {
            self.head_streams.remove(&h);
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.rpc.end_stream(&mut ctx, h);
        }
        self.try_open(node, net, request, dead);
    }
}
