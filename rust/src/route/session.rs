//! Per-request KV-cache residency on a shard stage.
//!
//! A [`KvSession`] is the stage-local state for one in-flight request: one
//! resident vector per owned layer (the KV-cache analogue of
//! [`super::SimModel`]) plus the next expected position. Sessions live in a
//! [`KvStore`] keyed by request id, with LRU eviction against an entry
//! capacity — accounting lands in [`crate::metrics::InferenceStats`].
//!
//! Replay correctness: a re-`open` with a higher generation resets the
//! session (state zeroed, position rewound) so replayed positions recompute
//! rather than double-append; a re-`open` with the *same* generation keeps
//! it. Out-of-order positions are detected per append: `pos < next_pos` is
//! a duplicate (dropped, counted), `pos > next_pos` is a gap (dropped,
//! counted) — the chain protocol never legitimately produces either.

use super::model::SimModel;
use crate::metrics::InferenceStats;
use crate::netsim::Time;
use std::collections::HashMap;

/// Outcome of feeding one position into a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advance {
    /// State advanced; the hidden vector now reflects this stage's layers.
    Ok,
    /// `pos` already applied (pre-repair retransmit) — dropped.
    Duplicate,
    /// `pos` skips ahead of the session — dropped.
    Gap,
    /// No session is open for this request.
    NoSession,
}

/// One request's resident state on one stage.
pub struct KvSession {
    pub request: u64,
    pub generation: u64,
    pub layers: (u32, u32),
    /// Per-owned-layer recurrent state, `layers.1 - layers.0` vectors.
    state: Vec<Vec<f32>>,
    /// Next position this session expects.
    pub next_pos: u64,
    pub last_used: Time,
}

impl KvSession {
    fn new(request: u64, generation: u64, layers: (u32, u32), d_model: usize, now: Time) -> Self {
        let n = (layers.1 - layers.0) as usize;
        KvSession {
            request,
            generation,
            layers,
            state: (0..n).map(|_| vec![0.0; d_model]).collect(),
            next_pos: 0,
            last_used: now,
        }
    }

    /// Resident KV entries: one per (owned layer, position) pair — the unit
    /// the store's capacity is accounted in.
    pub fn entries(&self) -> u64 {
        self.next_pos * self.state.len() as u64
    }

    fn advance(&mut self, model: &SimModel, pos: u64, h: &mut [f32], now: Time) -> Advance {
        self.last_used = now;
        if pos < self.next_pos {
            return Advance::Duplicate;
        }
        if pos > self.next_pos {
            return Advance::Gap;
        }
        for (i, l) in (self.layers.0..self.layers.1).enumerate() {
            model.layer_step(l, h, &mut self.state[i]);
        }
        self.next_pos += 1;
        Advance::Ok
    }
}

/// All resident sessions on one stage, with LRU eviction against an entry
/// capacity.
pub struct KvStore {
    pub capacity_entries: u64,
    sessions: HashMap<u64, KvSession>,
}

impl KvStore {
    pub fn new(capacity_entries: u64) -> KvStore {
        KvStore { capacity_entries, sessions: HashMap::new() }
    }

    /// Open (or re-open) the session for `request`. Same generation: keep
    /// resident state (duplicate Opens are harmless). Newer generation:
    /// reset — the client is replaying after a repair and every position
    /// must recompute. Older generation: stale frame, ignored.
    pub fn open(
        &mut self,
        request: u64,
        generation: u64,
        layers: (u32, u32),
        d_model: usize,
        now: Time,
        stats: &mut InferenceStats,
    ) {
        match self.sessions.get(&request) {
            Some(s) if s.generation == generation => {}
            Some(s) if s.generation > generation => {}
            Some(_) => {
                self.sessions
                    .insert(request, KvSession::new(request, generation, layers, d_model, now));
                stats.sessions_reset += 1;
            }
            None => {
                self.sessions
                    .insert(request, KvSession::new(request, generation, layers, d_model, now));
                stats.sessions_opened += 1;
            }
        }
        self.account(stats);
    }

    /// Feed position `pos` through `request`'s owned layers, evicting idle
    /// sessions first if the append would exceed capacity. The active
    /// request itself is never evicted.
    pub fn advance(
        &mut self,
        model: &SimModel,
        request: u64,
        pos: u64,
        h: &mut [f32],
        now: Time,
        stats: &mut InferenceStats,
    ) -> Advance {
        let Some(per_pos) = self
            .sessions
            .get(&request)
            .map(|s| (s.layers.1 - s.layers.0) as u64)
        else {
            return Advance::NoSession;
        };
        while self.total_entries() + per_pos > self.capacity_entries {
            if !self.evict_lru(request, stats) {
                break; // only the active session left: let it run
            }
        }
        let s = self.sessions.get_mut(&request).expect("checked above");
        let adv = s.advance(model, pos, h, now);
        match adv {
            Advance::Ok => stats.kv_appends += 1,
            Advance::Duplicate => stats.duplicate_appends += 1,
            Advance::Gap => stats.gap_drops += 1,
            Advance::NoSession => unreachable!(),
        }
        self.account(stats);
        adv
    }

    /// Drop `request`'s session (stream closed or request complete).
    pub fn close(&mut self, request: u64, stats: &mut InferenceStats) {
        if self.sessions.remove(&request).is_some() {
            stats.sessions_closed += 1;
        }
        self.account(stats);
    }

    pub fn get(&self, request: &u64) -> Option<&KvSession> {
        self.sessions.get(request)
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn total_entries(&self) -> u64 {
        self.sessions.values().map(|s| s.entries()).sum()
    }

    /// Utilization percent for load advertisement.
    pub fn load_pct(&self) -> u32 {
        if self.capacity_entries == 0 {
            return 100;
        }
        ((self.total_entries() * 100 / self.capacity_entries) as u32).min(100)
    }

    fn account(&self, stats: &mut InferenceStats) {
        stats.kv_entries = self.total_entries();
        stats.kv_peak = stats.kv_peak.max(stats.kv_entries);
    }

    /// Evict the least-recently-used session other than `keep`. Ties break
    /// on request id for determinism.
    fn evict_lru(&mut self, keep: u64, stats: &mut InferenceStats) -> bool {
        let victim = self
            .sessions
            .values()
            .filter(|s| s.request != keep)
            .map(|s| (s.last_used, s.request))
            .min();
        match victim {
            Some((_, req)) => {
                self.sessions.remove(&req);
                stats.sessions_evicted += 1;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SimModel {
        SimModel::tiny()
    }

    fn push(store: &mut KvStore, m: &SimModel, req: u64, pos: u64, now: Time, st: &mut InferenceStats) -> Advance {
        let mut h = m.embed(1, pos);
        store.advance(m, req, pos, &mut h, now, st)
    }

    #[test]
    fn lru_eviction_order_and_capacity() {
        let m = model();
        let mut st = InferenceStats::default();
        // Each session owns 12 layers; capacity of 48 entries = 4 positions
        // across all sessions.
        let mut store = KvStore::new(48);
        for req in 0..3u64 {
            store.open(req, 0, (0, m.n_layer), m.d_model, req, &mut st);
            assert_eq!(push(&mut store, &m, req, 0, req, &mut st), Advance::Ok);
        }
        assert_eq!(store.total_entries(), 36);
        // Touch 0 so 1 becomes LRU, then grow 2 past capacity.
        assert_eq!(push(&mut store, &m, 0, 1, 10, &mut st), Advance::Ok);
        assert_eq!(push(&mut store, &m, 2, 1, 11, &mut st), Advance::Ok);
        assert_eq!(st.sessions_evicted, 1);
        assert!(store.get(&1).is_none(), "LRU session (1) must be evicted");
        assert!(store.get(&0).is_some() && store.get(&2).is_some());
        assert!(store.total_entries() <= 48);
        assert_eq!(st.kv_entries, store.total_entries());
        assert!(st.kv_peak >= st.kv_entries);
    }

    #[test]
    fn active_session_never_evicted() {
        let m = model();
        let mut st = InferenceStats::default();
        let mut store = KvStore::new(12); // one position of one session
        store.open(7, 0, (0, m.n_layer), m.d_model, 0, &mut st);
        for pos in 0..5 {
            assert_eq!(push(&mut store, &m, 7, pos, pos, &mut st), Advance::Ok);
        }
        assert_eq!(st.sessions_evicted, 0);
        assert!(store.get(&7).is_some());
    }

    #[test]
    fn duplicates_and_gaps_do_not_mutate() {
        let m = model();
        let mut st = InferenceStats::default();
        let mut store = KvStore::new(1_000_000);
        store.open(1, 0, (0, 4), m.d_model, 0, &mut st);
        assert_eq!(push(&mut store, &m, 1, 0, 0, &mut st), Advance::Ok);
        assert_eq!(push(&mut store, &m, 1, 1, 1, &mut st), Advance::Ok);
        let entries = store.total_entries();
        assert_eq!(push(&mut store, &m, 1, 0, 2, &mut st), Advance::Duplicate);
        assert_eq!(push(&mut store, &m, 1, 5, 3, &mut st), Advance::Gap);
        assert_eq!(store.total_entries(), entries);
        assert_eq!(st.duplicate_appends, 1);
        assert_eq!(st.gap_drops, 1);
        assert_eq!(push(&mut store, &m, 99, 0, 4, &mut st), Advance::NoSession);
    }

    #[test]
    fn generation_bump_resets_same_keeps() {
        let m = model();
        let mut st = InferenceStats::default();
        let mut store = KvStore::new(1_000_000);
        store.open(1, 0, (0, 4), m.d_model, 0, &mut st);
        push(&mut store, &m, 1, 0, 0, &mut st);
        push(&mut store, &m, 1, 1, 0, &mut st);
        // Same generation: duplicate Open keeps state.
        store.open(1, 0, (0, 4), m.d_model, 1, &mut st);
        assert_eq!(store.get(&1).unwrap().next_pos, 2);
        // Newer generation: replay resets to position 0.
        store.open(1, 1, (0, 4), m.d_model, 2, &mut st);
        assert_eq!(store.get(&1).unwrap().next_pos, 0);
        assert_eq!(st.sessions_reset, 1);
        assert_eq!(push(&mut store, &m, 1, 0, 3, &mut st), Advance::Ok);
    }

    /// Three stages driven by hand through their KvStores reproduce the
    /// single-process oracle exactly — the distributed-equals-reference
    /// property the networked scenario also asserts.
    #[test]
    fn staged_sessions_match_reference() {
        let m = model();
        let prompt = [5u32, 9, 2, 7];
        let gen_len = 6;
        let want = m.reference_generate(&prompt, gen_len);

        let ranges = [(0u32, 4u32), (4, 8), (8, 12)];
        let mut st = InferenceStats::default();
        let mut stores: Vec<KvStore> = ranges.iter().map(|_| KvStore::new(1 << 20)).collect();
        for (i, r) in ranges.iter().enumerate() {
            stores[i].open(1, 0, *r, m.d_model, 0, &mut st);
        }
        let mut got = Vec::new();
        let mut feed: Vec<u32> = prompt.to_vec();
        let mut pos = 0u64;
        while got.len() < gen_len {
            let mut h = m.embed(feed[pos as usize], pos);
            for (i, _) in ranges.iter().enumerate() {
                assert_eq!(stores[i].advance(&m, 1, pos, &mut h, pos, &mut st), Advance::Ok);
            }
            if (pos + 1) as usize >= prompt.len() {
                let t = m.logits_argmax(&h);
                got.push(t);
                feed.push(t);
            }
            pos += 1;
        }
        assert_eq!(got, want);
    }
}
