//! Wire frames for the inference plane's stage-to-stage streams.
//!
//! Everything that flows over a `route` Streaming-class RPC session is one
//! [`RouteFrame`]: a 1-byte tag + varint fields (raw LE bytes for the f32
//! activation payload). Frames carry the request id explicitly so a stage
//! can multiplex many requests over per-peer state without per-stream
//! bookkeeping, and so stale frames from a pre-repair generation are cheap
//! to discard.
//!
//! Decode is hostile-input safe: every length is capped before allocation
//! and clamped to the bytes actually remaining, mirroring the discipline
//! the codec fuzz corpus enforces across the repo.

use crate::identity::PeerId;
use crate::multiaddr::{Multiaddr, Proto, SimAddr};
use crate::util::varint;
use anyhow::{bail, ensure, Result};

/// Max hops in an advertised chain (paranoia bound; real chains are ≤ the
/// model's layer count / 1).
pub const MAX_CHAIN: usize = 64;
/// Max model-id bytes on the wire.
pub const MAX_MODEL_ID: usize = 128;
/// Max activation width (f32 elements) a stage will accept.
pub const MAX_HIDDEN: usize = 1 << 16;
/// Max fault detail bytes.
pub const MAX_DETAIL: usize = 512;

const T_OPEN: u8 = 1;
const T_TOKEN: u8 = 2;
const T_ACT: u8 = 3;
const T_EMIT: u8 = 4;
const T_FAULT: u8 = 5;

/// One chain stage (or the client endpoint): who, where to dial them, and
/// which layer range they compute. `layers == (0, 0)` marks a non-compute
/// endpoint (the client hop in [`OpenFrame::client`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hop {
    pub peer: PeerId,
    pub host: u32,
    pub port: u16,
    pub layers: (u32, u32),
}

impl Hop {
    /// Dialable address for this hop (direct QUIC-like, as published).
    pub fn multiaddr(&self) -> Multiaddr {
        Multiaddr::direct(SimAddr::new(self.host, self.port), Proto::QuicLike).with_peer(self.peer)
    }

    fn put(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.peer.0);
        varint::put_uvarint(out, self.host as u64);
        varint::put_uvarint(out, self.port as u64);
        varint::put_uvarint(out, self.layers.0 as u64);
        varint::put_uvarint(out, self.layers.1 as u64);
    }

    fn get(r: &mut varint::Reader<'_>) -> Result<Hop> {
        let id = r.take(32)?;
        let mut peer = [0u8; 32];
        peer.copy_from_slice(id);
        let host = r.uvarint()?;
        ensure!(host <= u32::MAX as u64, "hop host out of range");
        let port = r.uvarint()?;
        ensure!(port <= u16::MAX as u64, "hop port out of range");
        let a = r.uvarint()?;
        let b = r.uvarint()?;
        ensure!(a <= u32::MAX as u64 && b <= u32::MAX as u64 && a <= b, "bad hop layer range");
        Ok(Hop {
            peer: PeerId(peer),
            host: host as u32,
            port: port as u16,
            layers: (a as u32, b as u32),
        })
    }
}

/// Session open: carries the full routed chain so every stage knows its
/// successor without further lookups, plus the client endpoint the tail
/// dials back to with emitted tokens.
///
/// Repair does not need a separate resume field: the client re-opens with
/// `generation + 1` and folds already-acked tokens into the prompt
/// (`n_prompt' = prompt + acked`), so the tail's first emit is exactly the
/// next unacked position.
#[derive(Clone, Debug, PartialEq)]
pub struct OpenFrame {
    pub request: u64,
    pub generation: u64,
    pub model: String,
    /// This receiver's index into `chain`.
    pub hop_index: u32,
    /// Context length already decided (prompt + previously acked tokens):
    /// positions `>= n_prompt - 1` produce emits.
    pub n_prompt: u64,
    pub client: Hop,
    pub chain: Vec<Hop>,
}

/// A stage-to-stage (or client↔chain) inference-plane frame.
#[derive(Clone, Debug, PartialEq)]
pub enum RouteFrame {
    Open(OpenFrame),
    /// Client → head: next context token (prompt during prefill, then the
    /// echoed emit during decode).
    Token { request: u64, pos: u64, token: u32 },
    /// Stage k → stage k+1: hidden activations for one position.
    Act { request: u64, pos: u64, hidden: Vec<f32> },
    /// Tail → client: greedy-decoded token at `pos` (predicts `pos + 1`).
    Emit { request: u64, pos: u64, token: u32 },
    /// Any stage → upstream: my downstream for this request died; the
    /// router should splice in an alternate for `chain[hop_index]`.
    Fault { request: u64, hop_index: u32, detail: String },
}

impl RouteFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        match self {
            RouteFrame::Open(o) => {
                out.push(T_OPEN);
                varint::put_uvarint(&mut out, o.request);
                varint::put_uvarint(&mut out, o.generation);
                varint::put_length_prefixed(&mut out, o.model.as_bytes());
                varint::put_uvarint(&mut out, o.hop_index as u64);
                varint::put_uvarint(&mut out, o.n_prompt);
                o.client.put(&mut out);
                varint::put_uvarint(&mut out, o.chain.len() as u64);
                for h in &o.chain {
                    h.put(&mut out);
                }
            }
            RouteFrame::Token { request, pos, token } => {
                out.push(T_TOKEN);
                varint::put_uvarint(&mut out, *request);
                varint::put_uvarint(&mut out, *pos);
                varint::put_uvarint(&mut out, *token as u64);
            }
            RouteFrame::Act { request, pos, hidden } => {
                out.push(T_ACT);
                varint::put_uvarint(&mut out, *request);
                varint::put_uvarint(&mut out, *pos);
                varint::put_uvarint(&mut out, hidden.len() as u64);
                for v in hidden {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            RouteFrame::Emit { request, pos, token } => {
                out.push(T_EMIT);
                varint::put_uvarint(&mut out, *request);
                varint::put_uvarint(&mut out, *pos);
                varint::put_uvarint(&mut out, *token as u64);
            }
            RouteFrame::Fault { request, hop_index, detail } => {
                out.push(T_FAULT);
                varint::put_uvarint(&mut out, *request);
                varint::put_uvarint(&mut out, *hop_index as u64);
                varint::put_length_prefixed(&mut out, detail.as_bytes());
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RouteFrame> {
        ensure!(!buf.is_empty(), "empty route frame");
        let mut r = varint::Reader::new(&buf[1..]);
        let f = match buf[0] {
            T_OPEN => {
                let request = r.uvarint()?;
                let generation = r.uvarint()?;
                let model_bytes = r.length_prefixed()?;
                ensure!(model_bytes.len() <= MAX_MODEL_ID, "model id too long");
                let model = std::str::from_utf8(model_bytes)?.to_string();
                let hop_index = r.uvarint()?;
                ensure!(hop_index < MAX_CHAIN as u64, "hop index out of range");
                let n_prompt = r.uvarint()?;
                let client = Hop::get(&mut r)?;
                let n = r.uvarint()? as usize;
                ensure!(n >= 1 && n <= MAX_CHAIN, "chain length {n} out of range");
                ensure!((hop_index as usize) < n, "hop index beyond chain");
                // ≥ 36 bytes per hop on the wire: never trust n alone.
                let mut chain = Vec::with_capacity(n.min(r.remaining() / 36 + 1));
                for _ in 0..n {
                    chain.push(Hop::get(&mut r)?);
                }
                RouteFrame::Open(OpenFrame {
                    request,
                    generation,
                    model,
                    hop_index: hop_index as u32,
                    n_prompt,
                    client,
                    chain,
                })
            }
            T_TOKEN | T_EMIT => {
                let request = r.uvarint()?;
                let pos = r.uvarint()?;
                let token = r.uvarint()?;
                ensure!(token <= u32::MAX as u64, "token out of range");
                if buf[0] == T_TOKEN {
                    RouteFrame::Token { request, pos, token: token as u32 }
                } else {
                    RouteFrame::Emit { request, pos, token: token as u32 }
                }
            }
            T_ACT => {
                let request = r.uvarint()?;
                let pos = r.uvarint()?;
                let n = r.uvarint()? as usize;
                ensure!(n <= MAX_HIDDEN, "activation width {n} exceeds cap");
                ensure!(r.remaining() >= n * 4, "activation payload truncated");
                let mut hidden = Vec::with_capacity(n);
                for _ in 0..n {
                    let b = r.take(4)?;
                    hidden.push(f32::from_le_bytes(b.try_into()?));
                }
                RouteFrame::Act { request, pos, hidden }
            }
            T_FAULT => {
                let request = r.uvarint()?;
                let hop_index = r.uvarint()?;
                ensure!(hop_index <= MAX_CHAIN as u64, "fault hop index out of range");
                let detail_bytes = r.length_prefixed()?;
                ensure!(detail_bytes.len() <= MAX_DETAIL, "fault detail too long");
                let detail = String::from_utf8_lossy(detail_bytes).into_owned();
                RouteFrame::Fault { request, hop_index: hop_index as u32, detail }
            }
            t => bail!("unknown route frame tag {t}"),
        };
        ensure!(r.is_empty(), "trailing bytes after route frame");
        Ok(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    fn hop(seed: u64) -> Hop {
        Hop {
            peer: Keypair::from_seed(seed).peer_id(),
            host: 10 + seed as u32,
            port: 4001,
            layers: (seed as u32 * 4, seed as u32 * 4 + 4),
        }
    }

    #[test]
    fn frames_roundtrip() {
        let frames = vec![
            RouteFrame::Open(OpenFrame {
                request: 7,
                generation: 2,
                model: "sim-tiny".into(),
                hop_index: 1,
                n_prompt: 9,
                client: Hop { layers: (0, 0), ..hop(0) },
                chain: vec![hop(1), hop(2), hop(3)],
            }),
            RouteFrame::Token { request: 7, pos: 0, token: 42 },
            RouteFrame::Act { request: 7, pos: 3, hidden: vec![0.5, -1.25, 3.0] },
            RouteFrame::Emit { request: 7, pos: 8, token: 11 },
            RouteFrame::Fault { request: 7, hop_index: 2, detail: "conn closed".into() },
        ];
        for f in frames {
            let enc = f.encode();
            assert_eq!(RouteFrame::decode(&enc).unwrap(), f, "frame {f:?}");
        }
    }

    #[test]
    fn hostile_lengths_rejected_without_allocating() {
        // Act frame claiming 2^60 floats: must error before allocation.
        let mut buf = vec![T_ACT];
        crate::util::varint::put_uvarint(&mut buf, 1);
        crate::util::varint::put_uvarint(&mut buf, 0);
        crate::util::varint::put_uvarint(&mut buf, 1u64 << 60);
        assert!(RouteFrame::decode(&buf).is_err());

        // Open frame claiming a 10k-hop chain with no bytes behind it.
        let mut buf = vec![T_OPEN];
        crate::util::varint::put_uvarint(&mut buf, 1); // request
        crate::util::varint::put_uvarint(&mut buf, 0); // generation
        crate::util::varint::put_length_prefixed(&mut buf, b"m");
        crate::util::varint::put_uvarint(&mut buf, 0); // hop_index
        crate::util::varint::put_uvarint(&mut buf, 1); // n_prompt
        Hop { layers: (0, 0), ..hop(0) }.put(&mut buf);
        crate::util::varint::put_uvarint(&mut buf, 10_000);
        assert!(RouteFrame::decode(&buf).is_err());
    }

    #[test]
    fn truncations_never_panic() {
        let f = RouteFrame::Open(OpenFrame {
            request: 1,
            generation: 1,
            model: "m".into(),
            hop_index: 0,
            n_prompt: 4,
            client: Hop { layers: (0, 0), ..hop(0) },
            chain: vec![hop(1), hop(2)],
        });
        let enc = f.encode();
        for cut in 0..enc.len() {
            let _ = RouteFrame::decode(&enc[..cut]);
        }
    }
}
