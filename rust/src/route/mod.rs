//! The distributed inference plane (DESIGN.md §Inference plane).
//!
//! Turns "model sync + RPC" into an end-to-end serving system on top of
//! the mesh:
//!
//! * [`ads`] — layer advertisement: DHT provider buckets + a gossip fast
//!   path announcing which model layers each node hosts;
//! * [`router`] — latency-aware chain assembly over measured RTTs, with
//!   quarantine and splice-repair;
//! * [`session`] — per-request KV-cache residency on shard stages with
//!   LRU eviction and capacity accounting;
//! * [`shard`] — the stage itself: `route` streams in, activations
//!   forwarded downstream, faults upstream;
//! * [`client`] — chain ownership, token-level pipelining, repair/replay;
//! * [`model`] — the deterministic synthetic model standing in for the
//!   stubbed PJRT runtime;
//! * [`wire`] — the stream frame codec.

pub mod ads;
pub mod client;
pub mod model;
pub mod router;
pub mod session;
pub mod shard;
pub mod wire;

pub use ads::{bucket_key, buckets, AdBook, LayerAd, AD_INTERVAL, AD_TTL, LAYER_ADS_TOPIC};
pub use client::{ChainClient, Completed, RouteMode, STALL_TIMEOUT};
pub use model::SimModel;
pub use router::{LayerRouter, RttTable, QUARANTINE};
pub use session::{Advance, KvSession, KvStore};
pub use shard::{RouteShard, ShardSpec, ROUTE_SERVICE};
pub use wire::{Hop, OpenFrame, RouteFrame, MAX_CHAIN, MAX_HIDDEN, MAX_MODEL_ID};
