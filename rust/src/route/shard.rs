//! Shard-side of the inference plane: one pipeline stage.
//!
//! [`RouteShard::install`] registers the `route` service on a node and
//! wires an [`App`] interceptor, turning it into a stage that:
//!
//! * advertises its layer range on [`LAYER_ADS_TOPIC`] + DHT provider
//!   buckets (see [`super::ads`]) and answers unary `describe` with its
//!   current [`LayerAd`];
//! * accepts `route` streams carrying [`RouteFrame`]s: `Open` pins a
//!   [`KvSession`](super::KvSession) and a downstream stream to the next
//!   hop (or an `emit` stream back to the client if this stage is the
//!   tail), `Token`/`Act` advance the session through this stage's layers
//!   and forward the result while later positions are already in flight —
//!   token-level pipelining with the KV state resident stage-side;
//! * on downstream death, sends a `Fault` *upstream* on the inbound
//!   stream so the client can splice in an alternate holder and replay.
//!
//! Ticks are scenario-driven (call [`RouteShard::tick`] alongside the
//! node's own timers), matching how the relay manager is driven.

use super::ads::{bucket_key, buckets, AdBook, LayerAd, AD_INTERVAL, LAYER_ADS_TOPIC, MAX_AD_RTTS};
use super::model::SimModel;
use super::session::{Advance, KvStore};
use super::wire::{OpenFrame, RouteFrame};
use crate::identity::PeerId;
use crate::metrics::InferenceStats;
use crate::multiaddr::Multiaddr;
use crate::netsim::{Net, Time, SECOND};
use crate::node::{App, LatticaNode, NodeEvent};
use crate::protocols::gossip::GossipEvent;
use crate::protocols::Ctx;
use crate::rpc::{Outcome, RpcEvent, Service, StreamHandle};
use crate::util::buf::Buf;
use crate::wire::Message;
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

/// Service name for inference-plane streams and `describe`.
pub const ROUTE_SERVICE: &str = "route";
/// RTT probe cadence (round-robin over known holders).
pub const PROBE_INTERVAL: Time = SECOND;

/// Static description of what this stage hosts.
#[derive(Clone)]
pub struct ShardSpec {
    pub model: SimModel,
    /// Layer range this node hosts (ads and Opens use it).
    pub layers: (u32, u32),
    /// Region hint advertised for unmeasured-edge costing.
    pub region: u32,
    /// KV capacity in entries (layer × position).
    pub capacity_entries: u64,
}

/// Where a flow's forwarded frames go.
struct Flow {
    generation: u64,
    hop_index: u32,
    n_prompt: u64,
    is_tail: bool,
    /// Stream the frames for this request arrive on.
    inbound: Option<StreamHandle>,
    down_peer: PeerId,
    down_addr: Multiaddr,
    /// "open" towards the next stage, "emit" back to the client.
    down_method: &'static str,
    down: Option<StreamHandle>,
    dialing: bool,
    /// Encoded frames buffered while the downstream dial is in flight.
    pending: VecDeque<Vec<u8>>,
}

struct RouteState {
    spec: ShardSpec,
    book: AdBook,
    kv: KvStore,
    stats: InferenceStats,
    flows: HashMap<u64, Flow>,
    inbound: HashMap<StreamHandle, u64>,
    outbound: HashMap<StreamHandle, u64>,
    last_ad: Time,
    last_probe: Time,
    probe_rr: usize,
    provided: bool,
}

/// Handle to an installed stage; clone-cheap (shared state).
#[derive(Clone)]
pub struct RouteShard {
    st: Rc<RefCell<RouteState>>,
}

impl RouteShard {
    /// Register the `route` service + app interceptor on `node` and start
    /// advertising `spec`.
    pub fn install(node: &mut LatticaNode, net: &mut Net, spec: ShardSpec) -> RouteShard {
        let st = Rc::new(RefCell::new(RouteState {
            kv: KvStore::new(spec.capacity_entries),
            spec,
            book: AdBook::new(),
            stats: InferenceStats::default(),
            flows: HashMap::new(),
            inbound: HashMap::new(),
            outbound: HashMap::new(),
            last_ad: 0,
            last_probe: 0,
            probe_rr: 0,
            provided: false,
        }));
        {
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.gossip.subscribe(&mut ctx, LAYER_ADS_TOPIC);
        }
        let describe_st = st.clone();
        let svc = Service::new(ROUTE_SERVICE)
            .unary("describe", move |node, net, _rctx, _payload| {
                let s = describe_st.borrow();
                Outcome::reply(build_ad(node, net, &s).encode())
            })
            .streaming(ShardStream { st: st.clone() });
        node.register_service(svc);
        node.app = Some(Box::new(ShardApp { st: st.clone() }));
        RouteShard { st }
    }

    /// Snapshot of this stage's counters.
    pub fn stats(&self) -> InferenceStats {
        self.st.borrow().stats.clone()
    }

    /// Resident sessions right now.
    pub fn resident_sessions(&self) -> usize {
        self.st.borrow().kv.len()
    }

    /// Holders currently known via ads.
    pub fn known_holders(&self) -> usize {
        self.st.borrow().book.len()
    }

    /// Periodic drive: ad publish/provide, RTT probes, ad expiry, and
    /// downstream-dial retries.
    pub fn tick(&self, node: &mut LatticaNode, net: &mut Net) {
        let now = net.now();
        let (publish, provide, probe_peer, retries) = {
            let mut s = self.st.borrow_mut();
            s.book.prune(now);
            let publish = if now.saturating_sub(s.last_ad) >= AD_INTERVAL || s.last_ad == 0 {
                s.last_ad = now;
                true
            } else {
                false
            };
            let provide = if !s.provided {
                s.provided = true;
                Some((s.spec.model.model_id.clone(), s.spec.layers))
            } else {
                None
            };
            let probe_peer = if now.saturating_sub(s.last_probe) >= PROBE_INTERVAL {
                s.last_probe = now;
                let peers = s.book.peers();
                if peers.is_empty() {
                    None
                } else {
                    let p = peers[s.probe_rr % peers.len()];
                    s.probe_rr = s.probe_rr.wrapping_add(1);
                    s.book.get(&p).map(|ad| (p, ad.multiaddr()))
                }
            } else {
                None
            };
            let retries: Vec<u64> = s
                .flows
                .iter()
                .filter(|(_, f)| f.down.is_none())
                .map(|(r, _)| *r)
                .collect();
            (publish, provide, probe_peer, retries)
        };
        if publish {
            let ad = {
                let s = self.st.borrow();
                build_ad(node, net, &s)
            };
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.gossip.publish(&mut ctx, LAYER_ADS_TOPIC, ad.encode());
        }
        if let Some((model, layers)) = provide {
            let mut ctx = Ctx::new(&mut node.swarm, net);
            for b in buckets(layers) {
                node.kad.provide(&mut ctx, bucket_key(&model, b));
            }
        }
        if let Some((peer, addr)) = probe_peer {
            if peer != node.peer_id() {
                node.swarm.peerstore.add_address(peer, addr);
                if node.swarm.is_connected(&peer) {
                    let mut ctx = Ctx::new(&mut node.swarm, net);
                    let _ = node.ping.ping(&mut ctx, &peer);
                } else {
                    let mut ctx = Ctx::new(&mut node.swarm, net);
                    let _ = ctx.ensure_connected(&peer);
                }
            }
        }
        for r in retries {
            ensure_down(&self.st, node, net, r);
        }
    }
}

/// Current advertisement for this stage.
fn build_ad(node: &LatticaNode, _net: &Net, s: &RouteState) -> LayerAd {
    let mut rtts = node.rtt.samples();
    rtts.truncate(MAX_AD_RTTS);
    LayerAd {
        peer: node.peer_id(),
        host: node.swarm.local_addr.host,
        port: node.swarm.local_addr.port,
        model: s.spec.model.model_id.clone(),
        layers: s.spec.layers,
        region: s.spec.region,
        capacity: s.kv.capacity_entries.min(u32::MAX as u64) as u32,
        load: s.kv.load_pct(),
        rtts,
    }
}

/// Open (or reuse) the downstream stream for `request` and flush pending
/// frames. Dials first when not yet connected; `PeerConnected` (or the
/// next tick) retries.
fn ensure_down(st: &Rc<RefCell<RouteState>>, node: &mut LatticaNode, net: &mut Net, request: u64) {
    let (peer, addr, method) = {
        let s = st.borrow();
        let Some(f) = s.flows.get(&request) else { return };
        if f.down.is_some() {
            return;
        }
        (f.down_peer, f.down_addr.clone(), f.down_method)
    };
    node.swarm.peerstore.add_address(peer, addr);
    if !node.swarm.is_connected(&peer) {
        let mut ctx = Ctx::new(&mut node.swarm, net);
        let _ = ctx.ensure_connected(&peer);
        if let Some(f) = st.borrow_mut().flows.get_mut(&request) {
            f.dialing = true;
        }
        return;
    }
    let opened = {
        let mut ctx = Ctx::new(&mut node.swarm, net);
        node.rpc.open_rpc_stream_method(&mut ctx, &peer, ROUTE_SERVICE, method)
    };
    match opened {
        Ok(h) => {
            let pend: Vec<Vec<u8>> = {
                let mut s = st.borrow_mut();
                s.outbound.insert(h, request);
                let f = s.flows.get_mut(&request).expect("flow checked above");
                f.down = Some(h);
                f.dialing = false;
                f.pending.drain(..).collect()
            };
            for b in pend {
                let mut ctx = Ctx::new(&mut node.swarm, net);
                node.rpc.send_item(&mut ctx, h, b);
            }
        }
        Err(_) => {
            if let Some(f) = st.borrow_mut().flows.get_mut(&request) {
                f.dialing = true;
            }
        }
    }
}

/// Forward one encoded frame downstream, buffering if the stream isn't up.
fn queue_frame(
    st: &Rc<RefCell<RouteState>>,
    node: &mut LatticaNode,
    net: &mut Net,
    request: u64,
    bytes: Vec<u8>,
) {
    let down = {
        let mut s = st.borrow_mut();
        let Some(f) = s.flows.get_mut(&request) else { return };
        match f.down {
            Some(h) => Some(h),
            None => {
                f.pending.push_back(bytes.clone());
                None
            }
        }
    };
    match down {
        Some(h) => {
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.rpc.send_item(&mut ctx, h, bytes);
        }
        None => ensure_down(st, node, net, request),
    }
}

/// Downstream stream died: detach it and report a `Fault` upstream naming
/// the dead hop, so the client repairs the chain.
fn downstream_died(
    st: &Rc<RefCell<RouteState>>,
    node: &mut LatticaNode,
    net: &mut Net,
    request: u64,
    handle: StreamHandle,
) {
    let up = {
        let mut s = st.borrow_mut();
        s.outbound.remove(&handle);
        let Some(f) = s.flows.get_mut(&request) else { return };
        if f.down != Some(handle) {
            return; // stale generation's stream
        }
        f.down = None;
        f.dialing = false;
        s.stats.faults_propagated += 1;
        let f = s.flows.get(&request).expect("just updated");
        f.inbound.map(|h| (h, f.hop_index + 1))
    };
    if let Some((h, dead_idx)) = up {
        let frame = RouteFrame::Fault {
            request,
            hop_index: dead_idx,
            detail: "downstream stream ended".into(),
        }
        .encode();
        let mut ctx = Ctx::new(&mut node.swarm, net);
        node.rpc.send_item(&mut ctx, h, frame);
    }
}

struct ShardStream {
    st: Rc<RefCell<RouteState>>,
}

impl ShardStream {
    fn handle_open(&self, node: &mut LatticaNode, net: &mut Net, handle: StreamHandle, o: OpenFrame) {
        let now = net.now();
        let end_old;
        let forward;
        {
            let mut s = self.st.borrow_mut();
            if o.model != s.spec.model.model_id {
                return;
            }
            let Some(hop) = o.chain.get(o.hop_index as usize).copied() else { return };
            if hop.peer != node.peer_id()
                || hop.layers.0 < s.spec.layers.0
                || hop.layers.1 > s.spec.layers.1
                || hop.layers.0 >= hop.layers.1
            {
                return;
            }
            if let Some(f) = s.flows.get(&o.request) {
                if f.generation >= o.generation {
                    return; // duplicate or stale Open
                }
            }
            let is_tail = o.hop_index as usize == o.chain.len() - 1;
            let (down_peer, down_addr, down_method) = if is_tail {
                (o.client.peer, o.client.multiaddr(), "emit")
            } else {
                let nh = o.chain[o.hop_index as usize + 1];
                (nh.peer, nh.multiaddr(), "open")
            };
            {
                let RouteState { spec, kv, stats, .. } = &mut *s;
                kv.open(o.request, o.generation, hop.layers, spec.model.d_model, now, stats);
            }
            // Detach any previous generation's streams for this request.
            end_old = s.flows.get(&o.request).and_then(|f| f.down);
            if let Some(f) = s.flows.get(&o.request) {
                if let Some(h) = f.inbound {
                    s.inbound.remove(&h);
                }
                if let Some(h) = f.down {
                    s.outbound.remove(&h);
                }
            }
            forward = if is_tail {
                None
            } else {
                let mut fwd = o.clone();
                fwd.hop_index += 1;
                Some(RouteFrame::Open(fwd).encode())
            };
            s.flows.insert(
                o.request,
                Flow {
                    generation: o.generation,
                    hop_index: o.hop_index,
                    n_prompt: o.n_prompt,
                    is_tail,
                    inbound: Some(handle),
                    down_peer,
                    down_addr,
                    down_method,
                    down: None,
                    dialing: false,
                    pending: VecDeque::new(),
                },
            );
            s.inbound.insert(handle, o.request);
        }
        if let Some(h) = end_old {
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.rpc.end_stream(&mut ctx, h);
        }
        match forward {
            Some(bytes) => queue_frame(&self.st, node, net, o.request, bytes),
            // Tail: open the emit stream eagerly so the first token isn't
            // blocked on a dial.
            None => ensure_down(&self.st, node, net, o.request),
        }
    }

    /// Run one position through this stage's layers and forward. Frames
    /// from a stream that is no longer the flow's current inbound (a
    /// pre-repair generation draining late) are discarded before they can
    /// touch the session.
    fn process(
        &self,
        node: &mut LatticaNode,
        net: &mut Net,
        handle: StreamHandle,
        request: u64,
        pos: u64,
        mut h: Vec<f32>,
    ) {
        let now = net.now();
        let out = {
            let mut s = self.st.borrow_mut();
            let Some(f) = s.flows.get(&request) else { return };
            if f.inbound != Some(handle) {
                return;
            }
            let (is_tail, n_prompt) = (f.is_tail, f.n_prompt);
            let adv = {
                let RouteState { spec, kv, stats, .. } = &mut *s;
                kv.advance(&spec.model, request, pos, &mut h, now, stats)
            };
            if adv != Advance::Ok {
                return;
            }
            if is_tail {
                if pos + 1 >= n_prompt {
                    let token = s.spec.model.logits_argmax(&h);
                    s.stats.tokens_streamed += 1;
                    Some(RouteFrame::Emit { request, pos, token }.encode())
                } else {
                    None // prefill position: state absorbed, nothing to emit
                }
            } else {
                Some(RouteFrame::Act { request, pos, hidden: h }.encode())
            }
        };
        if let Some(bytes) = out {
            queue_frame(&self.st, node, net, request, bytes);
        }
    }
}

impl crate::rpc::StreamHandler for ShardStream {
    fn on_item(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        handle: StreamHandle,
        _seq: u64,
        payload: Buf,
    ) {
        let Ok(frame) = RouteFrame::decode(payload.as_slice()) else { return };
        match frame {
            RouteFrame::Open(o) => self.handle_open(node, net, handle, o),
            RouteFrame::Token { request, pos, token } => {
                // Head of the chain: embed, then run our layers.
                let h = self.st.borrow().spec.model.embed(token, pos);
                self.process(node, net, handle, request, pos, h);
            }
            RouteFrame::Act { request, pos, hidden } => {
                if hidden.len() == self.st.borrow().spec.model.d_model {
                    self.process(node, net, handle, request, pos, hidden);
                }
            }
            // Emit/Fault never legitimately arrive on an inbound stream.
            RouteFrame::Emit { .. } | RouteFrame::Fault { .. } => {}
        }
    }

    /// Inbound stream closed (client finished, repaired away from us, or
    /// the upstream died): release the session and cascade the close
    /// downstream.
    fn on_end(&mut self, node: &mut LatticaNode, net: &mut Net, handle: StreamHandle) {
        let down = {
            let mut s = self.st.borrow_mut();
            let Some(request) = s.inbound.remove(&handle) else { return };
            let current = s.flows.get(&request).and_then(|f| f.inbound) == Some(handle);
            if !current {
                return; // an old generation's stream drained late
            }
            let f = s.flows.remove(&request).expect("checked above");
            if let Some(h) = f.down {
                s.outbound.remove(&h);
            }
            {
                let RouteState { kv, stats, .. } = &mut *s;
                kv.close(request, stats);
            }
            f.down
        };
        if let Some(h) = down {
            let mut ctx = Ctx::new(&mut node.swarm, net);
            node.rpc.end_stream(&mut ctx, h);
        }
    }
}

struct ShardApp {
    st: Rc<RefCell<RouteState>>,
}

impl App for ShardApp {
    fn handle(&mut self, node: &mut LatticaNode, net: &mut Net, ev: NodeEvent) -> Option<NodeEvent> {
        match ev {
            NodeEvent::Gossip(GossipEvent::Received { ref topic, ref data, .. })
                if topic == LAYER_ADS_TOPIC =>
            {
                self.st.borrow_mut().book.ingest_bytes(net.now(), data);
                None
            }
            NodeEvent::PeerConnected { peer, .. } => {
                let waiting: Vec<u64> = self
                    .st
                    .borrow()
                    .flows
                    .iter()
                    .filter(|(_, f)| f.dialing && f.down_peer == peer)
                    .map(|(r, _)| *r)
                    .collect();
                for r in waiting {
                    ensure_down(&self.st, node, net, r);
                }
                Some(ev)
            }
            NodeEvent::Rpc(RpcEvent::StreamEnded { handle }) => {
                let request = self.st.borrow().outbound.get(&handle).copied();
                match request {
                    Some(r) => {
                        downstream_died(&self.st, node, net, r, handle);
                        None
                    }
                    None => Some(ev),
                }
            }
            NodeEvent::Rpc(RpcEvent::StreamItem { handle, ref payload, .. })
                if self.st.borrow().outbound.contains_key(&handle) =>
            {
                // Items flowing *backward* on a stream we opened: a Fault
                // from further down the chain — relay it upstream.
                if let Ok(RouteFrame::Fault { request, hop_index, detail }) =
                    RouteFrame::decode(payload.as_slice())
                {
                    let up = {
                        let mut s = self.st.borrow_mut();
                        s.stats.faults_propagated += 1;
                        s.flows.get(&request).and_then(|f| f.inbound)
                    };
                    if let Some(h) = up {
                        let frame = RouteFrame::Fault { request, hop_index, detail }.encode();
                        let mut ctx = Ctx::new(&mut node.swarm, net);
                        node.rpc.send_item(&mut ctx, h, frame);
                    }
                }
                None
            }
            NodeEvent::Rpc(RpcEvent::CreditsAvailable { handle, .. })
                if self.st.borrow().outbound.contains_key(&handle) =>
            {
                // Backlog already drained by the rpc layer on the grant.
                None
            }
            other => Some(other),
        }
    }
}
