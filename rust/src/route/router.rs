//! Chain routing: assemble the lowest-cost chain of layer-holders covering
//! `[0, n_layer)`.
//!
//! Costing uses three sources, best first:
//!
//! 1. the local [`RttTable`] (EWMA of ping probes) for edges touching this
//!    node;
//! 2. RTT samples piggybacked on [`LayerAd`]s for inter-stage edges this
//!    node can never measure itself (either endpoint's sample counts);
//! 3. a region estimate ([`SAME_REGION_RTT`] / [`CROSS_REGION_RTT`]) when
//!    nothing was measured — enough to prefer LAN/same-region holders from
//!    the first request, before any probe returns.
//!
//! Advertised load adds up to [`LOAD_PENALTY_FULL`] so a saturated local
//! replica loses to an idle remote one. Chain search is Dijkstra over
//! `(covered_layers, holder)` states with peer-id tie-breaks, so results
//! are deterministic for a given book + RTT table.

use super::ads::{AdBook, LayerAd};
use super::wire::Hop;
use crate::identity::PeerId;
use crate::netsim::{Time, MILLI, SECOND};
use std::collections::{BinaryHeap, HashMap};

/// EWMA weight of a new RTT sample.
const RTT_ALPHA_NUM: u64 = 3;
const RTT_ALPHA_DEN: u64 = 10;

/// Cost estimate for an unmeasured same-region edge.
pub const SAME_REGION_RTT: Time = 25 * MILLI;
/// Cost estimate for an unmeasured cross-region edge.
pub const CROSS_REGION_RTT: Time = 150 * MILLI;
/// Added cost at 100% advertised load.
pub const LOAD_PENALTY_FULL: Time = 50 * MILLI;
/// How long a peer reported dead stays out of chain assembly.
pub const QUARANTINE: Time = 15 * SECOND;

/// EWMA round-trip times per peer, fed from ping probes (see the ping loop
/// in `LatticaNode::pump`).
#[derive(Default)]
pub struct RttTable {
    ewma: HashMap<PeerId, Time>,
}

impl RttTable {
    pub fn new() -> RttTable {
        RttTable::default()
    }

    pub fn observe(&mut self, peer: PeerId, sample: Time) {
        let e = self.ewma.entry(peer).or_insert(sample);
        *e = (*e * (RTT_ALPHA_DEN - RTT_ALPHA_NUM) + sample * RTT_ALPHA_NUM) / RTT_ALPHA_DEN;
    }

    pub fn get(&self, peer: &PeerId) -> Option<Time> {
        self.ewma.get(peer).copied()
    }

    pub fn len(&self) -> usize {
        self.ewma.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ewma.is_empty()
    }

    /// Samples in deterministic (peer-id) order, for ad piggybacking.
    pub fn samples(&self) -> Vec<(PeerId, Time)> {
        let mut v: Vec<(PeerId, Time)> = self.ewma.iter().map(|(p, r)| (*p, *r)).collect();
        v.sort_by_key(|(p, _)| *p);
        v
    }
}

/// Assembles and repairs layer chains for one model.
pub struct LayerRouter {
    pub model: String,
    pub n_layer: u32,
    /// Region of the node doing the routing (the client), for estimating
    /// unmeasured client↔holder edges.
    pub my_region: u32,
    quarantine: HashMap<PeerId, Time>,
}

fn region_estimate(a: u32, b: u32) -> Time {
    if a == b {
        SAME_REGION_RTT
    } else {
        CROSS_REGION_RTT
    }
}

fn load_penalty(ad: &LayerAd) -> Time {
    LOAD_PENALTY_FULL * ad.load.min(100) as Time / 100
}

impl LayerRouter {
    pub fn new(model: &str, n_layer: u32, my_region: u32) -> LayerRouter {
        LayerRouter {
            model: model.to_string(),
            n_layer,
            my_region,
            quarantine: HashMap::new(),
        }
    }

    /// Exclude `peer` from assembly for [`QUARANTINE`] after a mid-stream
    /// death or fault report.
    pub fn mark_dead(&mut self, peer: PeerId, now: Time) {
        self.quarantine.insert(peer, now + QUARANTINE);
    }

    pub fn is_quarantined(&self, peer: &PeerId, now: Time) -> bool {
        self.quarantine.get(peer).is_some_and(|&until| until > now)
    }

    /// Cost of the client↔holder edge (used for both the head hop and the
    /// tail's emit path back to the client).
    fn client_cost(&self, ad: &LayerAd, rtt: &RttTable) -> Time {
        rtt.get(&ad.peer)
            .unwrap_or_else(|| region_estimate(self.my_region, ad.region))
    }

    /// Cost of the stage→stage edge `prev → next`.
    fn hop_cost(prev: &LayerAd, next: &LayerAd) -> Time {
        prev.rtt_to(&next.peer)
            .or_else(|| next.rtt_to(&prev.peer))
            .unwrap_or_else(|| region_estimate(prev.region, next.region))
    }

    /// Lowest-cost chain covering `[0, n_layer)`, or None if the live ads
    /// can't cover the range. Cost = client→head RTT + inter-stage RTTs +
    /// tail→client RTT + per-stage load penalties.
    pub fn assemble(&self, now: Time, book: &AdBook, rtt: &RttTable) -> Option<Vec<Hop>> {
        let usable: Vec<&LayerAd> = book
            .ads_for(&self.model)
            .filter(|ad| !self.is_quarantined(&ad.peer, now) && ad.layers.1 <= self.n_layer)
            .collect();
        if usable.is_empty() {
            return None;
        }
        // State = index into `usable`; dist keyed by holder (its covered
        // end is fixed by its ad). Deterministic: BinaryHeap ties broken by
        // (cost, covered_end, peer id).
        let mut dist: HashMap<PeerId, (Time, Option<PeerId>)> = HashMap::new();
        let by_peer: HashMap<PeerId, &LayerAd> =
            usable.iter().map(|ad| (ad.peer, *ad)).collect();
        let mut heap: BinaryHeap<std::cmp::Reverse<(Time, u32, PeerId)>> = BinaryHeap::new();
        for ad in &usable {
            if ad.layers.0 == 0 {
                let c = self.client_cost(ad, rtt) + load_penalty(ad);
                let better = dist.get(&ad.peer).is_none_or(|(d, _)| c < *d);
                if better {
                    dist.insert(ad.peer, (c, None));
                    heap.push(std::cmp::Reverse((c, ad.layers.1, ad.peer)));
                }
            }
        }
        let mut best_tail: Option<(Time, PeerId)> = None;
        while let Some(std::cmp::Reverse((c, end, peer))) = heap.pop() {
            let Some(&(dc, _)) = dist.get(&peer) else { continue };
            if c > dc {
                continue; // stale heap entry
            }
            let ad = by_peer[&peer];
            if end == self.n_layer {
                let total = c + self.client_cost(ad, rtt);
                let better = best_tail.is_none_or(|(t, p)| total < t || (total == t && peer < p));
                if better {
                    best_tail = Some((total, peer));
                }
                continue;
            }
            for next in book.holders_starting_at(&self.model, end) {
                if self.is_quarantined(&next.peer, now)
                    || next.layers.1 > self.n_layer
                    || next.peer == peer
                {
                    continue;
                }
                let nc = c + Self::hop_cost(ad, next) + load_penalty(next);
                let better = dist
                    .get(&next.peer)
                    .is_none_or(|(d, p)| nc < *d || (nc == *d && Some(peer) < *p));
                if better {
                    dist.insert(next.peer, (nc, Some(peer)));
                    heap.push(std::cmp::Reverse((nc, next.layers.1, next.peer)));
                }
            }
        }
        let (_, tail) = best_tail?;
        // Reconstruct hops tail-to-head, then reverse.
        let mut chain = Vec::new();
        let mut cur = Some(tail);
        while let Some(p) = cur {
            let ad = by_peer[&p];
            chain.push(Hop { peer: ad.peer, host: ad.host, port: ad.port, layers: ad.layers });
            cur = dist[&p].1;
        }
        chain.reverse();
        debug_assert_eq!(chain.first().map(|h| h.layers.0), Some(0));
        debug_assert_eq!(chain.last().map(|h| h.layers.1), Some(self.n_layer));
        Some(chain)
    }

    /// Placement-blind baseline: greedily take the lowest peer id that
    /// starts at each uncovered layer — what the pre-router hand-assigned
    /// stage map amounts to. Used by the bench's naive arm.
    pub fn naive(&self, now: Time, book: &AdBook) -> Option<Vec<Hop>> {
        let mut chain = Vec::new();
        let mut covered = 0u32;
        while covered < self.n_layer {
            let next = book
                .holders_starting_at(&self.model, covered)
                .into_iter()
                .filter(|ad| !self.is_quarantined(&ad.peer, now) && ad.layers.1 <= self.n_layer)
                .min_by_key(|ad| ad.peer)?;
            chain.push(Hop {
                peer: next.peer,
                host: next.host,
                port: next.port,
                layers: next.layers,
            });
            covered = next.layers.1;
        }
        Some(chain)
    }

    /// Repair a chain whose hop `dead` died mid-stream: splice the cheapest
    /// alternate holder of the same range(s) and keep every live hop.
    /// Falls back to full re-assembly when no drop-in alternate exists.
    pub fn repair(
        &self,
        now: Time,
        book: &AdBook,
        rtt: &RttTable,
        chain: &[Hop],
        dead: &PeerId,
    ) -> Option<Vec<Hop>> {
        let mut out = Vec::with_capacity(chain.len());
        for (i, hop) in chain.iter().enumerate() {
            if hop.peer != *dead {
                out.push(*hop);
                continue;
            }
            let alt = book
                .ads_for(&self.model)
                .filter(|ad| {
                    ad.layers == hop.layers
                        && ad.peer != *dead
                        && !self.is_quarantined(&ad.peer, now)
                        && !chain.iter().any(|h| h.peer == ad.peer)
                })
                .min_by_key(|ad| {
                    let up = match i.checked_sub(1).and_then(|j| chain.get(j)) {
                        Some(prev) => match book.get(&prev.peer) {
                            Some(pad) => Self::hop_cost(pad, ad),
                            None => self.client_cost(ad, rtt),
                        },
                        None => self.client_cost(ad, rtt),
                    };
                    let down = match chain.get(i + 1) {
                        Some(nx) => match book.get(&nx.peer) {
                            Some(nad) => Self::hop_cost(ad, nad),
                            None => self.client_cost(ad, rtt),
                        },
                        None => self.client_cost(ad, rtt),
                    };
                    (up + down + load_penalty(ad), ad.peer)
                })?;
            out.push(Hop { peer: alt.peer, host: alt.host, port: alt.port, layers: alt.layers });
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    fn peer(seed: u64) -> PeerId {
        Keypair::from_seed(seed).peer_id()
    }

    fn ad(seed: u64, layers: (u32, u32), region: u32, load: u32) -> LayerAd {
        LayerAd {
            peer: peer(seed),
            host: seed as u32 + 10,
            port: 4001,
            model: "m".into(),
            layers,
            region,
            capacity: 1024,
            load,
            rtts: Vec::new(),
        }
    }

    /// Two stages, each with a local (region 0) and a remote (region 1)
    /// replica. The router must pick both locals.
    #[test]
    fn prefers_measured_low_rtt_chain() {
        let mut book = AdBook::new();
        book.ingest(0, ad(1, (0, 4), 0, 0)); // local head
        book.ingest(0, ad(2, (0, 4), 1, 0)); // remote head
        book.ingest(0, ad(3, (4, 8), 0, 0)); // local tail
        book.ingest(0, ad(4, (4, 8), 1, 0)); // remote tail
        let mut rtt = RttTable::new();
        rtt.observe(peer(1), 2 * MILLI);
        rtt.observe(peer(2), 160 * MILLI);
        rtt.observe(peer(3), 2 * MILLI);
        rtt.observe(peer(4), 160 * MILLI);
        let router = LayerRouter::new("m", 8, 0);
        let chain = router.assemble(0, &book, &rtt).unwrap();
        assert_eq!(
            chain.iter().map(|h| h.peer).collect::<Vec<_>>(),
            vec![peer(1), peer(3)]
        );

        // RTTs shift (local head now slow): the router re-scores.
        rtt.observe(peer(1), 500 * MILLI);
        rtt.observe(peer(1), 500 * MILLI);
        rtt.observe(peer(1), 500 * MILLI);
        rtt.observe(peer(1), 500 * MILLI);
        rtt.observe(peer(1), 500 * MILLI);
        let chain = router.assemble(0, &book, &rtt).unwrap();
        assert_eq!(chain[0].peer, peer(2), "router must re-score on RTT shift");
    }

    /// With no measurements at all, region hints drive the choice.
    #[test]
    fn region_estimates_prefer_local() {
        let mut book = AdBook::new();
        book.ingest(0, ad(1, (0, 4), 1, 0));
        book.ingest(0, ad(2, (0, 4), 0, 0));
        book.ingest(0, ad(3, (4, 8), 1, 0));
        book.ingest(0, ad(4, (4, 8), 0, 0));
        let router = LayerRouter::new("m", 8, 0);
        let chain = router.assemble(0, &book, &RttTable::new()).unwrap();
        assert_eq!(
            chain.iter().map(|h| h.peer).collect::<Vec<_>>(),
            vec![peer(2), peer(4)]
        );
    }

    /// A saturated local replica loses to an idle one.
    #[test]
    fn load_penalty_shifts_choice() {
        let mut book = AdBook::new();
        book.ingest(0, ad(1, (0, 8), 0, 100));
        book.ingest(0, ad(2, (0, 8), 0, 0));
        let router = LayerRouter::new("m", 8, 0);
        let chain = router.assemble(0, &book, &RttTable::new()).unwrap();
        assert_eq!(chain[0].peer, peer(2));
    }

    #[test]
    fn quarantine_and_repair_splice() {
        let mut book = AdBook::new();
        book.ingest(0, ad(1, (0, 4), 0, 0));
        book.ingest(0, ad(3, (4, 8), 0, 0));
        book.ingest(0, ad(4, (4, 8), 1, 0));
        let mut router = LayerRouter::new("m", 8, 0);
        let rtt = RttTable::new();
        let chain = router.assemble(0, &book, &rtt).unwrap();
        assert_eq!(chain[1].peer, peer(3));

        // Mid-stream death of the tail: splice in the remote alternate,
        // keep the live head.
        let repaired = router.repair(0, &book, &rtt, &chain, &peer(3)).unwrap();
        assert_eq!(repaired[0].peer, peer(1), "live hop must be preserved");
        assert_eq!(repaired[1].peer, peer(4));

        // Quarantine also bars it from fresh assembly, then expires.
        router.mark_dead(peer(3), 0);
        let chain = router.assemble(MILLI, &book, &rtt).unwrap();
        assert_eq!(chain[1].peer, peer(4));
        let chain = router.assemble(QUARANTINE + MILLI, &book, &rtt).unwrap();
        assert_eq!(chain[1].peer, peer(3));
    }

    #[test]
    fn uncoverable_range_returns_none() {
        let mut book = AdBook::new();
        book.ingest(0, ad(1, (0, 4), 0, 0));
        let router = LayerRouter::new("m", 8, 0);
        assert!(router.assemble(0, &book, &RttTable::new()).is_none());
        assert!(router.naive(0, &book).is_none());
    }

    #[test]
    fn naive_ignores_latency() {
        let mut book = AdBook::new();
        // peer(1) < peer(2) not guaranteed; compare against computed order.
        book.ingest(0, ad(1, (0, 8), 1, 0));
        book.ingest(0, ad(2, (0, 8), 0, 0));
        let router = LayerRouter::new("m", 8, 0);
        let naive = router.naive(0, &book).unwrap();
        let expect = peer(1).min(peer(2));
        assert_eq!(naive[0].peer, expect);
    }
}
