//! Deterministic synthetic transformer stand-in for the inference plane.
//!
//! The PJRT runtime in this tree is a stub, so nothing in the route plane
//! can execute a compiled model. [`SimModel`] substitutes a tiny pure-Rust
//! recurrence with the *structural* properties the serving system needs:
//!
//! * layers are split across stages exactly like pipeline parallelism —
//!   stage k applies layers `[a, b)` to a hidden vector and forwards it;
//! * each layer carries per-request state (one vector per layer) that must
//!   stay resident on the stage between tokens — the KV-cache analogue that
//!   [`crate::route::KvSession`] manages;
//! * decode is autoregressive: the token at position `p + 1` is the argmax
//!   of the logits at position `p`, so a stage that loses state and replays
//!   from the wrong context produces visibly different output.
//!
//! Everything is seeded integer hashing mapped to `f32`, with a fixed
//! operation order (position outer, layer inner), so a distributed chain
//! and [`SimModel::reference_generate`] produce byte-identical token
//! streams — the property the kill/replay scenario asserts.

/// Synthetic model description: enough of a `ModelConfig` to size the
/// hidden state and vocab without any compiled artifacts.
#[derive(Clone, Debug, PartialEq)]
pub struct SimModel {
    pub model_id: String,
    pub n_layer: u32,
    pub d_model: usize,
    pub vocab: u32,
}

/// splitmix64 — the repo's standard deterministic mixer.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Map a seed to a float in `[-1, 1)`. Derived from the high bits so the
/// value is identical on every platform.
#[inline]
fn unit(seed: u64) -> f32 {
    let v = (mix(seed) >> 40) as u32; // 24 bits
    (v as f32) / ((1u32 << 23) as f32) - 1.0
}

impl SimModel {
    /// Small default used by benches/tests when no artifacts exist: 12
    /// layers (splits evenly into 2/3/4 stages), tiny hidden dim, small
    /// vocab so argmax decode stays cheap.
    pub fn tiny() -> SimModel {
        SimModel {
            model_id: "sim-tiny".to_string(),
            n_layer: 12,
            d_model: 16,
            vocab: 61,
        }
    }

    fn salt(&self) -> u64 {
        self.model_id
            .bytes()
            .fold(0xa076_1d64_78bd_642fu64, |h, b| mix(h ^ b as u64))
    }

    /// Token + position embedding: the hidden vector entering layer 0.
    pub fn embed(&self, token: u32, pos: u64) -> Vec<f32> {
        let salt = self.salt();
        (0..self.d_model)
            .map(|i| {
                let t = unit(salt ^ ((token as u64) << 20) ^ i as u64);
                let p = unit(salt ^ 0x517c_c1b7_2722_0a95 ^ (pos << 20) ^ i as u64);
                0.9 * t + 0.1 * p
            })
            .collect()
    }

    /// Apply one layer at one position. `state` is that layer's resident
    /// per-request state (the KV-cache analogue); both the hidden vector
    /// and the state are updated in place. The contraction (coefficients
    /// sum below 1 plus a small bounded injection) keeps values bounded
    /// over arbitrarily long sequences.
    pub fn layer_step(&self, layer: u32, h: &mut [f32], state: &mut [f32]) {
        debug_assert_eq!(h.len(), self.d_model);
        debug_assert_eq!(state.len(), self.d_model);
        let salt = self.salt() ^ ((layer as u64) << 40);
        for i in 0..self.d_model {
            let w = unit(salt ^ i as u64);
            let hv = 0.7 * h[i] + 0.3 * state[i] + 0.05 * w;
            state[i] = 0.5 * state[i] + 0.5 * hv;
            h[i] = hv;
        }
    }

    /// Greedy decode head: argmax over pseudo-random per-vocab projections
    /// of the final hidden vector. Ties break to the lowest token id, so
    /// the result is deterministic even under f32 equality.
    pub fn logits_argmax(&self, h: &[f32]) -> u32 {
        let salt = self.salt() ^ 0xd6e8_feb8_6659_fd93;
        let mut best = 0u32;
        let mut best_score = f32::NEG_INFINITY;
        for v in 0..self.vocab {
            let mut score = 0.0f32;
            for (i, &hv) in h.iter().enumerate() {
                score += hv * unit(salt ^ ((v as u64) << 24) ^ i as u64);
            }
            if score > best_score {
                best_score = score;
                best = v;
            }
        }
        best
    }

    /// Single-process oracle: run the full layer stack autoregressively and
    /// return the `gen_len` generated tokens. The operation order (position
    /// outer, layer inner) matches the distributed chain exactly, so a
    /// correct chain — including one repaired mid-stream — reproduces this
    /// byte for byte.
    pub fn reference_generate(&self, prompt: &[u32], gen_len: usize) -> Vec<u32> {
        assert!(!prompt.is_empty(), "prompt must be non-empty");
        let mut state: Vec<Vec<f32>> = (0..self.n_layer).map(|_| vec![0.0; self.d_model]).collect();
        let mut out = Vec::with_capacity(gen_len);
        let mut pos = 0u64;
        let mut last_h = vec![0.0; self.d_model];
        let mut feed: Vec<u32> = prompt.to_vec();
        while out.len() < gen_len {
            let token = feed[pos as usize];
            let mut h = self.embed(token, pos);
            for l in 0..self.n_layer {
                self.layer_step(l, &mut h, &mut state[l as usize]);
            }
            last_h.copy_from_slice(&h);
            if (pos + 1) as usize >= prompt.len() {
                let next = self.logits_argmax(&last_h);
                out.push(next);
                feed.push(next);
            }
            pos += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic_and_in_vocab() {
        let m = SimModel::tiny();
        let prompt = [3, 1, 4, 1, 5];
        let a = m.reference_generate(&prompt, 12);
        let b = m.reference_generate(&prompt, 12);
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|&t| t < m.vocab));
        // Not a constant stream (the recurrence actually mixes state).
        assert!(a.windows(2).any(|w| w[0] != w[1]), "degenerate output {a:?}");
    }

    #[test]
    fn different_prompts_diverge() {
        let m = SimModel::tiny();
        let a = m.reference_generate(&[1, 2, 3], 8);
        let b = m.reference_generate(&[3, 2, 1], 8);
        assert_ne!(a, b);
    }

    #[test]
    fn values_stay_bounded() {
        let m = SimModel::tiny();
        let mut state: Vec<Vec<f32>> =
            (0..m.n_layer).map(|_| vec![0.0; m.d_model]).collect();
        for pos in 0..500u64 {
            let mut h = m.embed((pos % m.vocab as u64) as u32, pos);
            for l in 0..m.n_layer {
                m.layer_step(l, &mut h, &mut state[l as usize]);
            }
            assert!(h.iter().all(|v| v.abs() < 10.0), "unbounded at pos {pos}");
        }
    }
}
