//! AutoNAT (`/lattica/autonat/1`): dial-back reachability probing.
//!
//! A node asks a connected peer to dial the address it believes it listens
//! on; if the probe datagram arrives, the node is publicly reachable
//! (NatStatus::Public), otherwise it should obtain a relay reservation.

use super::Ctx;
use crate::identity::PeerId;
use crate::multiaddr::SimAddr;
use crate::netsim::{Time, SECOND};
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::VecDeque;

pub const AUTONAT_PROTO: &str = "/lattica/autonat/1";

/// Probe datagrams are prefixed with this magic so the node layer can
/// distinguish them from transport packets.
pub const PROBE_MAGIC: &[u8; 8] = b"LATPROBE";

const M_DIAL_REQUEST: u64 = 1;
#[allow(dead_code)]
const M_DIAL_DONE: u64 = 2;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct AutonatMsg {
    pub kind: u64,
    pub host: u32,
    pub port: u32,
    pub nonce: u64,
}

impl Message for AutonatMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.uint(2, self.host as u64);
        w.uint(3, self.port as u64);
        w.uint(4, self.nonce);
    }

    fn decode(buf: &[u8]) -> Result<AutonatMsg> {
        let mut m = AutonatMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.host = f.as_u64() as u32,
                3 => m.port = f.as_u64() as u32,
                4 => m.nonce = f.as_u64(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NatStatus {
    Unknown,
    /// Probes reach us directly.
    Public,
    /// Dial-back failed: we are behind a NAT/firewall.
    Private,
}

#[derive(Debug)]
pub enum AutonatEvent {
    StatusChanged { status: NatStatus },
}

pub struct Autonat {
    pub status: NatStatus,
    pending_nonce: Option<(u64, Time)>,
    events: VecDeque<AutonatEvent>,
}

impl Default for Autonat {
    fn default() -> Self {
        Self::new()
    }
}

impl Autonat {
    pub fn new() -> Autonat {
        Autonat {
            status: NatStatus::Unknown,
            pending_nonce: None,
            events: VecDeque::new(),
        }
    }

    pub fn poll_event(&mut self) -> Option<AutonatEvent> {
        self.events.pop_front()
    }

    /// Ask `peer` to dial us back at our bound address.
    pub fn probe(&mut self, ctx: &mut Ctx, peer: &PeerId) -> Result<()> {
        let nonce = ctx.net.rng.next_u64();
        let local = ctx.swarm.local_addr;
        let msg = AutonatMsg {
            kind: M_DIAL_REQUEST,
            host: local.host,
            port: local.port as u32,
            nonce,
        };
        let (cid, stream) = ctx.open_stream(peer, AUTONAT_PROTO)?;
        ctx.send(cid, stream, &msg.encode())?;
        ctx.finish(cid, stream);
        self.pending_nonce = Some((nonce, ctx.now() + 3 * SECOND));
        Ok(())
    }

    /// Server side: a DIAL_REQUEST arrived — fire the probe datagram.
    pub fn handle_msg(&mut self, ctx: &mut Ctx, msg: &[u8]) -> Result<()> {
        let m = AutonatMsg::decode(msg)?;
        if m.kind == M_DIAL_REQUEST {
            let mut probe = PROBE_MAGIC.to_vec();
            probe.extend_from_slice(&m.nonce.to_be_bytes());
            let target = SimAddr::new(m.host, m.port as u16);
            let local = ctx.swarm.local_addr;
            ctx.net.send(local, target, probe);
        }
        Ok(())
    }

    /// Node hook: a probe datagram arrived at our socket.
    pub fn handle_probe_datagram(&mut self, payload: &[u8]) {
        if payload.len() != 16 || &payload[..8] != PROBE_MAGIC {
            return;
        }
        let nonce = u64::from_be_bytes(payload[8..16].try_into().unwrap());
        if let Some((expect, _)) = self.pending_nonce {
            if expect == nonce {
                self.pending_nonce = None;
                if self.status != NatStatus::Public {
                    self.status = NatStatus::Public;
                    self.events.push_back(AutonatEvent::StatusChanged {
                        status: NatStatus::Public,
                    });
                }
            }
        }
    }

    /// Tick: a probe that never landed means we're private.
    pub fn tick(&mut self, now: Time) {
        if let Some((_, deadline)) = self.pending_nonce {
            if now >= deadline {
                self.pending_nonce = None;
                if self.status != NatStatus::Private {
                    self.status = NatStatus::Private;
                    self.events.push_back(AutonatEvent::StatusChanged {
                        status: NatStatus::Private,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip() {
        let m = AutonatMsg {
            kind: M_DIAL_REQUEST,
            host: 3,
            port: 4001,
            nonce: 0xDEADBEEF,
        };
        assert_eq!(AutonatMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn probe_datagram_recognition() {
        let mut a = Autonat::new();
        a.pending_nonce = Some((42, 1000));
        let mut probe = PROBE_MAGIC.to_vec();
        probe.extend_from_slice(&42u64.to_be_bytes());
        a.handle_probe_datagram(&probe);
        assert_eq!(a.status, NatStatus::Public);
        // Timeout path.
        let mut b = Autonat::new();
        b.pending_nonce = Some((7, 1000));
        b.tick(2000);
        assert_eq!(b.status, NatStatus::Private);
    }
}
