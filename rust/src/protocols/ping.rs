//! Liveness + RTT measurement (`/lattica/ping/1`): echo a 32-byte payload.

use super::Ctx;
use crate::identity::PeerId;
use crate::netsim::Time;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};

pub const PING_PROTO: &str = "/lattica/ping/1";

#[derive(Debug)]
pub enum PingEvent {
    Rtt { peer: PeerId, rtt: Time },
}

#[derive(Default)]
pub struct Ping {
    outstanding: HashMap<(u64, u64), (PeerId, Time, Vec<u8>)>,
    events: VecDeque<PingEvent>,
}

impl Ping {
    pub fn new() -> Ping {
        Ping::default()
    }

    pub fn poll_event(&mut self) -> Option<PingEvent> {
        self.events.pop_front()
    }

    pub fn ping(&mut self, ctx: &mut Ctx, peer: &PeerId) -> Result<()> {
        let (cid, stream) = ctx.open_stream(peer, PING_PROTO)?;
        let payload = {
            let mut p = vec![0u8; 32];
            ctx.net.rng.fill_bytes(&mut p);
            p
        };
        ctx.send(cid, stream, &payload)?;
        self.outstanding
            .insert((cid, stream), (*peer, ctx.now(), payload));
        Ok(())
    }

    /// Inbound message: echo if it's a request, record RTT if a response.
    pub fn handle_msg(&mut self, ctx: &mut Ctx, cid: u64, stream: u64, msg: &[u8]) {
        if let Some((peer, sent_at, payload)) = self.outstanding.remove(&(cid, stream)) {
            if payload == msg {
                self.events.push_back(PingEvent::Rtt {
                    peer,
                    rtt: ctx.now().saturating_sub(sent_at),
                });
            }
            ctx.finish(cid, stream);
        } else {
            // Server side: echo and finish.
            let _ = ctx.send(cid, stream, msg);
            ctx.finish(cid, stream);
        }
    }
}
