//! Gossip pub-sub (flood-sub with a seen-cache, bounded fanout and an
//! optional lazy-push layer).
//!
//! Protocol `/lattica/gossip/1`. Topics are strings; messages carry a
//! (origin, seq) id so duplicates are suppressed. Used to announce new
//! model versions (root CIDs) to inference clusters — Fig. 1(3).
//!
//! With [`Gossip::lazy_push`] on, full payloads go to only
//! [`EAGER_FANOUT`] peers per hop; every other connected peer gets a
//! batched IHAVE on the next tick — per-origin range-coded seq sets plus
//! a bloom digest of the sender's recent window — and pulls what it
//! misses with IWANT. That trades ≤ one tick + one RTT of latency for a
//! control plane that no longer scales with (messages × fanout).

use super::Ctx;
use crate::identity::PeerId;
use crate::netsim::{Time, SECOND};
use crate::wire::{
    encode_pooled, BloomDigest, Message, PbReader, PbWriter, RangeSet, BLOOM_BYTES,
};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

pub const GOSSIP_PROTO: &str = "/lattica/gossip/1";

/// Max peers a message is forwarded to per hop (eager flood mode).
pub const FANOUT: usize = 6;
/// Lazy push: peers that still get the full payload per hop; the rest
/// learn about the message from the next IHAVE.
pub const EAGER_FANOUT: usize = 2;
/// Seen-cache size.
pub const SEEN_CAP: usize = 4096;
/// Recently-seen messages kept to serve IWANT pulls (also the digest
/// window advertised in IHAVE).
const MCACHE_CAP: usize = 128;
/// An unanswered IWANT may be re-pulled (via a later IHAVE) after this.
const IWANT_TIMEOUT: Time = SECOND;
/// Hostile-input bounds when walking summaries of a received message.
const MAX_SUMMARIES: usize = 64;
const MAX_IDS_PER_SUMMARY: usize = 256;

/// Wire message kinds — public so lightweight responders (e.g. the
/// planet-scale background nodes in `scenarios::planet`) can join the
/// mesh without a full `Gossip` instance. Legacy decoders drop IHAVE and
/// IWANT in their unknown-kind arm, so lazy and eager nodes interoperate.
pub const M_PUBLISH: u64 = 1;
pub const M_SUBSCRIBE: u64 = 2;
pub const M_UNSUBSCRIBE: u64 = 3;
pub const M_IHAVE: u64 = 4;
pub const M_IWANT: u64 = 5;

/// One origin's message ids, range-coded over seq numbers. IHAVE carries
/// what the sender recently saw; IWANT carries what the receiver misses.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GossipSummary {
    pub origin: Vec<u8>,
    /// [`RangeSet::encode`] bytes over this origin's seq numbers.
    pub seqs: Vec<u8>,
}

impl Message for GossipSummary {
    fn encode_to(&self, w: &mut PbWriter) {
        w.bytes(1, &self.origin);
        w.bytes(2, &self.seqs);
    }

    fn decode(buf: &[u8]) -> Result<GossipSummary> {
        let mut m = GossipSummary::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.origin = f.as_bytes()?.to_vec(),
                2 => m.seqs = f.as_bytes()?.to_vec(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct GossipMsg {
    pub kind: u64,
    pub topic: String,
    pub origin: Vec<u8>,
    pub seq: u64,
    pub data: Vec<u8>,
    /// IHAVE / IWANT: per-origin range-coded message-id summaries.
    /// Absent on legacy kinds, so their encoding is byte-identical to the
    /// pre-lazy wire format.
    pub summaries: Vec<GossipSummary>,
    /// IHAVE: [`BloomDigest`] bytes over the sender's recent-id window —
    /// receivers skip eager pushes of messages the sender already holds.
    pub digest: Vec<u8>,
}

impl Message for GossipMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.string(2, &self.topic);
        w.bytes(3, &self.origin);
        w.uint(4, self.seq);
        w.bytes(5, &self.data);
        w.messages(6, &self.summaries);
        w.bytes(7, &self.digest);
    }

    fn decode(buf: &[u8]) -> Result<GossipMsg> {
        let mut m = GossipMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.topic = f.as_string()?,
                3 => m.origin = f.as_bytes()?.to_vec(),
                4 => m.seq = f.as_u64(),
                5 => m.data = f.as_bytes()?.to_vec(),
                6 => m.summaries.push(f.as_message()?),
                7 => m.digest = f.as_bytes()?.to_vec(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

/// Control-plane accounting: every gossip frame is metadata from the
/// transfer plane's point of view (DESIGN.md §Control-plane compression).
#[derive(Clone, Copy, Debug, Default)]
pub struct GossipStats {
    /// Wire bytes of every gossip message sent.
    pub bytes_sent: u64,
    /// Full-payload forwards (eager path).
    pub eager_pushes: u64,
    pub ihaves_sent: u64,
    pub iwants_sent: u64,
    /// PUBLISHes served from the mcache in answer to an IWANT.
    pub lazy_pulls_served: u64,
}

/// Message id as digest input: origin bytes ‖ big-endian seq.
fn id_bytes(origin: &[u8], seq: u64) -> Vec<u8> {
    let mut v = Vec::with_capacity(origin.len() + 8);
    v.extend_from_slice(origin);
    v.extend_from_slice(&seq.to_be_bytes());
    v
}

#[derive(Debug)]
pub enum GossipEvent {
    /// A message arrived on a subscribed topic.
    Received {
        topic: String,
        origin: PeerId,
        seq: u64,
        data: Vec<u8>,
    },
}

/// The gossip behaviour.
pub struct Gossip {
    local: PeerId,
    /// Topics we subscribe to.
    pub subscriptions: HashSet<String>,
    /// Peer → topics they subscribe to (learned from SUBSCRIBE msgs).
    peer_topics: HashMap<PeerId, HashSet<String>>,
    /// Open gossip stream per peer.
    streams: HashMap<PeerId, (u64, u64)>,
    seen: HashSet<(Vec<u8>, u64)>,
    seen_order: VecDeque<(Vec<u8>, u64)>,
    /// Lazy push (IHAVE/IWANT) on. Set from `NodeConfig::compact_control`;
    /// lazy and eager nodes interoperate on the same mesh.
    pub lazy_push: bool,
    /// Recently seen messages, kept to serve IWANT pulls.
    mcache: HashMap<(Vec<u8>, u64), (String, Vec<u8>)>,
    mcache_order: VecDeque<(Vec<u8>, u64)>,
    /// Ids seen since the last tick, advertised in the next IHAVE batch.
    adverts: Vec<(Vec<u8>, u64)>,
    /// Outstanding pulls: id → retry deadline (a later IHAVE may re-pull).
    pending_iwant: HashMap<(Vec<u8>, u64), Time>,
    /// Last digest each peer advertised (eager-push suppression).
    peer_digests: HashMap<PeerId, BloomDigest>,
    next_seq: u64,
    events: VecDeque<GossipEvent>,
    pub messages_forwarded: u64,
    pub stats: GossipStats,
}

impl Gossip {
    pub fn new(local: PeerId) -> Gossip {
        Gossip {
            local,
            subscriptions: HashSet::new(),
            peer_topics: HashMap::new(),
            streams: HashMap::new(),
            seen: HashSet::new(),
            seen_order: VecDeque::new(),
            lazy_push: false,
            mcache: HashMap::new(),
            mcache_order: VecDeque::new(),
            adverts: Vec::new(),
            pending_iwant: HashMap::new(),
            peer_digests: HashMap::new(),
            next_seq: 1,
            events: VecDeque::new(),
            messages_forwarded: 0,
            stats: GossipStats::default(),
        }
    }

    /// Send one gossip frame, crediting its wire size to
    /// [`GossipStats::bytes_sent`]. Associated fn so callers can hold
    /// disjoint `self` borrows.
    fn send_counted(
        stats: &mut GossipStats,
        ctx: &mut Ctx,
        conn: u64,
        stream: u64,
        msg: &GossipMsg,
    ) -> bool {
        match encode_pooled(msg, |b| ctx.send(conn, stream, b).map(|()| b.len())) {
            Ok(n) => {
                stats.bytes_sent += n as u64;
                true
            }
            Err(_) => false,
        }
    }

    /// Cache a message for IWANT pulls and queue its id for the next
    /// IHAVE advertisement (lazy mode only).
    fn remember(&mut self, topic: &str, origin: &[u8], seq: u64, data: &[u8]) {
        if !self.lazy_push {
            return;
        }
        let key = (origin.to_vec(), seq);
        if self.mcache.contains_key(&key) {
            return;
        }
        self.mcache.insert(key.clone(), (topic.to_string(), data.to_vec()));
        self.mcache_order.push_back(key.clone());
        if self.mcache_order.len() > MCACHE_CAP {
            if let Some(old) = self.mcache_order.pop_front() {
                self.mcache.remove(&old);
            }
        }
        self.adverts.push(key);
    }

    pub fn poll_event(&mut self) -> Option<GossipEvent> {
        self.events.pop_front()
    }

    fn stream_to(&mut self, ctx: &mut Ctx, peer: &PeerId) -> Result<(u64, u64)> {
        if let Some(&s) = self.streams.get(peer) {
            return Ok(s);
        }
        let s = ctx.open_stream(peer, GOSSIP_PROTO)?;
        self.streams.insert(*peer, s);
        Ok(s)
    }

    /// Subscribe locally and tell connected peers.
    pub fn subscribe(&mut self, ctx: &mut Ctx, topic: &str) {
        self.subscriptions.insert(topic.to_string());
        let msg = GossipMsg {
            kind: M_SUBSCRIBE,
            topic: topic.to_string(),
            ..Default::default()
        };
        let peers: Vec<PeerId> = ctx
            .swarm
            .peerstore
            .known_peers()
            .copied()
            .filter(|p| ctx.swarm.is_connected(p))
            .collect();
        for p in peers {
            if let Ok((c, s)) = self.stream_to(ctx, &p) {
                Self::send_counted(&mut self.stats, ctx, c, s, &msg);
            }
        }
    }

    /// Greet a newly connected peer with our subscriptions.
    pub fn on_peer_connected(&mut self, ctx: &mut Ctx, peer: PeerId) {
        let topics: Vec<String> = self.subscriptions.iter().cloned().collect();
        for t in topics {
            let msg = GossipMsg {
                kind: M_SUBSCRIBE,
                topic: t,
                ..Default::default()
            };
            if let Ok((c, s)) = self.stream_to(ctx, &peer) {
                Self::send_counted(&mut self.stats, ctx, c, s, &msg);
            }
        }
    }

    pub fn on_peer_disconnected(&mut self, peer: PeerId) {
        self.streams.remove(&peer);
        self.peer_topics.remove(&peer);
        self.peer_digests.remove(&peer);
    }

    /// Publish to a topic.
    pub fn publish(&mut self, ctx: &mut Ctx, topic: &str, data: Vec<u8>) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let msg = GossipMsg {
            kind: M_PUBLISH,
            topic: topic.to_string(),
            origin: self.local.as_bytes().to_vec(),
            seq,
            data,
            ..GossipMsg::default()
        };
        self.mark_seen(msg.origin.clone(), seq);
        self.remember(topic, &msg.origin, seq, &msg.data);
        self.forward(ctx, &msg, None);
        seq
    }

    fn mark_seen(&mut self, origin: Vec<u8>, seq: u64) -> bool {
        let key = (origin, seq);
        if self.seen.contains(&key) {
            return false;
        }
        self.seen.insert(key.clone());
        self.seen_order.push_back(key);
        if self.seen_order.len() > SEEN_CAP {
            if let Some(old) = self.seen_order.pop_front() {
                self.seen.remove(&old);
            }
        }
        true
    }

    fn forward(&mut self, ctx: &mut Ctx, msg: &GossipMsg, exclude: Option<PeerId>) {
        // Prefer peers known to subscribe; fall back to any connected peer.
        let mut targets: Vec<PeerId> = self
            .peer_topics
            .iter()
            .filter(|(_, t)| t.contains(&msg.topic))
            .map(|(p, _)| *p)
            .collect();
        if targets.len() < FANOUT {
            for p in ctx.swarm.peerstore.known_peers().copied().collect::<Vec<_>>() {
                if !targets.contains(&p) && ctx.swarm.is_connected(&p) {
                    targets.push(p);
                }
            }
        }
        // Lazy push: only EAGER_FANOUT peers get the payload now; the
        // rest learn about it from the next tick's IHAVE and pull.
        let cap = if self.lazy_push { EAGER_FANOUT } else { FANOUT };
        let id = id_bytes(&msg.origin, msg.seq);
        let mut sent = 0;
        for p in targets {
            if Some(p) == exclude || p == self.local {
                continue;
            }
            if sent >= cap {
                break;
            }
            if !ctx.swarm.is_connected(&p) {
                continue;
            }
            // Skip peers whose advertised digest already covers this id
            // (a bloom false positive only costs them an IWANT pull).
            if self.lazy_push
                && self.peer_digests.get(&p).is_some_and(|d| d.contains(&id))
            {
                continue;
            }
            if let Ok((c, s)) = self.stream_to(ctx, &p) {
                if Self::send_counted(&mut self.stats, ctx, c, s, msg) {
                    sent += 1;
                    self.messages_forwarded += 1;
                    self.stats.eager_pushes += 1;
                }
            }
        }
    }

    /// Node hook: inbound gossip message.
    pub fn handle_msg(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        conn: u64,
        stream: u64,
        msg: &[u8],
    ) -> Result<()> {
        self.streams.entry(peer).or_insert((conn, stream));
        let m = GossipMsg::decode(msg)?;
        match m.kind {
            M_SUBSCRIBE => {
                self.peer_topics.entry(peer).or_default().insert(m.topic);
            }
            M_UNSUBSCRIBE => {
                if let Some(t) = self.peer_topics.get_mut(&peer) {
                    t.remove(&m.topic);
                }
            }
            M_PUBLISH => {
                self.pending_iwant.remove(&(m.origin.clone(), m.seq));
                if !self.mark_seen(m.origin.clone(), m.seq) {
                    return Ok(()); // duplicate
                }
                self.remember(&m.topic, &m.origin, m.seq, &m.data);
                if self.subscriptions.contains(&m.topic) {
                    let mut origin = [0u8; 32];
                    if m.origin.len() == 32 {
                        origin.copy_from_slice(&m.origin);
                    }
                    self.events.push_back(GossipEvent::Received {
                        topic: m.topic.clone(),
                        origin: PeerId(origin),
                        seq: m.seq,
                        data: m.data.clone(),
                    });
                }
                self.forward(ctx, &m, Some(peer));
            }
            M_IHAVE => {
                if m.digest.len() == BLOOM_BYTES {
                    if let Ok(d) = BloomDigest::from_bytes(&m.digest) {
                        self.peer_digests.insert(peer, d);
                    }
                }
                let now = ctx.now();
                let mut missing: BTreeMap<Vec<u8>, RangeSet> = BTreeMap::new();
                for s in m.summaries.iter().take(MAX_SUMMARIES) {
                    let Ok(set) = RangeSet::decode(&s.seqs) else { continue };
                    for seq in set.iter().take(MAX_IDS_PER_SUMMARY) {
                        let key = (s.origin.clone(), seq);
                        if self.seen.contains(&key) || self.pending_iwant.contains_key(&key) {
                            continue;
                        }
                        self.pending_iwant.insert(key, now + IWANT_TIMEOUT);
                        missing.entry(s.origin.clone()).or_default().insert(seq);
                    }
                }
                if !missing.is_empty() {
                    let reply = GossipMsg {
                        kind: M_IWANT,
                        summaries: missing
                            .into_iter()
                            .map(|(origin, set)| GossipSummary {
                                origin,
                                seqs: set.encode(),
                            })
                            .collect(),
                        ..GossipMsg::default()
                    };
                    if Self::send_counted(&mut self.stats, ctx, conn, stream, &reply) {
                        self.stats.iwants_sent += 1;
                    }
                }
            }
            M_IWANT => {
                for s in m.summaries.iter().take(MAX_SUMMARIES) {
                    let Ok(set) = RangeSet::decode(&s.seqs) else { continue };
                    for seq in set.iter().take(MAX_IDS_PER_SUMMARY) {
                        let key = (s.origin.clone(), seq);
                        let Some((topic, data)) = self.mcache.get(&key) else { continue };
                        let reply = GossipMsg {
                            kind: M_PUBLISH,
                            topic: topic.clone(),
                            origin: s.origin.clone(),
                            seq,
                            data: data.clone(),
                            ..GossipMsg::default()
                        };
                        if Self::send_counted(&mut self.stats, ctx, conn, stream, &reply) {
                            self.stats.lazy_pulls_served += 1;
                        }
                    }
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Node hook: periodic tick. Flushes the lazy-push layer — one IHAVE
    /// per connected peer summarizing everything seen since the last tick
    /// (range-coded per origin, plus a bloom digest of the whole mcache
    /// window) — and expires unanswered IWANTs so a later IHAVE can retry
    /// the pull from another holder.
    pub fn tick(&mut self, ctx: &mut Ctx) {
        if !self.lazy_push {
            return;
        }
        let now = ctx.now();
        self.pending_iwant.retain(|_, deadline| *deadline > now);
        if self.adverts.is_empty() {
            return;
        }
        let mut by_origin: BTreeMap<Vec<u8>, RangeSet> = BTreeMap::new();
        for (origin, seq) in self.adverts.drain(..) {
            by_origin.entry(origin).or_default().insert(seq);
        }
        let summaries: Vec<GossipSummary> = by_origin
            .into_iter()
            .map(|(origin, set)| GossipSummary {
                origin,
                seqs: set.encode(),
            })
            .collect();
        let mut digest = BloomDigest::new();
        for (origin, seq) in self.mcache_order.iter() {
            digest.insert(&id_bytes(origin, *seq));
        }
        let msg = GossipMsg {
            kind: M_IHAVE,
            summaries,
            digest: digest.as_bytes().to_vec(),
            ..GossipMsg::default()
        };
        let targets: Vec<PeerId> = ctx
            .swarm
            .peerstore
            .known_peers()
            .copied()
            .filter(|p| *p != self.local && ctx.swarm.is_connected(p))
            .collect();
        for p in targets {
            if let Ok((c, s)) = self.stream_to(ctx, &p) {
                if Self::send_counted(&mut self.stats, ctx, c, s, &msg) {
                    self.stats.ihaves_sent += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    #[test]
    fn msg_roundtrip() {
        let m = GossipMsg {
            kind: M_PUBLISH,
            topic: "models".into(),
            origin: vec![1u8; 32],
            seq: 42,
            data: b"root-cid".to_vec(),
            ..GossipMsg::default()
        };
        assert_eq!(GossipMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn seen_cache_dedupes_and_bounds() {
        let mut g = Gossip::new(Keypair::from_seed(1).peer_id());
        assert!(g.mark_seen(vec![1], 1));
        assert!(!g.mark_seen(vec![1], 1));
        for i in 0..SEEN_CAP + 10 {
            g.mark_seen(vec![2], i as u64);
        }
        assert!(g.seen.len() <= SEEN_CAP);
    }

    #[test]
    fn legacy_encoding_byte_identical() {
        // A message without summaries/digest must encode exactly as it
        // did before fields 6/7 existed; legacy decoders skip the new
        // fields and drop IHAVE/IWANT in their unknown-kind arm.
        let m = GossipMsg {
            kind: M_PUBLISH,
            topic: "models".into(),
            origin: vec![1u8; 32],
            seq: 42,
            data: b"root-cid".to_vec(),
            ..GossipMsg::default()
        };
        let mut w = PbWriter::new();
        w.uint(1, M_PUBLISH);
        w.string(2, "models");
        w.bytes(3, &[1u8; 32]);
        w.uint(4, 42);
        w.bytes(5, b"root-cid");
        assert_eq!(m.encode(), w.finish());
    }

    #[test]
    fn ihave_summary_roundtrip() {
        let mut set = RangeSet::new();
        for s in [1u64, 2, 3, 9, 10, 40] {
            set.insert(s);
        }
        let mut digest = BloomDigest::new();
        digest.insert(&id_bytes(&[7u8; 32], 3));
        let m = GossipMsg {
            kind: M_IHAVE,
            summaries: vec![
                GossipSummary {
                    origin: vec![7u8; 32],
                    seqs: set.encode(),
                },
                GossipSummary {
                    origin: vec![8u8; 32],
                    seqs: RangeSet::from_iter([5u64]).encode(),
                },
            ],
            digest: digest.as_bytes().to_vec(),
            ..GossipMsg::default()
        };
        let d = GossipMsg::decode(&m.encode()).unwrap();
        assert_eq!(d, m);
        let back = RangeSet::decode(&d.summaries[0].seqs).unwrap();
        assert_eq!(back.iter().collect::<Vec<_>>(), vec![1, 2, 3, 9, 10, 40]);
    }

    #[test]
    fn mcache_bounded_and_feeds_adverts() {
        let mut g = Gossip::new(Keypair::from_seed(2).peer_id());
        // Off: remember() is a no-op, nothing accumulates.
        g.remember("t", &[1u8; 32], 1, b"x");
        assert!(g.mcache.is_empty() && g.adverts.is_empty());
        g.lazy_push = true;
        for i in 0..(MCACHE_CAP as u64 + 50) {
            g.remember("t", &[1u8; 32], i, b"payload");
        }
        assert!(g.mcache.len() <= MCACHE_CAP);
        assert_eq!(g.mcache_order.len(), g.mcache.len());
        assert_eq!(g.adverts.len(), MCACHE_CAP + 50);
        // Duplicates neither grow the cache nor re-advertise.
        g.remember("t", &[1u8; 32], MCACHE_CAP as u64 + 10, b"payload");
        assert_eq!(g.adverts.len(), MCACHE_CAP + 50);
    }
}
