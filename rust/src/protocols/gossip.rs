//! Gossip pub-sub (flood-sub with a seen-cache and bounded fanout).
//!
//! Protocol `/lattica/gossip/1`. Topics are strings; messages carry a
//! (origin, seq) id so duplicates are suppressed. Used to announce new
//! model versions (root CIDs) to inference clusters — Fig. 1(3).

use super::Ctx;
use crate::identity::PeerId;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::{HashMap, HashSet, VecDeque};

pub const GOSSIP_PROTO: &str = "/lattica/gossip/1";

/// Max peers a message is forwarded to per hop.
pub const FANOUT: usize = 6;
/// Seen-cache size.
pub const SEEN_CAP: usize = 4096;

/// Wire message kinds — public so lightweight responders (e.g. the
/// planet-scale background nodes in `scenarios::planet`) can join the
/// mesh without a full `Gossip` instance.
pub const M_PUBLISH: u64 = 1;
pub const M_SUBSCRIBE: u64 = 2;
pub const M_UNSUBSCRIBE: u64 = 3;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct GossipMsg {
    pub kind: u64,
    pub topic: String,
    pub origin: Vec<u8>,
    pub seq: u64,
    pub data: Vec<u8>,
}

impl Message for GossipMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.string(2, &self.topic);
        w.bytes(3, &self.origin);
        w.uint(4, self.seq);
        w.bytes(5, &self.data);
    }

    fn decode(buf: &[u8]) -> Result<GossipMsg> {
        let mut m = GossipMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.topic = f.as_string()?,
                3 => m.origin = f.as_bytes()?.to_vec(),
                4 => m.seq = f.as_u64(),
                5 => m.data = f.as_bytes()?.to_vec(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

#[derive(Debug)]
pub enum GossipEvent {
    /// A message arrived on a subscribed topic.
    Received {
        topic: String,
        origin: PeerId,
        seq: u64,
        data: Vec<u8>,
    },
}

/// The gossip behaviour.
pub struct Gossip {
    local: PeerId,
    /// Topics we subscribe to.
    pub subscriptions: HashSet<String>,
    /// Peer → topics they subscribe to (learned from SUBSCRIBE msgs).
    peer_topics: HashMap<PeerId, HashSet<String>>,
    /// Open gossip stream per peer.
    streams: HashMap<PeerId, (u64, u64)>,
    seen: HashSet<(Vec<u8>, u64)>,
    seen_order: VecDeque<(Vec<u8>, u64)>,
    next_seq: u64,
    events: VecDeque<GossipEvent>,
    pub messages_forwarded: u64,
}

impl Gossip {
    pub fn new(local: PeerId) -> Gossip {
        Gossip {
            local,
            subscriptions: HashSet::new(),
            peer_topics: HashMap::new(),
            streams: HashMap::new(),
            seen: HashSet::new(),
            seen_order: VecDeque::new(),
            next_seq: 1,
            events: VecDeque::new(),
            messages_forwarded: 0,
        }
    }

    pub fn poll_event(&mut self) -> Option<GossipEvent> {
        self.events.pop_front()
    }

    fn stream_to(&mut self, ctx: &mut Ctx, peer: &PeerId) -> Result<(u64, u64)> {
        if let Some(&s) = self.streams.get(peer) {
            return Ok(s);
        }
        let s = ctx.open_stream(peer, GOSSIP_PROTO)?;
        self.streams.insert(*peer, s);
        Ok(s)
    }

    /// Subscribe locally and tell connected peers.
    pub fn subscribe(&mut self, ctx: &mut Ctx, topic: &str) {
        self.subscriptions.insert(topic.to_string());
        let msg = GossipMsg {
            kind: M_SUBSCRIBE,
            topic: topic.to_string(),
            ..Default::default()
        };
        let peers: Vec<PeerId> = ctx
            .swarm
            .peerstore
            .known_peers()
            .copied()
            .filter(|p| ctx.swarm.is_connected(p))
            .collect();
        for p in peers {
            if let Ok((c, s)) = self.stream_to(ctx, &p) {
                let _ = ctx.send(c, s, &msg.encode());
            }
        }
    }

    /// Greet a newly connected peer with our subscriptions.
    pub fn on_peer_connected(&mut self, ctx: &mut Ctx, peer: PeerId) {
        let topics: Vec<String> = self.subscriptions.iter().cloned().collect();
        for t in topics {
            let msg = GossipMsg {
                kind: M_SUBSCRIBE,
                topic: t,
                ..Default::default()
            };
            if let Ok((c, s)) = self.stream_to(ctx, &peer) {
                let _ = ctx.send(c, s, &msg.encode());
            }
        }
    }

    pub fn on_peer_disconnected(&mut self, peer: PeerId) {
        self.streams.remove(&peer);
        self.peer_topics.remove(&peer);
    }

    /// Publish to a topic.
    pub fn publish(&mut self, ctx: &mut Ctx, topic: &str, data: Vec<u8>) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let msg = GossipMsg {
            kind: M_PUBLISH,
            topic: topic.to_string(),
            origin: self.local.as_bytes().to_vec(),
            seq,
            data,
        };
        self.mark_seen(msg.origin.clone(), seq);
        self.forward(ctx, &msg, None);
        seq
    }

    fn mark_seen(&mut self, origin: Vec<u8>, seq: u64) -> bool {
        let key = (origin, seq);
        if self.seen.contains(&key) {
            return false;
        }
        self.seen.insert(key.clone());
        self.seen_order.push_back(key);
        if self.seen_order.len() > SEEN_CAP {
            if let Some(old) = self.seen_order.pop_front() {
                self.seen.remove(&old);
            }
        }
        true
    }

    fn forward(&mut self, ctx: &mut Ctx, msg: &GossipMsg, exclude: Option<PeerId>) {
        // Prefer peers known to subscribe; fall back to any connected peer.
        let mut targets: Vec<PeerId> = self
            .peer_topics
            .iter()
            .filter(|(_, t)| t.contains(&msg.topic))
            .map(|(p, _)| *p)
            .collect();
        if targets.len() < FANOUT {
            for p in ctx.swarm.peerstore.known_peers().copied().collect::<Vec<_>>() {
                if !targets.contains(&p) && ctx.swarm.is_connected(&p) {
                    targets.push(p);
                }
            }
        }
        let mut sent = 0;
        for p in targets {
            if Some(p) == exclude || p == self.local {
                continue;
            }
            if sent >= FANOUT {
                break;
            }
            if !ctx.swarm.is_connected(&p) {
                continue;
            }
            if let Ok((c, s)) = self.stream_to(ctx, &p) {
                if ctx.send(c, s, &msg.encode()).is_ok() {
                    sent += 1;
                    self.messages_forwarded += 1;
                }
            }
        }
    }

    /// Node hook: inbound gossip message.
    pub fn handle_msg(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        conn: u64,
        stream: u64,
        msg: &[u8],
    ) -> Result<()> {
        self.streams.entry(peer).or_insert((conn, stream));
        let m = GossipMsg::decode(msg)?;
        match m.kind {
            M_SUBSCRIBE => {
                self.peer_topics.entry(peer).or_default().insert(m.topic);
            }
            M_UNSUBSCRIBE => {
                if let Some(t) = self.peer_topics.get_mut(&peer) {
                    t.remove(&m.topic);
                }
            }
            M_PUBLISH => {
                if !self.mark_seen(m.origin.clone(), m.seq) {
                    return Ok(()); // duplicate
                }
                if self.subscriptions.contains(&m.topic) {
                    let mut origin = [0u8; 32];
                    if m.origin.len() == 32 {
                        origin.copy_from_slice(&m.origin);
                    }
                    self.events.push_back(GossipEvent::Received {
                        topic: m.topic.clone(),
                        origin: PeerId(origin),
                        seq: m.seq,
                        data: m.data.clone(),
                    });
                }
                self.forward(ctx, &m, Some(peer));
            }
            _ => {}
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    #[test]
    fn msg_roundtrip() {
        let m = GossipMsg {
            kind: M_PUBLISH,
            topic: "models".into(),
            origin: vec![1u8; 32],
            seq: 42,
            data: b"root-cid".to_vec(),
        };
        assert_eq!(GossipMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn seen_cache_dedupes_and_bounds() {
        let mut g = Gossip::new(Keypair::from_seed(1).peer_id());
        assert!(g.mark_seen(vec![1], 1));
        assert!(!g.mark_seen(vec![1], 1));
        for i in 0..SEEN_CAP + 10 {
            g.mark_seen(vec![2], i as u64);
        }
        assert!(g.seen.len() <= SEEN_CAP);
    }
}
