//! DCUtR (`/lattica/dcutr/1`): Direct Connection Upgrade through Relay.
//!
//! Runs over a *relayed* connection: the two sides exchange their observed
//! public addresses and a synchronization point, then both call
//! [`crate::swarm::Swarm::start_punch`] simultaneously. The swarm handles
//! path probing and migration; this protocol is the coordination layer.

use super::Ctx;
use crate::identity::PeerId;
use crate::multiaddr::SimAddr;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::VecDeque;

pub const DCUTR_PROTO: &str = "/lattica/dcutr/1";

const M_CONNECT: u64 = 1; // initiator → responder: my addrs
const M_SYNC: u64 = 2; // responder → initiator: my addrs, punch now

#[derive(Clone, Debug, Default, PartialEq)]
pub struct DcutrMsg {
    pub kind: u64,
    pub host: u32,
    pub port: u32,
}

impl Message for DcutrMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.uint(2, self.host as u64);
        w.uint(3, self.port as u64);
    }

    fn decode(buf: &[u8]) -> Result<DcutrMsg> {
        let mut m = DcutrMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.host = f.as_u64() as u32,
                3 => m.port = f.as_u64() as u32,
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

#[derive(Debug)]
pub enum DcutrEvent {
    /// Both sides agreed; the swarm punch has been started on `conn`.
    PunchStarted { conn: u64, peer: PeerId },
}

#[derive(Default)]
pub struct Dcutr {
    events: VecDeque<DcutrEvent>,
}

impl Dcutr {
    pub fn new() -> Dcutr {
        Dcutr::default()
    }

    pub fn poll_event(&mut self) -> Option<DcutrEvent> {
        self.events.pop_front()
    }

    fn best_external(ctx: &Ctx) -> Option<SimAddr> {
        ctx.swarm.external_addrs.first().copied()
    }

    /// Initiate an upgrade on relayed connection `conn` to `peer`.
    pub fn upgrade(&mut self, ctx: &mut Ctx, conn: u64, peer: &PeerId) -> Result<()> {
        let ext = Self::best_external(ctx)
            .ok_or_else(|| anyhow::anyhow!("no observed external address yet"))?;
        let (cid, stream) = {
            let stream = ctx.swarm.open_stream_on(ctx.net, conn, DCUTR_PROTO)?;
            (conn, stream)
        };
        let msg = DcutrMsg {
            kind: M_CONNECT,
            host: ext.host,
            port: ext.port as u32,
        };
        ctx.send(cid, stream, &msg.encode())?;
        let _ = peer;
        Ok(())
    }

    /// Inbound dcutr message on connection `conn`.
    pub fn handle_msg(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        conn: u64,
        stream: u64,
        msg: &[u8],
    ) -> Result<()> {
        let m = DcutrMsg::decode(msg)?;
        let their_addr = SimAddr::new(m.host, m.port as u16);
        match m.kind {
            M_CONNECT => {
                // Responder: reply with our address, then punch.
                if let Some(ext) = Self::best_external(ctx) {
                    let reply = DcutrMsg {
                        kind: M_SYNC,
                        host: ext.host,
                        port: ext.port as u32,
                    };
                    ctx.send(conn, stream, &reply.encode())?;
                    ctx.finish(conn, stream);
                }
                if ctx.swarm.start_punch(ctx.net, conn, their_addr).is_ok() {
                    self.events.push_back(DcutrEvent::PunchStarted { conn, peer });
                }
            }
            M_SYNC => {
                // Initiator: punch now.
                if ctx.swarm.start_punch(ctx.net, conn, their_addr).is_ok() {
                    self.events.push_back(DcutrEvent::PunchStarted { conn, peer });
                }
            }
            _ => {}
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip() {
        let m = DcutrMsg {
            kind: M_SYNC,
            host: 3,
            port: 54321,
        };
        assert_eq!(DcutrMsg::decode(&m.encode()).unwrap(), m);
    }
}
