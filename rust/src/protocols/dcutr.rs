//! DCUtR (`/lattica/dcutr/1`): Direct Connection Upgrade through Relay.
//!
//! Runs over a *relayed* connection: the two sides exchange their observed
//! public addresses and a synchronization point, then both call
//! [`crate::swarm::Swarm::start_punch`] simultaneously. The swarm handles
//! path probing and migration; this protocol is the coordination layer.
//!
//! Failure is explicit: a responder that cannot punch (no observed external
//! address yet) replies `DENY` instead of going silent, and the initiator
//! arms a deadline per upgrade attempt — either way the attempt ends in a
//! [`DcutrEvent::PunchFailed`] and the connection cleanly stays relayed.

use super::Ctx;
use crate::identity::PeerId;
use crate::multiaddr::SimAddr;
use crate::netsim::{Time, SECOND};
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::VecDeque;

pub const DCUTR_PROTO: &str = "/lattica/dcutr/1";

const M_CONNECT: u64 = 1; // initiator → responder: my addrs
const M_SYNC: u64 = 2; // responder → initiator: my addrs, punch now
const M_DENY: u64 = 3; // responder → initiator: cannot punch now, retry later

/// How long the initiator waits for the responder's SYNC (or DENY) before
/// declaring the upgrade attempt failed. Generous: the exchange is one
/// round trip through the relay.
pub const UPGRADE_TIMEOUT: Time = 3 * SECOND;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct DcutrMsg {
    pub kind: u64,
    pub host: u32,
    pub port: u32,
    /// DENY reason (diagnostic only).
    pub error: String,
}

impl Message for DcutrMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.uint(2, self.host as u64);
        w.uint(3, self.port as u64);
        w.string(4, &self.error);
    }

    fn decode(buf: &[u8]) -> Result<DcutrMsg> {
        let mut m = DcutrMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.host = f.as_u64() as u32,
                3 => m.port = f.as_u64() as u32,
                4 => m.error = f.as_string()?,
                _ => {}
            }
            Ok(())
        })?;
        // Ports ride the wire as varints; anything above the u16 range
        // would silently truncate at the punch site. Reject at decode.
        anyhow::ensure!(
            m.port <= u16::MAX as u32,
            "dcutr port {} out of range",
            m.port
        );
        Ok(m)
    }
}

#[derive(Debug)]
pub enum DcutrEvent {
    /// Both sides agreed; the swarm punch has been started on `conn`.
    PunchStarted { conn: u64, peer: PeerId },
    /// The upgrade ended without a punch (denied, no external address, or
    /// the responder never answered); the connection stays relayed.
    PunchFailed {
        conn: u64,
        peer: PeerId,
        reason: String,
    },
}

/// An initiator-side upgrade waiting for the responder's SYNC/DENY.
struct PendingUpgrade {
    conn: u64,
    peer: PeerId,
    deadline: Time,
}

#[derive(Default)]
pub struct Dcutr {
    events: VecDeque<DcutrEvent>,
    pending: Vec<PendingUpgrade>,
}

impl Dcutr {
    pub fn new() -> Dcutr {
        Dcutr::default()
    }

    pub fn poll_event(&mut self) -> Option<DcutrEvent> {
        self.events.pop_front()
    }

    fn best_external(ctx: &Ctx) -> Option<SimAddr> {
        ctx.swarm.external_addrs.first().copied()
    }

    fn resolve_pending(&mut self, conn: u64) -> bool {
        let before = self.pending.len();
        self.pending.retain(|p| p.conn != conn);
        self.pending.len() != before
    }

    /// Initiate an upgrade on relayed connection `conn` to `peer`.
    pub fn upgrade(&mut self, ctx: &mut Ctx, conn: u64, peer: &PeerId) -> Result<()> {
        let ext = Self::best_external(ctx)
            .ok_or_else(|| anyhow::anyhow!("no observed external address yet"))?;
        let (cid, stream) = {
            let stream = ctx.swarm.open_stream_on(ctx.net, conn, DCUTR_PROTO)?;
            (conn, stream)
        };
        let msg = DcutrMsg {
            kind: M_CONNECT,
            host: ext.host,
            port: ext.port as u32,
            ..Default::default()
        };
        ctx.send(cid, stream, &msg.encode())?;
        self.pending.push(PendingUpgrade {
            conn,
            peer: *peer,
            deadline: ctx.now() + UPGRADE_TIMEOUT,
        });
        Ok(())
    }

    /// Expire upgrade attempts whose responder never answered. Call from
    /// the node's protocol tick.
    pub fn tick(&mut self, now: Time) {
        let mut expired = Vec::new();
        self.pending.retain(|p| {
            if p.deadline <= now {
                expired.push((p.conn, p.peer));
                false
            } else {
                true
            }
        });
        for (conn, peer) in expired {
            self.events.push_back(DcutrEvent::PunchFailed {
                conn,
                peer,
                reason: "timed out waiting for responder sync".into(),
            });
        }
    }

    /// Inbound dcutr message on connection `conn`.
    pub fn handle_msg(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        conn: u64,
        stream: u64,
        msg: &[u8],
    ) -> Result<()> {
        let m = DcutrMsg::decode(msg)?;
        let their_addr = SimAddr::new(m.host, m.port as u16);
        match m.kind {
            M_CONNECT => {
                // Responder: reply with our address and punch — or, if we
                // have no observed external address yet, say so explicitly
                // so the initiator doesn't dead-end waiting for SYNC.
                match Self::best_external(ctx) {
                    Some(ext) => {
                        let reply = DcutrMsg {
                            kind: M_SYNC,
                            host: ext.host,
                            port: ext.port as u32,
                            ..Default::default()
                        };
                        ctx.send(conn, stream, &reply.encode())?;
                        ctx.finish(conn, stream);
                        if ctx.swarm.start_punch(ctx.net, conn, their_addr).is_ok() {
                            self.events.push_back(DcutrEvent::PunchStarted { conn, peer });
                        }
                    }
                    None => {
                        let reply = DcutrMsg {
                            kind: M_DENY,
                            error: "no observed external address".into(),
                            ..Default::default()
                        };
                        ctx.send(conn, stream, &reply.encode())?;
                        ctx.finish(conn, stream);
                        self.events.push_back(DcutrEvent::PunchFailed {
                            conn,
                            peer,
                            reason: "no observed external address".into(),
                        });
                    }
                }
            }
            M_SYNC => {
                // Initiator: punch now.
                self.resolve_pending(conn);
                if ctx.swarm.start_punch(ctx.net, conn, their_addr).is_ok() {
                    self.events.push_back(DcutrEvent::PunchStarted { conn, peer });
                }
            }
            M_DENY => {
                if self.resolve_pending(conn) {
                    self.events.push_back(DcutrEvent::PunchFailed {
                        conn,
                        peer,
                        reason: format!("denied by responder: {}", m.error),
                    });
                }
            }
            _ => {}
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip() {
        let m = DcutrMsg {
            kind: M_SYNC,
            host: 3,
            port: 54321,
            error: String::new(),
        };
        assert_eq!(DcutrMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn deny_roundtrip() {
        let m = DcutrMsg {
            kind: M_DENY,
            error: "no observed external address".into(),
            ..Default::default()
        };
        assert_eq!(DcutrMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn oversized_port_rejected_at_decode() {
        // A varint port above u16::MAX used to truncate silently at the
        // punch site (`as u16`); it must be rejected at decode instead.
        let m = DcutrMsg {
            kind: M_CONNECT,
            host: 3,
            port: 70_000,
            ..Default::default()
        };
        assert!(DcutrMsg::decode(&m.encode()).is_err());
    }

    #[test]
    fn timeout_emits_punch_failed() {
        let mut d = Dcutr::new();
        d.pending.push(PendingUpgrade {
            conn: 7,
            peer: PeerId([9; 32]),
            deadline: 100,
        });
        d.tick(50);
        assert!(d.poll_event().is_none());
        d.tick(100);
        match d.poll_event() {
            Some(DcutrEvent::PunchFailed { conn: 7, .. }) => {}
            other => panic!("expected PunchFailed, got {other:?}"),
        }
        assert!(d.pending.is_empty());
    }
}
