//! Kademlia DHT: maintenance-complete k-bucket routing, iterative lookups,
//! provider records and a replicated key→value record store.
//!
//! Protocol `/lattica/kad/1`: one stream per request; the responder answers
//! on the same stream and finishes it. Queries run `ALPHA` probes in
//! parallel over the k-closest candidate set, converging in O(log N) hops
//! (measured by `benches/dht_lookup`).
//!
//! Churn hardening (DESIGN.md §Discovery & churn):
//! * 256 k-buckets (k = [`K`]) in least-recently-seen order. A full bucket
//!   never drops a live entry for a new one: the oldest entry is
//!   liveness-probed first and only evicted if it fails to answer
//!   (Maymounkov–Mazières eviction rule). Entries that already failed a
//!   request are evicted preferentially.
//! * Stale buckets are refreshed by lookups of random keys in their range,
//!   plus a periodic self-lookup.
//! * Provider/record stores expire by TTL; locally-published keys are
//!   republished to the *current* k-closest peers every
//!   [`REPUBLISH_INTERVAL`], so records follow the live topology.
//! * In-flight requests time out per-peer and fail over to the
//!   next-closest candidate; dial failures and closed connections fail
//!   waiting queries immediately instead of stalling to the timeout.

use super::Ctx;
use crate::identity::PeerId;
use crate::multiaddr::{Multiaddr, Proto, SimAddr};
use crate::netsim::{Time, SECOND};
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

pub const KAD_PROTO: &str = "/lattica/kad/1";

/// Replication factor (bucket size and lookup breadth).
pub const K: usize = 20;
/// Lookup parallelism.
pub const ALPHA: usize = 3;
/// Per-request timeout (also the liveness-probe timeout).
pub const REQUEST_TIMEOUT: Time = 5 * SECOND;
/// Request failures before a routing entry is dropped outright.
pub const MAX_FAILS: u32 = 2;
/// Default TTL for provider records.
pub const PROVIDER_TTL: Time = 60 * SECOND;
/// Default TTL for key→value records.
pub const RECORD_TTL: Time = 60 * SECOND;
/// Default republish period for locally-published keys.
pub const REPUBLISH_INTERVAL: Time = 12 * SECOND;
/// Default stale-bucket refresh period (also the self-lookup period).
pub const BUCKET_REFRESH_INTERVAL: Time = 30 * SECOND;
/// Stale-bucket refresh lookups started per tick at most.
const MAX_REFRESH_PER_TICK: usize = 2;
/// Maintenance refreshes pause above this many concurrent queries.
const MAX_MAINTENANCE_QUERIES: usize = 8;

/// Wire message kinds — public so lightweight responders (e.g. the
/// planet-scale background nodes in `scenarios::planet`) can speak the
/// protocol without a full `Kad` instance.
pub const M_FIND_NODE: u64 = 1;
pub const M_GET_PROVIDERS: u64 = 2;
pub const M_ADD_PROVIDER: u64 = 3;
pub const M_PUT_RECORD: u64 = 4;
pub const M_GET_RECORD: u64 = 5;
pub const M_REPLY: u64 = 6;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct PeerEntry {
    pub id: PeerId,
    pub host: u32,
    pub port: u16,
}

impl PeerEntry {
    pub fn to_multiaddr(&self) -> Multiaddr {
        Multiaddr::direct(SimAddr::new(self.host, self.port), Proto::QuicLike).with_peer(self.id)
    }
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct KadMsg {
    pub kind: u64,
    pub key: Vec<u8>,
    /// REPLY: closer peers.
    pub closer: Vec<PeerEntry>,
    /// REPLY: providers of `key`.
    pub providers: Vec<PeerEntry>,
    /// PUT_RECORD / REPLY: record value.
    pub value: Vec<u8>,
    /// REPLY: whether a record was found.
    pub found: bool,
    /// ADD_PROVIDER: the provider's reachable endpoint.
    pub provider: Option<PeerEntry>,
}

fn encode_entry(w: &mut PbWriter, field: u32, e: &PeerEntry) {
    let mut inner = PbWriter::new();
    inner.bytes_always(1, e.id.as_bytes());
    inner.uint(2, e.host as u64);
    inner.uint(3, e.port as u64);
    w.bytes_always(field, &inner.finish());
}

fn decode_entry(buf: &[u8]) -> Result<PeerEntry> {
    let mut e = PeerEntry::default();
    PbReader::new(buf).for_each(|f| {
        match f.number {
            1 => {
                let b = f.as_bytes()?;
                anyhow::ensure!(b.len() == 32, "bad peer id");
                let mut d = [0u8; 32];
                d.copy_from_slice(b);
                e.id = PeerId(d);
            }
            2 => e.host = f.as_u64() as u32,
            3 => e.port = f.as_u64() as u16,
            _ => {}
        }
        Ok(())
    })?;
    Ok(e)
}

impl Message for KadMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.bytes(2, &self.key);
        for e in &self.closer {
            encode_entry(w, 3, e);
        }
        for e in &self.providers {
            encode_entry(w, 4, e);
        }
        w.bytes(5, &self.value);
        w.boolean(6, self.found);
        if let Some(p) = &self.provider {
            encode_entry(w, 7, p);
        }
    }

    fn decode(buf: &[u8]) -> Result<KadMsg> {
        let mut m = KadMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.key = f.as_bytes()?.to_vec(),
                3 => m.closer.push(decode_entry(f.as_bytes()?)?),
                4 => m.providers.push(decode_entry(f.as_bytes()?)?),
                5 => m.value = f.as_bytes()?.to_vec(),
                6 => m.found = f.as_bool(),
                7 => m.provider = Some(decode_entry(f.as_bytes()?)?),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

// ---------------------------------------------------------------------------
// Routing table
// ---------------------------------------------------------------------------

/// One routing entry with liveness bookkeeping.
#[derive(Clone, Debug)]
pub struct BucketEntry {
    pub entry: PeerEntry,
    /// Virtual time of the last direct evidence of liveness.
    pub last_seen: Time,
    /// Consecutive request failures since `last_seen`.
    pub fails: u32,
}

#[derive(Clone, Debug, Default)]
struct Bucket {
    /// Least-recently-seen first (index 0 is the LRU eviction candidate).
    entries: Vec<BucketEntry>,
    /// Last time a lookup landed in this bucket's key range.
    last_refresh: Time,
}

/// What [`RoutingTable::insert`] did with a new contact.
#[derive(Clone, Debug, PartialEq)]
pub enum InsertOutcome {
    /// New entry added (a failed entry may have been evicted to make room).
    Added,
    /// Known peer: address/liveness refreshed, moved to MRU position.
    Refreshed,
    /// Self or un-indexable: dropped.
    Ignored,
    /// Bucket full of apparently-live entries. The caller should liveness-
    /// probe `oldest` and only evict it if the probe fails.
    Full { bucket: usize, oldest: PeerEntry },
}

/// 256-bucket XOR routing table with k-sized buckets in LRU order.
pub struct RoutingTable {
    pub local: PeerId,
    buckets: Vec<Bucket>,
}

impl RoutingTable {
    pub fn new(local: PeerId) -> RoutingTable {
        RoutingTable {
            local,
            buckets: vec![Bucket::default(); 256],
        }
    }

    /// Offer a contact. Never inserts the local peer and never silently
    /// drops a live entry: a full bucket reports `Full` so the caller can
    /// gate eviction on a liveness probe of the oldest entry.
    pub fn insert(&mut self, entry: PeerEntry, now: Time) -> InsertOutcome {
        if entry.id == self.local {
            return InsertOutcome::Ignored;
        }
        let Some(idx) = self.local.bucket_index(&entry.id) else {
            return InsertOutcome::Ignored;
        };
        let b = &mut self.buckets[idx].entries;
        if let Some(pos) = b.iter().position(|e| e.entry.id == entry.id) {
            let mut e = b.remove(pos);
            e.entry.host = entry.host;
            e.entry.port = entry.port;
            e.last_seen = now;
            e.fails = 0;
            b.push(e);
            return InsertOutcome::Refreshed;
        }
        if b.len() < K {
            b.push(BucketEntry { entry, last_seen: now, fails: 0 });
            return InsertOutcome::Added;
        }
        // Full bucket: prefer evicting an entry that already failed a
        // request over probing — dead peers go before fresh ones.
        let mut worst: Option<(u32, usize)> = None;
        for (i, e) in b.iter().enumerate() {
            let better = match worst {
                None => e.fails > 0,
                Some((f, _)) => e.fails > f,
            };
            if better {
                worst = Some((e.fails, i));
            }
        }
        if let Some((_, w)) = worst {
            b.remove(w);
            b.push(BucketEntry { entry, last_seen: now, fails: 0 });
            return InsertOutcome::Added;
        }
        InsertOutcome::Full {
            bucket: idx,
            oldest: b[0].entry.clone(),
        }
    }

    pub fn remove(&mut self, id: &PeerId) {
        if let Some(idx) = self.local.bucket_index(id) {
            self.buckets[idx].entries.retain(|e| e.entry.id != *id);
        }
    }

    /// Direct evidence the peer is alive: reset fails, move to MRU.
    pub fn mark_alive(&mut self, id: &PeerId, now: Time) {
        if let Some(idx) = self.local.bucket_index(id) {
            let b = &mut self.buckets[idx].entries;
            if let Some(pos) = b.iter().position(|e| e.entry.id == *id) {
                let mut e = b.remove(pos);
                e.last_seen = now;
                e.fails = 0;
                b.push(e);
            }
        }
    }

    /// A request to the peer failed; drop it after [`MAX_FAILS`] strikes.
    /// Returns true if the entry was removed.
    pub fn mark_failed(&mut self, id: &PeerId) -> bool {
        let Some(idx) = self.local.bucket_index(id) else { return false };
        let b = &mut self.buckets[idx].entries;
        if let Some(pos) = b.iter().position(|e| e.entry.id == *id) {
            b[pos].fails += 1;
            if b[pos].fails >= MAX_FAILS {
                b.remove(pos);
                return true;
            }
        }
        false
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `n` entries closest to `key` by XOR distance.
    pub fn closest(&self, key: &[u8; 32], n: usize) -> Vec<PeerEntry> {
        let mut all: Vec<&PeerEntry> = self.entries().map(|e| &e.entry).collect();
        all.sort_by_key(|e| xor_distance(e.id.as_bytes(), key));
        all.into_iter().take(n).cloned().collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &PeerEntry> {
        self.entries().map(|e| &e.entry)
    }

    /// All entries with their liveness bookkeeping.
    pub fn entries(&self) -> impl Iterator<Item = &BucketEntry> {
        self.buckets.iter().flat_map(|b| b.entries.iter())
    }

    /// Number of entries in bucket `idx`.
    pub fn bucket_len(&self, idx: usize) -> usize {
        self.buckets[idx].entries.len()
    }

    /// Bucket a key falls into relative to the local id (None = own key).
    pub fn bucket_of(&self, key: &[u8; 32]) -> Option<usize> {
        self.local.bucket_index(&PeerId(*key))
    }

    /// Record that a lookup landed in bucket `idx` (refresh bookkeeping).
    pub fn touch_refresh(&mut self, idx: usize, now: Time) {
        self.buckets[idx].last_refresh = now;
    }

    /// Non-empty buckets whose key range has not seen a lookup within
    /// `interval`.
    pub fn stale_buckets(&self, now: Time, interval: Time) -> Vec<usize> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| {
                !b.entries.is_empty() && now.saturating_sub(b.last_refresh) >= interval
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// A uniformly random key whose XOR distance to the local id falls in
    /// bucket `idx` (used for stale-bucket refresh lookups).
    pub fn random_key_in_bucket(&self, idx: usize, rng: &mut crate::util::Rng) -> [u8; 32] {
        let mut key = *self.local.as_bytes();
        let byte = (255 - idx) / 8;
        let bit = 7 - ((255 - idx) % 8); // bit position within the byte, LSB = 0
        key[byte] ^= 1 << bit;
        let low_mask: u8 = if bit == 0 { 0 } else { (1u8 << bit) - 1 };
        key[byte] = (key[byte] & !low_mask) | ((rng.next_u32() as u8) & low_mask);
        for b in key.iter_mut().skip(byte + 1) {
            *b = rng.next_u32() as u8;
        }
        key
    }
}

pub fn xor_distance(a: &[u8; 32], b: &[u8; 32]) -> [u8; 32] {
    let mut d = [0u8; 32];
    for i in 0..32 {
        d[i] = a[i] ^ b[i];
    }
    d
}

// ---------------------------------------------------------------------------
// Query engine
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryKind {
    FindNode,
    GetProviders,
    GetRecord,
}

/// A completed query's outcome.
#[derive(Debug)]
pub enum KadEvent {
    QueryFinished {
        query_id: u64,
        key: [u8; 32],
        kind: QueryKind,
        closest: Vec<PeerEntry>,
        providers: Vec<PeerEntry>,
        record: Option<Vec<u8>>,
        /// Hops = number of answered requests (O(log N) check).
        hops: u32,
    },
    /// Routing table learned a new peer.
    RoutingUpdated { peer: PeerId },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum CandState {
    /// Not yet contacted.
    Fresh,
    /// Request in flight (or waiting behind a dial).
    Waiting,
    Responded,
    Failed,
}

/// One tracked request within a query.
#[derive(Clone, Copy, Debug)]
struct InflightReq {
    /// Stream carrying the request once the connection is up.
    stream: Option<(u64, u64)>,
    deadline: Time,
}

/// Payload pushed to the k-closest peers when an announce query finishes.
#[derive(Clone, Debug)]
enum Announce {
    Provider,
    Record(Vec<u8>),
}

struct Query {
    kind: QueryKind,
    key: [u8; 32],
    /// Candidates sorted by XOR distance to `key`.
    candidates: Vec<(PeerEntry, CandState)>,
    /// Per-peer in-flight requests (covers dial-pending sends too, so a
    /// request waiting on a dead dial still times out and fails over).
    inflight: BTreeMap<PeerId, InflightReq>,
    providers: Vec<PeerEntry>,
    record: Option<Vec<u8>>,
    hops: u32,
    /// Stop early once providers/record found.
    early_exit: bool,
    /// Publish this to the discovered k-closest set on completion
    /// (provide/put_record run as FIND_NODE + announce, so records land on
    /// the *current* closest peers even as the topology churns).
    announce: Option<Announce>,
}

/// A liveness probe of a full bucket's oldest entry, gating LRU eviction.
struct Probe {
    bucket: usize,
    target: PeerId,
    /// The contact that wants the slot if `target` turns out dead.
    candidate: PeerEntry,
    stream: Option<(u64, u64)>,
    deadline: Time,
}

/// What a queued/in-flight request belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SendRef {
    /// Fire-and-forget (ADD_PROVIDER / PUT_RECORD).
    Free,
    Query(u64),
    Probe(u64),
}

/// Maintenance and traffic counters (aggregated by the churn bench).
#[derive(Clone, Debug, Default)]
pub struct KadStats {
    /// Query requests registered for tracking (sent or dial-pending) —
    /// the staleness denominator.
    pub requests_tracked: u64,
    /// Requests actually written to a stream (includes liveness probes).
    pub requests_sent: u64,
    pub replies: u64,
    pub requests_timed_out: u64,
    pub requests_failed: u64,
    pub probes_sent: u64,
    pub probes_ok: u64,
    pub probes_evicted: u64,
    pub refreshes: u64,
    pub republish_rounds: u64,
    pub providers_expired: u64,
    pub records_expired: u64,
    /// Wire bytes of every kad message sent (requests and replies) — the
    /// DHT share of the control-plane ratio (DESIGN.md §Control-plane
    /// compression).
    pub bytes_sent: u64,
}

impl KadStats {
    /// Accumulate another node's counters (scenario-wide aggregation).
    pub fn merge(&mut self, o: &KadStats) {
        self.requests_tracked += o.requests_tracked;
        self.requests_sent += o.requests_sent;
        self.replies += o.replies;
        self.requests_timed_out += o.requests_timed_out;
        self.requests_failed += o.requests_failed;
        self.probes_sent += o.probes_sent;
        self.probes_ok += o.probes_ok;
        self.probes_evicted += o.probes_evicted;
        self.refreshes += o.refreshes;
        self.republish_rounds += o.republish_rounds;
        self.providers_expired += o.providers_expired;
        self.records_expired += o.records_expired;
        self.bytes_sent += o.bytes_sent;
    }

    /// Share of tracked requests that hit a dead/stale peer (timed out or
    /// failed before delivery).
    pub fn staleness(&self) -> f64 {
        let bad = self.requests_timed_out + self.requests_failed;
        if self.requests_tracked == 0 {
            0.0
        } else {
            bad as f64 / self.requests_tracked as f64
        }
    }
}

/// A provider record with expiry.
#[derive(Clone, Debug)]
pub struct ProviderRecord {
    pub entry: PeerEntry,
    pub expires: Time,
}

/// A stored key→value record with expiry.
#[derive(Clone, Debug)]
pub struct StoredRecord {
    pub value: Vec<u8>,
    pub expires: Time,
}

/// The Kademlia behaviour.
pub struct Kademlia {
    pub table: RoutingTable,
    /// Local provider records: key → providers (TTL-expired).
    pub provider_store: BTreeMap<[u8; 32], Vec<ProviderRecord>>,
    /// Local record store (TTL-expired).
    pub record_store: BTreeMap<[u8; 32], StoredRecord>,
    /// This node's advertised endpoint.
    pub local_entry: PeerEntry,
    /// Maintenance tuning (defaults from the module consts; benches and
    /// tests tighten these for short virtual-time runs).
    pub provider_ttl: Time,
    pub record_ttl: Time,
    pub republish_interval: Time,
    pub refresh_interval: Time,
    pub stats: KadStats,
    queries: BTreeMap<u64, Query>,
    next_query_id: u64,
    probes: BTreeMap<u64, Probe>,
    next_probe_id: u64,
    /// Bucket index → outstanding probe id (one eviction probe per bucket).
    probe_by_bucket: BTreeMap<usize, u64>,
    /// Keys we provide and must republish.
    published_provides: BTreeSet<[u8; 32]>,
    /// Keys whose records we published and must republish.
    published_records: BTreeSet<[u8; 32]>,
    next_republish: Time,
    next_self_refresh: Time,
    /// Requests awaiting a connection to the peer.
    pending_sends: Vec<(PeerId, KadMsg, SendRef)>,
    events: VecDeque<KadEvent>,
}

impl Kademlia {
    pub fn new(local: PeerId, host: u32, port: u16) -> Kademlia {
        Kademlia {
            table: RoutingTable::new(local),
            provider_store: BTreeMap::new(),
            record_store: BTreeMap::new(),
            local_entry: PeerEntry { id: local, host, port },
            provider_ttl: PROVIDER_TTL,
            record_ttl: RECORD_TTL,
            republish_interval: REPUBLISH_INTERVAL,
            refresh_interval: BUCKET_REFRESH_INTERVAL,
            stats: KadStats::default(),
            queries: BTreeMap::new(),
            next_query_id: 1,
            probes: BTreeMap::new(),
            next_probe_id: 1,
            probe_by_bucket: BTreeMap::new(),
            published_provides: BTreeSet::new(),
            published_records: BTreeSet::new(),
            next_republish: REPUBLISH_INTERVAL,
            next_self_refresh: BUCKET_REFRESH_INTERVAL,
            pending_sends: Vec::new(),
            events: VecDeque::new(),
        }
    }

    pub fn poll_event(&mut self) -> Option<KadEvent> {
        self.events.pop_front()
    }

    /// Change the republish period; the next republish round becomes due
    /// immediately (next tick) so the new cadence takes effect promptly.
    pub fn set_republish_interval(&mut self, interval: Time) {
        self.republish_interval = interval;
        self.next_republish = 0;
    }

    pub fn active_queries(&self) -> usize {
        self.queries.len()
    }

    /// Add a bootstrap/learned peer.
    pub fn add_address(&mut self, ctx: &mut Ctx, entry: PeerEntry) {
        ctx.swarm
            .peerstore
            .add_address(entry.id, entry.to_multiaddr());
        self.observe(ctx, entry);
    }

    /// Offer a contact to the routing table, gating full-bucket eviction on
    /// a liveness probe of the bucket's oldest entry.
    fn observe(&mut self, ctx: &mut Ctx, entry: PeerEntry) {
        if entry.id == self.table.local {
            return;
        }
        let now = ctx.now();
        match self.table.insert(entry.clone(), now) {
            InsertOutcome::Added => {
                self.events
                    .push_back(KadEvent::RoutingUpdated { peer: entry.id });
            }
            InsertOutcome::Refreshed | InsertOutcome::Ignored => {}
            InsertOutcome::Full { bucket, oldest } => {
                if let Some(&pid) = self.probe_by_bucket.get(&bucket) {
                    // Probe already running: remember the freshest candidate.
                    if let Some(p) = self.probes.get_mut(&pid) {
                        p.candidate = entry;
                    }
                } else {
                    self.start_probe(ctx, bucket, oldest, entry);
                }
            }
        }
    }

    fn start_probe(&mut self, ctx: &mut Ctx, bucket: usize, oldest: PeerEntry, candidate: PeerEntry) {
        let pid = self.next_probe_id;
        self.next_probe_id += 1;
        self.stats.probes_sent += 1;
        self.probes.insert(
            pid,
            Probe {
                bucket,
                target: oldest.id,
                candidate,
                stream: None,
                deadline: ctx.now() + REQUEST_TIMEOUT,
            },
        );
        self.probe_by_bucket.insert(bucket, pid);
        let key = *self.table.local.as_bytes();
        let msg = Self::request_msg(QueryKind::FindNode, &key);
        self.send_request(ctx, oldest.id, msg, SendRef::Probe(pid));
    }

    /// Probe came back: the oldest entry is alive — keep it, drop candidate.
    fn probe_succeeded(&mut self, ctx: &mut Ctx, pid: u64) {
        let Some(p) = self.probes.remove(&pid) else { return };
        self.probe_by_bucket.remove(&p.bucket);
        self.stats.probes_ok += 1;
        self.table.mark_alive(&p.target, ctx.now());
    }

    /// Probe failed: evict the dead oldest entry, admit the candidate.
    fn probe_failed(&mut self, ctx: &mut Ctx, pid: u64) {
        let Some(p) = self.probes.remove(&pid) else { return };
        self.probe_by_bucket.remove(&p.bucket);
        self.stats.probes_evicted += 1;
        self.table.remove(&p.target);
        if let InsertOutcome::Added = self.table.insert(p.candidate.clone(), ctx.now()) {
            self.events
                .push_back(KadEvent::RoutingUpdated { peer: p.candidate.id });
        }
    }

    /// Start an iterative FIND_NODE (also used for table refresh).
    pub fn find_node(&mut self, ctx: &mut Ctx, key: [u8; 32]) -> u64 {
        self.start_query(ctx, QueryKind::FindNode, key, false, None)
    }

    /// Find providers for a CID key.
    pub fn get_providers(&mut self, ctx: &mut Ctx, key: [u8; 32]) -> u64 {
        self.start_query(ctx, QueryKind::GetProviders, key, true, None)
    }

    /// Fetch a record.
    pub fn get_record(&mut self, ctx: &mut Ctx, key: [u8; 32]) -> u64 {
        self.start_query(ctx, QueryKind::GetRecord, key, true, None)
    }

    /// Announce ourselves as a provider: locate the current k-closest peers
    /// with a lookup, push ADD_PROVIDER to them, and keep re-announcing
    /// every [`Kademlia::republish_interval`].
    pub fn provide(&mut self, ctx: &mut Ctx, key: [u8; 32]) {
        self.published_provides.insert(key);
        self.announce_provider(ctx, key);
    }

    /// One-shot provider announce that is NOT enrolled for periodic
    /// republish — bulk keys (blob chunks) use this so a publish doesn't
    /// accumulate unbounded background republish load; the record simply
    /// expires at TTL unless re-announced.
    pub fn provide_once(&mut self, ctx: &mut Ctx, key: [u8; 32]) {
        self.announce_provider(ctx, key);
    }

    /// Stop republishing `key` and drop our own local provider record.
    pub fn stop_providing(&mut self, key: [u8; 32]) {
        self.published_provides.remove(&key);
        let local = self.local_entry.id;
        if let Some(list) = self.provider_store.get_mut(&key) {
            list.retain(|r| r.entry.id != local);
            if list.is_empty() {
                self.provider_store.remove(&key);
            }
        }
    }

    fn announce_provider(&mut self, ctx: &mut Ctx, key: [u8; 32]) {
        let now = ctx.now();
        let me = self.local_entry.clone();
        let ttl = self.provider_ttl;
        let list = self.provider_store.entry(key).or_default();
        list.retain(|r| r.entry.id != me.id);
        list.push(ProviderRecord { entry: me, expires: now + ttl });
        self.start_query(ctx, QueryKind::FindNode, key, false, Some(Announce::Provider));
    }

    /// Store a record on the k closest peers (and locally), republishing
    /// every [`Kademlia::republish_interval`].
    pub fn put_record(&mut self, ctx: &mut Ctx, key: [u8; 32], value: Vec<u8>) {
        self.published_records.insert(key);
        self.announce_record(ctx, key, value);
    }

    fn announce_record(&mut self, ctx: &mut Ctx, key: [u8; 32], value: Vec<u8>) {
        let now = ctx.now();
        self.record_store.insert(
            key,
            StoredRecord {
                value: value.clone(),
                expires: now + self.record_ttl,
            },
        );
        self.start_query(ctx, QueryKind::FindNode, key, false, Some(Announce::Record(value)));
    }

    fn start_query(
        &mut self,
        ctx: &mut Ctx,
        kind: QueryKind,
        key: [u8; 32],
        early: bool,
        announce: Option<Announce>,
    ) -> u64 {
        let id = self.next_query_id;
        self.next_query_id += 1;
        if let Some(b) = self.table.bucket_of(&key) {
            self.table.touch_refresh(b, ctx.now());
        }
        let candidates: Vec<(PeerEntry, CandState)> = self
            .table
            .closest(&key, K)
            .into_iter()
            .map(|e| (e, CandState::Fresh))
            .collect();
        let mut q = Query {
            kind,
            key,
            candidates,
            inflight: BTreeMap::new(),
            providers: Vec::new(),
            record: None,
            hops: 0,
            early_exit: early,
            announce,
        };
        // Check the local stores first.
        let now = ctx.now();
        if kind == QueryKind::GetProviders {
            if let Some(p) = self.provider_store.get(&key) {
                q.providers
                    .extend(p.iter().filter(|r| r.expires > now).map(|r| r.entry.clone()));
            }
        }
        if kind == QueryKind::GetRecord {
            q.record = self
                .record_store
                .get(&key)
                .filter(|r| r.expires > now)
                .map(|r| r.value.clone());
        }
        self.queries.insert(id, q);
        self.advance_query(ctx, id);
        id
    }

    fn request_msg(kind: QueryKind, key: &[u8; 32]) -> KadMsg {
        KadMsg {
            kind: match kind {
                QueryKind::FindNode => M_FIND_NODE,
                QueryKind::GetProviders => M_GET_PROVIDERS,
                QueryKind::GetRecord => M_GET_RECORD,
            },
            key: key.to_vec(),
            ..Default::default()
        }
    }

    /// Drive a query: issue up to α requests over the closest K non-failed
    /// candidates; finish when they have all answered (or the early-exit
    /// condition hit) and nothing is in flight.
    fn advance_query(&mut self, ctx: &mut Ctx, qid: u64) {
        let now = ctx.now();
        let Some(q) = self.queries.get_mut(&qid) else { return };
        let done_early =
            q.early_exit && (!q.providers.is_empty() || q.record.is_some()) && q.hops > 0;
        let mut to_send: Vec<PeerEntry> = Vec::new();
        if !done_early {
            let mut within_k = 0usize;
            for (e, st) in q.candidates.iter_mut() {
                if within_k >= K {
                    break;
                }
                match st {
                    CandState::Failed => continue,
                    CandState::Responded | CandState::Waiting => within_k += 1,
                    CandState::Fresh => {
                        within_k += 1;
                        if q.inflight.len() + to_send.len() < ALPHA {
                            *st = CandState::Waiting;
                            to_send.push(e.clone());
                        }
                    }
                }
            }
            // Register in-flight state up front so re-entrant failures
            // during the sends below can't mis-detect completion.
            for e in &to_send {
                q.inflight.insert(
                    e.id,
                    InflightReq {
                        stream: None,
                        deadline: now + REQUEST_TIMEOUT,
                    },
                );
            }
            self.stats.requests_tracked += to_send.len() as u64;
        }
        // An early-exit hit finishes at once: outstanding requests are
        // abandoned (late replies to a dead query are ignored), so a
        // provider lookup is never held hostage by one slow/dead peer.
        let finished = done_early || (q.inflight.is_empty() && to_send.is_empty());
        let kind = q.kind;
        let key = q.key;
        if finished {
            // Drop any dial-pending sends still referencing this query so
            // a late ConnEstablished doesn't replay an orphaned request.
            self.pending_sends
                .retain(|(_, _, r)| *r != SendRef::Query(qid));
            let mut q = self.queries.remove(&qid).unwrap();
            let mut closest: Vec<PeerEntry> = q
                .candidates
                .into_iter()
                .filter(|(_, st)| *st != CandState::Failed)
                .map(|(e, _)| e)
                .collect();
            closest.sort_by_key(|e| xor_distance(e.id.as_bytes(), &key));
            closest.truncate(K);
            // Announce queries: push the record to the freshly-discovered
            // k-closest set.
            if let Some(a) = q.announce.take() {
                let msg = match a {
                    Announce::Provider => KadMsg {
                        kind: M_ADD_PROVIDER,
                        key: key.to_vec(),
                        provider: Some(self.local_entry.clone()),
                        ..Default::default()
                    },
                    Announce::Record(value) => KadMsg {
                        kind: M_PUT_RECORD,
                        key: key.to_vec(),
                        value,
                        ..Default::default()
                    },
                };
                for target in &closest {
                    self.send_request(ctx, target.id, msg.clone(), SendRef::Free);
                }
            }
            self.events.push_back(KadEvent::QueryFinished {
                query_id: qid,
                key,
                kind,
                closest,
                providers: q.providers,
                record: q.record,
                hops: q.hops,
            });
            return;
        }
        for e in to_send {
            let msg = Self::request_msg(kind, &key);
            self.send_request(ctx, e.id, msg, SendRef::Query(qid));
        }
    }

    /// Send a request, dialing first if necessary. Tracked requests
    /// (queries/probes) must already hold their deadline state; this only
    /// attaches the stream or reports failure.
    fn send_request(&mut self, ctx: &mut Ctx, peer: PeerId, msg: KadMsg, sref: SendRef) {
        if peer == self.table.local {
            self.fail_ref(ctx, sref, peer);
            return;
        }
        let oneway = matches!(msg.kind, M_ADD_PROVIDER | M_PUT_RECORD);
        match ctx.ensure_connected(&peer) {
            Ok(true) => match ctx.open_stream(&peer, KAD_PROTO) {
                Ok((cid, stream)) => {
                    let wire = msg.encode();
                    if ctx.send(cid, stream, &wire).is_ok() {
                        self.stats.bytes_sent += wire.len() as u64;
                    }
                    if oneway {
                        ctx.finish(cid, stream);
                    } else {
                        self.stats.requests_sent += 1;
                        self.attach_stream(sref, peer, cid, stream);
                    }
                }
                Err(_) => self.fail_ref(ctx, sref, peer),
            },
            Ok(false) => {
                // Dial in flight: queue for ConnEstablished / DialFailed.
                self.pending_sends.push((peer, msg, sref));
            }
            Err(_) => self.fail_ref(ctx, sref, peer),
        }
    }

    fn attach_stream(&mut self, sref: SendRef, peer: PeerId, cid: u64, stream: u64) {
        match sref {
            SendRef::Query(qid) => {
                if let Some(i) = self
                    .queries
                    .get_mut(&qid)
                    .and_then(|q| q.inflight.get_mut(&peer))
                {
                    i.stream = Some((cid, stream));
                }
            }
            SendRef::Probe(pid) => {
                if let Some(p) = self.probes.get_mut(&pid) {
                    p.stream = Some((cid, stream));
                }
            }
            SendRef::Free => {}
        }
    }

    /// A tracked request can't be delivered: fail over immediately.
    fn fail_ref(&mut self, ctx: &mut Ctx, sref: SendRef, peer: PeerId) {
        match sref {
            SendRef::Free => {}
            SendRef::Query(qid) => self.fail_query_peer(ctx, qid, peer),
            SendRef::Probe(pid) => self.probe_failed(ctx, pid),
        }
    }

    /// Mark a query's candidate failed and re-issue to the next-closest
    /// candidate (the churn failover path).
    fn fail_query_peer(&mut self, ctx: &mut Ctx, qid: u64, peer: PeerId) {
        let Some(q) = self.queries.get_mut(&qid) else { return };
        if q.inflight.remove(&peer).is_some() {
            self.stats.requests_failed += 1;
        }
        if let Some(c) = q.candidates.iter_mut().find(|(e, _)| e.id == peer) {
            c.1 = CandState::Failed;
        }
        self.advance_query(ctx, qid);
    }

    /// Node hook: a connection to `peer` is up — flush queued requests.
    pub fn on_peer_connected(&mut self, ctx: &mut Ctx, peer: PeerId) {
        let ready: Vec<(PeerId, KadMsg, SendRef)> = {
            let (ready, rest): (Vec<_>, Vec<_>) = self
                .pending_sends
                .drain(..)
                .partition(|(p, _, _)| *p == peer);
            self.pending_sends = rest;
            ready
        };
        for (p, msg, sref) in ready {
            self.send_request(ctx, p, msg, sref);
        }
    }

    /// Node hook: dialing `peer` failed (or its connection died before the
    /// request went out). Drops queued sends, soft-fails the routing entry,
    /// and — crucially under churn — fails over every in-flight query
    /// request that was waiting on that peer instead of letting the query
    /// stall until its timeout.
    pub fn on_peer_unreachable(&mut self, ctx: &mut Ctx, peer: PeerId) {
        self.pending_sends.retain(|(p, _, _)| *p != peer);
        self.table.mark_failed(&peer);
        let qids: Vec<u64> = self
            .queries
            .iter()
            .filter(|(_, q)| q.inflight.contains_key(&peer))
            .map(|(id, _)| *id)
            .collect();
        for qid in qids {
            self.fail_query_peer(ctx, qid, peer);
        }
        let pids: Vec<u64> = self
            .probes
            .iter()
            .filter(|(_, p)| p.target == peer)
            .map(|(id, _)| *id)
            .collect();
        for pid in pids {
            self.probe_failed(ctx, pid);
        }
    }

    /// Node hook: a connection closed. Requests in flight on its streams
    /// fail over; peers that announced a shutdown are dropped from the
    /// table, timeouts count as a liveness strike.
    pub fn on_conn_closed(&mut self, ctx: &mut Ctx, cid: u64, peer: Option<PeerId>, reason: &str) {
        let victims: Vec<(u64, PeerId)> = self
            .queries
            .iter()
            .flat_map(|(qid, q)| {
                q.inflight
                    .iter()
                    .filter(move |(_, i)| matches!(i.stream, Some((c, _)) if c == cid))
                    .map(move |(p, _)| (*qid, *p))
            })
            .collect();
        for (qid, p) in victims {
            self.fail_query_peer(ctx, qid, p);
        }
        let pids: Vec<u64> = self
            .probes
            .iter()
            .filter(|(_, p)| matches!(p.stream, Some((c, _)) if c == cid))
            .map(|(id, _)| *id)
            .collect();
        for pid in pids {
            self.probe_failed(ctx, pid);
        }
        if let Some(p) = peer {
            if reason.contains("shutdown") {
                self.table.remove(&p);
            } else if reason.contains("timeout") {
                self.table.mark_failed(&p);
            }
        }
    }

    /// Node hook: inbound request message on a kad stream.
    pub fn handle_request(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        cid: u64,
        stream: u64,
        msg: &[u8],
    ) -> Result<()> {
        let m = KadMsg::decode(msg)?;
        let now = ctx.now();
        // Any authenticated kad traffic is liveness evidence: admit the
        // requester into the routing table — but only when its observed
        // source address is a real listen address. A NAT'd peer's source
        // is a translated mapping that third parties cannot dial, so
        // admitting it would seed unreachable routing entries
        // (is_nat_face stands in for an AutoNAT dial-back verdict).
        if matches!(
            m.kind,
            M_FIND_NODE | M_GET_PROVIDERS | M_GET_RECORD | M_ADD_PROVIDER | M_PUT_RECORD
        ) {
            if let Some(crate::swarm::Path::Direct(a)) = ctx.swarm.connection_path(cid) {
                if !ctx.net.is_nat_face(a.host) {
                    let entry = PeerEntry { id: peer, host: a.host, port: a.port };
                    ctx.swarm.peerstore.add_address(peer, entry.to_multiaddr());
                    self.observe(ctx, entry);
                }
            }
        }
        match m.kind {
            M_FIND_NODE | M_GET_PROVIDERS | M_GET_RECORD => {
                let mut key = [0u8; 32];
                if m.key.len() == 32 {
                    key.copy_from_slice(&m.key);
                }
                let mut reply = KadMsg {
                    kind: M_REPLY,
                    key: m.key.clone(),
                    closer: self.table.closest(&key, K),
                    ..Default::default()
                };
                if m.kind == M_GET_PROVIDERS {
                    if let Some(p) = self.provider_store.get(&key) {
                        reply.providers = p
                            .iter()
                            .filter(|r| r.expires > now)
                            .map(|r| r.entry.clone())
                            .collect();
                    }
                }
                if m.kind == M_GET_RECORD {
                    if let Some(r) = self.record_store.get(&key) {
                        if r.expires > now {
                            reply.value = r.value.clone();
                            reply.found = true;
                        }
                    }
                }
                let wire = reply.encode();
                ctx.send(cid, stream, &wire)?;
                self.stats.bytes_sent += wire.len() as u64;
                ctx.finish(cid, stream);
            }
            M_ADD_PROVIDER => {
                let mut key = [0u8; 32];
                if m.key.len() == 32 {
                    key.copy_from_slice(&m.key);
                }
                if let Some(p) = m.provider {
                    // Only accept provider records attributed to the
                    // authenticated sender (Castro et al. secure routing).
                    if p.id == peer {
                        let ttl = self.provider_ttl;
                        let list = self.provider_store.entry(key).or_default();
                        list.retain(|e| e.entry.id != p.id);
                        list.push(ProviderRecord { entry: p, expires: now + ttl });
                        if list.len() > 2 * K {
                            list.remove(0);
                        }
                    }
                }
            }
            M_PUT_RECORD => {
                let mut key = [0u8; 32];
                if m.key.len() == 32 {
                    key.copy_from_slice(&m.key);
                }
                self.record_store.insert(
                    key,
                    StoredRecord { value: m.value, expires: now + self.record_ttl },
                );
            }
            _ => {}
        }
        Ok(())
    }

    /// Node hook: response message on a stream we opened.
    pub fn handle_response(&mut self, ctx: &mut Ctx, cid: u64, stream: u64, msg: &[u8]) {
        let Ok(m) = KadMsg::decode(msg) else { return };
        if m.kind != M_REPLY {
            return;
        }
        let now = ctx.now();
        // Liveness probe reply: oldest entry lives, keep it.
        if let Some(pid) = self
            .probes
            .iter()
            .find(|(_, p)| p.stream == Some((cid, stream)))
            .map(|(id, _)| *id)
        {
            self.probe_succeeded(ctx, pid);
            return;
        }
        // Find the owning query by stream.
        let qid = self
            .queries
            .iter()
            .find(|(_, q)| {
                q.inflight
                    .values()
                    .any(|i| i.stream == Some((cid, stream)))
            })
            .map(|(id, _)| *id);
        let Some(qid) = qid else { return };
        {
            let q = self.queries.get_mut(&qid).unwrap();
            let peer = q
                .inflight
                .iter()
                .find(|(_, i)| i.stream == Some((cid, stream)))
                .map(|(p, _)| *p)
                .unwrap();
            q.inflight.remove(&peer);
            if let Some(c) = q.candidates.iter_mut().find(|(e, _)| e.id == peer) {
                c.1 = CandState::Responded;
            }
            q.hops += 1;
            for p in &m.providers {
                if !q.providers.iter().any(|e| e.id == p.id) {
                    q.providers.push(p.clone());
                }
            }
            if m.found && q.record.is_none() {
                q.record = Some(m.value.clone());
            }
            self.stats.replies += 1;
            self.table.mark_alive(&peer, now);
        }
        // Learn closer peers (update table + candidates).
        for e in &m.closer {
            if e.id == self.table.local {
                continue;
            }
            ctx.swarm.peerstore.add_address(e.id, e.to_multiaddr());
            self.observe(ctx, e.clone());
            let q = self.queries.get_mut(&qid).unwrap();
            if !q.candidates.iter().any(|(c, _)| c.id == e.id) {
                q.candidates.push((e.clone(), CandState::Fresh));
            }
        }
        let q = self.queries.get_mut(&qid).unwrap();
        let key = q.key;
        q.candidates
            .sort_by_key(|(e, _)| xor_distance(e.id.as_bytes(), &key));
        if q.candidates.len() > 3 * K {
            // Trim the tail but never drop a tracked (waiting) candidate.
            let mut kept = 0usize;
            q.candidates.retain(|(_, st)| {
                kept += 1;
                kept <= 3 * K || *st == CandState::Waiting
            });
        }
        self.advance_query(ctx, qid);
    }

    /// Periodic tick: expire stalled requests and probes, expire stores,
    /// republish own keys, refresh stale buckets.
    pub fn tick(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        // 1. Per-request timeouts → candidate failover.
        let expired: Vec<(u64, PeerId)> = self
            .queries
            .iter()
            .flat_map(|(qid, q)| {
                q.inflight
                    .iter()
                    .filter(|(_, i)| i.deadline <= now)
                    .map(move |(p, _)| (*qid, *p))
            })
            .collect();
        // One liveness strike per peer per tick, however many concurrent
        // queries timed out on it — a single outage episode must not burn
        // through MAX_FAILS and evict a long-lived peer outright.
        let mut struck: BTreeSet<PeerId> = BTreeSet::new();
        for (qid, peer) in expired {
            self.stats.requests_timed_out += 1;
            if struck.insert(peer) {
                self.table.mark_failed(&peer);
            }
            self.pending_sends
                .retain(|(p, _, r)| !(*p == peer && *r == SendRef::Query(qid)));
            // Remove the inflight entry first so fail_query_peer doesn't
            // also count this as a delivery failure.
            if let Some(q) = self.queries.get_mut(&qid) {
                q.inflight.remove(&peer);
            }
            self.fail_query_peer(ctx, qid, peer);
        }
        // 2. Probe timeouts → eviction.
        let pexp: Vec<u64> = self
            .probes
            .iter()
            .filter(|(_, p)| p.deadline <= now)
            .map(|(id, _)| *id)
            .collect();
        for pid in pexp {
            if let Some(t) = self.probes.get(&pid).map(|p| p.target) {
                self.pending_sends
                    .retain(|(p, _, r)| !(*p == t && *r == SendRef::Probe(pid)));
            }
            self.probe_failed(ctx, pid);
        }
        // 3. Store expiry. Our own published keys never expire locally:
        // the publisher is the source of truth that republish re-seeds
        // from, even when the TTL is shorter than the republish period.
        let local_id = self.local_entry.id;
        let mut dropped = 0u64;
        {
            let published = &self.published_provides;
            self.provider_store.retain(|k, list| {
                let keep_own = published.contains(k);
                let before = list.len();
                list.retain(|r| r.expires > now || (keep_own && r.entry.id == local_id));
                dropped += (before - list.len()) as u64;
                !list.is_empty()
            });
        }
        self.stats.providers_expired += dropped;
        let expired_records;
        {
            let published = &self.published_records;
            let before = self.record_store.len();
            self.record_store
                .retain(|k, r| r.expires > now || published.contains(k));
            expired_records = (before - self.record_store.len()) as u64;
        }
        self.stats.records_expired += expired_records;
        // 4. Republish own keys to the current k-closest peers.
        if now >= self.next_republish {
            self.next_republish = now + self.republish_interval;
            let pkeys: Vec<[u8; 32]> = self.published_provides.iter().copied().collect();
            let rkeys: Vec<[u8; 32]> = self.published_records.iter().copied().collect();
            if !pkeys.is_empty() || !rkeys.is_empty() {
                self.stats.republish_rounds += 1;
            }
            for k in pkeys {
                self.announce_provider(ctx, k);
            }
            for k in rkeys {
                if let Some(v) = self.record_store.get(&k).map(|r| r.value.clone()) {
                    self.announce_record(ctx, k, v);
                }
            }
        }
        // 5. Periodic self-lookup + stale-bucket refresh.
        if now >= self.next_self_refresh && !self.table.is_empty() {
            self.next_self_refresh = now + self.refresh_interval;
            self.stats.refreshes += 1;
            let key = *self.table.local.as_bytes();
            self.start_query(ctx, QueryKind::FindNode, key, false, None);
        }
        if self.queries.len() < MAX_MAINTENANCE_QUERIES {
            let stale = self.table.stale_buckets(now, self.refresh_interval);
            for idx in stale.into_iter().take(MAX_REFRESH_PER_TICK) {
                let key = self.table.random_key_in_bucket(idx, &mut ctx.net.rng);
                self.table.touch_refresh(idx, now);
                self.stats.refreshes += 1;
                self.start_query(ctx, QueryKind::FindNode, key, false, None);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    fn entry(seed: u64) -> PeerEntry {
        PeerEntry {
            id: Keypair::from_seed(seed).peer_id(),
            host: seed as u32,
            port: 4001,
        }
    }

    #[test]
    fn kad_msg_roundtrip() {
        let m = KadMsg {
            kind: M_REPLY,
            key: vec![7u8; 32],
            closer: vec![entry(1), entry(2)],
            providers: vec![entry(3)],
            value: b"record".to_vec(),
            found: true,
            provider: Some(entry(4)),
        };
        assert_eq!(KadMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn routing_table_insert_and_closest() {
        let local = Keypair::from_seed(0).peer_id();
        let mut rt = RoutingTable::new(local);
        for s in 1..=50u64 {
            let _ = rt.insert(entry(s), s);
        }
        // Random ids concentrate in the top buckets; full buckets report
        // Full instead of silently evicting, so everything that fit stays.
        let before = rt.len();
        assert!((40..=50).contains(&before), "len={before}");
        // Self never inserted.
        assert_eq!(
            rt.insert(PeerEntry { id: local, host: 9, port: 9 }, 99),
            InsertOutcome::Ignored
        );
        assert_eq!(rt.len(), before);
        let key = *Keypair::from_seed(99).peer_id().as_bytes();
        let closest = rt.closest(&key, 10);
        assert_eq!(closest.len(), 10);
        // Verify ordering by XOR distance.
        for w in closest.windows(2) {
            assert!(
                xor_distance(w[0].id.as_bytes(), &key) <= xor_distance(w[1].id.as_bytes(), &key)
            );
        }
        // And that they really are the 10 closest of all entries.
        let mut all: Vec<PeerEntry> = rt.iter().cloned().collect();
        all.sort_by_key(|e| xor_distance(e.id.as_bytes(), &key));
        assert_eq!(
            closest.iter().map(|e| e.id).collect::<Vec<_>>(),
            all[..10].iter().map(|e| e.id).collect::<Vec<_>>()
        );
    }

    #[test]
    fn routing_table_update_refreshes_addr_and_lru() {
        let mut rt = RoutingTable::new(Keypair::from_seed(0).peer_id());
        let mut e = entry(5);
        assert_eq!(rt.insert(e.clone(), 1), InsertOutcome::Added);
        e.port = 9999;
        assert_eq!(rt.insert(e.clone(), 2), InsertOutcome::Refreshed);
        assert_eq!(rt.len(), 1);
        let got = rt.entries().next().unwrap();
        assert_eq!(got.entry.port, 9999);
        assert_eq!(got.last_seen, 2);
        assert_eq!(got.fails, 0);
    }

    #[test]
    fn full_bucket_reports_oldest_for_probe() {
        let local = Keypair::from_seed(0).peer_id();
        let mut rt = RoutingTable::new(local);
        // Find many seeds landing in one bucket.
        let mut in_bucket: Vec<(u64, usize)> = Vec::new();
        for s in 1..=600u64 {
            let id = Keypair::from_seed(s).peer_id();
            if let Some(b) = local.bucket_index(&id) {
                in_bucket.push((s, b));
            }
        }
        // Pick the most common bucket.
        let mut counts = std::collections::HashMap::new();
        for (_, b) in &in_bucket {
            *counts.entry(*b).or_insert(0usize) += 1;
        }
        let (&bucket, &n) = counts.iter().max_by_key(|(_, n)| **n).unwrap();
        assert!(n > K, "need an overfull bucket for this test");
        let seeds: Vec<u64> = in_bucket
            .iter()
            .filter(|(_, b)| *b == bucket)
            .map(|(s, _)| *s)
            .collect();
        for (i, s) in seeds.iter().take(K).enumerate() {
            assert_eq!(rt.insert(entry(*s), i as Time), InsertOutcome::Added);
        }
        // Bucket is full of live entries: insert reports Full with the LRU.
        let oldest_id = Keypair::from_seed(seeds[0]).peer_id();
        match rt.insert(entry(seeds[K]), 99) {
            InsertOutcome::Full { bucket: b, oldest } => {
                assert_eq!(b, bucket);
                assert_eq!(oldest.id, oldest_id);
            }
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(rt.bucket_len(bucket), K);
        // A failed entry is evicted preferentially, without a probe.
        let dead = Keypair::from_seed(seeds[3]).peer_id();
        assert!(!rt.mark_failed(&dead)); // one strike: still present
        assert_eq!(rt.insert(entry(seeds[K]), 100), InsertOutcome::Added);
        assert!(rt.iter().all(|e| e.id != dead), "dead peer evicted first");
        assert_eq!(rt.bucket_len(bucket), K);
    }

    #[test]
    fn mark_failed_removes_after_max_fails() {
        let mut rt = RoutingTable::new(Keypair::from_seed(0).peer_id());
        let e = entry(7);
        let _ = rt.insert(e.clone(), 1);
        assert!(!rt.mark_failed(&e.id));
        assert_eq!(rt.len(), 1);
        assert!(rt.mark_failed(&e.id));
        assert_eq!(rt.len(), 0);
        // mark_alive resets the strike counter.
        let _ = rt.insert(e.clone(), 2);
        assert!(!rt.mark_failed(&e.id));
        rt.mark_alive(&e.id, 3);
        assert!(!rt.mark_failed(&e.id), "fails were reset by mark_alive");
        assert_eq!(rt.len(), 1);
    }

    #[test]
    fn bucket_bounded_at_k() {
        let local = Keypair::from_seed(0).peer_id();
        let mut rt = RoutingTable::new(local);
        for s in 1..=200u64 {
            let _ = rt.insert(entry(s), s);
        }
        for b in 0..256 {
            assert!(rt.bucket_len(b) <= K, "bucket {b} has {}", rt.bucket_len(b));
        }
    }

    #[test]
    fn random_key_lands_in_requested_bucket() {
        let local = Keypair::from_seed(0).peer_id();
        let rt = RoutingTable::new(local);
        let mut rng = crate::util::Rng::new(17);
        for idx in [255usize, 254, 250, 248, 247, 200, 128, 8, 1, 0] {
            for _ in 0..10 {
                let key = rt.random_key_in_bucket(idx, &mut rng);
                assert_eq!(
                    local.bucket_index(&PeerId(key)),
                    Some(idx),
                    "key for bucket {idx} landed elsewhere"
                );
            }
        }
    }

    #[test]
    fn stale_bucket_tracking() {
        let local = Keypair::from_seed(0).peer_id();
        let mut rt = RoutingTable::new(local);
        let e = entry(3);
        let bucket = local.bucket_index(&e.id).unwrap();
        let _ = rt.insert(e, 0);
        assert_eq!(rt.stale_buckets(10 * SECOND, 5 * SECOND), vec![bucket]);
        rt.touch_refresh(bucket, 10 * SECOND);
        assert!(rt.stale_buckets(12 * SECOND, 5 * SECOND).is_empty());
        assert_eq!(rt.stale_buckets(15 * SECOND, 5 * SECOND), vec![bucket]);
    }

    #[test]
    fn xor_distance_is_metric_like() {
        let a = *Keypair::from_seed(1).peer_id().as_bytes();
        let b = *Keypair::from_seed(2).peer_id().as_bytes();
        assert_eq!(xor_distance(&a, &a), [0u8; 32]);
        assert_eq!(xor_distance(&a, &b), xor_distance(&b, &a));
    }
}
