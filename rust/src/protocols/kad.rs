//! Kademlia DHT: XOR-metric routing table, iterative lookups, provider
//! records and a replicated key→value record store.
//!
//! Protocol `/lattica/kad/1`: one stream per request; the responder answers
//! on the same stream and finishes it. Queries run `ALPHA` probes in
//! parallel over the k-closest candidate set, converging in O(log N) hops
//! (measured by `benches/dht_lookup`).

use super::Ctx;
use crate::identity::PeerId;
use crate::multiaddr::{Multiaddr, Proto, SimAddr};
use crate::netsim::{Time, SECOND};
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::{HashMap, HashSet, VecDeque};

pub const KAD_PROTO: &str = "/lattica/kad/1";

/// Replication factor (bucket size and lookup breadth).
pub const K: usize = 20;
/// Lookup parallelism.
pub const ALPHA: usize = 3;
/// Per-request timeout.
pub const REQUEST_TIMEOUT: Time = 5 * SECOND;

const M_FIND_NODE: u64 = 1;
const M_GET_PROVIDERS: u64 = 2;
const M_ADD_PROVIDER: u64 = 3;
const M_PUT_RECORD: u64 = 4;
const M_GET_RECORD: u64 = 5;
const M_REPLY: u64 = 6;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct PeerEntry {
    pub id: PeerId,
    pub host: u32,
    pub port: u16,
}

impl PeerEntry {
    pub fn to_multiaddr(&self) -> Multiaddr {
        Multiaddr::direct(SimAddr::new(self.host, self.port), Proto::QuicLike).with_peer(self.id)
    }
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct KadMsg {
    pub kind: u64,
    pub key: Vec<u8>,
    /// REPLY: closer peers.
    pub closer: Vec<PeerEntry>,
    /// REPLY: providers of `key`.
    pub providers: Vec<PeerEntry>,
    /// PUT_RECORD / REPLY: record value.
    pub value: Vec<u8>,
    /// REPLY: whether a record was found.
    pub found: bool,
    /// ADD_PROVIDER: the provider's reachable endpoint.
    pub provider: Option<PeerEntry>,
}

fn encode_entry(w: &mut PbWriter, field: u32, e: &PeerEntry) {
    let mut inner = PbWriter::new();
    inner.bytes_always(1, e.id.as_bytes());
    inner.uint(2, e.host as u64);
    inner.uint(3, e.port as u64);
    w.bytes_always(field, &inner.finish());
}

fn decode_entry(buf: &[u8]) -> Result<PeerEntry> {
    let mut e = PeerEntry::default();
    PbReader::new(buf).for_each(|f| {
        match f.number {
            1 => {
                let b = f.as_bytes()?;
                anyhow::ensure!(b.len() == 32, "bad peer id");
                let mut d = [0u8; 32];
                d.copy_from_slice(b);
                e.id = PeerId(d);
            }
            2 => e.host = f.as_u64() as u32,
            3 => e.port = f.as_u64() as u16,
            _ => {}
        }
        Ok(())
    })?;
    Ok(e)
}

impl Message for KadMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.bytes(2, &self.key);
        for e in &self.closer {
            encode_entry(w, 3, e);
        }
        for e in &self.providers {
            encode_entry(w, 4, e);
        }
        w.bytes(5, &self.value);
        w.boolean(6, self.found);
        if let Some(p) = &self.provider {
            encode_entry(w, 7, p);
        }
    }

    fn decode(buf: &[u8]) -> Result<KadMsg> {
        let mut m = KadMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.key = f.as_bytes()?.to_vec(),
                3 => m.closer.push(decode_entry(f.as_bytes()?)?),
                4 => m.providers.push(decode_entry(f.as_bytes()?)?),
                5 => m.value = f.as_bytes()?.to_vec(),
                6 => m.found = f.as_bool(),
                7 => m.provider = Some(decode_entry(f.as_bytes()?)?),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

// ---------------------------------------------------------------------------
// Routing table
// ---------------------------------------------------------------------------

/// 256-bucket XOR routing table with k-sized buckets (LRU eviction of
/// stale entries is approximated by replace-oldest).
pub struct RoutingTable {
    pub local: PeerId,
    buckets: Vec<Vec<PeerEntry>>,
}

impl RoutingTable {
    pub fn new(local: PeerId) -> RoutingTable {
        RoutingTable {
            local,
            buckets: vec![Vec::new(); 256],
        }
    }

    pub fn insert(&mut self, entry: PeerEntry) {
        if entry.id == self.local {
            return;
        }
        let Some(idx) = self.local.bucket_index(&entry.id) else {
            return;
        };
        let bucket = &mut self.buckets[idx];
        if let Some(pos) = bucket.iter().position(|e| e.id == entry.id) {
            let e = bucket.remove(pos);
            bucket.push(PeerEntry { host: entry.host, port: entry.port, ..e });
            return;
        }
        if bucket.len() >= K {
            bucket.remove(0);
        }
        bucket.push(entry);
    }

    pub fn remove(&mut self, id: &PeerId) {
        if let Some(idx) = self.local.bucket_index(id) {
            self.buckets[idx].retain(|e| e.id != *id);
        }
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `n` entries closest to `key` by XOR distance.
    pub fn closest(&self, key: &[u8; 32], n: usize) -> Vec<PeerEntry> {
        let mut all: Vec<&PeerEntry> = self.buckets.iter().flatten().collect();
        all.sort_by_key(|e| xor_distance(e.id.as_bytes(), key));
        all.into_iter().take(n).cloned().collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &PeerEntry> {
        self.buckets.iter().flatten()
    }
}

pub fn xor_distance(a: &[u8; 32], b: &[u8; 32]) -> [u8; 32] {
    let mut d = [0u8; 32];
    for i in 0..32 {
        d[i] = a[i] ^ b[i];
    }
    d
}

// ---------------------------------------------------------------------------
// Query engine
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryKind {
    FindNode,
    GetProviders,
    GetRecord,
}

/// A completed query's outcome.
#[derive(Debug)]
pub enum KadEvent {
    QueryFinished {
        query_id: u64,
        key: [u8; 32],
        kind: QueryKind,
        closest: Vec<PeerEntry>,
        providers: Vec<PeerEntry>,
        record: Option<Vec<u8>>,
        /// Hops = number of request rounds taken (O(log N) check).
        hops: u32,
    },
    /// Routing table learned a new peer.
    RoutingUpdated { peer: PeerId },
}

struct Query {
    #[allow(dead_code)]
    id: u64,
    kind: QueryKind,
    key: [u8; 32],
    /// Candidates sorted by distance; bool = queried.
    candidates: Vec<(PeerEntry, bool)>,
    inflight: HashMap<(u64, u64), (PeerId, Time)>, // (cid,stream) → peer,deadline
    providers: Vec<PeerEntry>,
    record: Option<Vec<u8>>,
    responded: HashSet<PeerId>,
    hops: u32,
    /// Stop early once providers/record found.
    early_exit: bool,
}

/// The Kademlia behaviour.
pub struct Kademlia {
    pub table: RoutingTable,
    /// Local provider records: key → providers.
    pub provider_store: HashMap<[u8; 32], Vec<PeerEntry>>,
    /// Local record store.
    pub record_store: HashMap<[u8; 32], Vec<u8>>,
    /// This node's advertised endpoint.
    pub local_entry: PeerEntry,
    queries: HashMap<u64, Query>,
    next_query_id: u64,
    /// Requests awaiting a connection to `peer`.
    pending_sends: Vec<(PeerId, KadMsg, Option<(u64, u64)>)>, // (target, msg, query ref)
    events: VecDeque<KadEvent>,
}

impl Kademlia {
    pub fn new(local: PeerId, host: u32, port: u16) -> Kademlia {
        Kademlia {
            table: RoutingTable::new(local),
            provider_store: HashMap::new(),
            record_store: HashMap::new(),
            local_entry: PeerEntry {
                id: local,
                host,
                port,
            },
            queries: HashMap::new(),
            next_query_id: 1,
            pending_sends: Vec::new(),
            events: VecDeque::new(),
        }
    }

    pub fn poll_event(&mut self) -> Option<KadEvent> {
        self.events.pop_front()
    }

    /// Add a bootstrap/learned peer.
    pub fn add_address(&mut self, ctx: &mut Ctx, entry: PeerEntry) {
        ctx.swarm
            .peerstore
            .add_address(entry.id, entry.to_multiaddr());
        self.table.insert(entry.clone());
        self.events
            .push_back(KadEvent::RoutingUpdated { peer: entry.id });
    }

    /// Start an iterative FIND_NODE (also used for table refresh).
    pub fn find_node(&mut self, ctx: &mut Ctx, key: [u8; 32]) -> u64 {
        self.start_query(ctx, QueryKind::FindNode, key, false)
    }

    /// Find providers for a CID key.
    pub fn get_providers(&mut self, ctx: &mut Ctx, key: [u8; 32]) -> u64 {
        self.start_query(ctx, QueryKind::GetProviders, key, true)
    }

    /// Fetch a record.
    pub fn get_record(&mut self, ctx: &mut Ctx, key: [u8; 32]) -> u64 {
        self.start_query(ctx, QueryKind::GetRecord, key, true)
    }

    /// Announce ourselves as a provider to the k closest peers.
    pub fn provide(&mut self, ctx: &mut Ctx, key: [u8; 32]) {
        // Store locally, then push ADD_PROVIDER to closest known peers.
        let me = self.local_entry.clone();
        self.provider_store
            .entry(key)
            .or_default()
            .retain(|e| e.id != me.id);
        self.provider_store.entry(key).or_default().push(me.clone());
        let msg = KadMsg {
            kind: M_ADD_PROVIDER,
            key: key.to_vec(),
            provider: Some(me),
            ..Default::default()
        };
        for target in self.table.closest(&key, K) {
            self.send_to(ctx, target.id, msg.clone(), None);
        }
    }

    /// Store a record on the k closest peers (and locally).
    pub fn put_record(&mut self, ctx: &mut Ctx, key: [u8; 32], value: Vec<u8>) {
        self.record_store.insert(key, value.clone());
        let msg = KadMsg {
            kind: M_PUT_RECORD,
            key: key.to_vec(),
            value,
            ..Default::default()
        };
        for target in self.table.closest(&key, K) {
            self.send_to(ctx, target.id, msg.clone(), None);
        }
    }

    fn start_query(&mut self, ctx: &mut Ctx, kind: QueryKind, key: [u8; 32], early: bool) -> u64 {
        let id = self.next_query_id;
        self.next_query_id += 1;
        let mut candidates: Vec<(PeerEntry, bool)> = self
            .table
            .closest(&key, K)
            .into_iter()
            .map(|e| (e, false))
            .collect();
        candidates.sort_by_key(|(e, _)| xor_distance(e.id.as_bytes(), &key));
        let mut q = Query {
            id,
            kind,
            key,
            candidates,
            inflight: HashMap::new(),
            providers: Vec::new(),
            record: None,
            responded: HashSet::new(),
            hops: 0,
            early_exit: early,
        };
        // Check the local stores first.
        if kind == QueryKind::GetProviders {
            if let Some(p) = self.provider_store.get(&key) {
                q.providers.extend(p.iter().cloned());
            }
        }
        if kind == QueryKind::GetRecord {
            q.record = self.record_store.get(&key).cloned();
        }
        self.queries.insert(id, q);
        self.advance_query(ctx, id);
        id
    }

    fn request_msg(kind: QueryKind, key: &[u8; 32]) -> KadMsg {
        KadMsg {
            kind: match kind {
                QueryKind::FindNode => M_FIND_NODE,
                QueryKind::GetProviders => M_GET_PROVIDERS,
                QueryKind::GetRecord => M_GET_RECORD,
            },
            key: key.to_vec(),
            ..Default::default()
        }
    }

    fn advance_query(&mut self, ctx: &mut Ctx, qid: u64) {
        let now = ctx.now();
        let Some(q) = self.queries.get_mut(&qid) else { return };
        // Early exit?
        let done_early =
            q.early_exit && (!q.providers.is_empty() || q.record.is_some()) && q.hops > 0;
        // Next unqueried candidates while under parallelism.
        let mut to_send: Vec<PeerEntry> = Vec::new();
        if !done_early {
            for (e, queried) in q.candidates.iter_mut() {
                if q.inflight.len() + to_send.len() >= ALPHA {
                    break;
                }
                if !*queried {
                    *queried = true;
                    to_send.push(e.clone());
                }
            }
        }
        let finished = q.inflight.is_empty() && to_send.is_empty();
        let kind = q.kind;
        let key = q.key;
        if finished {
            let q = self.queries.remove(&qid).unwrap();
            let mut closest: Vec<PeerEntry> =
                q.candidates.into_iter().map(|(e, _)| e).collect();
            closest.sort_by_key(|e| xor_distance(e.id.as_bytes(), &key));
            closest.truncate(K);
            self.events.push_back(KadEvent::QueryFinished {
                query_id: qid,
                key,
                kind,
                closest,
                providers: q.providers,
                record: q.record,
                hops: q.hops,
            });
            return;
        }
        let _ = now;
        for e in to_send {
            let msg = Self::request_msg(kind, &key);
            self.send_to(ctx, e.id, msg, Some((qid, 0)));
        }
    }

    /// Send a request, dialing first if necessary.
    fn send_to(&mut self, ctx: &mut Ctx, peer: PeerId, msg: KadMsg, query: Option<(u64, u64)>) {
        if peer == self.table.local {
            return;
        }
        match ctx.ensure_connected(&peer) {
            Ok(true) => {
                if let Ok((cid, stream)) = ctx.open_stream(&peer, KAD_PROTO) {
                    let _ = ctx.send(cid, stream, &msg.encode());
                    if !matches!(
                        msg.kind,
                        M_ADD_PROVIDER | M_PUT_RECORD
                    ) {
                        if let Some((qid, _)) = query {
                            if let Some(q) = self.queries.get_mut(&qid) {
                                q.inflight
                                    .insert((cid, stream), (peer, ctx.now() + REQUEST_TIMEOUT));
                            }
                        }
                    } else {
                        ctx.finish(cid, stream);
                    }
                } else if let Some((qid, _)) = query {
                    self.fail_inflight_peer(ctx, qid, peer);
                }
            }
            Ok(false) => {
                // Dial in flight: queue for ConnEstablished.
                self.pending_sends.push((peer, msg, query));
            }
            Err(_) => {
                if let Some((qid, _)) = query {
                    self.fail_inflight_peer(ctx, qid, peer);
                }
            }
        }
    }

    fn fail_inflight_peer(&mut self, ctx: &mut Ctx, qid: u64, _peer: PeerId) {
        self.advance_query(ctx, qid);
    }

    /// Node hook: a connection to `peer` is up — flush queued requests.
    pub fn on_peer_connected(&mut self, ctx: &mut Ctx, peer: PeerId) {
        let pending: Vec<(PeerId, KadMsg, Option<(u64, u64)>)> = {
            let (ready, rest): (Vec<_>, Vec<_>) = self
                .pending_sends
                .drain(..)
                .partition(|(p, _, _)| *p == peer);
            self.pending_sends = rest;
            ready
        };
        for (p, msg, query) in pending {
            self.send_to(ctx, p, msg, query);
        }
    }

    /// Node hook: dial failed or conn closed — fail pending sends to peer.
    pub fn on_peer_unreachable(&mut self, ctx: &mut Ctx, peer: PeerId) {
        let failed: Vec<(PeerId, KadMsg, Option<(u64, u64)>)> = {
            let (bad, rest): (Vec<_>, Vec<_>) = self
                .pending_sends
                .drain(..)
                .partition(|(p, _, _)| *p == peer);
            self.pending_sends = rest;
            bad
        };
        self.table.remove(&peer);
        for (_, _, query) in failed {
            if let Some((qid, _)) = query {
                self.advance_query(ctx, qid);
            }
        }
    }

    /// Node hook: inbound request message on a kad stream.
    pub fn handle_request(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        cid: u64,
        stream: u64,
        msg: &[u8],
    ) -> Result<()> {
        let m = KadMsg::decode(msg)?;
        match m.kind {
            M_FIND_NODE | M_GET_PROVIDERS | M_GET_RECORD => {
                let mut key = [0u8; 32];
                if m.key.len() == 32 {
                    key.copy_from_slice(&m.key);
                }
                let mut reply = KadMsg {
                    kind: M_REPLY,
                    key: m.key.clone(),
                    closer: self.table.closest(&key, K),
                    ..Default::default()
                };
                if m.kind == M_GET_PROVIDERS {
                    if let Some(p) = self.provider_store.get(&key) {
                        reply.providers = p.clone();
                    }
                }
                if m.kind == M_GET_RECORD {
                    if let Some(v) = self.record_store.get(&key) {
                        reply.value = v.clone();
                        reply.found = true;
                    }
                }
                ctx.send(cid, stream, &reply.encode())?;
                ctx.finish(cid, stream);
            }
            M_ADD_PROVIDER => {
                let mut key = [0u8; 32];
                if m.key.len() == 32 {
                    key.copy_from_slice(&m.key);
                }
                if let Some(p) = m.provider {
                    // Only accept provider records attributed to the
                    // authenticated sender (Castro et al. secure routing).
                    if p.id == peer {
                        let list = self.provider_store.entry(key).or_default();
                        list.retain(|e| e.id != p.id);
                        list.push(p);
                        if list.len() > 2 * K {
                            list.remove(0);
                        }
                    }
                }
            }
            M_PUT_RECORD => {
                let mut key = [0u8; 32];
                if m.key.len() == 32 {
                    key.copy_from_slice(&m.key);
                }
                self.record_store.insert(key, m.value);
            }
            _ => {}
        }
        Ok(())
    }

    /// Node hook: response message on a stream we opened.
    pub fn handle_response(&mut self, ctx: &mut Ctx, cid: u64, stream: u64, msg: &[u8]) {
        let Ok(m) = KadMsg::decode(msg) else { return };
        if m.kind != M_REPLY {
            return;
        }
        // Find the owning query.
        let qid = self
            .queries
            .iter()
            .find(|(_, q)| q.inflight.contains_key(&(cid, stream)))
            .map(|(id, _)| *id);
        let Some(qid) = qid else { return };
        {
            let q = self.queries.get_mut(&qid).unwrap();
            let (peer, _) = q.inflight.remove(&(cid, stream)).unwrap();
            q.responded.insert(peer);
            q.hops += 1;
            for p in &m.providers {
                if !q.providers.iter().any(|e| e.id == p.id) {
                    q.providers.push(p.clone());
                }
            }
            if m.found && q.record.is_none() {
                q.record = Some(m.value.clone());
            }
        }
        // Learn closer peers (update table + candidates).
        for e in &m.closer {
            self.table.insert(e.clone());
            ctx.swarm.peerstore.add_address(e.id, e.to_multiaddr());
            let q = self.queries.get_mut(&qid).unwrap();
            if !q.candidates.iter().any(|(c, _)| c.id == e.id) && e.id != self.table.local {
                q.candidates.push((e.clone(), false));
            }
        }
        let key = self.queries[&qid].key;
        let q = self.queries.get_mut(&qid).unwrap();
        q.candidates
            .sort_by_key(|(e, _)| xor_distance(e.id.as_bytes(), &key));
        q.candidates.truncate(3 * K);
        self.advance_query(ctx, qid);
    }

    /// Periodic tick: expire stalled requests.
    pub fn tick(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        let qids: Vec<u64> = self.queries.keys().copied().collect();
        for qid in qids {
            let expired: Vec<(u64, u64)> = self
                .queries
                .get(&qid)
                .map(|q| {
                    q.inflight
                        .iter()
                        .filter(|(_, (_, dl))| *dl <= now)
                        .map(|(k, _)| *k)
                        .collect()
                })
                .unwrap_or_default();
            if !expired.is_empty() {
                for k in expired {
                    if let Some(q) = self.queries.get_mut(&qid) {
                        q.inflight.remove(&k);
                        let _ = ctx; // stream will be reset by peer or idle out
                    }
                }
                self.advance_query(ctx, qid);
            }
        }
    }

    pub fn active_queries(&self) -> usize {
        self.queries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    fn entry(seed: u64) -> PeerEntry {
        PeerEntry {
            id: Keypair::from_seed(seed).peer_id(),
            host: seed as u32,
            port: 4001,
        }
    }

    #[test]
    fn kad_msg_roundtrip() {
        let m = KadMsg {
            kind: M_REPLY,
            key: vec![7u8; 32],
            closer: vec![entry(1), entry(2)],
            providers: vec![entry(3)],
            value: b"record".to_vec(),
            found: true,
            provider: Some(entry(4)),
        };
        assert_eq!(KadMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn routing_table_insert_and_closest() {
        let local = Keypair::from_seed(0).peer_id();
        let mut rt = RoutingTable::new(local);
        for s in 1..=50u64 {
            rt.insert(entry(s));
        }
        // Random ids concentrate in the top buckets; K-bucket eviction may
        // drop a few, but most survive.
        let before = rt.len();
        assert!((40..=50).contains(&before), "len={before}");
        // Self never inserted.
        rt.insert(PeerEntry {
            id: local,
            host: 9,
            port: 9,
        });
        assert_eq!(rt.len(), before);
        let key = *Keypair::from_seed(99).peer_id().as_bytes();
        let closest = rt.closest(&key, 10);
        assert_eq!(closest.len(), 10);
        // Verify ordering by XOR distance.
        for w in closest.windows(2) {
            assert!(
                xor_distance(w[0].id.as_bytes(), &key) <= xor_distance(w[1].id.as_bytes(), &key)
            );
        }
        // And that they really are the 10 closest of all 50.
        let mut all: Vec<PeerEntry> = rt.iter().cloned().collect();
        all.sort_by_key(|e| xor_distance(e.id.as_bytes(), &key));
        assert_eq!(
            closest.iter().map(|e| e.id).collect::<Vec<_>>(),
            all[..10].iter().map(|e| e.id).collect::<Vec<_>>()
        );
    }

    #[test]
    fn routing_table_update_refreshes_addr() {
        let mut rt = RoutingTable::new(Keypair::from_seed(0).peer_id());
        let mut e = entry(5);
        rt.insert(e.clone());
        e.port = 9999;
        rt.insert(e.clone());
        assert_eq!(rt.len(), 1);
        assert_eq!(rt.iter().next().unwrap().port, 9999);
    }

    #[test]
    fn bucket_bounded_at_k() {
        // Many peers in the same far bucket: stays ≤ K.
        let local = Keypair::from_seed(0).peer_id();
        let mut rt = RoutingTable::new(local);
        for s in 1..=200u64 {
            rt.insert(entry(s));
        }
        let key = *local.as_bytes();
        let _ = key;
        for b in 0..256 {
            let count = rt.iter().filter(|e| local.bucket_index(&e.id) == Some(b)).count();
            assert!(count <= K, "bucket {b} has {count}");
        }
    }

    #[test]
    fn xor_distance_is_metric_like() {
        let a = *Keypair::from_seed(1).peer_id().as_bytes();
        let b = *Keypair::from_seed(2).peer_id().as_bytes();
        assert_eq!(xor_distance(&a, &a), [0u8; 32]);
        assert_eq!(xor_distance(&a, &b), xor_distance(&b, &a));
    }
}
