//! Bitswap-style block exchange: wantlists, per-peer ledgers and a
//! swarm download scheduler for multi-provider fetch sessions.
//!
//! Protocol `/lattica/bitswap/1`: one persistent stream per peer pair,
//! carrying WANT / WANT_HAVE / HAVE / BLOCK / CANCEL messages. A
//! [`Session`] fetches a set of CIDs with:
//!
//! - **HAVE-based availability**: WANT_HAVE queries map which provider
//!   holds which chunk; peers that lack a chunk remember the interest and
//!   push a HAVE the moment it lands locally (mid-download re-serving).
//! - **Rarest-first selection**: the next chunk requested is the one with
//!   the fewest known holders, hash-diversified per node so a swarm of
//!   fetchers with identical information spreads over distinct chunks.
//! - **Per-peer pipelining windows**: AIMD windows bounded by measured
//!   per-peer delivery rate and by [`Ledger::debt_ratio`]-style politeness
//!   (deep unreciprocated debt shifts load to other holders).
//! - **Endgame duplicates**: the last few chunks may be requested from
//!   more than one holder; the losers get CANCELs and late duplicates are
//!   dropped without ledger credit or a second store write.
//!
//! This is the "decentralized CDN" data path of Fig. 1(2/3).

use super::Ctx;
use crate::content::{Blockstore, Cid};
use crate::identity::PeerId;
use crate::netsim::{Time, MILLI, SECOND};
use crate::util::buf::Buf;
use crate::wire::{encode_pooled, Message, PbReader, PbWriter, RangeSet};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};

pub const BITSWAP_PROTO: &str = "/lattica/bitswap/1";

/// Re-assign an unanswered block request after this long (scaled by the
/// peer's consecutive-timeout count).
pub const WANT_TIMEOUT: Time = SECOND;

/// Pipelining window bounds.
const MIN_WINDOW: usize = 1;
const START_WINDOW: usize = 2;
const MAX_WINDOW: usize = 32;
/// Keep roughly this much measured service time in flight per peer.
const PIPELINE_TARGET: Time = 500 * MILLI;
/// Unreciprocated bytes taken from one peer before politeness halves the
/// window we allow ourselves against it.
const POLITENESS_BYTES: u64 = 1024 * 1024;
/// Endgame: how many holders may be asked for the same chunk at once.
const ENDGAME_DUP: usize = 2;
/// Re-dial an unestablished provider at most this often.
const DIAL_RETRY: Time = 5 * SECOND;

/// Upload choking (swarm-mode seeders, e.g. a checkpoint publisher):
/// superseeding — the FIRST copy of every block always flows (the swarm
/// cannot replicate what it has never seen), but once a block has been
/// served somewhere, repeat serves to a peer whose unreciprocated debt
/// exceeds this many bytes queue behind the optimistic-unchoke drip —
/// the swarm, which reciprocates, carries the repeat fan-out.
const CHOKE_BYTES: u64 = 32 * 1024;
/// Blocks smaller than this (manifests, delta manifests) always serve.
const CHOKE_EXEMPT_SIZE: usize = 8 * 1024;
/// Optimistic unchoke: queued WANTs served per tick.
const UNCHOKE_PER_TICK: usize = 2;

const M_WANT: u64 = 1;
const M_BLOCK: u64 = 2;
const M_HAVE: u64 = 3;
const M_DONT_HAVE: u64 = 4;
const M_CANCEL: u64 = 5;
const M_WANT_HAVE: u64 = 6;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct BitswapMsg {
    pub kind: u64,
    pub cids: Vec<Cid>,
    /// BLOCK: payload (one per message keeps frames small). Shared
    /// zero-copy with the blockstore — serving a block to N peers bumps a
    /// reference count N times instead of cloning the bytes.
    pub block: Buf,
    /// Compact addressing for control messages: the manifest root whose
    /// ordered chunk list `indexes` selects into. `cids` is empty when
    /// set. Legacy messages never set these fields, so their encoding is
    /// byte-identical to the pre-compact wire format; legacy decoders
    /// skip them as unknown fields.
    pub root: Option<Cid>,
    /// Range-coded chunk index set over `root`'s manifest
    /// ([`RangeSet::encode`] bytes).
    pub indexes: Vec<u8>,
}

impl Message for BitswapMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        for c in &self.cids {
            w.bytes_always(2, c.as_bytes());
        }
        w.bytes(3, &self.block);
        if let Some(r) = &self.root {
            w.bytes_always(4, r.as_bytes());
        }
        w.bytes(5, &self.indexes);
    }

    fn decode(buf: &[u8]) -> Result<BitswapMsg> {
        let mut m = BitswapMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.cids.push(Cid::from_bytes(f.as_bytes()?)?),
                3 => m.block = Buf::copy_from_slice(f.as_bytes()?),
                4 => m.root = Some(Cid::from_bytes(f.as_bytes()?)?),
                5 => m.indexes = f.as_bytes()?.to_vec(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }

    /// Zero-copy decode: the block becomes a slice of `buf`, which the
    /// blockstore can retain without another copy.
    fn decode_buf(buf: &Buf) -> Result<BitswapMsg> {
        let mut m = BitswapMsg::default();
        PbReader::new(buf.as_slice()).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.cids.push(Cid::from_bytes(f.as_bytes()?)?),
                3 => {
                    f.as_bytes()?; // wire-type check
                    m.block = buf.slice(f.data_start..f.data_start + f.data.len());
                }
                4 => m.root = Some(Cid::from_bytes(f.as_bytes()?)?),
                5 => m.indexes = f.as_bytes()?.to_vec(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

/// Per-peer accounting (the paper's "ledger": debt ratio for fairness).
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

impl Ledger {
    /// Debt ratio: >1 means we've sent them more than received.
    pub fn debt_ratio(&self) -> f64 {
        self.bytes_sent as f64 / (self.bytes_received as f64 + 1.0)
    }
}

/// Scheduler counters (duplicate suppression, re-serving, endgame).
#[derive(Clone, Debug, Default)]
pub struct BitswapStats {
    pub blocks_received: u64,
    pub bytes_received: u64,
    pub blocks_served: u64,
    pub bytes_served: u64,
    /// Blocks that arrived after we already held them (late answers from
    /// slow providers, endgame losers). Not credited to any ledger.
    pub duplicate_blocks: u64,
    pub duplicate_bytes: u64,
    /// Blocks stored without a matching want (opportunistic cache fill).
    pub unsolicited_blocks: u64,
    /// WANTs deferred by upload choking.
    pub wants_choked: u64,
    /// Choked WANTs eventually served by the optimistic-unchoke drip.
    pub choked_served: u64,
    /// HAVEs pushed to peers whose interest we remembered.
    pub have_pushes: u64,
    pub want_timeouts: u64,
    pub endgame_duplicate_wants: u64,
    pub cancels_sent: u64,
    /// WANT_HAVE polls suppressed entirely because nothing changed since
    /// the last poll of that peer (delta polling).
    pub want_haves_suppressed: u64,
    /// Wire bytes of every non-BLOCK bitswap message sent — the bitswap
    /// share of the control-plane ratio (DESIGN.md §Control-plane
    /// compression).
    pub meta_bytes_sent: u64,
}

#[derive(Debug)]
pub enum BitswapEvent {
    /// A wanted block arrived (already stored + verified).
    BlockReceived { cid: Cid, from: PeerId, size: usize },
    /// A fetch session completed (all CIDs present locally).
    SessionComplete { session: u64 },
    /// A session cannot progress: no reachable provider has these CIDs.
    SessionStalled { session: u64, missing: Vec<Cid> },
}

/// Per-chunk fetch state, shared across sessions wanting the same CID.
#[derive(Default)]
struct WantState {
    sessions: BTreeSet<u64>,
    /// Peers that confirmed holding the chunk (HAVE or pushed HAVE).
    haves: BTreeSet<PeerId>,
    /// Peers that answered DONT_HAVE.
    lacks: BTreeSet<PeerId>,
    /// Outstanding block requests: peer → deadline. More than one entry
    /// only during endgame.
    inflight: BTreeMap<PeerId, Time>,
    /// Peers already asked for this chunk (preferred-last on re-stripe).
    tried: BTreeSet<PeerId>,
}

struct Session {
    #[allow(dead_code)]
    id: u64,
    /// CIDs still missing locally.
    wanted: BTreeSet<Cid>,
    /// Initial want count (endgame threshold base).
    total: usize,
    providers: BTreeSet<PeerId>,
    /// Providers that have received our WANT_HAVE subscription.
    subscribed: BTreeSet<PeerId>,
    /// Useful bytes fetched for this session's wants.
    bytes_fetched: u64,
    /// A stall has been reported and nothing has changed since (avoids
    /// one event per tick while truly stuck).
    stalled_reported: bool,
}

/// Per-peer scheduler state (windows, measured delivery rate).
struct PeerState {
    /// AIMD window: +1 per delivered block, halved on timeout.
    window: usize,
    /// Chunks currently requested from this peer.
    outstanding: BTreeSet<Cid>,
    /// EWMA delivery rate (bytes/sec) over inter-block gaps.
    ewma_bps: f64,
    /// EWMA delivered block size.
    ewma_block: f64,
    last_block_at: Time,
    /// Consecutive timeouts (deadline backoff).
    timeouts: u64,
}

impl PeerState {
    fn new() -> PeerState {
        PeerState {
            window: START_WINDOW,
            outstanding: BTreeSet::new(),
            ewma_bps: 0.0,
            ewma_block: 0.0,
            last_block_at: 0,
            timeouts: 0,
        }
    }
}

fn id64(b: &[u8; 32]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().expect("8-byte prefix"))
}

/// Deterministic tie-break hash over (local peer, remote peer, cid) —
/// diverse across nodes, stable within one.
fn mix(parts: &[u64]) -> u64 {
    parts
        .iter()
        .fold(0x5EED_CAFE, |acc, p| crate::util::rng::mix64(acc ^ *p))
}

/// (connection id, stream id) of an open bitswap stream.
type StreamRef = (u64, u64);

/// The Bitswap behaviour. The node owns the [`Blockstore`] and passes it in.
pub struct Bitswap {
    /// Open bitswap streams per peer: peer → (conn, stream).
    streams: HashMap<PeerId, StreamRef>,
    pub ledgers: HashMap<PeerId, Ledger>,
    /// BTreeMaps keep scheduling order deterministic across processes.
    wants: BTreeMap<Cid, WantState>,
    peers: BTreeMap<PeerId, PeerState>,
    sessions: BTreeMap<u64, Session>,
    /// Remembered WANT/WANT_HAVE interest in chunks we lack:
    /// cid → peer → stream for the HAVE push.
    interest: BTreeMap<Cid, BTreeMap<PeerId, StreamRef>>,
    /// Providers with a dial in flight (when it was issued) — dedup so a
    /// pending handshake isn't re-dialed every tick.
    dialing: BTreeMap<PeerId, Time>,
    /// Upload choking (off by default; swarm-mode publishers enable it).
    /// When on, WANTs from deeply-indebted peers are parked here and
    /// drained at [`UNCHOKE_PER_TICK`].
    pub serve_choking: bool,
    choked: VecDeque<(PeerId, Cid)>,
    choked_set: BTreeSet<(PeerId, Cid)>,
    /// Blocks this node has served at least once (superseeding: only
    /// repeats are choke-eligible). Tracked only while choking is on.
    served_once: BTreeSet<Cid>,
    /// Metadata blocks (manifests, delta manifests) that must never
    /// choke regardless of size — publishers register them.
    pub choke_exempt: BTreeSet<Cid>,
    /// Compact control plane: range-coded `(root, index set)` addressing
    /// and per-tick HAVE batching. Set from `NodeConfig::compact_control`;
    /// either encoding interoperates with either peer, so this only
    /// affects what *we* send (the bench A/B flag).
    pub compact_control: bool,
    /// Registered manifests: root → ordered chunk list (decode side of
    /// compact addressing).
    manifests: BTreeMap<Cid, Vec<Cid>>,
    /// Reverse chunk index: chunk → (root, position) (encode side).
    rev: BTreeMap<Cid, (Cid, u64)>,
    /// HAVE pushes queued per peer, flushed as one range-coded message
    /// per peer per tick instead of one message per block.
    pending_haves: BTreeMap<PeerId, (StreamRef, Vec<Cid>)>,
    /// Compact WANT/WANT_HAVE whose root manifest we don't know yet:
    /// root → peer → (stream, raw index bytes). Resolved the moment the
    /// manifest lands here (mid-download re-serving across the compact
    /// encoding).
    pending_root_interest: BTreeMap<Cid, BTreeMap<PeerId, (StreamRef, Vec<u8>)>>,
    /// Chunks already WANT_HAVE-announced per peer. Re-polls (restarted
    /// sessions, churn recovery) send only the delta — the peer remembers
    /// interest, so resending the full missing set is pure control waste.
    announced: BTreeMap<PeerId, BTreeSet<Cid>>,
    next_session: u64,
    events: VecDeque<BitswapEvent>,
    pub stats: BitswapStats,
}

impl Default for Bitswap {
    fn default() -> Self {
        Self::new()
    }
}

impl Bitswap {
    pub fn new() -> Bitswap {
        Bitswap {
            streams: HashMap::new(),
            ledgers: HashMap::new(),
            wants: BTreeMap::new(),
            peers: BTreeMap::new(),
            sessions: BTreeMap::new(),
            interest: BTreeMap::new(),
            dialing: BTreeMap::new(),
            serve_choking: false,
            choked: VecDeque::new(),
            choked_set: BTreeSet::new(),
            served_once: BTreeSet::new(),
            choke_exempt: BTreeSet::new(),
            compact_control: false,
            manifests: BTreeMap::new(),
            rev: BTreeMap::new(),
            pending_haves: BTreeMap::new(),
            pending_root_interest: BTreeMap::new(),
            announced: BTreeMap::new(),
            next_session: 1,
            events: VecDeque::new(),
            stats: BitswapStats::default(),
        }
    }

    pub fn poll_event(&mut self) -> Option<BitswapEvent> {
        self.events.pop_front()
    }

    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Useful bytes fetched so far by a live session.
    pub fn session_bytes(&self, session: u64) -> Option<u64> {
        self.sessions.get(&session).map(|s| s.bytes_fetched)
    }

    fn stream_to(&mut self, ctx: &mut Ctx, peer: &PeerId) -> Result<(u64, u64)> {
        if let Some(&(cid, stream)) = self.streams.get(peer) {
            return Ok((cid, stream));
        }
        // Block transfer is background traffic: the bulk class keeps
        // model sync from starving pings, DCUtR and gossip on a
        // congested uplink.
        let (cid, stream) =
            ctx.open_stream_class(peer, BITSWAP_PROTO, crate::transport::TrafficClass::Bulk)?;
        self.streams.insert(*peer, (cid, stream));
        Ok((cid, stream))
    }

    /// Node hook: a manifest's chunk list became known here (publish, or
    /// fetch start once the manifest block arrived) — enables compact
    /// `(root, index set)` addressing for its chunks and answers any
    /// compact interest parked on the root.
    pub fn register_manifest(
        &mut self,
        ctx: &mut Ctx,
        store: &Blockstore,
        root: Cid,
        chunks: &[Cid],
    ) {
        self.note_manifest(root, chunks);
        self.resolve_pending_root(ctx, store, root);
    }

    /// Bookkeeping half of [`Bitswap::register_manifest`]: index the chunk
    /// list both ways (root → chunks for decode, chunk → (root, index)
    /// for encode).
    fn note_manifest(&mut self, root: Cid, chunks: &[Cid]) {
        if self.manifests.contains_key(&root) {
            return;
        }
        for (i, c) in chunks.iter().enumerate() {
            self.rev.insert(*c, (root, i as u64));
        }
        self.manifests.insert(root, chunks.to_vec());
    }

    /// Try to index a manifest whose block is already in the store.
    fn try_load_manifest(&mut self, store: &Blockstore, root: &Cid) -> bool {
        if self.manifests.contains_key(root) {
            return true;
        }
        let Some(block) = store.get(root) else { return false };
        match crate::content::DagManifest::decode(&block) {
            Ok(man) if !man.chunks.is_empty() => {
                self.note_manifest(*root, &man.chunks);
                true
            }
            _ => false,
        }
    }

    /// Build a control message addressing `cids`. With compact control on
    /// and every cid belonging to one registered manifest, the set goes
    /// out as `(root, range-coded index set)` — bytes proportional to the
    /// number of runs, not the number of chunks. Falls back to the legacy
    /// per-cid encoding otherwise (mixed roots, unregistered blocks, and
    /// singletons, where the 32-byte root wouldn't pay for itself).
    fn make_msg(&self, kind: u64, cids: Vec<Cid>) -> BitswapMsg {
        if self.compact_control && cids.len() >= 2 {
            if let Some(&(root, _)) = self.rev.get(&cids[0]) {
                let mut set = RangeSet::new();
                let mut uniform = true;
                for c in &cids {
                    match self.rev.get(c) {
                        Some(&(r, i)) if r == root => set.insert(i),
                        _ => {
                            uniform = false;
                            break;
                        }
                    }
                }
                if uniform {
                    return BitswapMsg {
                        kind,
                        root: Some(root),
                        indexes: set.encode(),
                        ..BitswapMsg::default()
                    };
                }
            }
        }
        BitswapMsg {
            kind,
            cids,
            ..BitswapMsg::default()
        }
    }

    /// Send a metadata (non-BLOCK) message, crediting its wire size to
    /// [`BitswapStats::meta_bytes_sent`]. Associated fn so callers can
    /// hold disjoint `self` borrows.
    fn send_meta(
        stats: &mut BitswapStats,
        ctx: &mut Ctx,
        conn: u64,
        stream: u64,
        msg: &BitswapMsg,
    ) -> bool {
        match encode_pooled(msg, |b| ctx.send(conn, stream, b).map(|()| b.len())) {
            Ok(n) => {
                stats.meta_bytes_sent += n as u64;
                true
            }
            Err(_) => false,
        }
    }

    /// Resolve compact interest parked on `root` once its manifest is
    /// known: push HAVEs for chunks already held, remember interest in
    /// the rest (the normal mid-download re-serving path).
    fn resolve_pending_root(&mut self, ctx: &mut Ctx, store: &Blockstore, root: Cid) {
        let Some(pending) = self.pending_root_interest.remove(&root) else { return };
        let Some(chunks) = self.manifests.get(&root).cloned() else { return };
        let n = chunks.len() as u64;
        for (peer, ((conn, stream), indexes)) in pending {
            let Ok(set) = RangeSet::decode(&indexes) else { continue };
            let mut have = Vec::new();
            for i in set.iter().take_while(|&i| i < n) {
                let c = chunks[i as usize];
                if store.has(&c) {
                    have.push(c);
                } else {
                    self.interest.entry(c).or_default().insert(peer, (conn, stream));
                }
            }
            if !have.is_empty() {
                let pushed = have.len() as u64;
                let msg = self.make_msg(M_HAVE, have);
                if Self::send_meta(&mut self.stats, ctx, conn, stream, &msg) {
                    self.stats.have_pushes += pushed;
                }
            }
        }
    }

    /// Start fetching `cids` from `providers`. Returns the session id.
    /// More providers can join later via [`Bitswap::add_providers`] (DHT
    /// discovery) or by pushing HAVEs.
    pub fn fetch(
        &mut self,
        ctx: &mut Ctx,
        store: &Blockstore,
        cids: Vec<Cid>,
        providers: Vec<PeerId>,
    ) -> u64 {
        let id = self.next_session;
        self.next_session += 1;
        let local = ctx.local_peer();
        let wanted: BTreeSet<Cid> = cids.iter().filter(|c| !store.has(c)).copied().collect();
        if wanted.is_empty() {
            self.events.push_back(BitswapEvent::SessionComplete { session: id });
            return id;
        }
        for c in &wanted {
            self.wants.entry(*c).or_default().sessions.insert(id);
        }
        let total = wanted.len();
        self.sessions.insert(
            id,
            Session {
                id,
                wanted,
                total,
                providers: providers.into_iter().filter(|p| *p != local).collect(),
                subscribed: BTreeSet::new(),
                bytes_fetched: 0,
                stalled_reported: false,
            },
        );
        self.connect_and_subscribe(ctx, id);
        self.dispatch(ctx, id);
        id
    }

    /// Add freshly-discovered providers (e.g. from `kad::get_providers`)
    /// to a running session.
    pub fn add_providers(&mut self, ctx: &mut Ctx, session: u64, peers: Vec<PeerId>) {
        let local = ctx.local_peer();
        let mut added = false;
        if let Some(s) = self.sessions.get_mut(&session) {
            for p in peers {
                if p != local && s.providers.insert(p) {
                    added = true;
                }
            }
            if added {
                s.stalled_reported = false;
            }
        }
        if added {
            self.connect_and_subscribe(ctx, session);
            self.dispatch(ctx, session);
        }
    }

    /// Send WANT_HAVE subscriptions to providers we haven't polled yet,
    /// dialing unconnected ones (completion is picked up on a later tick
    /// or on `on_peer_connected`).
    fn connect_and_subscribe(&mut self, ctx: &mut Ctx, sid: u64) {
        let (pending, want_list) = {
            let Some(s) = self.sessions.get(&sid) else { return };
            if s.wanted.is_empty() {
                return;
            }
            let pending: Vec<PeerId> = s
                .providers
                .iter()
                .filter(|p| !s.subscribed.contains(p))
                .copied()
                .collect();
            let want_list: Vec<Cid> = s.wanted.iter().copied().collect();
            (pending, want_list)
        };
        for p in pending {
            if !ctx.swarm.is_connected(&p) {
                let now = ctx.now();
                let due = self
                    .dialing
                    .get(&p)
                    .is_none_or(|&t| now.saturating_sub(t) >= DIAL_RETRY);
                if due {
                    match ctx.ensure_connected(&p) {
                        Ok(_) => {
                            self.dialing.insert(p, now);
                        }
                        Err(_) => {
                            // No route at all: fail over to other providers.
                            self.on_peer_unreachable(ctx, p);
                        }
                    }
                }
                continue;
            }
            self.dialing.remove(&p);
            // Delta polling: only WANT_HAVE the chunks this peer hasn't
            // been asked about yet. Restarted sessions and churn re-polls
            // would otherwise resend the full missing set, and the peer's
            // remembered interest makes those resends pure control waste.
            let delta: Vec<Cid> = match self.announced.get(&p) {
                Some(a) => want_list.iter().filter(|c| !a.contains(c)).copied().collect(),
                None => want_list.clone(),
            };
            if delta.is_empty() {
                self.stats.want_haves_suppressed += 1;
                if let Some(s) = self.sessions.get_mut(&sid) {
                    s.subscribed.insert(p);
                }
                continue;
            }
            if let Ok((conn, stream)) = self.stream_to(ctx, &p) {
                let msg = self.make_msg(M_WANT_HAVE, delta.clone());
                if Self::send_meta(&mut self.stats, ctx, conn, stream, &msg) {
                    self.announced.entry(p).or_default().extend(delta);
                    if let Some(s) = self.sessions.get_mut(&sid) {
                        s.subscribed.insert(p);
                    }
                }
            }
        }
    }

    /// Effective pipelining window towards `peer`: the AIMD window bounded
    /// by the measured delivery rate (keep ~[`PIPELINE_TARGET`] in flight)
    /// and by ledger politeness (deep one-sided debt halves our appetite,
    /// steering load towards holders we haven't drained yet).
    fn effective_window(&self, peer: &PeerId) -> usize {
        let Some(ps) = self.peers.get(peer) else { return START_WINDOW };
        let mut w = ps.window;
        if ps.ewma_bps > 0.0 && ps.ewma_block > 0.0 {
            let pipelined = ps.ewma_bps * (PIPELINE_TARGET as f64 / 1e9) / ps.ewma_block;
            let cap = (pipelined.ceil() as usize).max(MIN_WINDOW) * 2;
            w = w.min(cap);
        }
        if let Some(l) = self.ledgers.get(peer) {
            if l.bytes_received.saturating_sub(l.bytes_sent) > POLITENESS_BYTES {
                w /= 2;
            }
        }
        w.clamp(MIN_WINDOW, MAX_WINDOW)
    }

    fn want_deadline(&self, peer: &PeerId) -> Time {
        let backoff = self.peers.get(peer).map_or(0, |p| p.timeouts.min(3));
        WANT_TIMEOUT * (1 + backoff)
    }

    /// The scheduler: assign wanted chunks (rarest first) to holders with
    /// free window slots, batch the WANTs per peer, and surface a stall if
    /// nothing can move.
    fn dispatch(&mut self, ctx: &mut Ctx, sid: u64) {
        let now = ctx.now();
        let local = ctx.local_peer();
        let local_h = id64(local.as_bytes());

        // Phase 0: no providers left at all — surface that once.
        {
            let Some(s) = self.sessions.get_mut(&sid) else { return };
            if s.wanted.is_empty() {
                return;
            }
            if s.providers.is_empty() {
                if !s.stalled_reported {
                    s.stalled_reported = true;
                    let missing: Vec<Cid> = s.wanted.iter().copied().collect();
                    self.events
                        .push_back(BitswapEvent::SessionStalled { session: sid, missing });
                }
                return;
            }
        }

        // Phase 1: plan assignments (read-only).
        let mut batches: BTreeMap<PeerId, Vec<Cid>> = BTreeMap::new();
        {
            let Some(s) = self.sessions.get(&sid) else { return };
            let providers: Vec<PeerId> = s.providers.iter().copied().collect();
            let endgame = s.wanted.len() <= (s.total / 16).max(2);
            let max_dup = if endgame { ENDGAME_DUP } else { 1 };

            // Rarest first: confirmed HAVEs plus providers not known to
            // lack the chunk; hash-diversified so identical fetchers
            // start on different chunks.
            let mut cands: Vec<(usize, u64, Cid)> = Vec::new();
            for c in &s.wanted {
                let Some(w) = self.wants.get(c) else { continue };
                if w.inflight.len() >= max_dup {
                    continue;
                }
                let presumed = providers
                    .iter()
                    .filter(|p| !w.lacks.contains(p) && !w.haves.contains(p))
                    .count();
                let holders = w.haves.len() + presumed;
                if holders == 0 {
                    continue;
                }
                cands.push((holders, mix(&[local_h, id64(c.as_bytes())]), *c));
            }
            cands.sort_unstable();

            let mut planned: BTreeMap<PeerId, usize> = BTreeMap::new();
            for (_, _, c) in cands {
                let w = self.wants.get(&c).expect("want state");
                let mut pool: Vec<PeerId> = providers
                    .iter()
                    .chain(w.haves.iter())
                    .copied()
                    .collect();
                pool.sort_unstable();
                pool.dedup();
                pool.retain(|p| {
                    *p != local && !w.lacks.contains(p) && !w.inflight.contains_key(p)
                });
                // Prefer peers not yet tried for this chunk; once everyone
                // has been tried, allow retries (slow ≠ dead).
                let fresh: Vec<PeerId> =
                    pool.iter().filter(|p| !w.tried.contains(p)).copied().collect();
                let pool = if fresh.is_empty() { pool } else { fresh };
                let mut best: Option<((u64, u64, u64), PeerId)> = None;
                for p in pool {
                    let win = self.effective_window(&p) as u64;
                    let out = self.peers.get(&p).map_or(0, |ps| ps.outstanding.len())
                        + planned.get(&p).copied().unwrap_or(0);
                    if out as u64 >= win {
                        continue;
                    }
                    // Load first, then ledger imbalance in 32 KiB buckets
                    // (spread away from peers we've already taken a lot
                    // from — e.g. the original publisher), then hash.
                    let load = (out as u64 * 1000) / win;
                    let taken = self
                        .ledgers
                        .get(&p)
                        .map_or(0, |l| l.bytes_received.saturating_sub(l.bytes_sent))
                        >> 15;
                    let tie = mix(&[local_h, id64(p.as_bytes()), id64(c.as_bytes())]);
                    let score = (load, taken, tie);
                    if best.as_ref().is_none_or(|(b, _)| score < *b) {
                        best = Some((score, p));
                    }
                }
                if let Some((_, p)) = best {
                    *planned.entry(p).or_insert(0) += 1;
                    batches.entry(p).or_default().push(c);
                }
            }
        }

        // Phase 2: send the batched WANTs, then record the bookkeeping
        // (unsent batches leave no state behind, so the next tick retries).
        let mut sent_any = false;
        for (peer, cids) in batches {
            let Ok((conn, stream)) = self.stream_to(ctx, &peer) else { continue };
            let msg = self.make_msg(M_WANT, cids.clone());
            if !Self::send_meta(&mut self.stats, ctx, conn, stream, &msg) {
                continue;
            }
            sent_any = true;
            let deadline = now + self.want_deadline(&peer);
            {
                let ps = self.peers.entry(peer).or_insert_with(PeerState::new);
                for c in &cids {
                    ps.outstanding.insert(*c);
                }
            }
            for c in cids {
                if let Some(w) = self.wants.get_mut(&c) {
                    if !w.inflight.is_empty() {
                        self.stats.endgame_duplicate_wants += 1;
                    }
                    w.inflight.insert(peer, deadline);
                    w.tried.insert(peer);
                }
            }
        }

        // Stall detection: a session with wants but nothing in flight,
        // and no pending subscriptions that could still change the
        // picture (a provider mid-handshake is pending, not stalled).
        // Reported once per stall episode; progress re-arms it.
        let stalled_missing: Option<Vec<Cid>> = {
            let Some(s) = self.sessions.get_mut(&sid) else { return };
            if sent_any {
                s.stalled_reported = false;
            }
            let all_subscribed = s.providers.iter().all(|p| s.subscribed.contains(p));
            let any_inflight = s
                .wanted
                .iter()
                .any(|c| self.wants.get(c).is_some_and(|w| !w.inflight.is_empty()));
            if !any_inflight && !s.wanted.is_empty() && all_subscribed && !s.stalled_reported {
                s.stalled_reported = true;
                Some(s.wanted.iter().copied().collect())
            } else {
                None
            }
        };
        if let Some(missing) = stalled_missing {
            self.events
                .push_back(BitswapEvent::SessionStalled { session: sid, missing });
        }
    }

    /// Node hook: message on a bitswap stream. Blocks are sliced zero-copy
    /// out of `msg` and stored without another copy.
    pub fn handle_msg(
        &mut self,
        ctx: &mut Ctx,
        store: &mut Blockstore,
        peer: PeerId,
        conn: u64,
        stream: u64,
        msg: &Buf,
    ) -> Result<()> {
        // Remember the stream for replies and pushes.
        self.streams.entry(peer).or_insert((conn, stream));
        let mut m = BitswapMsg::decode_buf(msg)?;
        // Compact addressing: materialize (root, index set) back into
        // CIDs. An unknown root cannot be materialized — for WANT and
        // WANT_HAVE we park the interest until the manifest lands here
        // and echo a compact DONT_HAVE so the requester fails over
        // meanwhile; other kinds carry no obligation and are dropped.
        if let Some(root) = m.root {
            let set = RangeSet::decode(&m.indexes)?;
            self.try_load_manifest(store, &root);
            match self.manifests.get(&root) {
                Some(chunks) => {
                    let n = chunks.len() as u64;
                    m.cids = set
                        .iter()
                        .take_while(|&i| i < n)
                        .map(|i| chunks[i as usize])
                        .collect();
                }
                None => {
                    if m.kind == M_WANT || m.kind == M_WANT_HAVE {
                        let reply = BitswapMsg {
                            kind: M_DONT_HAVE,
                            root: Some(root),
                            indexes: m.indexes.clone(),
                            ..BitswapMsg::default()
                        };
                        Self::send_meta(&mut self.stats, ctx, conn, stream, &reply);
                        self.pending_root_interest
                            .entry(root)
                            .or_default()
                            .insert(peer, ((conn, stream), m.indexes));
                    }
                    return Ok(());
                }
            }
        }
        match m.kind {
            M_WANT => {
                let mut dont = Vec::new();
                for c in m.cids {
                    match store.get(&c) {
                        Some(block) => {
                            let debt = self
                                .ledgers
                                .get(&peer)
                                .map_or(0, |l| l.bytes_sent.saturating_sub(l.bytes_received));
                            if self.serve_choking
                                && block.len() >= CHOKE_EXEMPT_SIZE
                                && !self.choke_exempt.contains(&c)
                                && debt > CHOKE_BYTES
                                && self.served_once.contains(&c)
                            {
                                // Repeat serve to an indebted peer: park
                                // it behind the unchoke drip; the
                                // fetcher's timeout re-stripes it to a
                                // reciprocating seeder meanwhile.
                                if self.choked_set.insert((peer, c)) {
                                    self.choked.push_back((peer, c));
                                    self.stats.wants_choked += 1;
                                }
                                continue;
                            }
                            self.serve_block(ctx, peer, conn, stream, c, block);
                        }
                        None => {
                            // Remember the interest: the moment this block
                            // lands here we push a HAVE so the peer can
                            // re-request from a now-nearer holder.
                            self.interest.entry(c).or_default().insert(peer, (conn, stream));
                            dont.push(c);
                        }
                    }
                }
                if !dont.is_empty() {
                    let reply = self.make_msg(M_DONT_HAVE, dont);
                    Self::send_meta(&mut self.stats, ctx, conn, stream, &reply);
                }
            }
            M_WANT_HAVE => {
                let mut have = Vec::new();
                let mut dont = Vec::new();
                for c in m.cids {
                    if store.has(&c) {
                        have.push(c);
                    } else {
                        self.interest.entry(c).or_default().insert(peer, (conn, stream));
                        dont.push(c);
                    }
                }
                for (kind, cids) in [(M_HAVE, have), (M_DONT_HAVE, dont)] {
                    if !cids.is_empty() {
                        let reply = self.make_msg(kind, cids);
                        Self::send_meta(&mut self.stats, ctx, conn, stream, &reply);
                    }
                }
            }
            M_HAVE => {
                let mut affected: BTreeSet<u64> = BTreeSet::new();
                for c in m.cids {
                    if let Some(w) = self.wants.get_mut(&c) {
                        w.lacks.remove(&peer);
                        if w.haves.insert(peer) {
                            affected.extend(w.sessions.iter().copied());
                        }
                    }
                }
                // A pushed HAVE promotes the pusher to session provider
                // (it is a mid-download seeder we may not know yet).
                for sid in &affected {
                    if let Some(s) = self.sessions.get_mut(sid) {
                        s.providers.insert(peer);
                    }
                }
                for sid in affected {
                    self.dispatch(ctx, sid);
                }
            }
            M_DONT_HAVE => {
                let mut affected: BTreeSet<u64> = BTreeSet::new();
                for c in m.cids {
                    if let Some(w) = self.wants.get_mut(&c) {
                        w.haves.remove(&peer);
                        w.lacks.insert(peer);
                        if w.inflight.remove(&peer).is_some() {
                            if let Some(ps) = self.peers.get_mut(&peer) {
                                ps.outstanding.remove(&c);
                            }
                        }
                        affected.extend(w.sessions.iter().copied());
                    }
                }
                for sid in affected {
                    self.dispatch(ctx, sid);
                }
            }
            M_CANCEL => {
                for c in m.cids {
                    if let Some(int) = self.interest.get_mut(&c) {
                        int.remove(&peer);
                        if int.is_empty() {
                            self.interest.remove(&c);
                        }
                    }
                    // Withdraw any choked serve (queue entries are skipped
                    // lazily once out of the set).
                    self.choked_set.remove(&(peer, c));
                }
            }
            M_BLOCK => {
                let Some(&c) = m.cids.first() else { return Ok(()) };
                let size = m.block.len();
                if store.has(&c) {
                    // Late duplicate (a slow provider answering after
                    // re-stripe, or an endgame loser): drop it without
                    // ledger credit, event, or a second store write.
                    self.stats.duplicate_blocks += 1;
                    self.stats.duplicate_bytes += size as u64;
                    if let Some(w) = self.wants.get_mut(&c) {
                        w.inflight.remove(&peer);
                    }
                    if let Some(ps) = self.peers.get_mut(&peer) {
                        ps.outstanding.remove(&c);
                    }
                    return Ok(());
                }
                if store.put_verified(c, m.block.clone()).is_err() {
                    crate::log_warn!("peer {peer} sent corrupt block for {c}");
                    return Ok(());
                }
                self.ledgers.entry(peer).or_default().bytes_received += size as u64;
                self.stats.blocks_received += 1;
                self.stats.bytes_received += size as u64;
                if !self.wants.contains_key(&c) {
                    self.stats.unsolicited_blocks += 1;
                }
                self.events.push_back(BitswapEvent::BlockReceived {
                    cid: c,
                    from: peer,
                    size,
                });
                // The stored block may itself be a manifest that compact
                // interest is parked on.
                if self.pending_root_interest.contains_key(&c) && self.try_load_manifest(store, &c)
                {
                    self.resolve_pending_root(ctx, store, c);
                }
                self.on_block_arrived(ctx, c, peer, size);
            }
            _ => {}
        }
        Ok(())
    }

    /// Serve one block to a peer (refcount bump, ledger + stats credit).
    fn serve_block(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        conn: u64,
        stream: u64,
        c: Cid,
        block: Buf,
    ) {
        let size = block.len() as u64;
        self.ledgers.entry(peer).or_default().bytes_sent += size;
        self.stats.blocks_served += 1;
        self.stats.bytes_served += size;
        if self.serve_choking {
            self.served_once.insert(c);
        }
        let reply = BitswapMsg {
            kind: M_BLOCK,
            cids: vec![c],
            block,
            ..BitswapMsg::default()
        };
        let _ = ctx.send_buf(conn, stream, reply.encode_buf());
    }

    fn send_cancel(&mut self, ctx: &mut Ctx, peer: &PeerId, cids: Vec<Cid>) {
        // A cancel withdraws the peer's remembered interest, so these
        // chunks must be re-announced if a later poll still wants them.
        if let Some(a) = self.announced.get_mut(peer) {
            for c in &cids {
                a.remove(c);
            }
        }
        if let Some(&(conn, stream)) = self.streams.get(peer) {
            let msg = self.make_msg(M_CANCEL, cids);
            if Self::send_meta(&mut self.stats, ctx, conn, stream, &msg) {
                self.stats.cancels_sent += 1;
            }
        }
    }

    fn on_block_arrived(&mut self, ctx: &mut Ctx, c: Cid, from: PeerId, size: usize) {
        let now = ctx.now();
        // Window growth + measured delivery rate for the serving peer.
        if let Some(ps) = self.peers.get_mut(&from) {
            ps.outstanding.remove(&c);
            if ps.last_block_at > 0 && now > ps.last_block_at {
                let inst = size as f64 * 1e9 / (now - ps.last_block_at) as f64;
                ps.ewma_bps = if ps.ewma_bps <= 0.0 {
                    inst
                } else {
                    0.8 * ps.ewma_bps + 0.2 * inst
                };
            }
            ps.last_block_at = now;
            ps.ewma_block = if ps.ewma_block <= 0.0 {
                size as f64
            } else {
                0.8 * ps.ewma_block + 0.2 * size as f64
            };
            ps.window = (ps.window + 1).min(MAX_WINDOW);
            ps.timeouts = 0;
        }
        if let Some(w) = self.wants.remove(&c) {
            // Withdraw duplicate endgame asks.
            let mut cancels: Vec<PeerId> = Vec::new();
            for p in w.inflight.keys() {
                if *p != from {
                    cancels.push(*p);
                    if let Some(ps) = self.peers.get_mut(p) {
                        ps.outstanding.remove(&c);
                    }
                }
            }
            for p in cancels {
                self.send_cancel(ctx, &p, vec![c]);
            }
            let sids: Vec<u64> = w.sessions.iter().copied().collect();
            for sid in sids {
                let complete = {
                    let Some(s) = self.sessions.get_mut(&sid) else { continue };
                    s.wanted.remove(&c);
                    s.bytes_fetched += size as u64;
                    s.stalled_reported = false;
                    s.wanted.is_empty()
                };
                if complete {
                    self.sessions.remove(&sid);
                    self.events
                        .push_back(BitswapEvent::SessionComplete { session: sid });
                } else {
                    self.dispatch(ctx, sid);
                }
            }
        }
        // The chunk is no longer wanted here: a future poll may announce
        // it again (e.g. for a later session).
        for a in self.announced.values_mut() {
            a.remove(&c);
        }
        // Mid-download re-serving: push a HAVE to every peer whose
        // interest in this chunk we remembered while we lacked it. With
        // compact control the pushes batch into one range-coded HAVE per
        // peer on the next tick instead of one message per block.
        if let Some(interested) = self.interest.remove(&c) {
            for (p, (conn, stream)) in interested {
                if p == from {
                    continue;
                }
                if self.compact_control {
                    let e = self
                        .pending_haves
                        .entry(p)
                        .or_insert_with(|| ((conn, stream), Vec::new()));
                    e.0 = (conn, stream);
                    e.1.push(c);
                } else {
                    let msg = BitswapMsg {
                        kind: M_HAVE,
                        cids: vec![c],
                        ..BitswapMsg::default()
                    };
                    if Self::send_meta(&mut self.stats, ctx, conn, stream, &msg) {
                        self.stats.have_pushes += 1;
                    }
                }
            }
        }
    }

    /// Node hook: periodic tick — drain the optimistic-unchoke drip,
    /// expire timed-out requests (halving the slow peer's window), retry
    /// subscriptions blocked on dials, and redispatch every session.
    pub fn tick(&mut self, ctx: &mut Ctx, store: &Blockstore) {
        let now = ctx.now();
        // Flush batched HAVE pushes: one (range-coded) HAVE per peer for
        // everything that arrived since the last tick.
        for (_, ((conn, stream), cids)) in std::mem::take(&mut self.pending_haves) {
            let pushed = cids.len() as u64;
            let msg = self.make_msg(M_HAVE, cids);
            if Self::send_meta(&mut self.stats, ctx, conn, stream, &msg) {
                self.stats.have_pushes += pushed;
            }
        }
        // Optimistic unchoke: serve a bounded number of parked WANTs so a
        // chunk only the choking seeder holds still spreads.
        let mut served = 0;
        while served < UNCHOKE_PER_TICK {
            let Some((p, c)) = self.choked.pop_front() else { break };
            if !self.choked_set.remove(&(p, c)) {
                continue; // canceled while parked
            }
            let Some(&(conn, stream)) = self.streams.get(&p) else { continue };
            let Some(block) = store.get(&c) else { continue };
            self.serve_block(ctx, p, conn, stream, c, block);
            self.stats.choked_served += 1;
            served += 1;
        }
        let mut expired: Vec<(Cid, PeerId)> = Vec::new();
        for (c, w) in &self.wants {
            for (p, deadline) in &w.inflight {
                if *deadline <= now {
                    expired.push((*c, *p));
                }
            }
        }
        let mut cancels: BTreeMap<PeerId, Vec<Cid>> = BTreeMap::new();
        // Multiplicative decrease once per (peer, episode): a stall that
        // expires a whole window must not collapse it 32→1 in one tick
        // (same once-per-round rule as transport/cc.rs).
        let mut punished: BTreeSet<PeerId> = BTreeSet::new();
        for (c, p) in expired {
            if let Some(w) = self.wants.get_mut(&c) {
                w.inflight.remove(&p);
                w.tried.insert(p);
            }
            self.stats.want_timeouts += 1;
            if let Some(ps) = self.peers.get_mut(&p) {
                ps.outstanding.remove(&c);
                if punished.insert(p) {
                    ps.window = (ps.window / 2).max(MIN_WINDOW);
                    ps.timeouts += 1;
                }
            }
            // Tell the slow peer we've moved on (it may answer anyway;
            // the duplicate guard in M_BLOCK swallows that).
            cancels.entry(p).or_default().push(c);
        }
        for (p, cids) in cancels {
            self.send_cancel(ctx, &p, cids);
        }
        let sids: Vec<u64> = self.sessions.keys().copied().collect();
        for sid in sids {
            self.connect_and_subscribe(ctx, sid);
            self.dispatch(ctx, sid);
        }
    }

    /// Node hook: a connection came up — subscribe any sessions that were
    /// waiting on a dial to this provider.
    pub fn on_peer_connected(&mut self, ctx: &mut Ctx, peer: PeerId) {
        let sids: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.providers.contains(&peer) && !s.subscribed.contains(&peer))
            .map(|(id, _)| *id)
            .collect();
        for sid in sids {
            self.connect_and_subscribe(ctx, sid);
            self.dispatch(ctx, sid);
        }
    }

    /// Node hook: peer disconnected — drop its stream and fail over.
    pub fn on_peer_disconnected(&mut self, ctx: &mut Ctx, peer: PeerId) {
        self.streams.remove(&peer);
        self.drop_peer(ctx, peer);
    }

    /// Node hook: a dial to `peer` failed (or it has no usable address) —
    /// stop treating it as a holder and fail over to other providers.
    pub fn on_peer_unreachable(&mut self, ctx: &mut Ctx, peer: PeerId) {
        self.drop_peer(ctx, peer);
    }

    fn drop_peer(&mut self, ctx: &mut Ctx, peer: PeerId) {
        self.peers.remove(&peer);
        self.dialing.remove(&peer);
        self.choked_set.retain(|(p, _)| *p != peer);
        // The peer's interest memory died with the connection: forget
        // what we announced so a reconnect re-polls from scratch.
        self.announced.remove(&peer);
        self.pending_haves.remove(&peer);
        for m in self.pending_root_interest.values_mut() {
            m.remove(&peer);
        }
        self.pending_root_interest.retain(|_, m| !m.is_empty());
        for int in self.interest.values_mut() {
            int.remove(&peer);
        }
        self.interest.retain(|_, m| !m.is_empty());
        let mut affected: BTreeSet<u64> = BTreeSet::new();
        for w in self.wants.values_mut() {
            let touched = w.haves.remove(&peer)
                | w.lacks.remove(&peer)
                | w.tried.remove(&peer)
                | w.inflight.remove(&peer).is_some();
            if touched {
                affected.extend(w.sessions.iter().copied());
            }
        }
        affected.extend(
            self.sessions
                .iter()
                .filter(|(_, s)| s.providers.contains(&peer))
                .map(|(id, _)| *id),
        );
        for sid in affected {
            if let Some(s) = self.sessions.get_mut(&sid) {
                s.providers.remove(&peer);
                s.subscribed.remove(&peer);
            }
            self.dispatch(ctx, sid);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip() {
        let m = BitswapMsg {
            kind: M_WANT,
            cids: vec![Cid::of(b"a"), Cid::of(b"b")],
            ..BitswapMsg::default()
        };
        assert_eq!(BitswapMsg::decode(&m.encode()).unwrap(), m);
        let m = BitswapMsg {
            kind: M_BLOCK,
            cids: vec![Cid::of(b"xyz")],
            block: b"xyz".into(),
            ..BitswapMsg::default()
        };
        assert_eq!(BitswapMsg::decode(&m.encode()).unwrap(), m);
        let m = BitswapMsg {
            kind: M_WANT_HAVE,
            cids: vec![Cid::of(b"q"), Cid::of(b"r"), Cid::of(b"s")],
            ..BitswapMsg::default()
        };
        assert_eq!(BitswapMsg::decode(&m.encode()).unwrap(), m);
        let m = BitswapMsg {
            kind: M_HAVE,
            root: Some(Cid::of(b"root")),
            indexes: RangeSet::from_iter([0u64, 1, 2, 9]).encode(),
            ..BitswapMsg::default()
        };
        assert_eq!(BitswapMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn decode_buf_block_is_zero_copy() {
        let m = BitswapMsg {
            kind: M_BLOCK,
            cids: vec![Cid::of(b"big")],
            block: vec![6u8; 64 * 1024].into(),
            ..BitswapMsg::default()
        };
        let wire = m.encode_buf();
        let d = BitswapMsg::decode_buf(&wire).unwrap();
        assert_eq!(d, m);
        assert_eq!(wire.ref_count(), 2, "block shares the wire buffer");
    }

    #[test]
    fn legacy_encoding_byte_identical() {
        // A message without compact fields must encode exactly as it did
        // before fields 4/5 existed: old and new nodes interoperate
        // bytewise, and old decoders skip the new fields as unknown.
        let m = BitswapMsg {
            kind: M_WANT_HAVE,
            cids: vec![Cid::of(b"q"), Cid::of(b"r")],
            ..BitswapMsg::default()
        };
        let mut w = PbWriter::new();
        w.uint(1, M_WANT_HAVE);
        w.bytes_always(2, Cid::of(b"q").as_bytes());
        w.bytes_always(2, Cid::of(b"r").as_bytes());
        assert_eq!(m.encode(), w.finish());
    }

    #[test]
    fn compact_roundtrip_and_wire_size() {
        let chunks: Vec<Cid> = (0..10_000u64).map(|i| Cid::of(&i.to_le_bytes())).collect();
        let root = Cid::of(b"manifest-root");
        let mut bs = Bitswap::new();
        bs.compact_control = true;
        bs.note_manifest(root, &chunks);
        let m = bs.make_msg(M_WANT_HAVE, chunks.clone());
        assert_eq!(m.root, Some(root));
        assert!(m.cids.is_empty());
        let wire = m.encode();
        // kind + 34B root field + ~5B index field vs 10k × 34B legacy.
        assert!(wire.len() <= 64, "compact wire size {}", wire.len());
        let legacy = BitswapMsg {
            kind: M_WANT_HAVE,
            cids: chunks.clone(),
            ..BitswapMsg::default()
        };
        assert!(legacy.encode().len() > 10_000 * 32);
        // The decode side materializes the identical cid set.
        let d = BitswapMsg::decode(&wire).unwrap();
        let set = RangeSet::decode(&d.indexes).unwrap();
        let back: Vec<Cid> = set.iter().map(|i| chunks[i as usize]).collect();
        assert_eq!(back, chunks);
    }

    #[test]
    fn make_msg_falls_back_without_manifest() {
        let mut bs = Bitswap::new();
        bs.compact_control = true;
        let cids = vec![Cid::of(b"a"), Cid::of(b"b")];
        let m = bs.make_msg(M_WANT, cids.clone());
        assert_eq!(m.root, None);
        assert_eq!(m.cids, cids);
        // Mixed / partially-registered sets also fall back.
        bs.note_manifest(Cid::of(b"r1"), &[Cid::of(b"a")]);
        let m = bs.make_msg(M_WANT, cids.clone());
        assert_eq!(m.root, None);
        assert_eq!(m.cids, cids);
        // Compact off keeps the legacy encoding even with a manifest.
        bs.compact_control = false;
        bs.note_manifest(Cid::of(b"r2"), &cids);
        let m = bs.make_msg(M_WANT, cids.clone());
        assert_eq!(m.root, None);
        assert_eq!(m.cids, cids);
    }

    #[test]
    fn ledger_debt_ratio() {
        let mut l = Ledger::default();
        assert!(l.debt_ratio() < 1e-9);
        l.bytes_sent = 100;
        l.bytes_received = 50;
        assert!(l.debt_ratio() > 1.9 && l.debt_ratio() < 2.1);
    }

    #[test]
    fn tiebreak_hash_is_node_diverse() {
        // Two different local peers must not rank chunks identically —
        // otherwise every fetcher in a swarm starts on the same chunk.
        let cids: Vec<Cid> = (0..32u8).map(|i| Cid::of(&[i])).collect();
        let order = |seed: u64| {
            let mut v: Vec<(u64, usize)> = cids
                .iter()
                .enumerate()
                .map(|(i, c)| (mix(&[seed, id64(c.as_bytes())]), i))
                .collect();
            v.sort_unstable();
            v.into_iter().map(|(_, i)| i).collect::<Vec<_>>()
        };
        let a = order(1);
        let b = order(2);
        assert_ne!(a, b);
        assert_eq!(a, order(1), "stable within one node");
    }
}
