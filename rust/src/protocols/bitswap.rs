//! Bitswap-style block exchange: wantlists, per-peer ledgers and
//! multi-provider fetch sessions.
//!
//! Protocol `/lattica/bitswap/1`: one persistent stream per peer pair,
//! carrying WANT / HAVE / BLOCK / CANCEL messages. A [`Session`] fetches a
//! set of CIDs by striping wants across providers, re-striping on timeout
//! or miss — this is the "decentralized CDN" data path of Fig. 1(2/3).

use super::Ctx;
use crate::content::{Blockstore, Cid};
use crate::identity::PeerId;
use crate::netsim::{Time, SECOND};
use crate::util::buf::Buf;
use crate::wire::{encode_pooled, Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::{HashMap, HashSet, VecDeque};

pub const BITSWAP_PROTO: &str = "/lattica/bitswap/1";

/// Re-stripe unanswered wants after this long.
pub const WANT_TIMEOUT: Time = SECOND;

const M_WANT: u64 = 1;
const M_BLOCK: u64 = 2;
const M_HAVE: u64 = 3;
const M_DONT_HAVE: u64 = 4;
const M_CANCEL: u64 = 5;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct BitswapMsg {
    pub kind: u64,
    pub cids: Vec<Cid>,
    /// BLOCK: payload (one per message keeps frames small). Shared
    /// zero-copy with the blockstore — serving a block to N peers bumps a
    /// reference count N times instead of cloning the bytes.
    pub block: Buf,
}

impl Message for BitswapMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        for c in &self.cids {
            w.bytes_always(2, c.as_bytes());
        }
        w.bytes(3, &self.block);
    }

    fn decode(buf: &[u8]) -> Result<BitswapMsg> {
        let mut m = BitswapMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.cids.push(Cid::from_bytes(f.as_bytes()?)?),
                3 => m.block = Buf::copy_from_slice(f.as_bytes()?),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }

    /// Zero-copy decode: the block becomes a slice of `buf`, which the
    /// blockstore can retain without another copy.
    fn decode_buf(buf: &Buf) -> Result<BitswapMsg> {
        let mut m = BitswapMsg::default();
        PbReader::new(buf.as_slice()).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.cids.push(Cid::from_bytes(f.as_bytes()?)?),
                3 => {
                    f.as_bytes()?; // wire-type check
                    m.block = buf.slice(f.data_start..f.data_start + f.data.len());
                }
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

/// Per-peer accounting (the paper's "ledger": debt ratio for fairness).
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

impl Ledger {
    /// Debt ratio: >1 means we've sent them more than received.
    pub fn debt_ratio(&self) -> f64 {
        self.bytes_sent as f64 / (self.bytes_received as f64 + 1.0)
    }
}

#[derive(Debug)]
pub enum BitswapEvent {
    /// A wanted block arrived (already stored + verified).
    BlockReceived { cid: Cid, from: PeerId, size: usize },
    /// A fetch session completed (all CIDs present locally).
    SessionComplete { session: u64 },
    /// A session cannot progress: no provider had some CID.
    SessionStalled { session: u64, missing: Vec<Cid> },
}

struct WantState {
    sessions: HashSet<u64>,
    asked: Vec<PeerId>,
    current: Option<(PeerId, Time)>, // who we asked last + deadline
}

struct Session {
    #[allow(dead_code)]
    id: u64,
    wanted: HashSet<Cid>,
    providers: Vec<PeerId>,
}

/// The Bitswap behaviour. The node owns the [`Blockstore`] and passes it in.
pub struct Bitswap {
    /// Open bitswap streams per peer: peer → (cid, stream).
    streams: HashMap<PeerId, (u64, u64)>,
    pub ledgers: HashMap<PeerId, Ledger>,
    wants: HashMap<Cid, WantState>,
    sessions: HashMap<u64, Session>,
    next_session: u64,
    events: VecDeque<BitswapEvent>,
    rr_counter: usize,
}

impl Default for Bitswap {
    fn default() -> Self {
        Self::new()
    }
}

impl Bitswap {
    pub fn new() -> Bitswap {
        Bitswap {
            streams: HashMap::new(),
            ledgers: HashMap::new(),
            wants: HashMap::new(),
            sessions: HashMap::new(),
            next_session: 1,
            events: VecDeque::new(),
            rr_counter: 0,
        }
    }

    pub fn poll_event(&mut self) -> Option<BitswapEvent> {
        self.events.pop_front()
    }

    fn stream_to(&mut self, ctx: &mut Ctx, peer: &PeerId) -> Result<(u64, u64)> {
        if let Some(&(cid, stream)) = self.streams.get(peer) {
            return Ok((cid, stream));
        }
        // Block transfer is background traffic: the bulk class keeps
        // model sync from starving pings, DCUtR and gossip on a
        // congested uplink.
        let (cid, stream) =
            ctx.open_stream_class(peer, BITSWAP_PROTO, crate::transport::TrafficClass::Bulk)?;
        self.streams.insert(*peer, (cid, stream));
        Ok((cid, stream))
    }

    /// Start fetching `cids` from `providers` (already-connected or known
    /// peers). Returns the session id.
    pub fn fetch(
        &mut self,
        ctx: &mut Ctx,
        store: &Blockstore,
        cids: Vec<Cid>,
        providers: Vec<PeerId>,
    ) -> u64 {
        let id = self.next_session;
        self.next_session += 1;
        let wanted: HashSet<Cid> = cids.iter().filter(|c| !store.has(c)).copied().collect();
        let session = Session {
            id,
            wanted: wanted.clone(),
            providers: providers.clone(),
        };
        self.sessions.insert(id, session);
        if wanted.is_empty() {
            self.events.push_back(BitswapEvent::SessionComplete { session: id });
            return id;
        }
        for c in wanted {
            let w = self.wants.entry(c).or_insert_with(|| WantState {
                sessions: HashSet::new(),
                asked: Vec::new(),
                current: None,
            });
            w.sessions.insert(id);
        }
        self.dispatch_wants(ctx, id);
        id
    }

    /// Stripe pending wants of a session across its providers.
    fn dispatch_wants(&mut self, ctx: &mut Ctx, session_id: u64) {
        let now = ctx.now();
        let Some(s) = self.sessions.get(&session_id) else { return };
        let providers = s.providers.clone();
        if providers.is_empty() {
            let missing: Vec<Cid> = s.wanted.iter().copied().collect();
            self.events.push_back(BitswapEvent::SessionStalled {
                session: session_id,
                missing,
            });
            return;
        }
        let wanted: Vec<Cid> = s.wanted.iter().copied().collect();
        // Group assignments per provider to batch WANT messages.
        let mut batches: HashMap<PeerId, Vec<Cid>> = HashMap::new();
        let mut stalled = Vec::new();
        for c in wanted {
            let w = self.wants.get_mut(&c).expect("want state");
            if let Some((_, deadline)) = w.current {
                if deadline > now {
                    continue; // outstanding ask still fresh
                }
            }
            // Pick the next provider we haven't asked for this cid.
            let next = providers
                .iter()
                .cycle()
                .skip(self.rr_counter % providers.len())
                .take(providers.len())
                .find(|p| !w.asked.contains(p))
                .copied();
            self.rr_counter += 1;
            match next {
                Some(p) => {
                    w.asked.push(p);
                    w.current = Some((p, now + WANT_TIMEOUT));
                    batches.entry(p).or_default().push(c);
                }
                None => {
                    // Every provider asked once: start a fresh round next
                    // tick (providers may come online / reconnect) and tell
                    // the application we're cycling.
                    w.asked.clear();
                    w.current = None;
                    stalled.push(c);
                }
            }
        }
        for (peer, cids) in batches {
            match self.stream_to(ctx, &peer) {
                Ok((cid, stream)) => {
                    let msg = BitswapMsg {
                        kind: M_WANT,
                        cids,
                        block: Buf::new(),
                    };
                    let _ = encode_pooled(&msg, |b| ctx.send(cid, stream, b));
                }
                Err(_) => {
                    // Not connected (yet): roll the asks back so the next
                    // tick retries this provider instead of skipping it.
                    for c in cids {
                        if let Some(w) = self.wants.get_mut(&c) {
                            w.asked.retain(|p| p != &peer);
                            w.current = None;
                        }
                    }
                }
            }
        }
        if !stalled.is_empty() {
            self.events.push_back(BitswapEvent::SessionStalled {
                session: session_id,
                missing: stalled,
            });
        }
    }

    /// Node hook: message on a bitswap stream. Blocks are sliced zero-copy
    /// out of `msg` and stored without another copy.
    pub fn handle_msg(
        &mut self,
        ctx: &mut Ctx,
        store: &mut Blockstore,
        peer: PeerId,
        conn: u64,
        stream: u64,
        msg: &Buf,
    ) -> Result<()> {
        // Remember the stream for replies.
        self.streams.entry(peer).or_insert((conn, stream));
        let m = BitswapMsg::decode_buf(msg)?;
        match m.kind {
            M_WANT => {
                for c in m.cids {
                    match store.get(&c) {
                        Some(block) => {
                            // Serving N peers bumps the refcount N times;
                            // the block bytes are never cloned.
                            self.ledgers.entry(peer).or_default().bytes_sent +=
                                block.len() as u64;
                            let reply = BitswapMsg {
                                kind: M_BLOCK,
                                cids: vec![c],
                                block,
                            };
                            let _ = ctx.send_buf(conn, stream, reply.encode_buf());
                        }
                        None => {
                            let reply = BitswapMsg {
                                kind: M_DONT_HAVE,
                                cids: vec![c],
                                block: Buf::new(),
                            };
                            let _ = encode_pooled(&reply, |b| ctx.send(conn, stream, b));
                        }
                    }
                }
            }
            M_BLOCK => {
                let Some(&c) = m.cids.first() else { return Ok(()) };
                if store.put_verified(c, m.block.clone()).is_err() {
                    crate::log_warn!("peer {peer} sent corrupt block for {c}");
                    return Ok(());
                }
                self.ledgers.entry(peer).or_default().bytes_received += m.block.len() as u64;
                self.events.push_back(BitswapEvent::BlockReceived {
                    cid: c,
                    from: peer,
                    size: m.block.len(),
                });
                self.on_block_arrived(ctx, store, c);
            }
            M_DONT_HAVE => {
                for c in m.cids {
                    let sessions: Vec<u64> = if let Some(w) = self.wants.get_mut(&c) {
                        if let Some((p, _)) = w.current {
                            if p == peer {
                                w.current = None; // re-stripe now
                            }
                        }
                        w.sessions.iter().copied().collect()
                    } else {
                        Vec::new()
                    };
                    for sid in sessions {
                        self.dispatch_wants(ctx, sid);
                    }
                }
            }
            M_HAVE | M_CANCEL => {}
            _ => {}
        }
        Ok(())
    }

    fn on_block_arrived(&mut self, ctx: &mut Ctx, store: &Blockstore, c: Cid) {
        let Some(w) = self.wants.remove(&c) else { return };
        for sid in w.sessions {
            let complete = {
                let Some(s) = self.sessions.get_mut(&sid) else { continue };
                s.wanted.remove(&c);
                s.wanted.is_empty()
            };
            if complete {
                self.sessions.remove(&sid);
                self.events
                    .push_back(BitswapEvent::SessionComplete { session: sid });
            } else {
                let _ = ctx;
            }
        }
        let _ = store;
    }

    /// Node hook: periodic tick — retry timed-out and unsent wants
    /// (a want can be unsent if the provider connection wasn't up yet).
    pub fn tick(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        let due: Vec<u64> = self
            .wants
            .values()
            .filter(|w| w.current.map_or(true, |(_, d)| d <= now))
            .flat_map(|w| w.sessions.iter().copied())
            .collect();
        let unique: HashSet<u64> = due.into_iter().collect();
        for sid in unique {
            self.dispatch_wants(ctx, sid);
        }
    }

    /// Node hook: peer disconnected — drop its stream and re-stripe.
    pub fn on_peer_disconnected(&mut self, ctx: &mut Ctx, peer: PeerId) {
        self.streams.remove(&peer);
        let affected: HashSet<u64> = self
            .wants
            .values_mut()
            .filter_map(|w| {
                if let Some((p, _)) = w.current {
                    if p == peer {
                        w.current = None;
                        return Some(w.sessions.iter().copied().collect::<Vec<_>>());
                    }
                }
                None
            })
            .flatten()
            .collect();
        for sid in affected {
            if let Some(s) = self.sessions.get_mut(&sid) {
                s.providers.retain(|p| *p != peer);
            }
            self.dispatch_wants(ctx, sid);
        }
    }

    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip() {
        let m = BitswapMsg {
            kind: M_WANT,
            cids: vec![Cid::of(b"a"), Cid::of(b"b")],
            block: Buf::new(),
        };
        assert_eq!(BitswapMsg::decode(&m.encode()).unwrap(), m);
        let m = BitswapMsg {
            kind: M_BLOCK,
            cids: vec![Cid::of(b"xyz")],
            block: b"xyz".into(),
        };
        assert_eq!(BitswapMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn decode_buf_block_is_zero_copy() {
        let m = BitswapMsg {
            kind: M_BLOCK,
            cids: vec![Cid::of(b"big")],
            block: vec![6u8; 64 * 1024].into(),
        };
        let wire = m.encode_buf();
        let d = BitswapMsg::decode_buf(&wire).unwrap();
        assert_eq!(d, m);
        assert_eq!(wire.ref_count(), 2, "block shares the wire buffer");
    }

    #[test]
    fn ledger_debt_ratio() {
        let mut l = Ledger::default();
        assert!(l.debt_ratio() < 1e-9);
        l.bytes_sent = 100;
        l.bytes_received = 50;
        assert!(l.debt_ratio() > 1.9 && l.debt_ratio() < 2.1);
    }
}
