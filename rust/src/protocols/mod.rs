//! Application protocols running over swarm streams.
//!
//! Each protocol is a state machine owned by the node; the node routes
//! [`crate::swarm::SwarmEvent`]s to it by protocol name and passes a
//! [`Ctx`] so handlers can open streams, send messages and dial peers.

pub mod kad;
pub mod bitswap;
pub mod gossip;
pub mod ping;
pub mod identify;
pub mod autonat;
pub mod rendezvous;
pub mod dcutr;

use crate::identity::PeerId;
use crate::multiaddr::Multiaddr;
use crate::netsim::Net;
use crate::swarm::Swarm;

/// Mutable access to the node's networking for protocol handlers.
pub struct Ctx<'a> {
    pub swarm: &'a mut Swarm,
    pub net: &'a mut Net,
}

impl<'a> Ctx<'a> {
    pub fn new(swarm: &'a mut Swarm, net: &'a mut Net) -> Ctx<'a> {
        Ctx { swarm, net }
    }

    pub fn local_peer(&self) -> PeerId {
        self.swarm.local_peer
    }

    pub fn now(&self) -> crate::netsim::Time {
        self.net.now()
    }

    /// Open a stream to a connected peer (class derived from the proto).
    pub fn open_stream(&mut self, peer: &PeerId, proto: &str) -> anyhow::Result<(u64, u64)> {
        self.swarm.open_stream(self.net, peer, proto)
    }

    /// Open a stream with an explicit scheduling class (control > unary
    /// RPC > streaming > bulk; see `transport/sched.rs`).
    pub fn open_stream_class(
        &mut self,
        peer: &PeerId,
        proto: &str,
        class: crate::transport::TrafficClass,
    ) -> anyhow::Result<(u64, u64)> {
        self.swarm.open_stream_class(self.net, peer, proto, class)
    }

    /// Send a message (copied into the stream framing).
    pub fn send(&mut self, cid: u64, stream: u64, msg: &[u8]) -> anyhow::Result<()> {
        self.swarm.send_msg(self.net, cid, stream, msg)
    }

    /// Send an owned message; large payloads ride zero-copy to the packetizer.
    pub fn send_buf(&mut self, cid: u64, stream: u64, msg: crate::util::Buf) -> anyhow::Result<()> {
        self.swarm.send_msg_buf(self.net, cid, stream, msg)
    }

    pub fn finish(&mut self, cid: u64, stream: u64) {
        self.swarm.finish_stream(self.net, cid, stream)
    }

    pub fn reset(&mut self, cid: u64, stream: u64, error: &str) {
        self.swarm.reset_stream(self.net, cid, stream, error)
    }

    /// Dial a peer if not already connected; returns true if connected now,
    /// false if a dial is in flight (caller retries on ConnEstablished).
    pub fn ensure_connected(&mut self, peer: &PeerId) -> anyhow::Result<bool> {
        if self.swarm.is_connected(peer) {
            return Ok(true);
        }
        let addr = self
            .swarm
            .peerstore
            .addrs(peer)
            .first()
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no known address for {peer}"))?;
        self.dial(&addr)?;
        Ok(false)
    }

    pub fn dial(&mut self, addr: &Multiaddr) -> anyhow::Result<u64> {
        self.swarm.dial(self.net, addr)
    }
}
