//! Identify (`/lattica/id/1`): on connection, exchange listen addresses,
//! supported protocols, and the *observed* remote address — the raw
//! material for AutoNAT reachability inference.

use super::Ctx;
use crate::identity::PeerId;
use crate::multiaddr::SimAddr;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::VecDeque;

pub const IDENTIFY_PROTO: &str = "/lattica/id/1";

#[derive(Clone, Debug, Default, PartialEq)]
pub struct IdentifyMsg {
    /// Our listen port (host is implicit from the connection).
    pub listen_port: u32,
    pub protocols: Vec<String>,
    /// The remote's address as we observe it on this connection.
    pub observed_host: u32,
    pub observed_port: u32,
    pub agent: String,
}

impl Message for IdentifyMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.listen_port as u64);
        for p in &self.protocols {
            w.bytes_always(2, p.as_bytes());
        }
        w.uint(3, self.observed_host as u64);
        w.uint(4, self.observed_port as u64);
        w.string(5, &self.agent);
    }

    fn decode(buf: &[u8]) -> Result<IdentifyMsg> {
        let mut m = IdentifyMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.listen_port = f.as_u64() as u32,
                2 => m.protocols.push(f.as_string()?),
                3 => m.observed_host = f.as_u64() as u32,
                4 => m.observed_port = f.as_u64() as u32,
                5 => m.agent = f.as_string()?,
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

#[derive(Debug)]
pub enum IdentifyEvent {
    /// Peer told us how it sees us.
    ObservedSelf { addr: SimAddr, by: PeerId },
    /// We learned a peer's info.
    Identified { peer: PeerId, protocols: Vec<String> },
}

#[derive(Default)]
pub struct Identify {
    pub local_protocols: Vec<String>,
    events: VecDeque<IdentifyEvent>,
}

impl Identify {
    pub fn new(protocols: Vec<String>) -> Identify {
        Identify {
            local_protocols: protocols,
            events: VecDeque::new(),
        }
    }

    pub fn poll_event(&mut self) -> Option<IdentifyEvent> {
        self.events.pop_front()
    }

    /// On connection established: push our identify to the peer.
    pub fn on_peer_connected(&mut self, ctx: &mut Ctx, peer: PeerId, remote_addr: SimAddr) {
        let msg = IdentifyMsg {
            listen_port: ctx.swarm.local_addr.port as u32,
            protocols: self.local_protocols.clone(),
            observed_host: remote_addr.host,
            observed_port: remote_addr.port as u32,
            agent: "lattica/0.1".into(),
        };
        if let Ok((cid, stream)) = ctx.open_stream(&peer, IDENTIFY_PROTO) {
            let _ = ctx.send(cid, stream, &msg.encode());
            ctx.finish(cid, stream);
        }
    }

    /// Inbound identify message.
    pub fn handle_msg(&mut self, ctx: &mut Ctx, peer: PeerId, msg: &[u8]) -> Result<()> {
        let m = IdentifyMsg::decode(msg)?;
        ctx.swarm
            .peerstore
            .set_protocols(peer, m.protocols.clone());
        let observed = SimAddr::new(m.observed_host, m.observed_port as u16);
        if !ctx.swarm.external_addrs.contains(&observed) {
            ctx.swarm.external_addrs.push(observed);
        }
        self.events.push_back(IdentifyEvent::ObservedSelf {
            addr: observed,
            by: peer,
        });
        self.events.push_back(IdentifyEvent::Identified {
            peer,
            protocols: m.protocols,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip() {
        let m = IdentifyMsg {
            listen_port: 4001,
            protocols: vec!["/lattica/rpc/1".into(), "/lattica/kad/1".into()],
            observed_host: 7,
            observed_port: 30000,
            agent: "lattica/0.1".into(),
        };
        assert_eq!(IdentifyMsg::decode(&m.encode()).unwrap(), m);
    }
}
