//! Rendezvous (`/lattica/rendezvous/1`): namespace registration/discovery
//! for expedited peer discovery (faster than a DHT walk for small groups).

use super::Ctx;
use crate::identity::PeerId;
use crate::netsim::{Time, SECOND};
use crate::protocols::kad::PeerEntry;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};

pub const RENDEZVOUS_PROTO: &str = "/lattica/rendezvous/1";

/// Registrations expire after this long without refresh.
pub const REGISTRATION_TTL: Time = 120 * SECOND;

const M_REGISTER: u64 = 1;
const M_DISCOVER: u64 = 2;
const M_PEERS: u64 = 3;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct RendezvousMsg {
    pub kind: u64,
    pub namespace: String,
    pub port: u32,
    pub peers: Vec<PeerEntry>,
}

impl Message for RendezvousMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.string(2, &self.namespace);
        w.uint(3, self.port as u64);
        for e in &self.peers {
            let mut inner = PbWriter::new();
            inner.bytes_always(1, e.id.as_bytes());
            inner.uint(2, e.host as u64);
            inner.uint(3, e.port as u64);
            w.bytes_always(4, &inner.finish());
        }
    }

    fn decode(buf: &[u8]) -> Result<RendezvousMsg> {
        let mut m = RendezvousMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.kind = f.as_u64(),
                2 => m.namespace = f.as_string()?,
                3 => m.port = f.as_u64() as u32,
                4 => {
                    let mut e = PeerEntry::default();
                    PbReader::new(f.as_bytes()?).for_each(|g| {
                        match g.number {
                            1 => {
                                let b = g.as_bytes()?;
                                anyhow::ensure!(b.len() == 32, "bad id");
                                let mut d = [0u8; 32];
                                d.copy_from_slice(b);
                                e.id = PeerId(d);
                            }
                            2 => e.host = g.as_u64() as u32,
                            3 => e.port = g.as_u64() as u16,
                            _ => {}
                        }
                        Ok(())
                    })?;
                    m.peers.push(e);
                }
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

#[derive(Debug)]
pub enum RendezvousEvent {
    Discovered {
        namespace: String,
        peers: Vec<PeerEntry>,
    },
}

/// Both roles: server (registry) and client.
pub struct Rendezvous {
    /// Server: namespace → (peer, entry, expiry).
    registry: HashMap<String, Vec<(PeerEntry, Time)>>,
    /// Client: discover requests awaiting replies, by (cid, stream).
    pending: HashMap<(u64, u64), String>,
    events: VecDeque<RendezvousEvent>,
    pub is_server: bool,
}

impl Rendezvous {
    pub fn new(is_server: bool) -> Rendezvous {
        Rendezvous {
            registry: HashMap::new(),
            pending: HashMap::new(),
            events: VecDeque::new(),
            is_server,
        }
    }

    pub fn poll_event(&mut self) -> Option<RendezvousEvent> {
        self.events.pop_front()
    }

    /// Register ourselves under `namespace` at a rendezvous server.
    pub fn register(&mut self, ctx: &mut Ctx, server: &PeerId, namespace: &str) -> Result<()> {
        let msg = RendezvousMsg {
            kind: M_REGISTER,
            namespace: namespace.to_string(),
            port: ctx.swarm.local_addr.port as u32,
            peers: vec![],
        };
        let (cid, stream) = ctx.open_stream(server, RENDEZVOUS_PROTO)?;
        ctx.send(cid, stream, &msg.encode())?;
        ctx.finish(cid, stream);
        Ok(())
    }

    /// Ask a rendezvous server who is registered under `namespace`.
    pub fn discover(&mut self, ctx: &mut Ctx, server: &PeerId, namespace: &str) -> Result<()> {
        let msg = RendezvousMsg {
            kind: M_DISCOVER,
            namespace: namespace.to_string(),
            ..Default::default()
        };
        let (cid, stream) = ctx.open_stream(server, RENDEZVOUS_PROTO)?;
        ctx.send(cid, stream, &msg.encode())?;
        self.pending.insert((cid, stream), namespace.to_string());
        Ok(())
    }

    /// Inbound message (either role).
    pub fn handle_msg(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        remote_host: u32,
        cid: u64,
        stream: u64,
        msg: &[u8],
    ) -> Result<()> {
        let m = RendezvousMsg::decode(msg)?;
        match m.kind {
            M_REGISTER if self.is_server => {
                let entry = PeerEntry {
                    id: peer,
                    host: remote_host,
                    port: m.port as u16,
                };
                let now = ctx.now();
                let list = self.registry.entry(m.namespace).or_default();
                list.retain(|(e, _)| e.id != peer);
                list.push((entry, now + REGISTRATION_TTL));
            }
            M_DISCOVER if self.is_server => {
                let now = ctx.now();
                let peers: Vec<PeerEntry> = self
                    .registry
                    .get(&m.namespace)
                    .map(|l| {
                        l.iter()
                            .filter(|(_, exp)| *exp > now)
                            .map(|(e, _)| e.clone())
                            .collect()
                    })
                    .unwrap_or_default();
                let reply = RendezvousMsg {
                    kind: M_PEERS,
                    namespace: m.namespace,
                    peers,
                    ..Default::default()
                };
                ctx.send(cid, stream, &reply.encode())?;
                ctx.finish(cid, stream);
            }
            M_PEERS => {
                if let Some(ns) = self.pending.remove(&(cid, stream)) {
                    for e in &m.peers {
                        ctx.swarm.peerstore.add_address(e.id, e.to_multiaddr());
                    }
                    self.events.push_back(RendezvousEvent::Discovered {
                        namespace: ns,
                        peers: m.peers,
                    });
                }
            }
            _ => {}
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    #[test]
    fn msg_roundtrip() {
        let m = RendezvousMsg {
            kind: M_PEERS,
            namespace: "inference-cluster-a".into(),
            port: 4001,
            peers: vec![PeerEntry {
                id: Keypair::from_seed(1).peer_id(),
                host: 4,
                port: 4001,
            }],
        };
        assert_eq!(RendezvousMsg::decode(&m.encode()).unwrap(), m);
    }
}
