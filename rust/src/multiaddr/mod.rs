//! Multiaddresses for the simulated network.
//!
//! A simplified multiaddr covering what the simulator can express:
//!
//! ```text
//! /sim/<host>/udp/<port>                  — raw datagram endpoint
//! /sim/<host>/udp/<port>/tcpl             — TCP-like reliable transport
//! /sim/<host>/udp/<port>/quicl            — QUIC-like transport
//! /sim/<host>/udp/<port>/quicl/p2p/<id>   — with an expected peer
//! /sim/<host>/udp/<port>/quicl/p2p/<relay>/p2p-circuit/p2p/<target>
//! ```
//!
//! `<host>` is the simulator host id (u32), mirroring an IP; NATs translate
//! `(host, port)` pairs exactly like IPv4 NATs translate `ip:port`.

use crate::identity::PeerId;
use crate::util::hex;
use anyhow::{bail, Context, Result};
use std::fmt;

/// Transport selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Proto {
    /// TCP-like reliable byte stream (upgraded with Noise + mux).
    TcpLike,
    /// QUIC-like multiplexed transport (integrated crypto).
    QuicLike,
}

impl Proto {
    pub fn tag(&self) -> &'static str {
        match self {
            Proto::TcpLike => "tcpl",
            Proto::QuicLike => "quicl",
        }
    }
}

/// A network-layer endpoint in the simulator: like `ip:port`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SimAddr {
    pub host: u32,
    pub port: u16,
}

impl SimAddr {
    pub fn new(host: u32, port: u16) -> SimAddr {
        SimAddr { host, port }
    }
}

impl fmt::Debug for SimAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.host, self.port)
    }
}

impl fmt::Display for SimAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.host, self.port)
    }
}

/// A full multiaddr: endpoint + transport + optional peer + optional relay.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Multiaddr {
    pub addr: SimAddr,
    pub proto: Proto,
    /// Expected peer at this address.
    pub peer: Option<PeerId>,
    /// If set, this is a circuit address: dial `addr` (the relay), then ask
    /// for a circuit to `target`.
    pub circuit_target: Option<PeerId>,
}

impl Multiaddr {
    pub fn direct(addr: SimAddr, proto: Proto) -> Multiaddr {
        Multiaddr {
            addr,
            proto,
            peer: None,
            circuit_target: None,
        }
    }

    pub fn with_peer(mut self, peer: PeerId) -> Multiaddr {
        self.peer = Some(peer);
        self
    }

    /// Circuit address via `relay_addr` (which must carry the relay's peer id)
    /// to `target`.
    pub fn circuit(relay: Multiaddr, target: PeerId) -> Multiaddr {
        Multiaddr {
            addr: relay.addr,
            proto: relay.proto,
            peer: relay.peer,
            circuit_target: Some(target),
        }
    }

    pub fn is_circuit(&self) -> bool {
        self.circuit_target.is_some()
    }

    /// Parse the textual form.
    pub fn parse(s: &str) -> Result<Multiaddr> {
        let parts: Vec<&str> = s.split('/').filter(|p| !p.is_empty()).collect();
        let mut iter = parts.into_iter();
        let mut next = |what: &str| -> Result<&str> {
            iter.next().with_context(|| format!("missing {what}"))
        };
        if next("sim")? != "sim" {
            bail!("multiaddr must start with /sim");
        }
        let host: u32 = next("host")?.parse().context("bad host")?;
        if next("udp")? != "udp" {
            bail!("expected /udp component");
        }
        let port: u16 = next("port")?.parse().context("bad port")?;
        let mut ma = Multiaddr::direct(SimAddr::new(host, port), Proto::QuicLike);
        let mut have_proto = false;
        while let Ok(component) = next("component") {
            match component {
                "tcpl" => {
                    ma.proto = Proto::TcpLike;
                    have_proto = true;
                }
                "quicl" => {
                    ma.proto = Proto::QuicLike;
                    have_proto = true;
                }
                "p2p" => {
                    let id_hex = next("peer id")?;
                    let digest = hex::decode(id_hex).context("bad peer id hex")?;
                    anyhow::ensure!(digest.len() == 32, "peer id must be 32 bytes");
                    let mut d = [0u8; 32];
                    d.copy_from_slice(&digest);
                    let pid = PeerId(d);
                    if ma.peer.is_none() {
                        ma.peer = Some(pid);
                    } else if ma.circuit_target.is_none() {
                        bail!("peer after peer requires /p2p-circuit");
                    } else {
                        ma.circuit_target = Some(pid);
                    }
                }
                "p2p-circuit" => {
                    anyhow::ensure!(ma.peer.is_some(), "circuit requires relay peer id");
                    // Mark pending target; replaced by following /p2p.
                    ma.circuit_target = Some(PeerId([0u8; 32]));
                }
                other => bail!("unknown multiaddr component {other:?}"),
            }
        }
        let _ = have_proto;
        if ma.circuit_target == Some(PeerId([0u8; 32])) {
            bail!("p2p-circuit missing target peer");
        }
        Ok(ma)
    }
}

impl fmt::Display for Multiaddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "/sim/{}/udp/{}/{}",
            self.addr.host,
            self.addr.port,
            self.proto.tag()
        )?;
        if let Some(p) = &self.peer {
            write!(f, "/p2p/{}", hex::encode(&p.0))?;
        }
        if let Some(t) = &self.circuit_target {
            write!(f, "/p2p-circuit/p2p/{}", hex::encode(&t.0))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    #[test]
    fn parse_direct() {
        let ma = Multiaddr::parse("/sim/7/udp/4001/quicl").unwrap();
        assert_eq!(ma.addr, SimAddr::new(7, 4001));
        assert_eq!(ma.proto, Proto::QuicLike);
        assert!(ma.peer.is_none());
    }

    #[test]
    fn roundtrip_with_peer() {
        let pid = Keypair::from_seed(3).peer_id();
        let ma = Multiaddr::direct(SimAddr::new(1, 9), Proto::TcpLike).with_peer(pid);
        let s = ma.to_string();
        assert_eq!(Multiaddr::parse(&s).unwrap(), ma);
    }

    #[test]
    fn roundtrip_circuit() {
        let relay_id = Keypair::from_seed(1).peer_id();
        let target_id = Keypair::from_seed(2).peer_id();
        let relay = Multiaddr::direct(SimAddr::new(5, 4001), Proto::QuicLike).with_peer(relay_id);
        let circ = Multiaddr::circuit(relay, target_id);
        assert!(circ.is_circuit());
        let s = circ.to_string();
        let back = Multiaddr::parse(&s).unwrap();
        assert_eq!(back, circ);
        assert_eq!(back.circuit_target, Some(target_id));
    }

    #[test]
    fn bad_addrs_rejected() {
        assert!(Multiaddr::parse("/ip4/1.2.3.4/tcp/80").is_err());
        assert!(Multiaddr::parse("/sim/x/udp/1").is_err());
        assert!(Multiaddr::parse("/sim/1/udp/99999").is_err());
        assert!(Multiaddr::parse("/sim/1/udp/1/bogus").is_err());
        assert!(Multiaddr::parse("/sim/1/udp/1/quicl/p2p/zz").is_err());
        assert!(Multiaddr::parse("/sim/1/udp/1/quicl/p2p-circuit").is_err());
    }
}
