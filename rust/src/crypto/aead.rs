//! Authenticated encryption: AES-128-CTR + HMAC-SHA256, encrypt-then-MAC.
//!
//! Interface mirrors an AEAD (96-bit nonce, associated data, 16-byte tag).
//! Used by the Noise transport ([`CipherState`]) with a counter nonce per
//! direction, giving replay protection and in-order integrity.
//!
//! The hot path is the in-place pair [`seal_in_place`] / [`open_in_place`]:
//! the transport builds a packet in one buffer and encrypts the frame
//! section where it sits, so sealing adds no copy beyond the keystream XOR
//! (see DESIGN.md §Buffer ownership).

use super::aes128::Aes128;
use crate::util::bytes::ct_eq;
use anyhow::{bail, Result};

/// AES-128 in CTR mode with a big-endian 128-bit counter.
struct Ctr128 {
    cipher: Aes128,
    counter: [u8; 16],
    keystream: [u8; 16],
    /// Bytes of `keystream` already consumed (16 = exhausted).
    used: usize,
}

impl Ctr128 {
    fn new(key: &[u8; 16], iv: [u8; 16]) -> Ctr128 {
        Ctr128 {
            cipher: Aes128::new(key),
            counter: iv,
            keystream: [0u8; 16],
            used: 16,
        }
    }

    fn refill(&mut self) {
        self.keystream = self.counter;
        self.cipher.encrypt_block(&mut self.keystream);
        self.used = 0;
        // Increment the 128-bit big-endian counter.
        for i in (0..16).rev() {
            self.counter[i] = self.counter[i].wrapping_add(1);
            if self.counter[i] != 0 {
                break;
            }
        }
    }

    fn apply_keystream(&mut self, data: &mut [u8]) {
        let mut i = 0usize;
        // Finish a partially used keystream block.
        while self.used < 16 && i < data.len() {
            data[i] ^= self.keystream[self.used];
            self.used += 1;
            i += 1;
        }
        // Whole blocks: generate keystream per 16 B and XOR as u128.
        while data.len() - i >= 16 {
            self.refill();
            self.used = 16;
            let ks = u128::from_le_bytes(self.keystream);
            let chunk: &mut [u8] = &mut data[i..i + 16];
            let v = u128::from_le_bytes(chunk.try_into().unwrap()) ^ ks;
            chunk.copy_from_slice(&v.to_le_bytes());
            i += 16;
        }
        // Tail.
        if i < data.len() {
            self.refill();
            while i < data.len() {
                data[i] ^= self.keystream[self.used];
                self.used += 1;
                i += 1;
            }
        }
    }
}

/// Tag length in bytes.
pub const TAG_LEN: usize = 16;

fn ctr_for(key_enc: &[u8], nonce: &[u8; 12]) -> Ctr128 {
    let mut iv = [0u8; 16];
    iv[..12].copy_from_slice(nonce);
    let mut ek = [0u8; 16];
    ek.copy_from_slice(key_enc);
    Ctr128::new(&ek, iv)
}

/// Encrypt `buf[from..]` in place with `key` (32 bytes: 16 enc || 16 mac)
/// and append the 16-byte tag. The caller's buffer becomes ciphertext || tag
/// with no intermediate allocation.
pub fn seal_in_place(key: &[u8; 32], nonce: &[u8; 12], ad: &[u8], buf: &mut Vec<u8>, from: usize) {
    debug_assert!(from <= buf.len());
    let (ek, mk) = key.split_at(16);
    ctr_for(ek, nonce).apply_keystream(&mut buf[from..]);
    let tag = mac(mk, nonce, ad, &buf[from..]);
    buf.extend_from_slice(&tag[..TAG_LEN]);
}

/// Verify and decrypt a ciphertext || tag slice in place; returns the
/// plaintext length (`buf.len() - TAG_LEN`). Fails on MAC mismatch (buffer
/// left unmodified). The caller narrows its view to the returned length.
pub fn open_in_place_slice(key: &[u8; 32], nonce: &[u8; 12], ad: &[u8], buf: &mut [u8]) -> Result<usize> {
    if buf.len() < TAG_LEN {
        bail!("ciphertext shorter than tag");
    }
    let ct_len = buf.len() - TAG_LEN;
    let (ek, mk) = key.split_at(16);
    let (ct, tag) = buf.split_at_mut(ct_len);
    let want = mac(mk, nonce, ad, ct);
    if !ct_eq(&want[..TAG_LEN], tag) {
        bail!("authentication tag mismatch");
    }
    ctr_for(ek, nonce).apply_keystream(ct);
    Ok(ct_len)
}

/// Verify and decrypt `buf[from..]` (ciphertext || tag) in place. On success
/// the buffer is truncated to end at the plaintext. Fails on MAC mismatch
/// (buffer left unmodified).
pub fn open_in_place(key: &[u8; 32], nonce: &[u8; 12], ad: &[u8], buf: &mut Vec<u8>, from: usize) -> Result<()> {
    debug_assert!(from <= buf.len());
    let n = open_in_place_slice(key, nonce, ad, &mut buf[from..])?;
    buf.truncate(from + n);
    Ok(())
}

/// Encrypt `plaintext` with `key` (32 bytes: 16 enc || 16 mac), 12-byte
/// nonce, and associated data. Output is ciphertext || tag.
pub fn seal(key: &[u8; 32], nonce: &[u8; 12], ad: &[u8], plaintext: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(plaintext.len() + TAG_LEN);
    out.extend_from_slice(plaintext);
    seal_in_place(key, nonce, ad, &mut out, 0);
    out
}

/// Open ciphertext || tag. Fails on MAC mismatch.
pub fn open(key: &[u8; 32], nonce: &[u8; 12], ad: &[u8], sealed: &[u8]) -> Result<Vec<u8>> {
    let mut buf = sealed.to_vec();
    open_in_place(key, nonce, ad, &mut buf, 0)?;
    Ok(buf)
}

fn mac(mk: &[u8], nonce: &[u8; 12], ad: &[u8], ct: &[u8]) -> [u8; 32] {
    // MAC over len(ad) || ad || nonce || ct to prevent boundary ambiguity.
    super::hkdf::hmac_sha256_parts(mk, &[&(ad.len() as u64).to_be_bytes(), ad, nonce, ct])
}

/// Per-direction transport cipher with a counter nonce (Noise CipherState).
pub struct CipherState {
    key: [u8; 32],
    counter: u64,
}

impl CipherState {
    pub fn new(key: [u8; 32]) -> CipherState {
        CipherState { key, counter: 0 }
    }

    fn next_nonce(&mut self) -> [u8; 12] {
        let mut n = [0u8; 12];
        n[4..].copy_from_slice(&self.counter.to_be_bytes());
        self.counter += 1;
        n
    }

    /// Encrypt the next message in sequence.
    pub fn seal(&mut self, ad: &[u8], plaintext: &[u8]) -> Vec<u8> {
        let n = self.next_nonce();
        seal(&self.key, &n, ad, plaintext)
    }

    /// Decrypt the next message in sequence.
    pub fn open(&mut self, ad: &[u8], sealed: &[u8]) -> Result<Vec<u8>> {
        let n = self.next_nonce();
        open(&self.key, &n, ad, sealed)
    }

    pub fn messages_processed(&self) -> u64 {
        self.counter
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    #[test]
    fn seal_open_roundtrip() {
        let key = [42u8; 32];
        let nonce = [1u8; 12];
        let sealed = seal(&key, &nonce, b"ad", b"hello world");
        assert_eq!(sealed.len(), 11 + TAG_LEN);
        let opened = open(&key, &nonce, b"ad", &sealed).unwrap();
        assert_eq!(opened, b"hello world");
    }

    #[test]
    fn known_answer_vector() {
        // Cross-checked against an independent AES-128-CTR + HMAC-SHA256
        // implementation (keys 00..1f, nonce 00..0b).
        let key: [u8; 32] = core::array::from_fn(|i| i as u8);
        let nonce: [u8; 12] = core::array::from_fn(|i| i as u8);
        let sealed = seal(&key, &nonce, b"ad", b"hello world, hello lattica!!");
        assert_eq!(
            hex::encode(&sealed),
            "9e0210fb9da0b26ecd135ffccbc8cac52f34bbcd4c01d0d7e9f65f8200ad415bfd1e89b2b6e84ecc4cc51dbb"
        );
    }

    #[test]
    fn ctr_keystream_vector() {
        // Keystream = AES-128(counter) with a big-endian counter starting at
        // nonce || 0^4; checked against an independent implementation.
        let key: [u8; 16] = core::array::from_fn(|i| i as u8);
        let nonce: [u8; 12] = core::array::from_fn(|i| i as u8);
        let mut data = vec![0u8; 33];
        ctr_for(&key, &nonce).apply_keystream(&mut data);
        assert_eq!(
            hex::encode(&data),
            "f6677c97f280c501bf7f3bd0eba0afa9435b9ba12d75a4be8a977ea3cd01189093"
        );
    }

    #[test]
    fn in_place_matches_copying_api() {
        let key = [9u8; 32];
        let nonce = [3u8; 12];
        let pt: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        // Seal in place after a 7-byte header; the header is the AD.
        let header = b"pkt-hdr";
        let mut buf = header.to_vec();
        buf.extend_from_slice(&pt);
        seal_in_place(&key, &nonce, header, &mut buf, header.len());
        assert_eq!(&buf[header.len()..], &seal(&key, &nonce, header, &pt)[..]);
        // Open in place restores the plaintext.
        open_in_place(&key, &nonce, header, &mut buf, header.len()).unwrap();
        assert_eq!(&buf[..header.len()], header);
        assert_eq!(&buf[header.len()..], &pt[..]);
    }

    #[test]
    fn open_in_place_rejects_tamper_without_modifying() {
        let key = [5u8; 32];
        let nonce = [0u8; 12];
        let mut buf = seal(&key, &nonce, b"", b"payload");
        buf[0] ^= 1;
        let before = buf.clone();
        assert!(open_in_place(&key, &nonce, b"", &mut buf, 0).is_err());
        assert_eq!(buf, before, "failed open must not modify the buffer");
    }

    #[test]
    fn tamper_detected() {
        let key = [42u8; 32];
        let nonce = [1u8; 12];
        let mut sealed = seal(&key, &nonce, b"", b"secret");
        sealed[0] ^= 1;
        assert!(open(&key, &nonce, b"", &sealed).is_err());
    }

    #[test]
    fn tag_tamper_detected() {
        let key = [42u8; 32];
        let nonce = [1u8; 12];
        let mut sealed = seal(&key, &nonce, b"", b"secret");
        let n = sealed.len();
        sealed[n - 1] ^= 0x80;
        assert!(open(&key, &nonce, b"", &sealed).is_err());
    }

    #[test]
    fn wrong_ad_rejected() {
        let key = [9u8; 32];
        let nonce = [0u8; 12];
        let sealed = seal(&key, &nonce, b"right", b"data");
        assert!(open(&key, &nonce, b"wrong", &sealed).is_err());
    }

    #[test]
    fn wrong_nonce_rejected() {
        let key = [9u8; 32];
        let sealed = seal(&key, &[0u8; 12], b"", b"data");
        assert!(open(&key, &[1u8; 12], b"", &sealed).is_err());
    }

    #[test]
    fn empty_plaintext() {
        let key = [3u8; 32];
        let nonce = [7u8; 12];
        let sealed = seal(&key, &nonce, b"", b"");
        assert_eq!(sealed.len(), TAG_LEN);
        assert_eq!(open(&key, &nonce, b"", &sealed).unwrap(), b"");
    }

    #[test]
    fn ciphertext_differs_from_plaintext() {
        let key = [5u8; 32];
        let nonce = [0u8; 12];
        let pt = vec![0u8; 64];
        let sealed = seal(&key, &nonce, b"", &pt);
        assert_ne!(&sealed[..64], &pt[..]);
    }

    #[test]
    fn cipherstate_sequence() {
        let mut tx = CipherState::new([8u8; 32]);
        let mut rx = CipherState::new([8u8; 32]);
        for i in 0..10u32 {
            let msg = format!("message {i}");
            let sealed = tx.seal(b"", msg.as_bytes());
            let opened = rx.open(b"", &sealed).unwrap();
            assert_eq!(opened, msg.as_bytes());
        }
    }

    #[test]
    fn cipherstate_out_of_order_fails() {
        let mut tx = CipherState::new([8u8; 32]);
        let mut rx = CipherState::new([8u8; 32]);
        let m1 = tx.seal(b"", b"one");
        let _m2 = tx.seal(b"", b"two");
        // Skip m1: rx nonce counter now mismatches.
        let _ = rx.open(b"", &m1).unwrap();
        // Replaying m1 must fail (counter advanced).
        assert!(rx.open(b"", &m1).is_err());
    }

    #[test]
    fn large_message() {
        let key = [1u8; 32];
        let nonce = [2u8; 12];
        let pt: Vec<u8> = (0..100_000).map(|i| (i % 251) as u8).collect();
        let sealed = seal(&key, &nonce, b"big", &pt);
        assert_eq!(open(&key, &nonce, b"big", &sealed).unwrap(), pt);
    }
}
