//! Authenticated encryption: AES-128-CTR + HMAC-SHA256, encrypt-then-MAC.
//!
//! Interface mirrors an AEAD (96-bit nonce, associated data, 16-byte tag).
//! Used by the Noise transport ([`CipherState`]) with a counter nonce per
//! direction, giving replay protection and in-order integrity.

use crate::util::bytes::ct_eq;
use aes::cipher::{KeyIvInit, StreamCipher};
use anyhow::{bail, Result};

type Aes128Ctr = ctr_impl::Ctr128BE;

mod ctr_impl {
    //! AES-128 in CTR mode built from the block cipher (the `ctr` crate is
    //! not vendored, so we implement the big-endian 128-bit counter mode).
    use aes::cipher::{BlockEncrypt, KeyInit};
    use aes::Aes128;

    pub struct Ctr128BE {
        cipher: Aes128,
        counter: [u8; 16],
        keystream: [u8; 16],
        used: usize,
    }

    impl aes::cipher::KeyIvInit for Ctr128BE {
        fn new(key: &aes::cipher::Key<Self>, iv: &aes::cipher::Iv<Self>) -> Self {
            let mut counter = [0u8; 16];
            counter.copy_from_slice(iv);
            Ctr128BE {
                cipher: Aes128::new(key),
                counter,
                keystream: [0u8; 16],
                used: 16,
            }
        }
    }

    impl aes::cipher::AlgorithmName for Ctr128BE {
        fn write_alg_name(f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
            f.write_str("AES-128-CTR-BE")
        }
    }

    impl aes::cipher::IvSizeUser for Ctr128BE {
        type IvSize = aes::cipher::consts::U16;
    }

    impl aes::cipher::KeySizeUser for Ctr128BE {
        type KeySize = aes::cipher::consts::U16;
    }

    impl Ctr128BE {
        fn refill(&mut self) {
            let mut block = aes::cipher::generic_array::GenericArray::clone_from_slice(&self.counter);
            self.cipher.encrypt_block(&mut block);
            self.keystream.copy_from_slice(&block);
            self.used = 0;
            // Increment 128-bit big-endian counter.
            for i in (0..16).rev() {
                self.counter[i] = self.counter[i].wrapping_add(1);
                if self.counter[i] != 0 {
                    break;
                }
            }
        }
    }

    impl aes::cipher::StreamCipher for Ctr128BE {
        fn try_apply_keystream_inout(
            &mut self,
            mut buf: aes::cipher::inout::InOutBuf<'_, '_, u8>,
        ) -> Result<(), aes::cipher::StreamCipherError> {
            let data = buf.get_out();
            let mut i = 0usize;
            // Finish a partially used keystream block.
            while self.used < 16 && i < data.len() {
                data[i] ^= self.keystream[self.used];
                self.used += 1;
                i += 1;
            }
            // Whole blocks: generate keystream per 16B and XOR as u128.
            while data.len() - i >= 16 {
                self.refill();
                self.used = 16;
                let ks = u128::from_le_bytes(self.keystream);
                let chunk: &mut [u8] = &mut data[i..i + 16];
                let v = u128::from_le_bytes(chunk.try_into().unwrap()) ^ ks;
                chunk.copy_from_slice(&v.to_le_bytes());
                i += 16;
            }
            // Tail.
            if i < data.len() {
                self.refill();
                self.used = 0;
                while i < data.len() {
                    data[i] ^= self.keystream[self.used];
                    self.used += 1;
                    i += 1;
                }
            }
            Ok(())
        }
    }
}

/// Tag length in bytes.
pub const TAG_LEN: usize = 16;

/// Encrypt `plaintext` with `key` (32 bytes: 16 enc || 16 mac), 12-byte
/// nonce, and associated data. Output is ciphertext || tag.
pub fn seal(key: &[u8; 32], nonce: &[u8; 12], ad: &[u8], plaintext: &[u8]) -> Vec<u8> {
    let (ek, mk) = key.split_at(16);
    let mut iv = [0u8; 16];
    iv[..12].copy_from_slice(nonce);
    let mut out = plaintext.to_vec();
    let mut c = Aes128Ctr::new(ek.into(), &iv.into());
    c.apply_keystream(&mut out);
    let tag = mac(mk, nonce, ad, &out);
    out.extend_from_slice(&tag[..TAG_LEN]);
    out
}

/// Open ciphertext || tag. Fails on MAC mismatch.
pub fn open(key: &[u8; 32], nonce: &[u8; 12], ad: &[u8], sealed: &[u8]) -> Result<Vec<u8>> {
    if sealed.len() < TAG_LEN {
        bail!("ciphertext shorter than tag");
    }
    let (ct, tag) = sealed.split_at(sealed.len() - TAG_LEN);
    let (ek, mk) = key.split_at(16);
    let want = mac(mk, nonce, ad, ct);
    if !ct_eq(&want[..TAG_LEN], tag) {
        bail!("authentication tag mismatch");
    }
    let mut iv = [0u8; 16];
    iv[..12].copy_from_slice(nonce);
    let mut out = ct.to_vec();
    let mut c = Aes128Ctr::new(ek.into(), &iv.into());
    c.apply_keystream(&mut out);
    Ok(out)
}

fn mac(mk: &[u8], nonce: &[u8; 12], ad: &[u8], ct: &[u8]) -> [u8; 32] {
    // MAC over len(ad) || ad || nonce || ct to prevent boundary ambiguity.
    let mut data = Vec::with_capacity(8 + ad.len() + 12 + ct.len());
    data.extend_from_slice(&(ad.len() as u64).to_be_bytes());
    data.extend_from_slice(ad);
    data.extend_from_slice(nonce);
    data.extend_from_slice(ct);
    super::hkdf::hmac_sha256(mk, &data)
}

/// Per-direction transport cipher with a counter nonce (Noise CipherState).
pub struct CipherState {
    key: [u8; 32],
    counter: u64,
}

impl CipherState {
    pub fn new(key: [u8; 32]) -> CipherState {
        CipherState { key, counter: 0 }
    }

    fn next_nonce(&mut self) -> [u8; 12] {
        let mut n = [0u8; 12];
        n[4..].copy_from_slice(&self.counter.to_be_bytes());
        self.counter += 1;
        n
    }

    /// Encrypt the next message in sequence.
    pub fn seal(&mut self, ad: &[u8], plaintext: &[u8]) -> Vec<u8> {
        let n = self.next_nonce();
        seal(&self.key, &n, ad, plaintext)
    }

    /// Decrypt the next message in sequence.
    pub fn open(&mut self, ad: &[u8], sealed: &[u8]) -> Result<Vec<u8>> {
        let n = self.next_nonce();
        open(&self.key, &n, ad, sealed)
    }

    pub fn messages_processed(&self) -> u64 {
        self.counter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seal_open_roundtrip() {
        let key = [42u8; 32];
        let nonce = [1u8; 12];
        let sealed = seal(&key, &nonce, b"ad", b"hello world");
        assert_eq!(sealed.len(), 11 + TAG_LEN);
        let opened = open(&key, &nonce, b"ad", &sealed).unwrap();
        assert_eq!(opened, b"hello world");
    }

    #[test]
    fn tamper_detected() {
        let key = [42u8; 32];
        let nonce = [1u8; 12];
        let mut sealed = seal(&key, &nonce, b"", b"secret");
        sealed[0] ^= 1;
        assert!(open(&key, &nonce, b"", &sealed).is_err());
    }

    #[test]
    fn tag_tamper_detected() {
        let key = [42u8; 32];
        let nonce = [1u8; 12];
        let mut sealed = seal(&key, &nonce, b"", b"secret");
        let n = sealed.len();
        sealed[n - 1] ^= 0x80;
        assert!(open(&key, &nonce, b"", &sealed).is_err());
    }

    #[test]
    fn wrong_ad_rejected() {
        let key = [9u8; 32];
        let nonce = [0u8; 12];
        let sealed = seal(&key, &nonce, b"right", b"data");
        assert!(open(&key, &nonce, b"wrong", &sealed).is_err());
    }

    #[test]
    fn wrong_nonce_rejected() {
        let key = [9u8; 32];
        let sealed = seal(&key, &[0u8; 12], b"", b"data");
        assert!(open(&key, &[1u8; 12], b"", &sealed).is_err());
    }

    #[test]
    fn empty_plaintext() {
        let key = [3u8; 32];
        let nonce = [7u8; 12];
        let sealed = seal(&key, &nonce, b"", b"");
        assert_eq!(sealed.len(), TAG_LEN);
        assert_eq!(open(&key, &nonce, b"", &sealed).unwrap(), b"");
    }

    #[test]
    fn ciphertext_differs_from_plaintext() {
        let key = [5u8; 32];
        let nonce = [0u8; 12];
        let pt = vec![0u8; 64];
        let sealed = seal(&key, &nonce, b"", &pt);
        assert_ne!(&sealed[..64], &pt[..]);
    }

    #[test]
    fn cipherstate_sequence() {
        let mut tx = CipherState::new([8u8; 32]);
        let mut rx = CipherState::new([8u8; 32]);
        for i in 0..10u32 {
            let msg = format!("message {i}");
            let sealed = tx.seal(b"", msg.as_bytes());
            let opened = rx.open(b"", &sealed).unwrap();
            assert_eq!(opened, msg.as_bytes());
        }
    }

    #[test]
    fn cipherstate_out_of_order_fails() {
        let mut tx = CipherState::new([8u8; 32]);
        let mut rx = CipherState::new([8u8; 32]);
        let m1 = tx.seal(b"", b"one");
        let _m2 = tx.seal(b"", b"two");
        // Skip m1: rx nonce counter now mismatches.
        let _ = rx.open(b"", &m1).unwrap();
        // Replaying m1 must fail (counter advanced).
        assert!(rx.open(b"", &m1).is_err());
    }

    #[test]
    fn large_message() {
        let key = [1u8; 32];
        let nonce = [2u8; 12];
        let pt: Vec<u8> = (0..100_000).map(|i| (i % 251) as u8).collect();
        let sealed = seal(&key, &nonce, b"big", &pt);
        assert_eq!(open(&key, &nonce, b"big", &sealed).unwrap(), pt);
    }
}
