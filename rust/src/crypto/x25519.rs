//! X25519 Diffie–Hellman (RFC 7748), implemented from scratch.
//!
//! Field arithmetic over GF(2^255 − 19) with five 51-bit limbs and a
//! constant-time Montgomery ladder. Validated against the RFC 7748 test
//! vectors (including the 1 000-iteration vector) in the test module.

/// Element of GF(2^255 − 19), five 51-bit limbs, loosely reduced.
#[derive(Clone, Copy, Debug)]
struct Fe([u64; 5]);

const MASK51: u64 = (1 << 51) - 1;

impl Fe {
    const ZERO: Fe = Fe([0; 5]);
    const ONE: Fe = Fe([1, 0, 0, 0, 0]);

    fn from_bytes(b: &[u8; 32]) -> Fe {
        let load = |i: usize| -> u64 {
            let mut v = 0u64;
            for j in 0..8 {
                v |= (b[i + j] as u64) << (8 * j);
            }
            v
        };
        // 51 bits at offsets 0,51,102,153,204.
        let l0 = load(0) & MASK51;
        let l1 = (load(6) >> 3) & MASK51;
        let l2 = (load(12) >> 6) & MASK51;
        let l3 = (load(19) >> 1) & MASK51;
        let l4 = (load(24) >> 12) & ((1 << 51) - 1);
        Fe([l0, l1, l2, l3, l4])
    }

    fn to_bytes(self) -> [u8; 32] {
        // Fully reduce mod p.
        let mut t = self;
        t = t.carry();
        t = t.carry();
        // Compute t + 19, if >= 2^255 then subtract p by adding 19 & masking.
        let mut l = t.0;
        let mut q = (l[0].wrapping_add(19)) >> 51;
        q = (l[1].wrapping_add(q)) >> 51;
        q = (l[2].wrapping_add(q)) >> 51;
        q = (l[3].wrapping_add(q)) >> 51;
        q = (l[4].wrapping_add(q)) >> 51;
        l[0] = l[0].wrapping_add(19u64.wrapping_mul(q));
        let mut carry = l[0] >> 51;
        l[0] &= MASK51;
        l[1] = l[1].wrapping_add(carry);
        carry = l[1] >> 51;
        l[1] &= MASK51;
        l[2] = l[2].wrapping_add(carry);
        carry = l[2] >> 51;
        l[2] &= MASK51;
        l[3] = l[3].wrapping_add(carry);
        carry = l[3] >> 51;
        l[3] &= MASK51;
        l[4] = l[4].wrapping_add(carry);
        l[4] &= MASK51;

        let mut out = [0u8; 32];
        let write = |out: &mut [u8; 32], bitpos: usize, v: u64| {
            let byte = bitpos / 8;
            let shift = bitpos % 8;
            let mut acc = (v as u128) << shift;
            let mut i = byte;
            while acc != 0 && i < 32 {
                out[i] |= (acc & 0xff) as u8;
                acc >>= 8;
                i += 1;
            }
        };
        write(&mut out, 0, l[0]);
        write(&mut out, 51, l[1]);
        write(&mut out, 102, l[2]);
        write(&mut out, 153, l[3]);
        write(&mut out, 204, l[4]);
        out
    }

    #[inline]
    fn carry(self) -> Fe {
        let mut l = self.0;
        let c0 = l[0] >> 51;
        l[0] &= MASK51;
        l[1] += c0;
        let c1 = l[1] >> 51;
        l[1] &= MASK51;
        l[2] += c1;
        let c2 = l[2] >> 51;
        l[2] &= MASK51;
        l[3] += c2;
        let c3 = l[3] >> 51;
        l[3] &= MASK51;
        l[4] += c3;
        let c4 = l[4] >> 51;
        l[4] &= MASK51;
        l[0] += 19 * c4;
        Fe(l)
    }

    #[inline]
    fn add(self, o: Fe) -> Fe {
        Fe([
            self.0[0] + o.0[0],
            self.0[1] + o.0[1],
            self.0[2] + o.0[2],
            self.0[3] + o.0[3],
            self.0[4] + o.0[4],
        ])
        .carry()
    }

    #[inline]
    fn sub(self, o: Fe) -> Fe {
        // Add 2p to avoid underflow (limbs are < 2^52).
        const TWOP: [u64; 5] = [
            0xFFFFFFFFFFFDA * 2,
            0xFFFFFFFFFFFFE * 2,
            0xFFFFFFFFFFFFE * 2,
            0xFFFFFFFFFFFFE * 2,
            0xFFFFFFFFFFFFE * 2,
        ];
        Fe([
            self.0[0] + TWOP[0] - o.0[0],
            self.0[1] + TWOP[1] - o.0[1],
            self.0[2] + TWOP[2] - o.0[2],
            self.0[3] + TWOP[3] - o.0[3],
            self.0[4] + TWOP[4] - o.0[4],
        ])
        .carry()
    }

    #[inline]
    fn mul(self, o: Fe) -> Fe {
        let a = self.0;
        let b = o.0;
        let m = |x: u64, y: u64| x as u128 * y as u128;
        // Schoolbook with 19-fold wraparound.
        let b1_19 = b[1] * 19;
        let b2_19 = b[2] * 19;
        let b3_19 = b[3] * 19;
        let b4_19 = b[4] * 19;

        let c0 = m(a[0], b[0]) + m(a[1], b4_19) + m(a[2], b3_19) + m(a[3], b2_19) + m(a[4], b1_19);
        let c1 = m(a[0], b[1]) + m(a[1], b[0]) + m(a[2], b4_19) + m(a[3], b3_19) + m(a[4], b2_19);
        let c2 = m(a[0], b[2]) + m(a[1], b[1]) + m(a[2], b[0]) + m(a[3], b4_19) + m(a[4], b3_19);
        let c3 = m(a[0], b[3]) + m(a[1], b[2]) + m(a[2], b[1]) + m(a[3], b[0]) + m(a[4], b4_19);
        let c4 = m(a[0], b[4]) + m(a[1], b[3]) + m(a[2], b[2]) + m(a[3], b[1]) + m(a[4], b[0]);

        Self::reduce_wide([c0, c1, c2, c3, c4])
    }

    #[inline]
    fn square(self) -> Fe {
        self.mul(self)
    }

    #[inline]
    fn reduce_wide(c: [u128; 5]) -> Fe {
        let mut l = [0u64; 5];
        let mut carry: u128 = 0;
        for i in 0..5 {
            let v = c[i] + carry;
            l[i] = (v as u64) & MASK51;
            carry = v >> 51;
        }
        // carry < 2^77; fold back via *19.
        let mut extra = carry * 19;
        let mut i = 0;
        while extra != 0 {
            let v = l[i] as u128 + extra;
            l[i] = (v as u64) & MASK51;
            extra = v >> 51;
            i = (i + 1) % 5;
            if i == 0 {
                extra *= 19;
            }
        }
        Fe(l)
    }

    /// Multiply by small constant.
    #[inline]
    fn mul_small(self, k: u64) -> Fe {
        let mut c = [0u128; 5];
        for i in 0..5 {
            c[i] = self.0[i] as u128 * k as u128;
        }
        Self::reduce_wide(c)
    }

    /// Inversion via Fermat: a^(p-2).
    fn invert(self) -> Fe {
        // Addition chain from curve25519 reference.
        let z2 = self.square();
        let z8 = z2.square().square();
        let z9 = self.mul(z8);
        let z11 = z2.mul(z9);
        let z22 = z11.square();
        let z_5_0 = z9.mul(z22);
        let mut t = z_5_0;
        for _ in 0..5 {
            t = t.square();
        }
        let z_10_0 = t.mul(z_5_0);
        t = z_10_0;
        for _ in 0..10 {
            t = t.square();
        }
        let z_20_0 = t.mul(z_10_0);
        t = z_20_0;
        for _ in 0..20 {
            t = t.square();
        }
        let z_40_0 = t.mul(z_20_0);
        t = z_40_0;
        for _ in 0..10 {
            t = t.square();
        }
        let z_50_0 = t.mul(z_10_0);
        t = z_50_0;
        for _ in 0..50 {
            t = t.square();
        }
        let z_100_0 = t.mul(z_50_0);
        t = z_100_0;
        for _ in 0..100 {
            t = t.square();
        }
        let z_200_0 = t.mul(z_100_0);
        t = z_200_0;
        for _ in 0..50 {
            t = t.square();
        }
        let z_250_0 = t.mul(z_50_0);
        t = z_250_0;
        for _ in 0..5 {
            t = t.square();
        }
        t.mul(z11)
    }

    /// Constant-time conditional swap.
    #[inline]
    fn cswap(swap: u64, a: &mut Fe, b: &mut Fe) {
        let mask = 0u64.wrapping_sub(swap);
        for i in 0..5 {
            let t = mask & (a.0[i] ^ b.0[i]);
            a.0[i] ^= t;
            b.0[i] ^= t;
        }
    }
}

/// Scalar multiplication on the Montgomery curve (RFC 7748 §5).
fn scalarmult(scalar: &[u8; 32], u: &[u8; 32]) -> [u8; 32] {
    let mut k = *scalar;
    k[0] &= 248;
    k[31] &= 127;
    k[31] |= 64;

    // Mask the top bit of u per RFC 7748.
    let mut ub = *u;
    ub[31] &= 127;
    let x1 = Fe::from_bytes(&ub);

    let mut x2 = Fe::ONE;
    let mut z2 = Fe::ZERO;
    let mut x3 = x1;
    let mut z3 = Fe::ONE;
    let mut swap = 0u64;

    for t in (0..255).rev() {
        let k_t = ((k[t / 8] >> (t % 8)) & 1) as u64;
        swap ^= k_t;
        Fe::cswap(swap, &mut x2, &mut x3);
        Fe::cswap(swap, &mut z2, &mut z3);
        swap = k_t;

        let a = x2.add(z2);
        let aa = a.square();
        let b = x2.sub(z2);
        let bb = b.square();
        let e = aa.sub(bb);
        let c = x3.add(z3);
        let d = x3.sub(z3);
        let da = d.mul(a);
        let cb = c.mul(b);
        x3 = da.add(cb).square();
        z3 = x1.mul(da.sub(cb).square());
        x2 = aa.mul(bb);
        z2 = e.mul(aa.add(e.mul_small(121665)));
    }
    Fe::cswap(swap, &mut x2, &mut x3);
    Fe::cswap(swap, &mut z2, &mut z3);

    x2.mul(z2.invert()).to_bytes()
}

const BASEPOINT: [u8; 32] = {
    let mut b = [0u8; 32];
    b[0] = 9;
    b
};

/// An X25519 private key.
#[derive(Clone)]
pub struct StaticSecret([u8; 32]);

/// An X25519 public key.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct PublicKey(pub [u8; 32]);

impl StaticSecret {
    /// Derive a secret from 32 bytes of entropy.
    pub fn from_bytes(b: [u8; 32]) -> StaticSecret {
        StaticSecret(b)
    }

    /// Generate from the deterministic simulation RNG.
    pub fn generate(rng: &mut crate::util::Rng) -> StaticSecret {
        let mut b = [0u8; 32];
        rng.fill_bytes(&mut b);
        StaticSecret(b)
    }

    /// The corresponding public key.
    pub fn public_key(&self) -> PublicKey {
        PublicKey(scalarmult(&self.0, &BASEPOINT))
    }

    /// Diffie–Hellman shared secret.
    pub fn diffie_hellman(&self, their: &PublicKey) -> [u8; 32] {
        scalarmult(&self.0, &their.0)
    }

    pub fn as_bytes(&self) -> &[u8; 32] {
        &self.0
    }
}

impl PublicKey {
    pub fn from_bytes(b: &[u8]) -> anyhow::Result<PublicKey> {
        anyhow::ensure!(b.len() == 32, "public key must be 32 bytes");
        let mut k = [0u8; 32];
        k.copy_from_slice(b);
        Ok(PublicKey(k))
    }

    pub fn as_bytes(&self) -> &[u8; 32] {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    fn arr(s: &str) -> [u8; 32] {
        let v = hex::decode(s).unwrap();
        let mut a = [0u8; 32];
        a.copy_from_slice(&v);
        a
    }

    #[test]
    fn rfc7748_vector_1() {
        let k = arr("a546e36bf0527c9d3b16154b82465edd62144c0ac1fc5a18506a2244ba449ac4");
        let u = arr("e6db6867583030db3594c1a424b15f7c726624ec26b3353b10a903a6d0ab1c4c");
        let out = scalarmult(&k, &u);
        assert_eq!(
            hex::encode(&out),
            "c3da55379de9c6908e94ea4df28d084f32eccf03491c71f754b4075577a28552"
        );
    }

    #[test]
    fn rfc7748_vector_2() {
        let k = arr("4b66e9d4d1b4673c5ad22691957d6af5c11b6421e0ea01d42ca4169e7918ba0d");
        let u = arr("e5210f12786811d3f4b7959d0538ae2c31dbe7106fc03c3efc4cd549c715a493");
        let out = scalarmult(&k, &u);
        assert_eq!(
            hex::encode(&out),
            "95cbde9476e8907d7aade45cb4b873f88b595a68799fa152e6f8f7647aac7957"
        );
    }

    #[test]
    fn rfc7748_iterated_1000() {
        // RFC 7748 §5.2 iteration test (1 000 rounds; the 1M variant is too
        // slow for CI).
        let mut k = BASEPOINT;
        let mut u = BASEPOINT;
        for _ in 0..1000 {
            let r = scalarmult(&k, &u);
            u = k;
            k = r;
        }
        assert_eq!(
            hex::encode(&k),
            "684cf59ba83309552800ef566f2f4d3c1c3887c49360e3875f2eb94d99532c51"
        );
    }

    #[test]
    fn dh_agreement() {
        // RFC 7748 §6.1 key agreement vectors.
        let alice = StaticSecret::from_bytes(arr(
            "77076d0a7318a57d3c16c17251b26645df4c2f87ebc0992ab177fba51db92c2a",
        ));
        let bob = StaticSecret::from_bytes(arr(
            "5dab087e624a8a4b79e17f8b83800ee66f3bb1292618b6fd1c2f8b27ff88e0eb",
        ));
        assert_eq!(
            hex::encode(alice.public_key().as_bytes()),
            "8520f0098930a754748b7ddcb43ef75a0dbf3a0d26381af4eba4a98eaa9b4e6a"
        );
        assert_eq!(
            hex::encode(bob.public_key().as_bytes()),
            "de9edb7d7b7dc1b4d35b61c2ece435373f8343c85b78674dadfc7e146f882b4f"
        );
        let s1 = alice.diffie_hellman(&bob.public_key());
        let s2 = bob.diffie_hellman(&alice.public_key());
        assert_eq!(s1, s2);
        assert_eq!(
            hex::encode(&s1),
            "4a5d9d5ba4ce2de1728e3bf480350f25e07e21c947d19e3376f09b3c1e161742"
        );
    }

    #[test]
    fn random_dh_pairs_agree() {
        let mut rng = crate::util::Rng::new(77);
        for _ in 0..8 {
            let a = StaticSecret::generate(&mut rng);
            let b = StaticSecret::generate(&mut rng);
            assert_eq!(
                a.diffie_hellman(&b.public_key()),
                b.diffie_hellman(&a.public_key())
            );
        }
    }

    #[test]
    fn fe_roundtrip() {
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..64 {
            let mut b = [0u8; 32];
            rng.fill_bytes(&mut b);
            b[31] &= 0x7f; // < 2^255
            let fe = Fe::from_bytes(&b);
            // Values >= p won't roundtrip byte-identically; mask to < p by
            // clearing high bits enough for the test.
            b[31] &= 0x3f;
            let fe2 = Fe::from_bytes(&b);
            assert_eq!(Fe::from_bytes(&fe2.to_bytes()).to_bytes(), fe2.to_bytes());
            let _ = fe; // first value exercised from_bytes only
        }
    }

    #[test]
    fn fe_algebra() {
        let mut rng = crate::util::Rng::new(15);
        for _ in 0..32 {
            let mut ab = [0u8; 32];
            rng.fill_bytes(&mut ab);
            ab[31] &= 0x3f;
            let a = Fe::from_bytes(&ab);
            // a * 1 == a
            assert_eq!(a.mul(Fe::ONE).to_bytes(), a.carry().to_bytes());
            // a + 0 == a
            assert_eq!(a.add(Fe::ZERO).to_bytes(), a.carry().to_bytes());
            // a - a == 0
            assert_eq!(a.sub(a).to_bytes(), Fe::ZERO.to_bytes());
            // a * a^-1 == 1 (if a != 0)
            if a.to_bytes() != Fe::ZERO.to_bytes() {
                assert_eq!(a.mul(a.invert()).to_bytes(), Fe::ONE.to_bytes());
            }
        }
    }
}
