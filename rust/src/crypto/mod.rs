//! Cryptographic substrate for the secure channel.
//!
//! The paper upgrades every connection with authenticated encryption ("Noise
//! protocol or TLS 1.3, as provided by libp2p", §2). Neither a Noise nor a
//! TLS implementation is available offline, so this module builds one from
//! primitives:
//!
//! * [`x25519`] — RFC 7748 Curve25519 Diffie–Hellman (from scratch, 51-bit
//!   limb field arithmetic, Montgomery ladder).
//! * [`sha256`] — FIPS 180-4 SHA-256 (from scratch; validated against the
//!   NIST vectors).
//! * [`aes128`] — FIPS 197 AES-128 block encryption (from scratch; validated
//!   against the FIPS appendix vectors).
//! * [`hkdf`] — HMAC-SHA256 and HKDF-SHA256 (RFC 5869) over [`sha256`].
//! * [`aead`] — AES-128-CTR + HMAC-SHA256 encrypt-then-MAC AEAD with a
//!   Poly1305-style interface (nonce, associated data, 16-byte tag) and
//!   in-place seal/open for the zero-copy packet path.
//! * [`noise`] — a Noise-XX-shaped 3-message handshake providing mutual
//!   static-key authentication and forward secrecy, producing a pair of
//!   [`aead::CipherState`]s for transport encryption.
//!
//! Signatures for identity records use a hash-based scheme in
//! [`crate::identity`]; channel authentication binds static x25519 keys.

pub mod x25519;
pub mod sha256;
pub mod aes128;
pub mod hkdf;
pub mod aead;
pub mod noise;

pub use x25519::{PublicKey, StaticSecret};
