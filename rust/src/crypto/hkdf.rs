//! HMAC-SHA256 (RFC 2104) and HKDF-SHA256 (RFC 5869), built on the in-tree
//! [`super::sha256`] implementation.

use super::sha256::Sha256;

const BLOCK: usize = 64;

fn hmac_pads(key: &[u8]) -> ([u8; BLOCK], [u8; BLOCK]) {
    let mut k = [0u8; BLOCK];
    if key.len() > BLOCK {
        k[..32].copy_from_slice(&super::sha256::digest(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0x36u8; BLOCK];
    let mut opad = [0x5cu8; BLOCK];
    for i in 0..BLOCK {
        ipad[i] ^= k[i];
        opad[i] ^= k[i];
    }
    (ipad, opad)
}

/// HMAC-SHA256 over the concatenation of `parts` (no intermediate copy).
pub fn hmac_sha256_parts(key: &[u8], parts: &[&[u8]]) -> [u8; 32] {
    let (ipad, opad) = hmac_pads(key);
    let mut inner = Sha256::new();
    inner.update(ipad);
    for p in parts {
        inner.update(p);
    }
    let inner = inner.finalize();
    let mut outer = Sha256::new();
    outer.update(opad);
    outer.update(inner);
    outer.finalize()
}

/// HMAC-SHA256 convenience.
pub fn hmac_sha256(key: &[u8], data: &[u8]) -> [u8; 32] {
    hmac_sha256_parts(key, &[data])
}

/// HKDF-Extract.
pub fn extract(salt: &[u8], ikm: &[u8]) -> [u8; 32] {
    hmac_sha256(salt, ikm)
}

/// HKDF-Expand to `out.len()` bytes (≤ 255*32).
pub fn expand(prk: &[u8; 32], info: &[u8], out: &mut [u8]) {
    assert!(out.len() <= 255 * 32);
    let mut prev = [0u8; 32];
    let mut have_prev = false;
    let mut pos = 0;
    let mut counter = 1u8;
    while pos < out.len() {
        let t: &[u8] = if have_prev { &prev } else { &[] };
        let block = hmac_sha256_parts(prk, &[t, info, &[counter]]);
        prev = block;
        have_prev = true;
        let n = (out.len() - pos).min(32);
        out[pos..pos + n].copy_from_slice(&prev[..n]);
        pos += n;
        counter += 1;
    }
}

/// Extract-then-expand in one call.
pub fn hkdf(salt: &[u8], ikm: &[u8], info: &[u8], out: &mut [u8]) {
    let prk = extract(salt, ikm);
    expand(&prk, info, out);
}

/// Derive two 32-byte keys (the Noise HKDF2 pattern).
pub fn hkdf2(chaining_key: &[u8; 32], ikm: &[u8]) -> ([u8; 32], [u8; 32]) {
    let prk = extract(chaining_key, ikm);
    let mut out = [0u8; 64];
    expand(&prk, &[], &mut out);
    let mut a = [0u8; 32];
    let mut b = [0u8; 32];
    a.copy_from_slice(&out[..32]);
    b.copy_from_slice(&out[32..]);
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    #[test]
    fn hmac_known_vector() {
        // hmac_sha256(key="key", data="abc"), cross-checked with hashlib.
        assert_eq!(
            hex::encode(&hmac_sha256(b"key", b"abc")),
            "9c196e32dc0175f86f4b1cb89289d6619de6bee699e4c378e68309ed97a1a6ab"
        );
    }

    #[test]
    fn hmac_parts_equal_concat() {
        let key = b"some-key";
        let whole = hmac_sha256(key, b"abcdefghij");
        let parts = hmac_sha256_parts(key, &[b"abc", b"", b"defg", b"hij"]);
        assert_eq!(whole, parts);
        // Long keys are hashed first.
        let long_key = vec![7u8; 100];
        assert_eq!(
            hmac_sha256(&long_key, b"x"),
            hmac_sha256_parts(&long_key, &[b"x"])
        );
    }

    #[test]
    fn rfc5869_case_1() {
        let ikm = hex::decode("0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b").unwrap();
        let salt = hex::decode("000102030405060708090a0b0c").unwrap();
        let info = hex::decode("f0f1f2f3f4f5f6f7f8f9").unwrap();
        let prk = extract(&salt, &ikm);
        assert_eq!(
            hex::encode(&prk),
            "077709362c2e32df0ddc3f0dc47bba6390b6c73bb50f9c3122ec844ad7c2b3e5"
        );
        let mut okm = [0u8; 42];
        expand(&prk, &info, &mut okm);
        assert_eq!(
            hex::encode(&okm),
            "3cb25f25faacd57a90434f64d0362f2a2d2d0a90cf1a5a4c5db02d56ecc4c5bf34007208d5b887185865"
        );
    }

    #[test]
    fn rfc5869_case_3_empty() {
        let ikm = [0x0b; 22];
        let prk = extract(&[], &ikm);
        let mut okm = [0u8; 42];
        expand(&prk, &[], &mut okm);
        assert_eq!(
            hex::encode(&okm),
            "8da4e775a563c18f715f802a063c5a31b8a11f5c5ee1879ec3454e5f3c738d2d9d201395faa4b61a96c8"
        );
    }

    #[test]
    fn hkdf2_splits() {
        let ck = [7u8; 32];
        let (a, b) = hkdf2(&ck, b"input");
        assert_ne!(a, b);
        let (a2, b2) = hkdf2(&ck, b"input");
        assert_eq!(a, a2);
        assert_eq!(b, b2);
        let (a3, _) = hkdf2(&ck, b"other");
        assert_ne!(a, a3);
    }
}
