//! AES-128 block encryption (FIPS 197), implemented from scratch for the
//! offline build. Only encryption is provided — CTR mode (see [`super::aead`])
//! uses the forward cipher for both directions.
//!
//! Validated against the FIPS 197 appendix vectors in the test module.

/// The S-box (forward substitution table).
const SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7, 0xab, 0x76,
    0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4, 0x72, 0xc0,
    0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15,
    0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2, 0xeb, 0x27, 0xb2, 0x75,
    0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0, 0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84,
    0x53, 0xd1, 0x00, 0xed, 0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf,
    0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8,
    0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5, 0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2,
    0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73,
    0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee, 0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb,
    0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c, 0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79,
    0xe7, 0xc8, 0x37, 0x6d, 0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08,
    0xba, 0x78, 0x25, 0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a,
    0x70, 0x3e, 0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e,
    0xe1, 0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f, 0xb0, 0x54, 0xbb, 0x16,
];

/// Key-schedule round constants.
const RCON: [u8; 10] = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1b, 0x36];

/// xtime: multiply by 2 in GF(2^8) modulo x^8 + x^4 + x^3 + x + 1.
#[inline]
fn xtime(a: u8) -> u8 {
    (a << 1) ^ (((a >> 7) & 1) * 0x1b)
}

/// An expanded AES-128 key (11 round keys of 16 bytes).
#[derive(Clone)]
pub struct Aes128 {
    round_keys: [[u8; 16]; 11],
}

impl Aes128 {
    pub fn new(key: &[u8; 16]) -> Aes128 {
        // 44 words of 4 bytes.
        let mut w = [[0u8; 4]; 44];
        for (i, word) in w.iter_mut().take(4).enumerate() {
            word.copy_from_slice(&key[i * 4..i * 4 + 4]);
        }
        for i in 4..44 {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                // RotWord + SubWord + Rcon.
                t = [SBOX[t[1] as usize], SBOX[t[2] as usize], SBOX[t[3] as usize], SBOX[t[0] as usize]];
                t[0] ^= RCON[i / 4 - 1];
            }
            for j in 0..4 {
                w[i][j] = w[i - 4][j] ^ t[j];
            }
        }
        let mut round_keys = [[0u8; 16]; 11];
        for r in 0..11 {
            for c in 0..4 {
                round_keys[r][c * 4..c * 4 + 4].copy_from_slice(&w[r * 4 + c]);
            }
        }
        Aes128 { round_keys }
    }

    /// Encrypt one 16-byte block in place. State layout is column-major
    /// (byte i of the block is row i%4, column i/4), matching FIPS 197.
    pub fn encrypt_block(&self, block: &mut [u8; 16]) {
        let mut s = *block;
        add_round_key(&mut s, &self.round_keys[0]);
        for r in 1..10 {
            sub_bytes(&mut s);
            shift_rows(&mut s);
            mix_columns(&mut s);
            add_round_key(&mut s, &self.round_keys[r]);
        }
        sub_bytes(&mut s);
        shift_rows(&mut s);
        add_round_key(&mut s, &self.round_keys[10]);
        *block = s;
    }
}

#[inline]
fn add_round_key(s: &mut [u8; 16], rk: &[u8; 16]) {
    for i in 0..16 {
        s[i] ^= rk[i];
    }
}

#[inline]
fn sub_bytes(s: &mut [u8; 16]) {
    for b in s.iter_mut() {
        *b = SBOX[*b as usize];
    }
}

/// Rotate row r left by r positions. Row r of column c is byte c*4 + r.
#[inline]
fn shift_rows(s: &mut [u8; 16]) {
    // Row 1: shift left by 1.
    let t = s[1];
    s[1] = s[5];
    s[5] = s[9];
    s[9] = s[13];
    s[13] = t;
    // Row 2: shift left by 2 (two swaps).
    s.swap(2, 10);
    s.swap(6, 14);
    // Row 3: shift left by 3 (= right by 1).
    let t = s[15];
    s[15] = s[11];
    s[11] = s[7];
    s[7] = s[3];
    s[3] = t;
}

#[inline]
fn mix_columns(s: &mut [u8; 16]) {
    for c in 0..4 {
        let i = c * 4;
        let (a0, a1, a2, a3) = (s[i], s[i + 1], s[i + 2], s[i + 3]);
        s[i] = xtime(a0) ^ (xtime(a1) ^ a1) ^ a2 ^ a3;
        s[i + 1] = a0 ^ xtime(a1) ^ (xtime(a2) ^ a2) ^ a3;
        s[i + 2] = a0 ^ a1 ^ xtime(a2) ^ (xtime(a3) ^ a3);
        s[i + 3] = (xtime(a0) ^ a0) ^ a1 ^ a2 ^ xtime(a3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    #[test]
    fn fips197_appendix_c_vector() {
        // Key 000102...0f, plaintext 00112233...eeff.
        let key: [u8; 16] = core::array::from_fn(|i| i as u8);
        let mut block = [0u8; 16];
        hex_fill(&mut block, "00112233445566778899aabbccddeeff");
        Aes128::new(&key).encrypt_block(&mut block);
        assert_eq!(hex::encode(&block), "69c4e0d86a7b0430d8cdb78070b4c55a");
    }

    #[test]
    fn fips197_appendix_b_vector() {
        let mut key = [0u8; 16];
        hex_fill(&mut key, "2b7e151628aed2a6abf7158809cf4f3c");
        let mut block = [0u8; 16];
        hex_fill(&mut block, "3243f6a8885a308d313198a2e0370734");
        Aes128::new(&key).encrypt_block(&mut block);
        assert_eq!(hex::encode(&block), "3925841d02dc09fbdc118597196a0b32");
    }

    fn hex_fill(out: &mut [u8], s: &str) {
        let v = hex::decode(s).unwrap();
        out.copy_from_slice(&v);
    }
}
