//! Noise-XX-shaped handshake providing mutual authentication and forward
//! secrecy for Lattica connections.
//!
//! Pattern (initiator → responder):
//!
//! ```text
//!   msg1: -> e
//!   msg2: <- e, ee, s, es
//!   msg3: -> s, se
//! ```
//!
//! Static keys are x25519; each DH result is mixed into a chaining key with
//! HKDF, and handshake payloads after the first DH are encrypted. Both sides
//! finish with two [`CipherState`]s (one per direction) and learn the peer's
//! authenticated static public key, which `swarm` binds to the `PeerId`.

use super::aead::{self, CipherState};
use super::hkdf;
use super::sha256::Sha256;
use super::x25519::{PublicKey, StaticSecret};
use anyhow::{bail, Context, Result};

const PROTOCOL_NAME: &[u8] = b"Noise_XX_25519_AESCTRHMAC_SHA256/lattica";

struct SymmetricState {
    ck: [u8; 32],
    h: [u8; 32],
    key: Option<[u8; 32]>,
    nonce: u64,
}

impl SymmetricState {
    fn new() -> SymmetricState {
        let mut hasher = Sha256::new();
        hasher.update(PROTOCOL_NAME);
        let h: [u8; 32] = hasher.finalize().into();
        SymmetricState {
            ck: h,
            h,
            key: None,
            nonce: 0,
        }
    }

    fn mix_hash(&mut self, data: &[u8]) {
        let mut hasher = Sha256::new();
        hasher.update(self.h);
        hasher.update(data);
        self.h = hasher.finalize().into();
    }

    fn mix_key(&mut self, ikm: &[u8]) {
        let (ck, k) = hkdf::hkdf2(&self.ck, ikm);
        self.ck = ck;
        self.key = Some(k);
        self.nonce = 0;
    }

    fn nonce_bytes(&mut self) -> [u8; 12] {
        let mut n = [0u8; 12];
        n[4..].copy_from_slice(&self.nonce.to_be_bytes());
        self.nonce += 1;
        n
    }

    fn encrypt_and_hash(&mut self, plaintext: &[u8]) -> Vec<u8> {
        let out = match self.key {
            None => plaintext.to_vec(),
            Some(k) => {
                let n = self.nonce_bytes();
                aead::seal(&k, &n, &self.h, plaintext)
            }
        };
        self.mix_hash(&out);
        out
    }

    fn decrypt_and_hash(&mut self, data: &[u8]) -> Result<Vec<u8>> {
        let out = match self.key {
            None => data.to_vec(),
            Some(k) => {
                let n = self.nonce_bytes();
                aead::open(&k, &n, &self.h, data).context("handshake decryption failed")?
            }
        };
        self.mix_hash(data);
        Ok(out)
    }

}

/// Result of a completed handshake.
pub struct Transport {
    /// Cipher for messages we send.
    pub tx: CipherState,
    /// Cipher for messages we receive.
    pub rx: CipherState,
    /// Raw send key, for datagram transports that derive nonces from packet
    /// numbers instead of the sequential CipherState counter.
    pub tx_key: [u8; 32],
    /// Raw receive key.
    pub rx_key: [u8; 32],
    /// The peer's authenticated static key.
    pub remote_static: PublicKey,
    /// Handshake channel-binding hash.
    pub handshake_hash: [u8; 32],
}

enum Role {
    Initiator,
    Responder,
}

enum Step {
    I1,     // initiator: send e
    R1,     // responder: expect e
    I2,     // initiator: expect e,ee,s,es
    R2,     // responder: send e,ee,s,es
    I3,     // initiator: send s,se
    R3,     // responder: expect s,se
    Done,
}

/// Driving state machine for the XX handshake. `write_message` /
/// `read_message` alternate until [`HandshakeState::is_done`].
pub struct HandshakeState {
    role: Role,
    step: Step,
    ss: SymmetricState,
    s: StaticSecret,
    e: Option<StaticSecret>,
    re: Option<PublicKey>,
    rs: Option<PublicKey>,
    rng_seed: [u8; 32],
    eph_counter: u64,
}

impl HandshakeState {
    pub fn initiator(static_key: StaticSecret, rng: &mut crate::util::Rng) -> HandshakeState {
        let mut seed = [0u8; 32];
        rng.fill_bytes(&mut seed);
        let mut ss = SymmetricState::new();
        ss.mix_hash(b"");
        HandshakeState {
            role: Role::Initiator,
            step: Step::I1,
            ss,
            s: static_key,
            e: None,
            re: None,
            rs: None,
            rng_seed: seed,
            eph_counter: 0,
        }
    }

    pub fn responder(static_key: StaticSecret, rng: &mut crate::util::Rng) -> HandshakeState {
        let mut seed = [0u8; 32];
        rng.fill_bytes(&mut seed);
        let mut ss = SymmetricState::new();
        ss.mix_hash(b"");
        HandshakeState {
            role: Role::Responder,
            step: Step::R1,
            ss,
            s: static_key,
            e: None,
            re: None,
            rs: None,
            rng_seed: seed,
            eph_counter: 0,
        }
    }

    fn gen_ephemeral(&mut self) -> StaticSecret {
        // Deterministic per-handshake ephemeral derivation from the seeded RNG.
        let mut ikm = Vec::with_capacity(40);
        ikm.extend_from_slice(&self.rng_seed);
        ikm.extend_from_slice(&self.eph_counter.to_be_bytes());
        self.eph_counter += 1;
        let mut out = [0u8; 32];
        hkdf::hkdf(b"lattica-eph", &ikm, b"", &mut out);
        StaticSecret::from_bytes(out)
    }

    pub fn is_done(&self) -> bool {
        matches!(self.step, Step::Done)
    }

    /// True when it is our turn to produce a message.
    pub fn is_my_turn(&self) -> bool {
        matches!(
            (&self.role, &self.step),
            (Role::Initiator, Step::I1)
                | (Role::Initiator, Step::I3)
                | (Role::Responder, Step::R2)
        )
    }

    /// Produce the next handshake message with optional payload.
    pub fn write_message(&mut self, payload: &[u8]) -> Result<Vec<u8>> {
        match (&self.role, &self.step) {
            (Role::Initiator, Step::I1) => {
                // -> e
                let e = self.gen_ephemeral();
                let epub = e.public_key();
                self.e = Some(e);
                let mut msg = epub.as_bytes().to_vec();
                self.ss.mix_hash(epub.as_bytes());
                msg.extend_from_slice(&self.ss.encrypt_and_hash(payload));
                self.step = Step::I2;
                Ok(msg)
            }
            (Role::Responder, Step::R2) => {
                // <- e, ee, s, es
                let e = self.gen_ephemeral();
                let epub = e.public_key();
                let re = self.re.context("no remote ephemeral")?;
                let mut msg = epub.as_bytes().to_vec();
                self.ss.mix_hash(epub.as_bytes());
                self.ss.mix_key(&e.diffie_hellman(&re)); // ee
                let s_pub = self.s.public_key();
                msg.extend_from_slice(&self.ss.encrypt_and_hash(s_pub.as_bytes())); // s
                self.ss.mix_key(&self.s.diffie_hellman(&re)); // es (responder side: s · re)
                self.e = Some(e);
                msg.extend_from_slice(&self.ss.encrypt_and_hash(payload));
                self.step = Step::R3;
                Ok(msg)
            }
            (Role::Initiator, Step::I3) => {
                // -> s, se
                let re = self.re.context("no remote ephemeral")?;
                let s_pub = self.s.public_key();
                let mut msg = self.ss.encrypt_and_hash(s_pub.as_bytes());
                self.ss.mix_key(&self.s.diffie_hellman(&re)); // se
                msg.extend_from_slice(&self.ss.encrypt_and_hash(payload));
                self.step = Step::Done;
                Ok(msg)
            }
            _ => bail!("write_message called out of turn"),
        }
    }

    /// Consume the peer's handshake message, returning its payload.
    pub fn read_message(&mut self, msg: &[u8]) -> Result<Vec<u8>> {
        match (&self.role, &self.step) {
            (Role::Responder, Step::R1) => {
                // -> e
                if msg.len() < 32 {
                    bail!("handshake msg1 too short");
                }
                let re = PublicKey::from_bytes(&msg[..32])?;
                self.ss.mix_hash(re.as_bytes());
                self.re = Some(re);
                let payload = self.ss.decrypt_and_hash(&msg[32..])?;
                self.step = Step::R2;
                Ok(payload)
            }
            (Role::Initiator, Step::I2) => {
                // <- e, ee, s, es
                if msg.len() < 32 + 32 + aead::TAG_LEN {
                    bail!("handshake msg2 too short");
                }
                let re = PublicKey::from_bytes(&msg[..32])?;
                self.ss.mix_hash(re.as_bytes());
                self.re = Some(re);
                let e = self.e.as_ref().context("no local ephemeral")?;
                self.ss.mix_key(&e.diffie_hellman(&re)); // ee
                let s_end = 32 + 32 + aead::TAG_LEN;
                let rs_bytes = self.ss.decrypt_and_hash(&msg[32..s_end])?;
                let rs = PublicKey::from_bytes(&rs_bytes)?;
                self.ss.mix_key(&e.diffie_hellman(&rs)); // es (initiator side: e · rs)
                self.rs = Some(rs);
                let payload = self.ss.decrypt_and_hash(&msg[s_end..])?;
                self.step = Step::I3;
                Ok(payload)
            }
            (Role::Responder, Step::R3) => {
                // -> s, se
                if msg.len() < 32 + aead::TAG_LEN {
                    bail!("handshake msg3 too short");
                }
                let s_end = 32 + aead::TAG_LEN;
                let rs_bytes = self.ss.decrypt_and_hash(&msg[..s_end])?;
                let rs = PublicKey::from_bytes(&rs_bytes)?;
                let e = self.e.as_ref().context("no local ephemeral")?;
                self.ss.mix_key(&e.diffie_hellman(&rs)); // se (responder side: e · rs)
                self.rs = Some(rs);
                let payload = self.ss.decrypt_and_hash(&msg[s_end..])?;
                self.step = Step::Done;
                Ok(payload)
            }
            _ => bail!("read_message called out of turn"),
        }
    }

    /// Finalize into transport ciphers. Call only when [`is_done`].
    pub fn into_transport(self) -> Result<Transport> {
        if !self.is_done() {
            bail!("handshake not complete");
        }
        let (k1, k2) = hkdf::hkdf2(&self.ss.ck, &[]);
        let remote_static = self.rs.context("peer static key not learned")?;
        let (tx_key, rx_key) = match self.role {
            Role::Initiator => (k1, k2),
            Role::Responder => (k2, k1),
        };
        Ok(Transport {
            tx: CipherState::new(tx_key),
            rx: CipherState::new(rx_key),
            tx_key,
            rx_key,
            remote_static,
            handshake_hash: self.ss.h,
        })
    }
}

/// Run a complete in-memory handshake (used by tests and by the simulated
/// transport's connection upgrade, which exchanges the three messages over
/// the wire).
pub fn handshake_pair(
    init_static: StaticSecret,
    resp_static: StaticSecret,
    rng: &mut crate::util::Rng,
) -> Result<(Transport, Transport)> {
    let mut i = HandshakeState::initiator(init_static, rng);
    let mut r = HandshakeState::responder(resp_static, rng);
    let m1 = i.write_message(b"")?;
    r.read_message(&m1)?;
    let m2 = r.write_message(b"")?;
    i.read_message(&m2)?;
    let m3 = i.write_message(b"")?;
    r.read_message(&m3)?;
    Ok((i.into_transport()?, r.into_transport()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn keys(rng: &mut Rng) -> (StaticSecret, StaticSecret) {
        (StaticSecret::generate(rng), StaticSecret::generate(rng))
    }

    #[test]
    fn full_handshake_and_transport() {
        let mut rng = Rng::new(1);
        let (si, sr) = keys(&mut rng);
        let i_pub = si.public_key();
        let r_pub = sr.public_key();
        let (mut ti, mut tr) = handshake_pair(si, sr, &mut rng).unwrap();

        // Static keys mutually learned.
        assert_eq!(ti.remote_static, r_pub);
        assert_eq!(tr.remote_static, i_pub);
        // Channel binding agrees.
        assert_eq!(ti.handshake_hash, tr.handshake_hash);

        // Bidirectional transport.
        let c = ti.tx.seal(b"", b"ping");
        assert_eq!(tr.rx.open(b"", &c).unwrap(), b"ping");
        let c = tr.tx.seal(b"", b"pong");
        assert_eq!(ti.rx.open(b"", &c).unwrap(), b"pong");
    }

    #[test]
    fn payloads_delivered() {
        let mut rng = Rng::new(2);
        let (si, sr) = keys(&mut rng);
        let mut i = HandshakeState::initiator(si, &mut rng);
        let mut r = HandshakeState::responder(sr, &mut rng);
        let m1 = i.write_message(b"hello-from-i").unwrap();
        assert_eq!(r.read_message(&m1).unwrap(), b"hello-from-i");
        let m2 = r.write_message(b"hello-from-r").unwrap();
        assert_eq!(i.read_message(&m2).unwrap(), b"hello-from-r");
        let m3 = i.write_message(b"final").unwrap();
        assert_eq!(r.read_message(&m3).unwrap(), b"final");
        assert!(i.is_done() && r.is_done());
    }

    #[test]
    fn msg2_payload_is_encrypted() {
        let mut rng = Rng::new(3);
        let (si, sr) = keys(&mut rng);
        let mut i = HandshakeState::initiator(si, &mut rng);
        let mut r = HandshakeState::responder(sr, &mut rng);
        let m1 = i.write_message(b"").unwrap();
        r.read_message(&m1).unwrap();
        let secret = b"secret-payload-xyz";
        let m2 = r.write_message(secret).unwrap();
        // Encrypted: plaintext must not appear in the message.
        assert!(!m2.windows(secret.len()).any(|w| w == secret));
    }

    #[test]
    fn tampered_handshake_fails() {
        let mut rng = Rng::new(4);
        let (si, sr) = keys(&mut rng);
        let mut i = HandshakeState::initiator(si, &mut rng);
        let mut r = HandshakeState::responder(sr, &mut rng);
        let m1 = i.write_message(b"").unwrap();
        r.read_message(&m1).unwrap();
        let mut m2 = r.write_message(b"").unwrap();
        let n = m2.len();
        m2[n - 1] ^= 0xff;
        assert!(i.read_message(&m2).is_err());
    }

    #[test]
    fn mitm_key_substitution_detected() {
        // An attacker replacing the responder's ephemeral breaks the es DH
        // and the static-key ciphertext fails to authenticate.
        let mut rng = Rng::new(5);
        let (si, sr) = keys(&mut rng);
        let mut i = HandshakeState::initiator(si, &mut rng);
        let mut r = HandshakeState::responder(sr, &mut rng);
        let m1 = i.write_message(b"").unwrap();
        r.read_message(&m1).unwrap();
        let mut m2 = r.write_message(b"").unwrap();
        // Replace the ephemeral (first 32 bytes) with an attacker key.
        let attacker = StaticSecret::generate(&mut rng);
        m2[..32].copy_from_slice(attacker.public_key().as_bytes());
        assert!(i.read_message(&m2).is_err());
    }

    #[test]
    fn out_of_turn_errors() {
        let mut rng = Rng::new(6);
        let (si, sr) = keys(&mut rng);
        let mut i = HandshakeState::initiator(si, &mut rng);
        let mut r = HandshakeState::responder(sr, &mut rng);
        assert!(r.write_message(b"").is_err()); // responder can't speak first
        assert!(i.read_message(&[0u8; 64]).is_err()); // initiator reads second
        let _ = i.write_message(b"").unwrap();
        assert!(i.write_message(b"").is_err()); // initiator must wait
    }

    #[test]
    fn sessions_have_distinct_keys() {
        let mut rng = Rng::new(7);
        let (si, sr) = keys(&mut rng);
        let (mut t1, _) = handshake_pair(si.clone(), sr.clone(), &mut rng).unwrap();
        let (mut t2, _) = handshake_pair(si, sr, &mut rng).unwrap();
        // Same plaintext encrypts differently across sessions (fresh ephemerals).
        let c1 = t1.tx.seal(b"", b"x");
        let c2 = t2.tx.seal(b"", b"x");
        assert_ne!(c1, c2);
    }
}
