//! `lattica` CLI — leader entrypoint and launcher.
//!
//! The library is driven through examples and benches (see README); this
//! binary provides environment self-checks and a config-file launcher for
//! scripted deployments on the simulator.

use anyhow::Result;
use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::config::{load_config, NodeConfig};
use lattica::node::{run_until, LatticaNode};
use lattica::util::cli::Args;

const USAGE: &str = "lattica <subcommand> [options]

subcommands:
  version                 print version info
  selftest                PJRT + artifacts smoke test (run `make artifacts` first)
  launch --config <file>  boot a deployment described by a TOML-subset file
                          ([node.<name>] sections; see node/config.rs) and
                          verify full-mesh connectivity
  demo                    pointer to the runnable examples
";

fn main() -> Result<()> {
    lattica::util::logging::init();
    let args = Args::from_env();
    match args.subcommand() {
        Some("version") | None => {
            println!("lattica {} (reproduction build)", env!("CARGO_PKG_VERSION"));
            if args.subcommand().is_none() {
                println!("{USAGE}");
            }
            Ok(())
        }
        Some("selftest") => {
            let client = lattica::runtime::pjrt::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            println!(
                "PJRT ok: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            match lattica::runtime::Engine::load("artifacts") {
                Ok(mut e) => {
                    let cfg = e.manifest.config.clone();
                    println!(
                        "artifacts ok: {} entries, model d={} layers={}",
                        e.manifest.artifacts.len(),
                        cfg.d_model,
                        cfg.n_layer
                    );
                    let params = e.manifest.load_init_params()?;
                    let tok = lattica::runtime::Tensor::from_i32(
                        &[1, cfg.seq_len],
                        &vec![1; cfg.seq_len],
                    );
                    let out = e.run("embed", &[tok, params[0].clone(), params[1].clone()])?;
                    println!("embed executed: output {:?}", out[0].shape);
                }
                Err(e) => println!("artifacts not available ({e}); run `make artifacts`"),
            }
            Ok(())
        }
        Some("launch") => {
            let path = args
                .opt("config")
                .ok_or_else(|| anyhow::anyhow!("--config <file> required"))?;
            let table = load_config(path)?;
            // Collect node sections: keys like "node.<name>.<field>".
            let mut names: Vec<String> = table
                .keys()
                .filter_map(|k| k.strip_prefix("node."))
                .filter_map(|k| k.split('.').next().map(|s| s.to_string()))
                .collect();
            names.sort();
            names.dedup();
            anyhow::ensure!(!names.is_empty(), "no [node.<name>] sections in {path}");
            let mut topo = TopologyBuilder::paper_regions();
            let hosts: Vec<u32> = names
                .iter()
                .map(|_| topo.public_host(0, LinkProfile::DATACENTER))
                .collect();
            let mut world = World::new(topo.build(1));
            let nodes: Vec<_> = names
                .iter()
                .zip(&hosts)
                .map(|(name, &h)| {
                    let cfg = NodeConfig::from_table(&table, &format!("node.{name}"));
                    println!("spawning {name}: seed={} relay={}", cfg.seed, cfg.relay_enabled);
                    LatticaNode::spawn(&mut world, h, cfg)
                })
                .collect();
            // Mesh them.
            let ma0 = nodes[0].borrow().listen_addr();
            for n in nodes.iter().skip(1) {
                n.borrow_mut().dial(&mut world.net, &ma0)?;
            }
            let ok = run_until(&mut world, 10 * SECOND, || {
                let p0 = nodes[0].borrow().peer_id();
                nodes.iter().skip(1).all(|n| n.borrow().swarm.is_connected(&p0))
            });
            anyhow::ensure!(ok, "deployment failed to connect");
            println!("deployment up: {} nodes connected", nodes.len());
            Ok(())
        }
        Some("demo") => {
            println!("runnable scenarios:");
            println!("  cargo run --release --example quickstart");
            println!("  cargo run --release --example collaborative_rl   (end-to-end driver)");
            println!("  cargo run --release --example sharded_inference");
            println!("  cargo run --release --example edge_intelligence");
            println!("  cargo run --release --example federated_learning");
            Ok(())
        }
        Some(other) => {
            anyhow::bail!("unknown subcommand {other:?}\nusage: {USAGE}");
        }
    }
}
