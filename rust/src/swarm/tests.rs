//! Swarm integration tests: dialing, streams, relay circuits, hole punching.

use super::*;
use crate::netsim::nat::NatType;
use crate::netsim::topology::{LinkProfile, TopologyBuilder};
use crate::netsim::{Endpoint, World, SECOND};
use std::cell::RefCell;
use std::rc::Rc;

/// Minimal node: a swarm plus a drained event log.
pub(crate) struct SwarmNode {
    pub swarm: Swarm,
    pub log: Vec<SwarmEvent>,
}

impl SwarmNode {
    pub(crate) fn drain(&mut self) {
        while let Some(e) = self.swarm.poll_event() {
            self.log.push(e);
        }
    }
}

impl Endpoint for SwarmNode {
    fn on_datagram(&mut self, net: &mut Net, from: SimAddr, to: SimAddr, payload: Vec<u8>) {
        self.swarm.handle_datagram(net, from, to, payload);
        self.drain();
    }

    fn on_timer(&mut self, net: &mut Net, token: u64) {
        self.swarm.on_timer(net, token);
        self.drain();
    }
}

/// Create a swarm node on `host`, bound to port 4001.
pub(crate) fn spawn_node(
    world: &mut World,
    host: u32,
    seed: u64,
    cfg: SwarmConfig,
) -> (Rc<RefCell<SwarmNode>>, PeerId, Multiaddr) {
    let keypair = Keypair::from_seed(seed);
    let peer = keypair.peer_id();
    let addr = SimAddr::new(host, 4001);
    let eid = world.next_endpoint_id();
    let swarm = Swarm::new(keypair, eid, addr, cfg, world.net.rng.fork());
    let node = Rc::new(RefCell::new(SwarmNode {
        swarm,
        log: Vec::new(),
    }));
    let got = world.add_endpoint(node.clone());
    assert_eq!(got, eid);
    world.net.bind(eid, addr).unwrap();
    let ma = Multiaddr::direct(addr, Proto::QuicLike).with_peer(peer);
    (node, peer, ma)
}

fn two_node_world(proto: Proto) -> (World, Rc<RefCell<SwarmNode>>, Rc<RefCell<SwarmNode>>, Multiaddr) {
    let mut t = TopologyBuilder::paper_regions();
    let ha = t.public_host(0, LinkProfile::DATACENTER);
    let hb = t.public_host(1, LinkProfile::DATACENTER);
    let mut world = World::new(t.build(11));
    let (a, _, _) = spawn_node(&mut world, ha, 1, SwarmConfig::default());
    let (b, _, mut mb) = spawn_node(&mut world, hb, 2, SwarmConfig::default());
    mb.proto = proto;
    (world, a, b, mb)
}

fn established_peers(log: &[SwarmEvent]) -> Vec<PeerId> {
    log.iter()
        .filter_map(|e| match e {
            SwarmEvent::ConnEstablished { peer, .. } => Some(*peer),
            _ => None,
        })
        .collect()
}

#[test]
fn dial_establishes_quic_like() {
    let (mut world, a, b, mb) = two_node_world(Proto::QuicLike);
    a.borrow_mut().swarm.dial(&mut world.net, &mb).unwrap();
    world.run_for(SECOND);
    let b_peer = b.borrow().swarm.local_peer;
    let a_peer = a.borrow().swarm.local_peer;
    assert_eq!(established_peers(&a.borrow().log), vec![b_peer]);
    assert_eq!(established_peers(&b.borrow().log), vec![a_peer]);
    assert!(a.borrow().swarm.is_connected(&b_peer));
}

#[test]
fn dial_establishes_tcp_like() {
    let (mut world, a, b, mb) = two_node_world(Proto::TcpLike);
    a.borrow_mut().swarm.dial(&mut world.net, &mb).unwrap();
    world.run_for(SECOND);
    let b_peer = b.borrow().swarm.local_peer;
    assert!(a.borrow().swarm.is_connected(&b_peer));
    assert!(b.borrow().log.iter().any(
        |e| matches!(e, SwarmEvent::ConnEstablished { role: Role::Server, .. })
    ));
}

#[test]
fn tcp_like_handshake_slower_than_quic_like() {
    // Measure virtual time to establishment for both profiles.
    let mut times = Vec::new();
    for proto in [Proto::QuicLike, Proto::TcpLike] {
        let (mut world, a, b, mb) = two_node_world(proto);
        a.borrow_mut().swarm.dial(&mut world.net, &mb).unwrap();
        let mut t = None;
        for step in 1..200 {
            world.run_until(step * 5 * crate::netsim::MILLI);
            if !established_peers(&b.borrow().log).is_empty() {
                t = Some(world.net.now());
                break;
            }
        }
        times.push(t.expect("established"));
    }
    assert!(
        times[1] > times[0],
        "tcp-like ({}) must establish slower than quic-like ({})",
        times[1],
        times[0]
    );
}

#[test]
fn stream_messages_roundtrip() {
    let (mut world, a, b, mb) = two_node_world(Proto::QuicLike);
    let b_peer = b.borrow().swarm.local_peer;
    a.borrow_mut().swarm.dial(&mut world.net, &mb).unwrap();
    world.run_for(SECOND);

    let (cid, stream) = a
        .borrow_mut()
        .swarm
        .open_stream(&mut world.net, &b_peer, "/test/echo/1")
        .unwrap();
    a.borrow_mut()
        .swarm
        .send_msg(&mut world.net, cid, stream, b"hello lattica")
        .unwrap();
    world.run_for(SECOND);

    // B got the inbound stream + message; reply.
    let (b_cid, b_stream) = {
        let b_ref = b.borrow();
        let open = b_ref
            .log
            .iter()
            .find_map(|e| match e {
                SwarmEvent::InboundStream { cid, stream, proto, .. }
                    if proto == "/test/echo/1" =>
                {
                    Some((*cid, *stream))
                }
                _ => None,
            })
            .expect("inbound stream");
        assert!(b_ref.log.iter().any(
            |e| matches!(e, SwarmEvent::StreamMsg { msg, .. } if msg == b"hello lattica")
        ));
        open
    };
    b.borrow_mut()
        .swarm
        .send_msg(&mut world.net, b_cid, b_stream, b"echo!")
        .unwrap();
    world.run_for(SECOND);
    assert!(a
        .borrow()
        .log
        .iter()
        .any(|e| matches!(e, SwarmEvent::StreamMsg { msg, .. } if msg == b"echo!")));
}

#[test]
fn conn_close_surfaces_on_both_sides() {
    let (mut world, a, b, mb) = two_node_world(Proto::QuicLike);
    let b_peer = b.borrow().swarm.local_peer;
    a.borrow_mut().swarm.dial(&mut world.net, &mb).unwrap();
    world.run_for(SECOND);
    let cid = a.borrow().swarm.conns_to(&b_peer)[0];
    a.borrow_mut().swarm.close_conn(&mut world.net, cid, "test over");
    world.run_for(SECOND);
    a.borrow_mut().drain();
    assert!(a
        .borrow()
        .log
        .iter()
        .any(|e| matches!(e, SwarmEvent::ConnClosed { .. })));
    assert!(b
        .borrow()
        .log
        .iter()
        .any(|e| matches!(e, SwarmEvent::ConnClosed { reason, .. } if reason == "test over")));
}

/// World with a public relay and two NATed nodes.
/// Returns (world, relay, a, b, relay_ma).
fn natted_world(
    nat_a: NatType,
    nat_b: NatType,
) -> (
    World,
    Rc<RefCell<SwarmNode>>,
    Rc<RefCell<SwarmNode>>,
    Rc<RefCell<SwarmNode>>,
    Multiaddr,
) {
    let mut t = TopologyBuilder::paper_regions();
    let hr = t.public_host(0, LinkProfile::DATACENTER);
    let na = t.nat(1, nat_a, LinkProfile::FIBER);
    let ha = t.natted_host(na, LinkProfile::UNLIMITED);
    let nb = t.nat(2, nat_b, LinkProfile::FIBER);
    let hb = t.natted_host(nb, LinkProfile::UNLIMITED);
    let mut world = World::new(t.build(13));
    let relay_cfg = SwarmConfig {
        relay_enabled: true,
        ..SwarmConfig::default()
    };
    let (r, _, mr) = spawn_node(&mut world, hr, 10, relay_cfg);
    let (a, _, _) = spawn_node(&mut world, ha, 11, SwarmConfig::default());
    let (b, _, _) = spawn_node(&mut world, hb, 12, SwarmConfig::default());
    (world, r, a, b, mr)
}

#[test]
fn relay_circuit_connects_two_natted_peers() {
    let (mut world, r, a, b, mr) = natted_world(NatType::Symmetric, NatType::Symmetric);
    let b_peer = b.borrow().swarm.local_peer;
    let r_peer = r.borrow().swarm.local_peer;

    // Both connect to the relay; B reserves.
    a.borrow_mut().swarm.dial(&mut world.net, &mr).unwrap();
    b.borrow_mut().swarm.dial(&mut world.net, &mr).unwrap();
    world.run_for(SECOND);
    b.borrow_mut()
        .swarm
        .relay_reserve(&mut world.net, &r_peer)
        .unwrap();
    world.run_for(SECOND);
    assert!(b
        .borrow()
        .log
        .iter()
        .any(|e| matches!(e, SwarmEvent::ObservedAddr { .. })));

    // A dials B through the relay circuit.
    let circuit_ma = Multiaddr::circuit(mr.clone(), b_peer);
    a.borrow_mut().swarm.dial(&mut world.net, &circuit_ma).unwrap();
    world.run_for(2 * SECOND);

    // Inner connection established end-to-end, authenticated as B.
    assert!(
        a.borrow().log.iter().any(|e| matches!(
            e,
            SwarmEvent::ConnEstablished { peer, relayed: true, .. } if *peer == b_peer
        )),
        "a log: {:?}",
        a.borrow().log
    );
    // Messages flow across the circuit.
    let (cid, stream) = a
        .borrow_mut()
        .swarm
        .open_stream(&mut world.net, &b_peer, "/relay-test/1")
        .unwrap();
    a.borrow_mut()
        .swarm
        .send_msg(&mut world.net, cid, stream, b"through the relay")
        .unwrap();
    world.run_for(2 * SECOND);
    assert!(b
        .borrow()
        .log
        .iter()
        .any(|e| matches!(e, SwarmEvent::StreamMsg { msg, .. } if msg == b"through the relay")));
}

/// Run the full relay + reserve + circuit + punch flow between two NAT types.
/// Returns whether the connection migrated to a direct path.
pub(crate) fn punch_outcome(nat_a: NatType, nat_b: NatType, seed: u64) -> bool {
    let mut t = TopologyBuilder::paper_regions();
    let hr = t.public_host(0, LinkProfile::DATACENTER);
    let na = t.nat(1, nat_a, LinkProfile::FIBER);
    let ha = t.natted_host(na, LinkProfile::UNLIMITED);
    let nb = t.nat(2, nat_b, LinkProfile::FIBER);
    let hb = t.natted_host(nb, LinkProfile::UNLIMITED);
    let mut world = World::new(t.build(seed));
    let relay_cfg = SwarmConfig {
        relay_enabled: true,
        ..SwarmConfig::default()
    };
    let (r, _, mr) = spawn_node(&mut world, hr, seed * 100 + 1, relay_cfg);
    let (a, _, _) = spawn_node(&mut world, ha, seed * 100 + 2, SwarmConfig::default());
    let (b, _, _) = spawn_node(&mut world, hb, seed * 100 + 3, SwarmConfig::default());
    let r_peer = r.borrow().swarm.local_peer;
    let a_peer = a.borrow().swarm.local_peer;
    let b_peer = b.borrow().swarm.local_peer;

    a.borrow_mut().swarm.dial(&mut world.net, &mr).unwrap();
    b.borrow_mut().swarm.dial(&mut world.net, &mr).unwrap();
    world.run_for(SECOND);
    // Both reserve (this also teaches each its observed address).
    a.borrow_mut().swarm.relay_reserve(&mut world.net, &r_peer).unwrap();
    b.borrow_mut().swarm.relay_reserve(&mut world.net, &r_peer).unwrap();
    world.run_for(SECOND);

    let circuit_ma = Multiaddr::circuit(mr.clone(), b_peer);
    a.borrow_mut().swarm.dial(&mut world.net, &circuit_ma).unwrap();
    world.run_for(2 * SECOND);

    let a_obs = a.borrow().swarm.external_addrs.first().copied();
    let b_obs = b.borrow().swarm.external_addrs.first().copied();
    let (Some(a_obs), Some(b_obs)) = (a_obs, b_obs) else {
        return false;
    };
    let a_cid = a.borrow().swarm.conns_to(&b_peer).first().copied();
    let b_cid = b.borrow().swarm.conns_to(&a_peer).first().copied();
    let (Some(a_cid), Some(b_cid)) = (a_cid, b_cid) else {
        return false;
    };
    // Coordinated simultaneous punch (the dcutr protocol's role).
    let _ = a.borrow_mut().swarm.start_punch(&mut world.net, a_cid, b_obs);
    let _ = b.borrow_mut().swarm.start_punch(&mut world.net, b_cid, a_obs);
    world.run_for(3 * SECOND);

    a.borrow_mut().drain(); b.borrow_mut().drain();
    if std::env::var("PUNCH_DEBUG").is_ok() {
        eprintln!("A path: {:?}", a.borrow().swarm.connection_path(a_cid));
        eprintln!("B path: {:?}", b.borrow().swarm.connection_path(b_cid));
        eprintln!("A punch evs: {:?}", a.borrow().log.iter().filter(|e| matches!(e, SwarmEvent::PunchResult{..})).collect::<Vec<_>>());
        eprintln!("B punch evs: {:?}", b.borrow().log.iter().filter(|e| matches!(e, SwarmEvent::PunchResult{..})).collect::<Vec<_>>());
        eprintln!("A obs {:?} B obs {:?}", a_obs, b_obs);
    }
    let a_direct = matches!(
        a.borrow().swarm.connection_path(a_cid),
        Some(Path::Direct(_))
    );
    let b_direct = matches!(
        b.borrow().swarm.connection_path(b_cid),
        Some(Path::Direct(_))
    );
    a_direct && b_direct
}

#[test]
fn punch_succeeds_full_cone_vs_port_restricted() {
    assert!(punch_outcome(NatType::FullCone, NatType::PortRestrictedCone, 21));
}

#[test]
fn punch_succeeds_restricted_vs_symmetric() {
    // Address-dependent filtering admits the symmetric NAT's fresh port.
    assert!(punch_outcome(NatType::RestrictedCone, NatType::Symmetric, 23));
}

#[test]
fn punch_fails_symmetric_vs_symmetric() {
    assert!(!punch_outcome(NatType::Symmetric, NatType::Symmetric, 25));
}

#[test]
fn punch_fails_symmetric_vs_port_restricted() {
    assert!(!punch_outcome(NatType::Symmetric, NatType::PortRestrictedCone, 27));
}

#[test]
fn punch_succeeds_port_restricted_pair() {
    assert!(punch_outcome(
        NatType::PortRestrictedCone,
        NatType::PortRestrictedCone,
        29
    ));
}

#[test]
fn relayed_connection_survives_when_punch_fails() {
    let (mut world, r, a, b, mr) = natted_world(NatType::Symmetric, NatType::Symmetric);
    let r_peer = r.borrow().swarm.local_peer;
    let b_peer = b.borrow().swarm.local_peer;
    a.borrow_mut().swarm.dial(&mut world.net, &mr).unwrap();
    b.borrow_mut().swarm.dial(&mut world.net, &mr).unwrap();
    world.run_for(SECOND);
    a.borrow_mut().swarm.relay_reserve(&mut world.net, &r_peer).unwrap();
    b.borrow_mut().swarm.relay_reserve(&mut world.net, &r_peer).unwrap();
    world.run_for(SECOND);
    let circuit_ma = Multiaddr::circuit(mr.clone(), b_peer);
    a.borrow_mut().swarm.dial(&mut world.net, &circuit_ma).unwrap();
    world.run_for(2 * SECOND);
    let a_cid = a.borrow().swarm.conns_to(&b_peer)[0];
    let b_obs = b.borrow().swarm.external_addrs[0];
    a.borrow_mut()
        .swarm
        .start_punch(&mut world.net, a_cid, b_obs)
        .unwrap();
    world.run_for(3 * SECOND);
    // Punch failed…
    assert!(a
        .borrow()
        .log
        .iter()
        .any(|e| matches!(e, SwarmEvent::PunchResult { success: false, .. })));
    // …but the relayed path still carries data.
    let (cid, stream) = a
        .borrow_mut()
        .swarm
        .open_stream(&mut world.net, &b_peer, "/fallback/1")
        .unwrap();
    a.borrow_mut()
        .swarm
        .send_msg(&mut world.net, cid, stream, b"still here")
        .unwrap();
    world.run_for(2 * SECOND);
    assert!(b
        .borrow()
        .log
        .iter()
        .any(|e| matches!(e, SwarmEvent::StreamMsg { msg, .. } if msg == b"still here")));
}
