//! Circuit-relay control messages (the `/lattica/relay/1` protocol).
//!
//! A client opens one control stream to each relay it uses. `Reserve`
//! registers it as a reachable circuit target (and teaches it its observed
//! public address); `Connect` asks the relay to splice a circuit to a
//! reserved peer; `Data` carries opaque inner-connection packets in both
//! directions. The relay enforces per-reservation circuit caps.

use crate::identity::PeerId;
use crate::multiaddr::SimAddr;
use crate::util::buf::Buf;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::{bail, Result};

pub const RELAY_PROTO: &str = "/lattica/relay/1";

pub const M_RESERVE: u64 = 1;
pub const M_RESERVE_OK: u64 = 2;
pub const M_CONNECT: u64 = 3;
pub const M_CONNECT_OK: u64 = 4;
pub const M_CONNECT_ERR: u64 = 5;
pub const M_INCOMING: u64 = 6;
pub const M_DATA: u64 = 7;
pub const M_CIRCUIT_CLOSED: u64 = 8;
pub const M_RESERVE_ERR: u64 = 9;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct RelayMsg {
    pub kind: u64,
    /// CONNECT: desired target. INCOMING: the initiating peer.
    pub peer: Option<PeerId>,
    /// Circuit id (CONNECT_OK, INCOMING, DATA, CIRCUIT_CLOSED).
    pub circuit: u64,
    /// DATA payload (an inner-connection packet), shared zero-copy.
    pub payload: Buf,
    /// RESERVE_OK: the client's address as observed by the relay.
    pub observed_host: u32,
    pub observed_port: u32,
    /// CONNECT_ERR / RESERVE_ERR / CIRCUIT_CLOSED reason.
    pub error: String,
    /// RESERVE_OK: the relay's advertised utilization, 0–100 (circuits,
    /// reservations and egress budget — whichever is most loaded). Clients
    /// feed this into load-aware relay selection. Absent (0) from legacy
    /// relays, which selection treats as "unknown, assume lightly loaded".
    pub load: u32,
}

impl RelayMsg {
    pub fn reserve() -> RelayMsg {
        RelayMsg {
            kind: M_RESERVE,
            ..Default::default()
        }
    }

    pub fn reserve_ok(observed: SimAddr, load: u32) -> RelayMsg {
        RelayMsg {
            kind: M_RESERVE_OK,
            observed_host: observed.host,
            observed_port: observed.port as u32,
            load,
            ..Default::default()
        }
    }

    pub fn reserve_err(error: &str) -> RelayMsg {
        RelayMsg {
            kind: M_RESERVE_ERR,
            error: error.to_string(),
            ..Default::default()
        }
    }

    pub fn connect(target: PeerId) -> RelayMsg {
        RelayMsg {
            kind: M_CONNECT,
            peer: Some(target),
            ..Default::default()
        }
    }

    pub fn connect_ok(circuit: u64) -> RelayMsg {
        RelayMsg {
            kind: M_CONNECT_OK,
            circuit,
            ..Default::default()
        }
    }

    pub fn connect_err(error: &str) -> RelayMsg {
        RelayMsg {
            kind: M_CONNECT_ERR,
            error: error.to_string(),
            ..Default::default()
        }
    }

    pub fn incoming(circuit: u64, from: PeerId) -> RelayMsg {
        RelayMsg {
            kind: M_INCOMING,
            circuit,
            peer: Some(from),
            ..Default::default()
        }
    }

    pub fn data(circuit: u64, payload: impl Into<Buf>) -> RelayMsg {
        RelayMsg {
            kind: M_DATA,
            circuit,
            payload: payload.into(),
            ..Default::default()
        }
    }

    pub fn circuit_closed(circuit: u64, error: &str) -> RelayMsg {
        RelayMsg {
            kind: M_CIRCUIT_CLOSED,
            circuit,
            error: error.to_string(),
            ..Default::default()
        }
    }

    pub fn observed_addr(&self) -> SimAddr {
        SimAddr::new(self.observed_host, self.observed_port as u16)
    }
}

impl Message for RelayMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        if let Some(p) = &self.peer {
            w.bytes(2, p.as_bytes());
        }
        w.uint(3, self.circuit);
        w.bytes(4, &self.payload);
        w.uint(5, self.observed_host as u64);
        w.uint(6, self.observed_port as u64);
        w.string(7, &self.error);
        w.uint(8, self.load as u64);
    }

    fn decode(buf: &[u8]) -> Result<RelayMsg> {
        let mut m = RelayMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                4 => m.payload = Buf::copy_from_slice(f.as_bytes()?),
                other => decode_common_field(&mut m, other, &f)?,
            }
            Ok(())
        })?;
        check_kind(&m)?;
        Ok(m)
    }

    /// Zero-copy decode: the DATA payload becomes a slice of `buf` (the
    /// relay data path forwards packets without copying them out).
    fn decode_buf(buf: &Buf) -> Result<RelayMsg> {
        let mut m = RelayMsg::default();
        PbReader::new(buf.as_slice()).for_each(|f| {
            match f.number {
                4 => {
                    f.as_bytes()?; // wire-type check
                    m.payload = buf.slice(f.data_start..f.data_start + f.data.len());
                }
                other => decode_common_field(&mut m, other, &f)?,
            }
            Ok(())
        })?;
        check_kind(&m)?;
        Ok(m)
    }
}

/// Shared decode arms for every field except 4 (`payload`).
fn decode_common_field(m: &mut RelayMsg, number: u32, f: &crate::wire::pb::Field<'_>) -> Result<()> {
    match number {
        1 => m.kind = f.as_u64(),
        2 => {
            let b = f.as_bytes()?;
            anyhow::ensure!(b.len() == 32, "bad peer id length");
            let mut d = [0u8; 32];
            d.copy_from_slice(b);
            m.peer = Some(PeerId(d));
        }
        3 => m.circuit = f.as_u64(),
        5 => m.observed_host = f.as_u64() as u32,
        6 => m.observed_port = f.as_u64() as u32,
        7 => m.error = f.as_string()?,
        8 => m.load = f.as_u64() as u32,
        _ => {}
    }
    Ok(())
}

fn check_kind(m: &RelayMsg) -> Result<()> {
    if m.kind == 0 || m.kind > M_RESERVE_ERR {
        bail!("invalid relay message kind {}", m.kind);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;

    #[test]
    fn roundtrip_all_kinds() {
        let pid = Keypair::from_seed(4).peer_id();
        let msgs = vec![
            RelayMsg::reserve(),
            RelayMsg::reserve_ok(SimAddr::new(9, 1234), 63),
            RelayMsg::connect(pid),
            RelayMsg::connect_ok(77),
            RelayMsg::connect_err("no reservation"),
            RelayMsg::incoming(77, pid),
            RelayMsg::data(77, vec![1, 2, 3]),
            RelayMsg::circuit_closed(77, "peer gone"),
            RelayMsg::reserve_err("relay at reservation capacity"),
        ];
        for m in msgs {
            let enc = m.encode();
            assert_eq!(RelayMsg::decode(&enc).unwrap(), m);
        }
    }

    #[test]
    fn observed_addr_roundtrip() {
        let m = RelayMsg::reserve_ok(SimAddr::new(42, 65_000), 0);
        assert_eq!(m.observed_addr(), SimAddr::new(42, 65_000));
    }

    #[test]
    fn bad_kind_rejected() {
        let m = RelayMsg {
            kind: 99,
            ..Default::default()
        };
        assert!(RelayMsg::decode(&m.encode()).is_err());
    }
}
