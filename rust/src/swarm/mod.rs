//! The swarm: connection management, listening/dialing, protocol routing,
//! relay circuits and hole-punch path migration.
//!
//! One [`Swarm`] per node. It owns every [`Connection`], routes datagrams by
//! destination connection id, runs the circuit-relay protocol (both as
//! client and as relay server), performs DCUtR-style path migration, and
//! surfaces [`SwarmEvent`]s to the node layer where application protocols
//! (DHT, Bitswap, RPC, gossip…) live.
//!
//! Stream protocol routing follows multistream-select in spirit: the opener
//! attaches a protocol name to the STREAM_OPEN frame; the responder's node
//! layer dispatches on it.

pub mod relay_msg;
pub mod peerstore;

use crate::identity::{Keypair, PeerId};
use crate::multiaddr::{Multiaddr, Proto, SimAddr};
use crate::netsim::{EndpointId, Net, Time, MILLI, SECOND};
use crate::transport::connection::{ConnEvent, Connection, ConnectionConfig, Role, RxInfo};
use crate::transport::packet::Packet;
use crate::transport::{TrafficClass, TransportProfile};
use crate::util::buf::Buf;
use crate::util::Rng;
use crate::wire::Message;
use anyhow::{bail, Context, Result};
use relay_msg::{RelayMsg, RELAY_PROTO};
use std::collections::{BTreeMap, HashMap, VecDeque};

pub use peerstore::Peerstore;

/// How a connection currently reaches its peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Path {
    Direct(SimAddr),
    /// Tunnelled through a relay connection (`relay_cid`) on `circuit`.
    Relayed { relay_cid: u64, circuit: u64 },
}

/// Events surfaced to the node layer.
#[derive(Debug)]
pub enum SwarmEvent {
    ConnEstablished {
        cid: u64,
        peer: PeerId,
        role: Role,
        relayed: bool,
        /// Remote address (direct) or relay address (relayed).
        remote_addr: SimAddr,
    },
    ConnClosed {
        cid: u64,
        peer: Option<PeerId>,
        reason: String,
    },
    DialFailed {
        cid: u64,
        /// The peer the dial targeted, when known (from the multiaddr or a
        /// circuit CONNECT) — lets protocols fail over routing state.
        peer: Option<PeerId>,
        reason: String,
    },
    /// Remote opened a stream; the node dispatches on `proto`.
    InboundStream {
        cid: u64,
        peer: PeerId,
        stream: u64,
        proto: String,
    },
    /// Message on a stream (either direction). The payload is a zero-copy
    /// [`Buf`] view of the transport receive path.
    StreamMsg {
        cid: u64,
        stream: u64,
        msg: Buf,
    },
    StreamFinished {
        cid: u64,
        stream: u64,
    },
    StreamReset {
        cid: u64,
        stream: u64,
        error: String,
    },
    /// A relay told us our public address (from RESERVE_OK).
    ObservedAddr {
        addr: SimAddr,
    },
    /// Hole punch finished: the connection migrated to a direct path (or
    /// failed and stays relayed).
    PunchResult {
        cid: u64,
        peer: PeerId,
        success: bool,
    },
}

struct PunchState {
    target: SimAddr,
    token: u64,
    attempts_left: u32,
    deadline: Time,
    /// After the last probe, wait this long for a late response before
    /// declaring failure (responses cross two NATs and a WAN).
    in_grace: bool,
}

struct ConnState {
    conn: Connection,
    path: Path,
    proto: Proto,
    /// Peer we intended to reach (set on outbound dials before the
    /// handshake confirms `conn.peer`; used for DialFailed attribution).
    expected_peer: Option<PeerId>,
    /// Stream id → protocol (both directions).
    stream_protos: HashMap<u64, String>,
    /// Control stream to speak relay protocol on (when this conn is to a
    /// relay and we are the client).
    relay_ctrl_stream: Option<u64>,
    /// Outstanding CONNECT requests (targets in request order).
    pending_connects: VecDeque<PeerId>,
    punch: Option<PunchState>,
    /// True once this conn was reported established to the node layer.
    reported: bool,
    /// Set while the conn's relay path is dead and a re-home is pending;
    /// the conn is torn down if no backup circuit lands by this deadline.
    parked: Option<Time>,
}

/// Relay-server side state for one circuit.
struct Circuit {
    a_cid: u64,
    a_stream: u64,
    a_circuit_id: u64,
    b_cid: u64,
    b_stream: u64,
    b_circuit_id: u64,
}

/// A pending dial that first needs a relay connection to establish.
struct PendingCircuitDial {
    relay_cid: u64,
    target: PeerId,
    #[allow(dead_code)] // retained: the inner conn inherits this profile
    proto: Proto,
}

/// Swarm configuration.
#[derive(Clone, Debug)]
pub struct SwarmConfig {
    pub conn: ConnectionConfig,
    /// Accept inbound direct connections.
    pub accept_inbound: bool,
    /// Act as a relay for others.
    pub relay_enabled: bool,
    /// Max circuits when acting as a relay.
    pub max_circuits: usize,
    /// Max reservations when acting as a relay; further RESERVEs get a
    /// RESERVE_ERR so clients fail over to another relay.
    pub max_reservations: usize,
    /// Egress budget when acting as a relay (bytes/s of forwarded inner
    /// packets); 0 = unlimited. New circuits are refused while the measured
    /// forwarding rate exceeds the budget, bounding per-relay egress.
    pub relay_egress_bps: u64,
    /// Hole-punch probe schedule: attempts and spacing.
    pub punch_attempts: u32,
    pub punch_interval: Time,
    /// Port-prediction spray width: from the second volley on, probes also
    /// target this many sequential ports above the observed endpoint
    /// (defeats sequential-allocating symmetric NATs; harmless otherwise).
    pub punch_spray: u16,
    /// How long an inner connection may sit parked while we re-home it
    /// through a backup relay after its relay connection died.
    pub rehome_grace: Time,
}

impl Default for SwarmConfig {
    fn default() -> Self {
        SwarmConfig {
            conn: ConnectionConfig::default(),
            accept_inbound: true,
            relay_enabled: false,
            max_circuits: 1024,
            max_reservations: 512,
            relay_egress_bps: 0,
            punch_attempts: 5,
            punch_interval: 50 * MILLI,
            punch_spray: 16,
            // Must absorb the worst-case skew between the two endpoints
            // detecting the dead relay (keepalive phase + RTO backoff).
            rehome_grace: 15 * SECOND,
        }
    }
}

/// How long a relay honours a reservation before the client must refresh
/// it (clients re-reserve at roughly half this interval).
pub const RESERVATION_TTL: Time = 60 * SECOND;

/// Relay-server side state for one reservation.
struct Reservation {
    cid: u64,
    stream: u64,
    expires: Time,
}

/// An inner connection being re-homed onto a backup relay after its relay
/// connection died mid-stream.
struct Rehome {
    inner_cid: u64,
    target: PeerId,
    /// Relay conn ids already tried (first entry: the dead relay).
    tried: Vec<u64>,
}

/// Timer tokens the node layer must route to [`Swarm::on_timer`].
pub const TIMER_SWARM_TICK: u64 = 1;

/// See module docs.
pub struct Swarm {
    pub keypair: Keypair,
    pub local_peer: PeerId,
    pub endpoint_id: EndpointId,
    pub local_addr: SimAddr,
    pub cfg: SwarmConfig,
    pub peerstore: Peerstore,
    rng: Rng,

    /// BTreeMap (not HashMap) so per-tick iteration and shutdown order are
    /// independent of process-random hashing — keeps simulated runs
    /// reproducible across processes for a given seed.
    conns: BTreeMap<u64, ConnState>,
    /// (remote addr, remote cid) → local cid, for initial-packet dedup.
    initial_index: HashMap<(SimAddr, u64), u64>,
    peer_conns: HashMap<PeerId, Vec<u64>>,

    // Relay server state.
    reservations: HashMap<PeerId, Reservation>,
    circuits: HashMap<u64, Circuit>,
    next_circuit_id: u64,
    /// Rolling 1 s egress window for the relay bytes/s budget.
    egress_window_start: Time,
    egress_window_bytes: u64,
    egress_last_bps: u64,
    /// Relay-role counters (circuits, refusals, failovers, bytes).
    pub relay_stats: crate::metrics::RelayStats,

    // Relay client: pending circuit dials keyed by relay cid.
    pending_circuit_dials: Vec<PendingCircuitDial>,
    /// Inner connections by (relay_cid, circuit_id).
    circuit_conns: HashMap<(u64, u64), u64>,
    /// Relays this node holds reservations on (peer → last RESERVE_OK time).
    my_reservations: HashMap<PeerId, Time>,
    /// Last advertised utilization per relay peer (from RESERVE_OK).
    relay_loads: HashMap<PeerId, u32>,
    /// Inner connections awaiting a backup circuit (mid-stream failover).
    pending_rehomes: Vec<Rehome>,

    events: VecDeque<SwarmEvent>,
    /// Next scheduled tick (so we arm at most one timer).
    tick_armed_until: Time,

    /// Addresses this node believes it is reachable at (observed + bound).
    pub external_addrs: Vec<SimAddr>,
}

impl Swarm {
    /// Create a swarm; the caller must already have bound `local_addr` to
    /// this node's endpoint id in the simulator.
    pub fn new(
        keypair: Keypair,
        endpoint_id: EndpointId,
        local_addr: SimAddr,
        cfg: SwarmConfig,
        rng: Rng,
    ) -> Swarm {
        let local_peer = keypair.peer_id();
        Swarm {
            keypair,
            local_peer,
            endpoint_id,
            local_addr,
            cfg,
            peerstore: Peerstore::new(),
            rng,
            conns: BTreeMap::new(),
            initial_index: HashMap::new(),
            peer_conns: HashMap::new(),
            reservations: HashMap::new(),
            circuits: HashMap::new(),
            next_circuit_id: 1,
            egress_window_start: 0,
            egress_window_bytes: 0,
            egress_last_bps: 0,
            relay_stats: crate::metrics::RelayStats::default(),
            pending_circuit_dials: Vec::new(),
            circuit_conns: HashMap::new(),
            my_reservations: HashMap::new(),
            relay_loads: HashMap::new(),
            pending_rehomes: Vec::new(),
            events: VecDeque::new(),
            tick_armed_until: 0,
            external_addrs: Vec::new(),
        }
    }

    pub fn poll_event(&mut self) -> Option<SwarmEvent> {
        self.events.pop_front()
    }

    /// Established connections to `peer`, direct paths first.
    pub fn conns_to(&self, peer: &PeerId) -> Vec<u64> {
        let mut v: Vec<u64> = self
            .peer_conns
            .get(peer)
            .map(|x| {
                x.iter()
                    .copied()
                    .filter(|cid| {
                        self.conns
                            .get(cid)
                            .map_or(false, |c| c.conn.is_established() && !c.conn.is_closed())
                    })
                    .collect()
            })
            .unwrap_or_default();
        v.sort_by_key(|cid| match self.conns[cid].path {
            Path::Direct(_) => 0,
            Path::Relayed { .. } => 1,
        });
        v
    }

    pub fn is_connected(&self, peer: &PeerId) -> bool {
        !self.conns_to(peer).is_empty()
    }

    /// Peers with at least one established connection, in stable order.
    pub fn connected_peers(&self) -> Vec<PeerId> {
        let mut v: Vec<PeerId> = self
            .peer_conns
            .keys()
            .filter(|p| self.is_connected(p))
            .copied()
            .collect();
        v.sort_unstable();
        v
    }

    pub fn connection_path(&self, cid: u64) -> Option<Path> {
        self.conns.get(&cid).map(|c| c.path)
    }

    pub fn connection_peer(&self, cid: u64) -> Option<PeerId> {
        self.conns.get(&cid).and_then(|c| c.conn.peer)
    }

    /// Protocol negotiated for a stream (either direction).
    pub fn stream_proto(&self, cid: u64, stream: u64) -> Option<String> {
        self.conns
            .get(&cid)
            .and_then(|c| c.stream_protos.get(&stream).cloned())
    }

    pub fn connection_srtt(&self, cid: u64) -> Option<Time> {
        self.conns.get(&cid).map(|c| c.conn.srtt())
    }

    /// Transport-health snapshot for one connection.
    pub fn connection_stats(&self, cid: u64) -> Option<crate::metrics::TransportStats> {
        self.conns.get(&cid).map(|c| c.conn.stats())
    }

    /// Aggregate transport health across all connections (cwnd, srtt,
    /// retransmissions, loss events, pacer pressure).
    pub fn transport_health(&self) -> crate::metrics::TransportHealth {
        let mut h = crate::metrics::TransportHealth::default();
        for c in self.conns.values() {
            h.record(&c.conn.stats());
        }
        h
    }

    pub fn connection_backlog(&self, cid: u64) -> u64 {
        self.conns.get(&cid).map_or(0, |c| c.conn.backlog())
    }

    pub fn connection_count(&self) -> usize {
        self.conns.len()
    }

    /// Ids of every live connection (used for clean node shutdown).
    pub fn connection_ids(&self) -> Vec<u64> {
        self.conns.keys().copied().collect()
    }

    // ------------------------------------------------------------------
    // Dialing
    // ------------------------------------------------------------------

    /// Dial a multiaddr. Returns the new connection's cid (circuit dials
    /// return the *inner* connection's cid once created; before that, the
    /// returned id refers to the pending dial and resolves on success).
    pub fn dial(&mut self, net: &mut Net, ma: &Multiaddr) -> Result<u64> {
        if let Some(target) = ma.circuit_target {
            // Need an established conn to the relay first.
            let relay_peer = ma.peer.context("circuit dial requires relay peer id")?;
            let relay_cid = match self.conns_to(&relay_peer).first() {
                Some(&cid) => cid,
                None => {
                    let direct = Multiaddr::direct(ma.addr, ma.proto).with_peer(relay_peer);
                    self.dial(net, &direct)?
                }
            };
            self.pending_circuit_dials.push(PendingCircuitDial {
                relay_cid,
                target,
                proto: ma.proto,
            });
            // If the relay conn is already up, fire the CONNECT now.
            self.try_fire_circuit_dials(net);
            return Ok(relay_cid);
        }
        let mut cfg = self.cfg.conn.clone();
        cfg.profile = TransportProfile::for_proto(ma.proto);
        cfg.mtu = net.mtu;
        let conn = Connection::new(Role::Client, cfg, self.keypair.clone(), net.now(), &mut self.rng);
        let cid = conn.local_cid;
        self.conns.insert(
            cid,
            ConnState {
                conn,
                path: Path::Direct(ma.addr),
                proto: ma.proto,
                expected_peer: ma.peer,
                stream_protos: HashMap::new(),
                relay_ctrl_stream: None,
                pending_connects: VecDeque::new(),
                punch: None,
                reported: false,
                parked: None,
            },
        );
        self.flush_conn(net, cid);
        self.arm_tick(net);
        Ok(cid)
    }

    /// Open a stream to `peer` on the best available connection. The
    /// scheduling class defaults from the protocol name.
    pub fn open_stream(&mut self, net: &mut Net, peer: &PeerId, proto: &str) -> Result<(u64, u64)> {
        self.open_stream_class(net, peer, proto, TrafficClass::for_proto(proto))
    }

    /// Open a stream to `peer` with an explicit traffic class.
    pub fn open_stream_class(
        &mut self,
        net: &mut Net,
        peer: &PeerId,
        proto: &str,
        class: TrafficClass,
    ) -> Result<(u64, u64)> {
        let cid = *self
            .conns_to(peer)
            .first()
            .with_context(|| format!("no connection to {peer}"))?;
        let stream = self.open_stream_on_class(net, cid, proto, class)?;
        Ok((cid, stream))
    }

    /// Open a stream on a specific connection.
    pub fn open_stream_on(&mut self, net: &mut Net, cid: u64, proto: &str) -> Result<u64> {
        self.open_stream_on_class(net, cid, proto, TrafficClass::for_proto(proto))
    }

    /// Open a stream on a specific connection with an explicit class.
    pub fn open_stream_on_class(
        &mut self,
        net: &mut Net,
        cid: u64,
        proto: &str,
        class: TrafficClass,
    ) -> Result<u64> {
        let c = self.conns.get_mut(&cid).context("unknown connection")?;
        let stream = c.conn.open_stream_class(proto, class);
        c.stream_protos.insert(stream, proto.to_string());
        self.flush_conn(net, cid);
        Ok(stream)
    }

    /// Send a message on a stream (copies into the stream framing).
    pub fn send_msg(&mut self, net: &mut Net, cid: u64, stream: u64, msg: &[u8]) -> Result<()> {
        let c = self.conns.get_mut(&cid).context("unknown connection")?;
        c.conn.send_msg(stream, msg)?;
        self.flush_conn(net, cid);
        // The flush may be pacer-throttled: arm the refill deadline.
        self.arm_tick_for(net, cid);
        Ok(())
    }

    /// Send an owned message on a stream; large messages are queued
    /// zero-copy all the way to packetization.
    pub fn send_msg_buf(&mut self, net: &mut Net, cid: u64, stream: u64, msg: Buf) -> Result<()> {
        let c = self.conns.get_mut(&cid).context("unknown connection")?;
        c.conn.send_msg_buf(stream, msg)?;
        self.flush_conn(net, cid);
        self.arm_tick_for(net, cid);
        Ok(())
    }

    pub fn finish_stream(&mut self, net: &mut Net, cid: u64, stream: u64) {
        if let Some(c) = self.conns.get_mut(&cid) {
            c.conn.finish_stream(stream);
            self.flush_conn(net, cid);
            self.arm_tick_for(net, cid);
        }
    }

    pub fn reset_stream(&mut self, net: &mut Net, cid: u64, stream: u64, error: &str) {
        if let Some(c) = self.conns.get_mut(&cid) {
            c.conn.reset_stream(stream, error);
            self.flush_conn(net, cid);
        }
    }

    pub fn close_conn(&mut self, net: &mut Net, cid: u64, reason: &str) {
        if let Some(c) = self.conns.get_mut(&cid) {
            c.conn.close(reason);
            self.flush_conn(net, cid);
        }
    }

    // ------------------------------------------------------------------
    // Relay client operations
    // ------------------------------------------------------------------

    /// Reserve a slot on a connected relay so peers can reach us through it.
    pub fn relay_reserve(&mut self, net: &mut Net, relay_peer: &PeerId) -> Result<()> {
        let cid = *self
            .conns_to(relay_peer)
            .first()
            .context("not connected to relay")?;
        let stream = self.ensure_relay_ctrl(net, cid)?;
        self.send_msg(net, cid, stream, &RelayMsg::reserve().encode())
    }

    fn ensure_relay_ctrl(&mut self, net: &mut Net, cid: u64) -> Result<u64> {
        if let Some(s) = self.conns.get(&cid).and_then(|c| c.relay_ctrl_stream) {
            return Ok(s);
        }
        let stream = self.open_stream_on(net, cid, RELAY_PROTO)?;
        self.conns.get_mut(&cid).unwrap().relay_ctrl_stream = Some(stream);
        Ok(stream)
    }

    fn try_fire_circuit_dials(&mut self, net: &mut Net) {
        let ready: Vec<usize> = self
            .pending_circuit_dials
            .iter()
            .enumerate()
            .filter(|(_, d)| {
                self.conns
                    .get(&d.relay_cid)
                    .map_or(false, |c| c.conn.is_established())
            })
            .map(|(i, _)| i)
            .collect();
        for i in ready.into_iter().rev() {
            let d = self.pending_circuit_dials.remove(i);
            if let Ok(stream) = self.ensure_relay_ctrl(net, d.relay_cid) {
                if let Some(c) = self.conns.get_mut(&d.relay_cid) {
                    c.pending_connects.push_back(d.target);
                }
                let _ = self.send_msg(
                    net,
                    d.relay_cid,
                    stream,
                    &RelayMsg::connect(d.target).encode(),
                );
            }
        }
    }

    // ------------------------------------------------------------------
    // Hole punching (DCUtR)
    // ------------------------------------------------------------------

    /// Start a hole punch on a relayed connection toward `remote_addr`
    /// (the peer's observed public address, exchanged via the dcutr
    /// protocol at the node layer).
    pub fn start_punch(&mut self, net: &mut Net, cid: u64, remote_addr: SimAddr) -> Result<()> {
        let token = self.rng.next_u64();
        let c = self.conns.get_mut(&cid).context("unknown connection")?;
        if !matches!(c.path, Path::Relayed { .. }) {
            bail!("punch only applies to relayed connections");
        }
        c.punch = Some(PunchState {
            target: remote_addr,
            token,
            attempts_left: self.cfg.punch_attempts,
            deadline: net.now(),
            in_grace: false,
        });
        self.drive_punch(net, cid);
        self.arm_tick(net);
        Ok(())
    }

    fn drive_punch(&mut self, net: &mut Net, cid: u64) {
        let local_addr = self.local_addr;
        let Some(c) = self.conns.get_mut(&cid) else { return };
        let Some(p) = c.punch.as_mut() else { return };
        if p.attempts_left == 0 {
            if !p.in_grace {
                // Last probe is out; give late responses one more window
                // (they cross two NATs and possibly a WAN) before failing.
                p.in_grace = true;
                p.deadline = net.now() + 6 * self.cfg.punch_interval;
                return;
            }
            if net.now() < p.deadline {
                return;
            }
            let peer = c.conn.peer.unwrap_or(PeerId([0; 32]));
            c.punch = None;
            self.events.push_back(SwarmEvent::PunchResult {
                cid,
                peer,
                success: false,
            });
            return;
        }
        if net.now() >= p.deadline {
            let first = p.attempts_left == self.cfg.punch_attempts;
            p.attempts_left -= 1;
            p.deadline = net.now() + self.cfg.punch_interval;
            let target = p.target;
            let probe = c.conn.make_path_challenge(p.token);
            // First volley targets the observed endpoint only. Later ones
            // also spray sequential ports above it: a sequential symmetric
            // NAT allocates new mappings near the observed one, so a few
            // predicted probes open its filter (birthday-paradox port
            // prediction). Random-allocating NATs just drop the extras.
            let spray = if first { 0 } else { self.cfg.punch_spray };
            for d in 0..=spray {
                let t = SimAddr::new(target.host, target.port.wrapping_add(d));
                net.send(local_addr, t, probe.clone());
            }
        }
    }

    // ------------------------------------------------------------------
    // Datagram input
    // ------------------------------------------------------------------

    /// Feed a datagram from the simulator. The packet payload stays a
    /// zero-copy slice of the datagram buffer — and, as the sole reference
    /// to it, is decrypted in place by the connection.
    pub fn handle_datagram(&mut self, net: &mut Net, from: SimAddr, _to: SimAddr, payload: Vec<u8>) {
        // The temporary wrapper drops here, so `pkt.payload` is unique.
        let Ok(pkt) = Packet::decode_buf(&Buf::from_vec(payload)) else {
            return;
        };
        let cid = if pkt.dst_cid != 0 && self.conns.contains_key(&pkt.dst_cid) {
            pkt.dst_cid
        } else if pkt.dst_cid == 0 {
            // Initial packet: find or create a server connection.
            match self.initial_index.get(&(from, pkt.src_cid)) {
                Some(&cid) => cid,
                None => {
                    if !self.cfg.accept_inbound {
                        return;
                    }
                    let mut cfg = self.cfg.conn.clone();
                    cfg.mtu = net.mtu;
                    // Profile is symmetric; the client's choice dominates
                    // timing. Server answers with the default profile.
                    let conn = Connection::new(
                        Role::Server,
                        cfg,
                        self.keypair.clone(),
                        net.now(),
                        &mut self.rng,
                    );
                    let cid = conn.local_cid;
                    self.conns.insert(
                        cid,
                        ConnState {
                            conn,
                            path: Path::Direct(from),
                            proto: Proto::QuicLike,
                            expected_peer: None,
                            stream_protos: HashMap::new(),
                            relay_ctrl_stream: None,
                            pending_connects: VecDeque::new(),
                            punch: None,
                            reported: false,
                            parked: None,
                        },
                    );
                    self.initial_index.insert((from, pkt.src_cid), cid);
                    cid
                }
            }
        } else {
            // Unknown destination cid: stateless drop.
            return;
        };

        let info = {
            let c = self.conns.get_mut(&cid).unwrap();
            match c.conn.handle_packet(net.now(), pkt) {
                Ok(info) => info,
                Err(e) => {
                    crate::log_debug!("conn {cid}: packet error: {e}");
                    RxInfo::default()
                }
            }
        };
        self.post_rx(net, cid, Some(from), info);
    }

    /// Shared post-ingest processing (path migration, probe answers,
    /// event pumping, flush). `from` is None for circuit-delivered packets.
    fn post_rx(&mut self, net: &mut Net, cid: u64, from: Option<SimAddr>, info: RxInfo) {
        let local_addr = self.local_addr;
        if let Some(from) = from {
            if info.accepted {
                let c = self.conns.get_mut(&cid).unwrap();
                // Answer path challenges on the arrival path.
                for token in &info.path_challenges {
                    let resp = c.conn.make_path_response(*token);
                    net.send(local_addr, from, resp);
                }
                // A challenge from a new direct address while we are
                // punching means the peer's true mapping differs from the
                // observed one (symmetric NAT allocates per-remote ports):
                // retarget our probes at the address that actually works.
                if !info.path_challenges.is_empty() {
                    if let Some(p) = c.punch.as_mut() {
                        if p.target != from {
                            p.target = from;
                            p.attempts_left = p.attempts_left.max(2);
                            p.in_grace = false;
                            p.deadline = net.now();
                        }
                    }
                }
                // Path migration:
                // * a PATH_RESPONSE from our punch target validates it;
                // * authenticated app traffic from a new direct address
                //   follows the peer's migration.
                let migrate = match (&c.path, &c.punch) {
                    (Path::Relayed { .. }, Some(p)) if !info.path_responses.is_empty() => {
                        info.path_responses.contains(&p.token).then_some(from)
                    }
                    (Path::Relayed { .. }, _) if info.has_app_frames => Some(from),
                    (Path::Direct(cur), _) if *cur != from && info.has_app_frames => Some(from),
                    _ => None,
                };
                if let Some(new_addr) = migrate {
                    let was_relayed = matches!(c.path, Path::Relayed { .. });
                    c.path = Path::Direct(new_addr);
                    if was_relayed {
                        let peer = c.conn.peer.unwrap_or(PeerId([0; 32]));
                        c.punch = None;
                        self.events.push_back(SwarmEvent::PunchResult {
                            cid,
                            peer,
                            success: true,
                        });
                    }
                }
            }
        }
        self.pump_conn_events(net, cid);
        self.flush_conn(net, cid);
        self.arm_tick(net);
    }

    // ------------------------------------------------------------------
    // Event pumping / relay protocol handling
    // ------------------------------------------------------------------

    fn pump_conn_events(&mut self, net: &mut Net, cid: u64) {
        loop {
            let ev = match self.conns.get_mut(&cid) {
                Some(c) => c.conn.poll_event(),
                None => return,
            };
            let Some(ev) = ev else { break };
            match ev {
                ConnEvent::Established { peer, key } => {
                    self.peerstore.set_key(peer, key);
                    self.peer_conns.entry(peer).or_default().push(cid);
                    let c = self.conns.get_mut(&cid).unwrap();
                    c.reported = true;
                    let (relayed, remote_addr) = match c.path {
                        Path::Direct(a) => (false, a),
                        Path::Relayed { relay_cid, .. } => {
                            let addr = match self.conns.get(&relay_cid).map(|r| r.path) {
                                Some(Path::Direct(a)) => a,
                                _ => SimAddr::new(0, 0),
                            };
                            (true, addr)
                        }
                    };
                    let role = self.conns[&cid].conn.role;
                    self.events.push_back(SwarmEvent::ConnEstablished {
                        cid,
                        peer,
                        role,
                        relayed,
                        remote_addr,
                    });
                    self.try_fire_circuit_dials(net);
                }
                ConnEvent::StreamOpened { stream_id, proto } => {
                    let peer = self.conns[&cid].conn.peer.unwrap_or(PeerId([0; 32]));
                    self.conns
                        .get_mut(&cid)
                        .unwrap()
                        .stream_protos
                        .insert(stream_id, proto.clone());
                    if proto == RELAY_PROTO {
                        // Relay control stream opened towards us: nothing to
                        // do until messages arrive.
                        if !self.cfg.relay_enabled {
                            self.reset_stream(net, cid, stream_id, "relay disabled");
                        }
                    } else {
                        self.events.push_back(SwarmEvent::InboundStream {
                            cid,
                            peer,
                            stream: stream_id,
                            proto,
                        });
                    }
                }
                ConnEvent::Msg { stream_id, msg } => {
                    let proto = self
                        .conns[&cid]
                        .stream_protos
                        .get(&stream_id)
                        .cloned()
                        .unwrap_or_default();
                    if proto == RELAY_PROTO {
                        if let Err(e) = self.handle_relay_msg(net, cid, stream_id, &msg) {
                            crate::log_debug!("relay msg error on conn {cid}: {e}");
                        }
                    } else {
                        self.events.push_back(SwarmEvent::StreamMsg {
                            cid,
                            stream: stream_id,
                            msg,
                        });
                    }
                }
                ConnEvent::StreamFinished { stream_id } => {
                    self.events.push_back(SwarmEvent::StreamFinished {
                        cid,
                        stream: stream_id,
                    });
                }
                ConnEvent::StreamReset { stream_id, error } => {
                    self.events.push_back(SwarmEvent::StreamReset {
                        cid,
                        stream: stream_id,
                        error,
                    });
                }
                ConnEvent::PathValidated { .. } => {
                    // Handled via RxInfo in post_rx (needs arrival address).
                }
                ConnEvent::Closed { error } => {
                    self.teardown_conn(net, cid, &error);
                    return;
                }
            }
        }
    }

    fn teardown_conn(&mut self, net: &mut Net, cid: u64, reason: &str) {
        let Some(c) = self.conns.get(&cid) else { return };
        let peer = c.conn.peer;
        let dial_target = c.expected_peer.or(peer);
        let was_reported = c.reported;
        let had_relay_ctrl = c.relay_ctrl_stream.is_some();
        if had_relay_ctrl {
            if let Some(p) = peer {
                self.my_reservations.remove(&p);
            }
        }
        // Close circuits riding this connection (relay server side).
        let dead_circuits: Vec<u64> = self
            .circuits
            .iter()
            .filter(|(_, circ)| circ.a_cid == cid || circ.b_cid == cid)
            .map(|(id, _)| *id)
            .collect();
        for id in dead_circuits {
            let circ = self.circuits.remove(&id).unwrap();
            let (other_cid, other_stream, other_circ) = if circ.a_cid == cid {
                (circ.b_cid, circ.b_stream, circ.b_circuit_id)
            } else {
                (circ.a_cid, circ.a_stream, circ.a_circuit_id)
            };
            let _ = self.send_msg(
                net,
                other_cid,
                other_stream,
                &RelayMsg::circuit_closed(other_circ, "relay conn closed").encode(),
            );
        }
        // Inner connections riding this relay conn (client side): don't
        // tear them down — park them and try to re-home each onto a backup
        // relay so the logical connection survives the relay's death.
        let mut dead_inner: Vec<u64> = self
            .circuit_conns
            .iter()
            .filter(|((rcid, _), _)| *rcid == cid)
            .map(|(_, inner)| *inner)
            .collect();
        dead_inner.sort_unstable(); // deterministic failover order
        self.circuit_conns.retain(|(rcid, _), _| *rcid != cid);
        for inner in dead_inner {
            self.begin_rehome(net, inner, cid);
        }
        self.reservations.retain(|_, r| r.cid != cid);
        if let Some(p) = peer {
            if let Some(v) = self.peer_conns.get_mut(&p) {
                v.retain(|x| *x != cid);
            }
        }
        self.initial_index.retain(|_, v| *v != cid);
        self.conns.remove(&cid);
        if was_reported {
            self.events.push_back(SwarmEvent::ConnClosed {
                cid,
                peer,
                reason: reason.to_string(),
            });
        } else {
            self.events.push_back(SwarmEvent::DialFailed {
                cid,
                peer: dial_target,
                reason: reason.to_string(),
            });
        }
    }

    /// The relay connection under `inner` died. Park the inner connection
    /// (its path keeps pointing at the dead relay, so sends no-op and the
    /// transport's retransmissions cover the gap) and, on the circuit
    /// initiator, start re-establishing a circuit through a backup relay.
    fn begin_rehome(&mut self, net: &mut Net, inner: u64, dead_relay: u64) {
        let now = net.now();
        let grace = self.cfg.rehome_grace;
        let (target, is_client) = match self.conns.get_mut(&inner) {
            Some(c) => {
                c.parked = Some(now + grace);
                (
                    c.expected_peer.or(c.conn.peer),
                    matches!(c.conn.role, Role::Client),
                )
            }
            None => return,
        };
        self.arm_at(net, now, now + grace);
        // Only the circuit initiator re-homes actively; the responder parks
        // and waits for the initiator's re-homed packets to find it (see the
        // M_DATA dst_cid fallback). Both avoids duplicate circuits and
        // matches who knows how to CONNECT.
        let Some(target) = target else { return };
        if !is_client {
            return;
        }
        self.relay_stats.failovers_started += 1;
        let mut r = Rehome {
            inner_cid: inner,
            target,
            tried: vec![dead_relay],
        };
        if self.try_next_rehome(net, &mut r) {
            self.pending_rehomes.push(r);
        } else {
            self.relay_stats.failovers_failed += 1;
            self.teardown_conn(net, inner, "relay connection lost (no backup relay)");
        }
    }

    /// Send a CONNECT for `r.target` on the next untried relay connection.
    /// Candidates are established direct conns we already speak the relay
    /// protocol on (reservations or prior circuit dials).
    fn try_next_rehome(&mut self, net: &mut Net, r: &mut Rehome) -> bool {
        loop {
            let cand = self
                .conns
                .iter()
                .filter(|(cid2, c)| {
                    !r.tried.contains(cid2)
                        && c.relay_ctrl_stream.is_some()
                        && c.conn.is_established()
                        && !c.conn.is_closed()
                        && matches!(c.path, Path::Direct(_))
                })
                .map(|(cid2, _)| *cid2)
                .next();
            let Some(rcid) = cand else { return false };
            r.tried.push(rcid);
            let Ok(stream) = self.ensure_relay_ctrl(net, rcid) else {
                continue;
            };
            if let Some(c) = self.conns.get_mut(&rcid) {
                c.pending_connects.push_back(r.target);
            }
            if self
                .send_msg(net, rcid, stream, &RelayMsg::connect(r.target).encode())
                .is_ok()
            {
                return true;
            }
        }
    }

    /// Drop reservations past their TTL (relay server side).
    fn expire_reservations(&mut self, now: Time) {
        self.reservations.retain(|_, r| r.expires > now);
    }

    /// Account forwarded bytes into the rolling 1 s egress window.
    fn note_egress(&mut self, now: Time, bytes: u64) {
        let elapsed = now.saturating_sub(self.egress_window_start);
        if elapsed >= SECOND {
            self.egress_last_bps =
                self.egress_window_bytes.saturating_mul(SECOND) / elapsed.max(1);
            self.egress_window_start = now;
            self.egress_window_bytes = 0;
        }
        self.egress_window_bytes += bytes;
        self.relay_stats.bytes_relayed += bytes;
    }

    /// Measured relay egress rate in bytes/s. Blends the live window with
    /// the last completed one so short windows don't read as zero.
    pub fn measured_egress_bps(&self, now: Time) -> u64 {
        let elapsed = now.saturating_sub(self.egress_window_start).max(1);
        let cur = self.egress_window_bytes.saturating_mul(SECOND) / elapsed;
        if elapsed >= SECOND {
            cur // last window is stale; extrapolation decays toward zero
        } else if elapsed >= SECOND / 4 {
            cur.max(self.egress_last_bps)
        } else {
            self.egress_last_bps
        }
    }

    fn relay_overloaded(&self, now: Time) -> bool {
        self.cfg.relay_egress_bps > 0 && self.measured_egress_bps(now) >= self.cfg.relay_egress_bps
    }

    /// Advertised utilization 0–100: the most loaded of circuits,
    /// reservations and the egress budget.
    pub fn relay_utilization(&self, now: Time) -> u32 {
        let frac = |num: u64, den: u64| if den == 0 { 0 } else { (num * 100 / den).min(100) };
        let c = frac(self.circuits.len() as u64, self.cfg.max_circuits as u64);
        let r = frac(
            self.reservations.len() as u64,
            self.cfg.max_reservations as u64,
        );
        let e = if self.cfg.relay_egress_bps > 0 {
            frac(self.measured_egress_bps(now), self.cfg.relay_egress_bps)
        } else {
            0
        };
        c.max(r).max(e) as u32
    }

    /// Relays this node currently holds reservations on (sorted for
    /// deterministic iteration).
    pub fn reserved_relays(&self) -> Vec<PeerId> {
        let mut v: Vec<PeerId> = self.my_reservations.keys().copied().collect();
        v.sort_unstable_by_key(|p| p.0);
        v
    }

    /// Last utilization a relay advertised to us (via RESERVE_OK), if any.
    pub fn relay_load_of(&self, peer: &PeerId) -> Option<u32> {
        self.relay_loads.get(peer).copied()
    }

    /// Flip relay-server duty at runtime (self-promotion when the relay
    /// tier saturates).
    pub fn set_relay_enabled(&mut self, on: bool) {
        self.cfg.relay_enabled = on;
    }

    fn handle_relay_msg(&mut self, net: &mut Net, cid: u64, stream: u64, msg: &Buf) -> Result<()> {
        let m = RelayMsg::decode_buf(msg)?;
        match m.kind {
            relay_msg::M_RESERVE => {
                anyhow::ensure!(self.cfg.relay_enabled, "relaying disabled");
                let now = net.now();
                self.expire_reservations(now);
                let c = self.conns.get(&cid).context("conn gone")?;
                let peer = c.conn.peer.context("unidentified peer")?;
                let observed = match c.path {
                    Path::Direct(a) => a,
                    _ => bail!("reservation over relayed conn"),
                };
                if self.reservations.len() >= self.cfg.max_reservations
                    && !self.reservations.contains_key(&peer)
                {
                    self.relay_stats.reservations_refused += 1;
                    self.send_msg(
                        net,
                        cid,
                        stream,
                        &RelayMsg::reserve_err("relay at reservation capacity").encode(),
                    )?;
                } else {
                    self.reservations.insert(
                        peer,
                        Reservation {
                            cid,
                            stream,
                            expires: now + RESERVATION_TTL,
                        },
                    );
                    let load = self.relay_utilization(now);
                    self.send_msg(
                        net,
                        cid,
                        stream,
                        &RelayMsg::reserve_ok(observed, load).encode(),
                    )?;
                }
            }
            relay_msg::M_RESERVE_OK => {
                let addr = m.observed_addr();
                if let Some(p) = self.conns.get(&cid).and_then(|c| c.conn.peer) {
                    self.my_reservations.insert(p, net.now());
                    self.relay_loads.insert(p, m.load);
                }
                if !self.external_addrs.contains(&addr) {
                    self.external_addrs.push(addr);
                }
                self.events.push_back(SwarmEvent::ObservedAddr { addr });
            }
            relay_msg::M_RESERVE_ERR => {
                // Saturated relay: drop it from our reservation set and
                // remember it as fully loaded so selection avoids it.
                if let Some(p) = self.conns.get(&cid).and_then(|c| c.conn.peer) {
                    self.my_reservations.remove(&p);
                    self.relay_loads.insert(p, 100);
                }
                crate::log_debug!("reservation refused on conn {cid}: {}", m.error);
            }
            relay_msg::M_CONNECT => {
                anyhow::ensure!(self.cfg.relay_enabled, "relaying disabled");
                let now = net.now();
                self.expire_reservations(now);
                let target = m.peer.context("CONNECT missing target")?;
                let res = self.reservations.get(&target).map(|r| (r.cid, r.stream));
                let reply = match res {
                    None => {
                        self.relay_stats.circuits_refused += 1;
                        RelayMsg::connect_err("no reservation for target")
                    }
                    Some((t_cid, t_stream)) => {
                        if self.circuits.len() >= self.cfg.max_circuits {
                            self.relay_stats.circuits_refused += 1;
                            RelayMsg::connect_err("relay at circuit capacity")
                        } else if self.relay_overloaded(now) {
                            self.relay_stats.circuits_refused += 1;
                            RelayMsg::connect_err("relay egress budget exhausted")
                        } else {
                            let from_peer = self
                                .conns
                                .get(&cid)
                                .and_then(|c| c.conn.peer)
                                .context("unidentified initiator")?;
                            let circuit_id = self.next_circuit_id;
                            self.next_circuit_id += 1;
                            self.circuits.insert(
                                circuit_id,
                                Circuit {
                                    a_cid: cid,
                                    a_stream: stream,
                                    a_circuit_id: circuit_id,
                                    b_cid: t_cid,
                                    b_stream: t_stream,
                                    b_circuit_id: circuit_id,
                                },
                            );
                            self.relay_stats.circuits_opened += 1;
                            self.send_msg(
                                net,
                                t_cid,
                                t_stream,
                                &RelayMsg::incoming(circuit_id, from_peer).encode(),
                            )?;
                            RelayMsg::connect_ok(circuit_id)
                        }
                    }
                };
                self.send_msg(net, cid, stream, &reply.encode())?;
            }
            relay_msg::M_CONNECT_OK => {
                // We are the circuit initiator: create the inner connection.
                let target = self
                    .conns
                    .get_mut(&cid)
                    .and_then(|c| c.pending_connects.pop_front())
                    .context("CONNECT_OK without pending connect")?;
                // A pending re-home for this target rebinds the surviving
                // inner connection onto the fresh circuit instead of
                // creating a new one — the logical connection (and all its
                // streams) continues where it left off.
                if let Some(pos) = self
                    .pending_rehomes
                    .iter()
                    .position(|r| r.target == target && r.tried.contains(&cid))
                {
                    let r = self.pending_rehomes.remove(pos);
                    if let Some(c) = self.conns.get_mut(&r.inner_cid) {
                        c.path = Path::Relayed {
                            relay_cid: cid,
                            circuit: m.circuit,
                        };
                        c.parked = None;
                        self.circuit_conns.insert((cid, m.circuit), r.inner_cid);
                        self.relay_stats.failovers_completed += 1;
                        self.flush_conn(net, r.inner_cid);
                        self.arm_tick_for(net, r.inner_cid);
                    }
                    return Ok(());
                }
                let proto = self.conns.get(&cid).map(|c| c.proto).unwrap_or(Proto::QuicLike);
                let mut cfg = self.cfg.conn.clone();
                cfg.profile = TransportProfile::for_proto(proto);
                cfg.mtu = net.mtu;
                let mut inner = Connection::new(
                    Role::Client,
                    cfg,
                    self.keypair.clone(),
                    net.now(),
                    &mut self.rng,
                );
                inner.tune_for_tunnel();
                let inner_cid = inner.local_cid;
                self.conns.insert(
                    inner_cid,
                    ConnState {
                        conn: inner,
                        path: Path::Relayed {
                            relay_cid: cid,
                            circuit: m.circuit,
                        },
                        proto,
                        expected_peer: Some(target),
                        stream_protos: HashMap::new(),
                        relay_ctrl_stream: None,
                        pending_connects: VecDeque::new(),
                        punch: None,
                        reported: false,
                        parked: None,
                    },
                );
                self.circuit_conns.insert((cid, m.circuit), inner_cid);
                self.flush_conn(net, inner_cid);
            }
            relay_msg::M_CONNECT_ERR => {
                let target = self
                    .conns
                    .get_mut(&cid)
                    .and_then(|c| c.pending_connects.pop_front());
                // A refused re-home tries the next backup relay before
                // giving up on the parked inner connection.
                if let Some(t) = target {
                    if let Some(pos) = self
                        .pending_rehomes
                        .iter()
                        .position(|r| r.target == t && r.tried.contains(&cid))
                    {
                        let mut r = self.pending_rehomes.remove(pos);
                        if self.try_next_rehome(net, &mut r) {
                            self.pending_rehomes.push(r);
                        } else {
                            self.relay_stats.failovers_failed += 1;
                            self.teardown_conn(net, r.inner_cid, "relay failover exhausted");
                        }
                        return Ok(());
                    }
                }
                crate::log_debug!("circuit dial to {target:?} failed: {}", m.error);
                self.events.push_back(SwarmEvent::DialFailed {
                    cid,
                    peer: target,
                    reason: format!("relay: {}", m.error),
                });
            }
            relay_msg::M_INCOMING => {
                // We are the circuit target: accept an inner server conn.
                let mut cfg = self.cfg.conn.clone();
                cfg.mtu = net.mtu;
                let mut inner = Connection::new(
                    Role::Server,
                    cfg,
                    self.keypair.clone(),
                    net.now(),
                    &mut self.rng,
                );
                inner.tune_for_tunnel();
                let inner_cid = inner.local_cid;
                self.conns.insert(
                    inner_cid,
                    ConnState {
                        conn: inner,
                        path: Path::Relayed {
                            relay_cid: cid,
                            circuit: m.circuit,
                        },
                        proto: Proto::QuicLike,
                        expected_peer: None,
                        stream_protos: HashMap::new(),
                        relay_ctrl_stream: None,
                        pending_connects: VecDeque::new(),
                        punch: None,
                        reported: false,
                        parked: None,
                    },
                );
                self.circuit_conns.insert((cid, m.circuit), inner_cid);
            }
            relay_msg::M_DATA => {
                if let Some(circ) = self.circuits.get(&m.circuit) {
                    // Relay server: forward to the other side.
                    let (o_cid, o_stream, o_circ) = if circ.a_cid == cid {
                        (circ.b_cid, circ.b_stream, circ.b_circuit_id)
                    } else {
                        (circ.a_cid, circ.a_stream, circ.a_circuit_id)
                    };
                    self.note_egress(net.now(), m.payload.len() as u64);
                    self.send_msg_buf(
                        net,
                        o_cid,
                        o_stream,
                        RelayMsg::data(o_circ, m.payload).encode_buf(),
                    )?;
                } else {
                    // Client side: feed the inner connection (zero-copy view
                    // of the relay message payload).
                    let pkt = Packet::decode_buf(&m.payload)?;
                    let mapped = self.circuit_conns.get(&(cid, m.circuit)).copied();
                    // Passive re-home: packets addressed to an established
                    // inner connection arriving on a circuit it doesn't
                    // ride mean the initiator failed over to a backup
                    // relay. Rebind the connection onto this circuit and
                    // drop the placeholder conn M_INCOMING created.
                    let inner_cid = if pkt.dst_cid != 0 && self.conns.contains_key(&pkt.dst_cid) {
                        let ic = pkt.dst_cid;
                        let here = Path::Relayed {
                            relay_cid: cid,
                            circuit: m.circuit,
                        };
                        let cur = self.conns[&ic].path;
                        if !matches!(cur, Path::Direct(_)) && cur != here {
                            if let Some(c) = self.conns.get_mut(&ic) {
                                c.path = here;
                                c.parked = None;
                            }
                            if let Some(old) = mapped {
                                if old != ic {
                                    self.teardown_conn(
                                        net,
                                        old,
                                        "superseded by re-homed connection",
                                    );
                                }
                            }
                            self.circuit_conns.retain(|_, v| *v != ic);
                            self.circuit_conns.insert((cid, m.circuit), ic);
                        }
                        ic
                    } else if let Some(ic) = mapped {
                        ic
                    } else {
                        return Ok(()); // unknown circuit: stateless drop
                    };
                    let info = {
                        let c = self.conns.get_mut(&inner_cid).context("inner conn gone")?;
                        c.conn.handle_packet(net.now(), pkt).unwrap_or_default()
                    };
                    // Path challenges over the circuit are answered over the
                    // circuit (no address migration).
                    let responses: Vec<Vec<u8>> = {
                        let c = self.conns.get_mut(&inner_cid).unwrap();
                        info.path_challenges
                            .iter()
                            .map(|t| c.conn.make_path_response(*t))
                            .collect()
                    };
                    for r in responses {
                        self.send_circuit_datagram(net, cid, m.circuit, r);
                    }
                    self.post_rx(net, inner_cid, None, info);
                }
            }
            relay_msg::M_CIRCUIT_CLOSED => {
                // The circuit died (usually the peer's relay leg). Park the
                // inner conn and attempt failover through another relay
                // rather than tearing it down outright; if no backup works
                // out the parked conn is torn down by its grace deadline.
                if let Some(inner_cid) = self.circuit_conns.remove(&(cid, m.circuit)) {
                    self.begin_rehome(net, inner_cid, cid);
                }
            }
            other => bail!("unexpected relay message kind {other}"),
        }
        Ok(())
    }

    fn send_circuit_datagram(&mut self, net: &mut Net, relay_cid: u64, circuit: u64, pkt: Vec<u8>) {
        let Ok(stream) = self.ensure_relay_ctrl(net, relay_cid) else {
            return;
        };
        let _ = self.send_msg_buf(net, relay_cid, stream, RelayMsg::data(circuit, pkt).encode_buf());
    }

    // ------------------------------------------------------------------
    // Output + timers
    // ------------------------------------------------------------------

    /// Drain a connection's pending packets onto its path.
    fn flush_conn(&mut self, net: &mut Net, cid: u64) {
        let local_addr = self.local_addr;
        loop {
            let (packets, path) = {
                let Some(c) = self.conns.get_mut(&cid) else { return };
                let out = c.conn.poll_output(net.now());
                (out, c.path)
            };
            if packets.is_empty() {
                break;
            }
            match path {
                Path::Direct(addr) => {
                    for p in packets {
                        net.send(local_addr, addr, p);
                    }
                }
                Path::Relayed { relay_cid, circuit } => {
                    for p in packets {
                        self.send_circuit_datagram(net, relay_cid, circuit, p);
                    }
                }
            }
        }
        // Closed after flush? tear down.
        let closed = self
            .conns
            .get(&cid)
            .map(|c| c.conn.is_closed())
            .unwrap_or(false);
        if closed {
            let reason = self
                .conns
                .get(&cid)
                .and_then(|c| c.conn.closed_reason.clone())
                .unwrap_or_else(|| "closed".into());
            self.teardown_conn(net, cid, &reason);
        }
    }

    /// Earliest deadline across connections and punches.
    pub fn next_deadline(&self, now: Time) -> Option<Time> {
        let mut t: Option<Time> = None;
        let mut consider = |x: Time| t = Some(t.map_or(x, |v: Time| v.min(x)));
        for c in self.conns.values() {
            if let Some(d) = c.conn.next_timeout(now) {
                consider(d);
            }
            if let Some(p) = &c.punch {
                consider(p.deadline);
            }
            if let Some(d) = c.parked {
                consider(d);
            }
        }
        t
    }

    /// Arm (or re-arm) the swarm tick timer at the next deadline.
    pub fn arm_tick(&mut self, net: &mut Net) {
        let now = net.now();
        if let Some(d) = self.next_deadline(now) {
            self.arm_at(net, now, d);
        }
    }

    /// Arm the tick for one connection's deadline only — the hot send
    /// paths use this to avoid rescanning every connection per message.
    fn arm_tick_for(&mut self, net: &mut Net, cid: u64) {
        let now = net.now();
        let d = self.conns.get(&cid).and_then(|c| c.conn.next_timeout(now));
        if let Some(d) = d {
            self.arm_at(net, now, d);
        }
    }

    fn arm_at(&mut self, net: &mut Net, now: Time, d: Time) {
        let d = d.max(now + 100); // clamp: never schedule in the past
        if self.tick_armed_until == 0 || d < self.tick_armed_until || now >= self.tick_armed_until
        {
            net.set_timer(self.endpoint_id, d - now, TIMER_SWARM_TICK);
            self.tick_armed_until = d;
        }
    }

    /// Timer tick: drive per-connection timers and punches.
    pub fn on_timer(&mut self, net: &mut Net, token: u64) {
        if token != TIMER_SWARM_TICK {
            return;
        }
        self.tick_armed_until = 0;
        let now = net.now();
        let cids: Vec<u64> = self.conns.keys().copied().collect();
        for cid in cids {
            let due = self
                .conns
                .get(&cid)
                .and_then(|c| c.conn.next_timeout(now))
                .map_or(false, |d| d <= now);
            if due {
                if let Some(c) = self.conns.get_mut(&cid) {
                    c.conn.on_timer(now);
                }
                self.pump_conn_events(net, cid);
                self.flush_conn(net, cid);
            }
            let punch_due = self
                .conns
                .get(&cid)
                .and_then(|c| c.punch.as_ref())
                .map_or(false, |p| p.deadline <= now);
            if punch_due {
                self.drive_punch(net, cid);
            }
            // Parked conns whose re-home grace expired are torn down.
            let park_due = self
                .conns
                .get(&cid)
                .and_then(|c| c.parked)
                .map_or(false, |d| d <= now);
            if park_due {
                self.pending_rehomes.retain(|r| r.inner_cid != cid);
                self.teardown_conn(net, cid, "relay failover timed out");
            }
        }
        if self.cfg.relay_enabled {
            self.expire_reservations(now);
        }
        self.arm_tick(net);
    }
}

#[cfg(test)]
mod tests;
