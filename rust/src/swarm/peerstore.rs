//! The peerstore: known addresses, keys and protocol support per peer.

use crate::crypto::PublicKey;
use crate::identity::PeerId;
use crate::multiaddr::Multiaddr;
use crate::netsim::Time;
use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct PeerInfo {
    pub addrs: Vec<Multiaddr>,
    pub key: Option<PublicKey>,
    pub protocols: Vec<String>,
    pub last_seen: Time,
}

/// Address book + key cache. Protocols (identify, DHT, rendezvous) feed it;
/// dial logic and shard-aware RPC stubs read from it.
#[derive(Default)]
pub struct Peerstore {
    peers: HashMap<PeerId, PeerInfo>,
}

impl Peerstore {
    pub fn new() -> Peerstore {
        Peerstore::default()
    }

    pub fn add_address(&mut self, peer: PeerId, addr: Multiaddr) {
        let info = self.peers.entry(peer).or_default();
        if !info.addrs.contains(&addr) {
            info.addrs.push(addr);
        }
    }

    pub fn set_key(&mut self, peer: PeerId, key: PublicKey) {
        self.peers.entry(peer).or_default().key = Some(key);
    }

    pub fn set_protocols(&mut self, peer: PeerId, protocols: Vec<String>) {
        self.peers.entry(peer).or_default().protocols = protocols;
    }

    pub fn touch(&mut self, peer: PeerId, now: Time) {
        self.peers.entry(peer).or_default().last_seen = now;
    }

    pub fn addrs(&self, peer: &PeerId) -> &[Multiaddr] {
        self.peers.get(peer).map(|p| p.addrs.as_slice()).unwrap_or(&[])
    }

    pub fn key(&self, peer: &PeerId) -> Option<&PublicKey> {
        self.peers.get(peer).and_then(|p| p.key.as_ref())
    }

    pub fn info(&self, peer: &PeerId) -> Option<&PeerInfo> {
        self.peers.get(peer)
    }

    pub fn known_peers(&self) -> impl Iterator<Item = &PeerId> {
        self.peers.keys()
    }

    pub fn len(&self) -> usize {
        self.peers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.peers.is_empty()
    }

    pub fn remove(&mut self, peer: &PeerId) {
        self.peers.remove(peer);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::identity::Keypair;
    use crate::multiaddr::{Proto, SimAddr};

    #[test]
    fn addresses_dedupe() {
        let mut ps = Peerstore::new();
        let pid = Keypair::from_seed(1).peer_id();
        let ma = Multiaddr::direct(SimAddr::new(1, 2), Proto::QuicLike);
        ps.add_address(pid, ma.clone());
        ps.add_address(pid, ma.clone());
        assert_eq!(ps.addrs(&pid).len(), 1);
        let ma2 = Multiaddr::direct(SimAddr::new(1, 3), Proto::QuicLike);
        ps.add_address(pid, ma2);
        assert_eq!(ps.addrs(&pid).len(), 2);
    }

    #[test]
    fn unknown_peer_empty() {
        let ps = Peerstore::new();
        let pid = Keypair::from_seed(9).peer_id();
        assert!(ps.addrs(&pid).is_empty());
        assert!(ps.key(&pid).is_none());
    }

    #[test]
    fn keys_and_protocols() {
        let mut ps = Peerstore::new();
        let kp = Keypair::from_seed(2);
        ps.set_key(kp.peer_id(), kp.public());
        ps.set_protocols(kp.peer_id(), vec!["/lattica/rpc/1".into()]);
        assert_eq!(ps.key(&kp.peer_id()), Some(&kp.public()));
        assert_eq!(ps.info(&kp.peer_id()).unwrap().protocols.len(), 1);
        assert_eq!(ps.len(), 1);
    }
}
