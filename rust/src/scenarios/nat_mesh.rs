//! Mixed-NAT mesh scenario: the acceptance harness behind
//! `tests/nat_traversal.rs` and `BENCH_nat_traversal.json`.
//!
//! Builds a deployment where every non-relay node sits behind a NAT type
//! sampled from [`super::NAT_DISTRIBUTION`] (with `nat_realistic`
//! misbehaviour enabled), lets the relay-autoscaling machinery settle
//! (AutoNAT probes → relay ads → load-aware reservations), then samples
//! peer pairs and records per-NAT-pair connectivity, direct-upgrade
//! fraction, and per-relay load. The optional relay-kill arm proves
//! mid-stream failover: a circuit's relay dies unclean and the logical
//! connection must recover onto a backup relay without a disconnect.

use super::{echo_service, sample_nat, stub_call_blocking, Node};
use crate::identity::PeerId;
use crate::multiaddr::Multiaddr;
use crate::netsim::nat::NatType;
use crate::netsim::topology::{LinkProfile, TopologyBuilder};
use crate::netsim::{World, SECOND};
use crate::node::{run_until, LatticaNode, NodeConfig, NodeEvent};
use crate::protocols::Ctx;
use crate::rpc::{Status, Stub};
use std::collections::BTreeMap;

/// Configuration for [`nat_mesh`].
#[derive(Clone, Debug)]
pub struct NatMeshConfig {
    /// Non-relay nodes (NAT types sampled from the distribution).
    pub nodes: usize,
    /// Seed relay nodes (public, `relay_enabled`).
    pub relays: usize,
    /// Random peer pairs to attempt connecting.
    pub pair_samples: usize,
    /// Run the relay-kill failover arm after pair sampling.
    pub relay_kill: bool,
    /// Non-relay nodes may self-promote when the relay tier saturates.
    pub autopromote: bool,
    /// Relay capacity knobs (forwarded to every node's swarm so promoted
    /// nodes inherit them).
    pub relay_max_circuits: usize,
    pub relay_max_reservations: usize,
    /// Relay forwarding budget in bytes/s (0 = unlimited).
    pub relay_egress_bps: u64,
    /// Settle time before sampling: AutoNAT probes (2 s cadence), relay
    /// ads and reservation maintenance all need a few ticks.
    pub settle_secs: u64,
    pub seed: u64,
}

impl NatMeshConfig {
    /// Small deterministic arm for always-on tests.
    pub fn quick(seed: u64) -> NatMeshConfig {
        NatMeshConfig {
            nodes: 36,
            relays: 3,
            pair_samples: 40,
            relay_kill: false,
            autopromote: false,
            relay_max_circuits: 1024,
            relay_max_reservations: 512,
            relay_egress_bps: 0,
            settle_secs: 8,
            seed,
        }
    }

    /// The issue's 1k-node acceptance arm (release bench only).
    pub fn ci(seed: u64) -> NatMeshConfig {
        NatMeshConfig {
            nodes: 1000,
            relays: 8,
            pair_samples: 200,
            relay_kill: false,
            autopromote: true,
            relay_max_circuits: 1024,
            relay_max_reservations: 512,
            // Generous but finite: the budget is enforced (over-budget
            // CONNECTs are refused) without binding on handshake traffic.
            relay_egress_bps: 50_000_000,
            settle_secs: 12,
            seed,
        }
    }
}

/// Outcomes for one unordered NAT-type pairing (e.g. `prc|sym`).
#[derive(Clone, Debug, Default)]
pub struct NatPairRow {
    pub label: String,
    pub attempted: u64,
    /// Pairs that ended connected at all (direct or relayed).
    pub connected: u64,
    /// Pairs that ended with a direct (punched or dialed) path.
    pub direct: u64,
    /// Pairs connected but still relayed after the upgrade attempt.
    pub relayed: u64,
}

/// One relay's end-of-run load summary.
#[derive(Clone, Debug)]
pub struct RelayRow {
    pub label: String,
    pub bytes_relayed: u64,
    pub circuits_opened: u64,
    pub circuits_refused: u64,
    pub reservations_refused: u64,
    /// Utilization 0–100 at collection time.
    pub utilization: u32,
    /// Average forwarding egress over the whole run, bytes/s.
    pub egress_bps_avg: u64,
}

/// Result of the relay-kill failover arm.
#[derive(Clone, Debug)]
pub struct FailoverOutcome {
    /// The initiator rebound its inner connection to a backup relay.
    pub recovered: bool,
    /// An RPC issued after the kill completed OK over the re-homed path.
    pub call_after_kill_ok: bool,
    /// The logical connection surfaced a disconnect (must stay false).
    pub peer_disconnected_seen: bool,
    pub failovers_completed: u64,
}

/// Everything [`nat_mesh`] measures.
#[derive(Clone, Debug)]
pub struct NatMeshOutcome {
    pub nodes: usize,
    pub relays: usize,
    pub pair_rows: Vec<NatPairRow>,
    pub relay_rows: Vec<RelayRow>,
    pub attempted: u64,
    pub connected: u64,
    pub direct: u64,
    /// connected / attempted.
    pub connectivity: f64,
    /// Fraction of NATted nodes holding ≥1 relay reservation after settle.
    pub reservation_coverage: f64,
    /// Nodes that self-promoted to relay duty.
    pub promoted: usize,
    pub failover: Option<FailoverOutcome>,
}

fn nat_label(n: Option<NatType>) -> &'static str {
    match n {
        None => "public",
        Some(t) => t.label(),
    }
}

/// Canonical unordered pairing label, e.g. `full-cone|symmetric`.
fn pair_label(a: Option<NatType>, b: Option<NatType>) -> String {
    let (x, y) = (nat_label(a), nat_label(b));
    if x <= y {
        format!("{x}|{y}")
    } else {
        format!("{y}|{x}")
    }
}

fn has_direct_path(node: &Node, peer: &PeerId) -> bool {
    let n = node.borrow();
    n.swarm
        .conns_to(peer)
        .iter()
        .any(|c| matches!(n.swarm.connection_path(*c), Some(crate::swarm::Path::Direct(_))))
}

/// Build the mesh, settle autoscaling, sample pairs, optionally kill a
/// relay mid-stream. Fully deterministic in the config.
pub fn nat_mesh(cfg: &NatMeshConfig) -> NatMeshOutcome {
    let mut rng = crate::util::Rng::new(cfg.seed ^ 0x4A70);
    let mut t = TopologyBuilder::paper_regions();
    let relay_hosts: Vec<u32> = (0..cfg.relays)
        .map(|i| t.public_host(i % 3, LinkProfile::DATACENTER))
        .collect();
    let mut node_nats: Vec<Option<NatType>> = Vec::with_capacity(cfg.nodes);
    let node_hosts: Vec<u32> = (0..cfg.nodes)
        .map(|i| {
            let region = i % 3;
            let nat = sample_nat(&mut rng);
            node_nats.push(nat);
            match nat {
                None => t.public_host(region, LinkProfile::FIBER),
                Some(n) => {
                    let id = t.nat_realistic(region, n, LinkProfile::FIBER);
                    t.natted_host(id, LinkProfile::UNLIMITED)
                }
            }
        })
        .collect();
    let mut world = World::new(t.build(cfg.seed));

    let relays: Vec<Node> = relay_hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            LatticaNode::spawn(&mut world, h, {
                let mut c = NodeConfig::relay(cfg.seed * 1000 + i as u64);
                c.relay_max_circuits = cfg.relay_max_circuits;
                c.relay_max_reservations = cfg.relay_max_reservations;
                c.relay_egress_bps = cfg.relay_egress_bps;
                c.label = format!("relay-{i}");
                c
            })
        })
        .collect();
    let workers: Vec<Node> = node_hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            LatticaNode::spawn(&mut world, h, {
                let mut c = NodeConfig::with_seed(cfg.seed * 1000 + 100 + i as u64);
                c.relay_autopromote = cfg.autopromote;
                c.relay_max_circuits = cfg.relay_max_circuits;
                c.relay_max_reservations = cfg.relay_max_reservations;
                c.relay_egress_bps = cfg.relay_egress_bps;
                c.label = format!("node-{i}");
                c
            })
        })
        .collect();

    let entry0 = crate::protocols::kad::PeerEntry {
        id: relays[0].borrow().peer_id(),
        host: relay_hosts[0],
        port: 4001,
    };
    for nd in relays.iter().skip(1).chain(workers.iter()) {
        nd.borrow_mut().bootstrap(&mut world.net, entry0.clone());
    }
    world.run_for(cfg.settle_secs * SECOND);

    // Reservation coverage: every Private node should hold a reservation
    // by now (RelayManager maintains TARGET_RESERVATIONS of them).
    let natted: Vec<usize> = (0..cfg.nodes).filter(|&i| node_nats[i].is_some()).collect();
    let with_res = natted
        .iter()
        .filter(|&&i| !workers[i].borrow().swarm.reserved_relays().is_empty())
        .count();
    let reservation_coverage = if natted.is_empty() {
        1.0
    } else {
        with_res as f64 / natted.len() as f64
    };

    // Address book of every relay-capable node (seed relays + promoted).
    let relay_addrs = |relays: &[Node], workers: &[Node]| -> BTreeMap<PeerId, Multiaddr> {
        let mut m = BTreeMap::new();
        for nd in relays.iter().chain(workers.iter()) {
            let n = nd.borrow();
            if n.swarm.cfg.relay_enabled {
                m.insert(n.peer_id(), n.listen_addr());
            }
        }
        m
    };

    // --- Pair sampling -----------------------------------------------------
    let mut rows: BTreeMap<String, NatPairRow> = BTreeMap::new();
    let (mut attempted, mut connected_n, mut direct_n) = (0u64, 0u64, 0u64);
    for _ in 0..cfg.pair_samples {
        let ai = rng.gen_index(cfg.nodes);
        let mut bi = rng.gen_index(cfg.nodes);
        if bi == ai {
            bi = (bi + 1) % cfg.nodes;
        }
        let a = &workers[ai];
        let b = &workers[bi];
        let b_peer = b.borrow().peer_id();
        let label = pair_label(node_nats[ai], node_nats[bi]);

        let mut ok = a.borrow().swarm.is_connected(&b_peer);
        if !ok {
            if node_nats[bi].is_none() {
                // Public target: plain direct dial.
                let ma = b.borrow().listen_addr();
                let _ = a.borrow_mut().dial(&mut world.net, &ma);
                ok = run_until(&mut world, 10 * SECOND, || {
                    a.borrow().swarm.is_connected(&b_peer)
                });
            } else {
                // NATted target: circuit via a relay it holds a
                // reservation on, then a DCUtR upgrade attempt.
                let book = relay_addrs(&relays, &workers);
                let reserved = b.borrow().swarm.reserved_relays();
                if let Some(relay_ma) =
                    reserved.iter().find_map(|p| book.get(p).cloned())
                {
                    let circuit = Multiaddr::circuit(relay_ma, b_peer);
                    let _ = a.borrow_mut().dial(&mut world.net, &circuit);
                    ok = run_until(&mut world, 10 * SECOND, || {
                        a.borrow().swarm.is_connected(&b_peer)
                    });
                    if ok && !has_direct_path(a, &b_peer) {
                        let cid = a.borrow().swarm.conns_to(&b_peer)[0];
                        {
                            let mut n = a.borrow_mut();
                            let LatticaNode { swarm, dcutr, .. } = &mut *n;
                            let mut ctx = Ctx::new(swarm, &mut world.net);
                            let _ = dcutr.upgrade(&mut ctx, cid, &b_peer);
                        }
                        world.run_for(4 * SECOND);
                    }
                }
            }
        }
        let direct = ok && has_direct_path(a, &b_peer);
        let row = rows.entry(label.clone()).or_insert_with(|| NatPairRow {
            label,
            ..Default::default()
        });
        row.attempted += 1;
        attempted += 1;
        if ok {
            row.connected += 1;
            connected_n += 1;
            if direct {
                row.direct += 1;
                direct_n += 1;
            } else {
                row.relayed += 1;
            }
        }
    }

    // --- Relay-kill failover arm ------------------------------------------
    let mut killed_row: Option<RelayRow> = None;
    let mut killed_idx: Option<usize> = None;
    let failover = if cfg.relay_kill && cfg.relays >= 2 {
        run_relay_kill(
            &mut world,
            &relays,
            &workers,
            &node_nats,
            &mut killed_row,
            &mut killed_idx,
        )
    } else {
        None
    };

    // --- Collect -----------------------------------------------------------
    let now = world.net.now();
    let mut relay_rows: Vec<RelayRow> = Vec::new();
    for (i, nd) in relays.iter().enumerate() {
        if killed_idx == Some(i) {
            relay_rows.push(killed_row.clone().expect("killed relay row captured"));
            continue;
        }
        let n = nd.borrow();
        relay_rows.push(relay_row(&n, now));
    }
    let mut promoted = 0usize;
    for (i, nd) in workers.iter().enumerate() {
        let n = nd.borrow();
        if n.relay_mgr.promoted {
            promoted += 1;
            let mut row = relay_row(&n, now);
            row.label = format!("promoted-node-{i}");
            relay_rows.push(row);
        }
    }

    NatMeshOutcome {
        nodes: cfg.nodes,
        relays: cfg.relays,
        pair_rows: rows.into_values().collect(),
        relay_rows,
        attempted,
        connected: connected_n,
        direct: direct_n,
        connectivity: if attempted == 0 {
            1.0
        } else {
            connected_n as f64 / attempted as f64
        },
        reservation_coverage,
        promoted,
        failover,
    }
}

fn relay_row(n: &LatticaNode, now: crate::netsim::Time) -> RelayRow {
    let s = n.swarm.relay_stats.clone();
    RelayRow {
        label: n.cfg.label.clone(),
        bytes_relayed: s.bytes_relayed,
        circuits_opened: s.circuits_opened,
        circuits_refused: s.circuits_refused,
        reservations_refused: s.reservations_refused,
        utilization: n.swarm.relay_utilization(now),
        egress_bps_avg: s.bytes_relayed / (now / SECOND).max(1),
    }
}

/// Kill the relay under an in-use circuit; the logical connection must
/// re-home to a backup relay without surfacing a disconnect, and an RPC
/// issued afterwards must still complete.
fn run_relay_kill(
    world: &mut World,
    relays: &[Node],
    workers: &[Node],
    node_nats: &[Option<NatType>],
    killed_row: &mut Option<RelayRow>,
    killed_idx: &mut Option<usize>,
) -> Option<FailoverOutcome> {
    // Find a NATted pair sharing ≥2 reservations: one relay to kill, one
    // to fail over to. (RelayManager targets 2 reservations per node, so
    // with a small relay tier a shared pair is the common case.)
    let relay_peers: Vec<PeerId> = relays.iter().map(|r| r.borrow().peer_id()).collect();
    let mut pick: Option<(usize, usize, Vec<PeerId>)> = None;
    'outer: for ai in 0..workers.len() {
        if node_nats[ai].is_none() {
            continue;
        }
        let ar = workers[ai].borrow().swarm.reserved_relays();
        for bi in 0..workers.len() {
            if bi == ai || node_nats[bi].is_none() {
                continue;
            }
            let br = workers[bi].borrow().swarm.reserved_relays();
            let common: Vec<PeerId> = ar
                .iter()
                .filter(|p| br.contains(p) && relay_peers.contains(p))
                .copied()
                .collect();
            if common.len() >= 2 {
                pick = Some((ai, bi, common));
                break 'outer;
            }
        }
    }
    let (ai, bi, common) = pick?;
    let a = &workers[ai];
    let b = &workers[bi];
    let b_peer = b.borrow().peer_id();
    b.borrow_mut().register_service(echo_service(1024));

    // Circuit through the first common relay (the one we will kill).
    let kill_peer = common[0];
    let ki = relay_peers.iter().position(|p| *p == kill_peer)?;
    let relay_ma = relays[ki].borrow().listen_addr();
    if !a.borrow().swarm.is_connected(&b_peer) {
        let circuit = Multiaddr::circuit(relay_ma, b_peer);
        let _ = a.borrow_mut().dial(&mut world.net, &circuit);
        if !run_until(world, 10 * SECOND, || a.borrow().swarm.is_connected(&b_peer)) {
            return None;
        }
    }
    // Prove the path carries traffic before the kill.
    let mut stub = Stub::new("bench", vec![b_peer]);
    let pre = stub_call_blocking(world, a, &mut stub, "echo", b"pre".to_vec(), 10 * SECOND);
    if pre.map(|d| d.status) != Some(Status::Ok) {
        return None;
    }
    a.borrow_mut().drain_events(); // post-kill disconnect detection baseline

    // Unclean kill: no close frames, circuits die with the process.
    let kill_at = world.net.now();
    *killed_row = Some({
        let n = relays[ki].borrow();
        let mut row = relay_row(&n, kill_at);
        row.label = format!("{} (killed)", row.label);
        row
    });
    *killed_idx = Some(ki);
    let eid = {
        let mut n = relays[ki].borrow_mut();
        n.shutdown(&mut world.net, false);
        n.endpoint_id()
    };
    world.remove_endpoint(eid);

    // The initiator detects the dead relay connection (keepalive/RTO),
    // parks the inner connection and re-homes it via CONNECT on the
    // backup relay — all within the rehome grace window.
    let recovered = run_until(world, 60 * SECOND, || {
        let n = a.borrow();
        n.swarm.relay_stats.failovers_completed >= 1
            || n.swarm.relay_stats.failovers_failed >= 1
    });
    let fs = a.borrow().swarm.relay_stats.clone();
    let still_connected = a.borrow().swarm.is_connected(&b_peer);
    let peer_disconnected_seen = a
        .borrow_mut()
        .drain_events()
        .iter()
        .any(|ev| matches!(ev, NodeEvent::PeerDisconnected { peer } if *peer == b_peer));
    let post = stub_call_blocking(world, a, &mut stub, "echo", b"post".to_vec(), 15 * SECOND);
    Some(FailoverOutcome {
        recovered: recovered && fs.failovers_completed >= 1 && still_connected,
        call_after_kill_ok: post.map(|d| d.status) == Some(Status::Ok),
        peer_disconnected_seen,
        failovers_completed: fs.failovers_completed,
    })
}
