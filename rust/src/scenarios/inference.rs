//! Distributed-inference scenario (DESIGN.md §Inference plane): a
//! geo-distributed set of layer-shard replicas serving token streams to a
//! client, comparing latency-aware chain routing against a
//! placement-blind static chain, with an optional mid-stream stage kill
//! exercising splice-repair + replay.
//!
//! Deployment: the client sits in region 0; every pipeline stage has two
//! replicas — one in the client's region (LAN) and one across a continent
//! (region 1 or 2). The static baseline pins each stage to its
//! first-registered holder, which is the remote one (a capacity-ordered
//! assignment that never looked at latency); the routed arm assembles the
//! chain from live ads + measured RTTs and should discover the all-local
//! chain.
//!
//! Fully deterministic in the config.

use super::Node;
use crate::metrics::{Histogram, InferenceStats};
use crate::netsim::topology::{LinkProfile, TopologyBuilder};
use crate::netsim::{Time, World, MILLI, SECOND};
use crate::node::{LatticaNode, NodeConfig, NodeEvent};
use crate::protocols::kad::KadEvent;
use crate::protocols::Ctx;
use crate::route::{bucket_key, ChainClient, Hop, RouteMode, RouteShard, ShardSpec, SimModel};

/// Deployment + workload for [`route_inference`].
#[derive(Clone)]
pub struct RouteScenarioConfig {
    pub seed: u64,
    /// Requests issued (staggered starts, concurrent streams).
    pub requests: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    /// Pipeline stages; must divide the model's layer count.
    pub stages: usize,
    /// Latency-aware routing; false = the static first-holder baseline.
    pub routed: bool,
    /// Kill the local replica of the middle stage once at least one
    /// request is mid-stream (has acked ≥ 1 token). Routed arm only.
    pub kill: bool,
    pub model: SimModel,
    /// Per-stage KV capacity in entries (owned-layer × position).
    pub capacity_entries: u64,
}

impl RouteScenarioConfig {
    /// Small smoke-test shape (unit-test friendly).
    pub fn quick(routed: bool, kill: bool) -> RouteScenarioConfig {
        RouteScenarioConfig {
            seed: 7,
            requests: 2,
            prompt_len: 4,
            gen_len: 4,
            stages: 2,
            routed,
            kill,
            model: SimModel::tiny(),
            capacity_entries: 1 << 16,
        }
    }

    /// The shape the release tests and `BENCH_sharded_inference.json` use.
    pub fn ci(routed: bool, kill: bool) -> RouteScenarioConfig {
        RouteScenarioConfig {
            seed: 42,
            requests: 6,
            prompt_len: 6,
            gen_len: 8,
            stages: 3,
            routed,
            kill,
            model: SimModel::tiny(),
            capacity_entries: 1 << 16,
        }
    }
}

/// Result of one [`route_inference`] run.
pub struct RouteOutcome {
    pub requests: usize,
    pub completed: usize,
    /// Requests that missed the deadline (client-visible failures).
    pub failed: usize,
    /// Time-to-first-token per completed request.
    pub ttft: Histogram,
    /// Tokens delivered to the client.
    pub tokens: u64,
    /// Tokens per virtual second, first start → last completion.
    pub tokens_per_sec: f64,
    /// Chain repairs performed by the client.
    pub repairs: u64,
    /// Duplicate KV appends across all stages (must be 0: replays
    /// recompute via generation reset, they never double-append).
    pub duplicate_appends: u64,
    pub evictions: u64,
    pub kv_peak: u64,
    /// Every completed request's tokens matched the single-process
    /// oracle ([`SimModel::reference_generate`]).
    pub reference_match: bool,
    /// Providers returned for the model's first layer bucket (DHT
    /// advertisement path).
    pub dht_holders: usize,
    /// Merged stage-side counters (including any killed stage, captured
    /// pre-kill).
    pub shard_stats: InferenceStats,
}

struct Replica {
    node: Node,
    shard: RouteShard,
    /// Index of the pipeline stage this replica serves.
    stage: usize,
    /// True for the replica in the client's region.
    local: bool,
    alive: bool,
}

/// Build the deployment, run the workload, and collect the outcome.
pub fn route_inference(cfg: &RouteScenarioConfig) -> RouteOutcome {
    assert!(cfg.stages >= 2, "need a chain, not a single stage");
    assert_eq!(
        cfg.model.n_layer as usize % cfg.stages,
        0,
        "stages must divide n_layer"
    );
    let per_stage = cfg.model.n_layer / cfg.stages as u32;

    // --- Topology: client region 0; per stage one remote + one local
    // replica. Remote-first spawn order makes the static baseline's
    // "first registered holder" the cross-continent one.
    let mut t = TopologyBuilder::paper_regions();
    let client_host = t.public_host(0, LinkProfile::FIBER);
    let mut replica_hosts: Vec<(u32, u32, bool)> = Vec::new(); // (host, region, local)
    for i in 0..cfg.stages {
        let remote_region = 1 + (i as u32 % 2);
        replica_hosts.push((
            t.public_host(remote_region as usize, LinkProfile::FIBER),
            remote_region,
            false,
        ));
        replica_hosts.push((t.public_host(0, LinkProfile::FIBER), 0, true));
    }
    let mut world = World::new(t.build(cfg.seed));
    let client = LatticaNode::spawn(&mut world, client_host, {
        let mut c = NodeConfig::with_seed(cfg.seed * 1000);
        c.label = "client".into();
        c
    });
    let mut replicas: Vec<Replica> = replica_hosts
        .iter()
        .enumerate()
        .map(|(i, &(host, region, local))| {
            let node = LatticaNode::spawn(&mut world, host, {
                let mut c = NodeConfig::with_seed(cfg.seed * 1000 + 1 + i as u64);
                c.label = format!("shard-{}-{}", i / 2, if local { "local" } else { "remote" });
                c
            });
            let stage = i / 2;
            let layers = (stage as u32 * per_stage, (stage as u32 + 1) * per_stage);
            let shard = {
                let mut n = node.borrow_mut();
                RouteShard::install(
                    &mut n,
                    &mut world.net,
                    ShardSpec {
                        model: cfg.model.clone(),
                        layers,
                        region,
                        capacity_entries: cfg.capacity_entries,
                    },
                )
            };
            Replica { node, shard, stage, local, alive: true }
        })
        .collect();

    let entry = crate::protocols::kad::PeerEntry {
        id: client.borrow().peer_id(),
        host: client_host,
        port: 4001,
    };
    for r in &replicas {
        r.node.borrow_mut().bootstrap(&mut world.net, entry.clone());
    }
    world.run_for(3 * SECOND);

    // Static baseline: first-registered (remote) holder per stage.
    let static_chain: Vec<Hop> = replicas
        .iter()
        .filter(|r| !r.local)
        .map(|r| {
            let n = r.node.borrow();
            Hop {
                peer: n.peer_id(),
                host: n.swarm.local_addr.host,
                port: n.swarm.local_addr.port,
                layers: (r.stage as u32 * per_stage, (r.stage as u32 + 1) * per_stage),
            }
        })
        .collect();
    let mode = if cfg.routed {
        RouteMode::Routed
    } else {
        RouteMode::Static(static_chain)
    };
    let mut chain = {
        let mut n = client.borrow_mut();
        ChainClient::new(&mut n, &mut world.net, cfg.model.clone(), 0, mode)
    };

    // One pump step: advance the world, tick every stage and the client,
    // feed client events through the chain, return unconsumed ones.
    let step = |world: &mut World,
                replicas: &mut Vec<Replica>,
                chain: &mut ChainClient,
                client: &Node|
     -> Vec<NodeEvent> {
        world.run_for(50 * MILLI);
        for r in replicas.iter().filter(|r| r.alive) {
            r.node.borrow_mut().drain_events();
            let mut n = r.node.borrow_mut();
            r.shard.tick(&mut n, &mut world.net);
        }
        let evs = client.borrow_mut().drain_events();
        let mut n = client.borrow_mut();
        let mut leftover = Vec::new();
        for ev in evs {
            if !chain.on_event(&mut n, &mut world.net, &ev) {
                leftover.push(ev);
            }
        }
        chain.tick(&mut n, &mut world.net);
        leftover
    };

    // Warm-up: ads gossip out, provider records land, probes measure RTTs.
    for _ in 0..120 {
        step(&mut world, &mut replicas, &mut chain, &client);
    }

    // DHT advertisement check: who provides the model's first bucket?
    let qid = {
        let mut n = client.borrow_mut();
        let LatticaNode { swarm, kad, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        kad.get_providers(&mut ctx, bucket_key(&cfg.model.model_id, 0))
    };
    let mut dht_holders = 0usize;
    let lookup_deadline = world.net.now() + 10 * SECOND;
    'lookup: while world.net.now() < lookup_deadline {
        for ev in step(&mut world, &mut replicas, &mut chain, &client) {
            if let NodeEvent::Kad(KadEvent::QueryFinished { query_id, providers, .. }) = ev {
                if query_id == qid {
                    dht_holders = providers.len();
                    break 'lookup;
                }
            }
        }
    }

    // Workload: staggered starts.
    let mut prompts: Vec<(u64, Vec<u32>)> = Vec::new();
    for i in 0..cfg.requests {
        let prompt: Vec<u32> = (0..cfg.prompt_len)
            .map(|j| ((i * 7 + j * 3 + 1) % cfg.model.vocab as usize) as u32)
            .collect();
        let id = {
            let mut n = client.borrow_mut();
            chain.start(&mut n, &mut world.net, prompt.clone(), cfg.gen_len)
        };
        prompts.push((id, prompt));
        for _ in 0..6 {
            step(&mut world, &mut replicas, &mut chain, &client);
        }
    }

    // Drive to completion; fire the kill once a request is mid-stream.
    let mut kill_pending = cfg.kill;
    let mut killed_stats: Option<InferenceStats> = None;
    let deadline = world.net.now() + 120 * SECOND;
    while world.net.now() < deadline && chain.in_flight() > 0 {
        step(&mut world, &mut replicas, &mut chain, &client);
        if kill_pending && chain.partially_acked() >= 1 {
            kill_pending = false;
            let mid = cfg.stages / 2;
            if let Some(r) = replicas.iter_mut().find(|r| r.alive && r.local && r.stage == mid) {
                killed_stats = Some(r.shard.stats());
                let eid = {
                    let mut n = r.node.borrow_mut();
                    n.shutdown(&mut world.net, false);
                    n.endpoint_id()
                };
                world.remove_endpoint(eid);
                r.alive = false;
            }
        }
    }

    // --- Collect -----------------------------------------------------------
    let mut shard_stats = killed_stats.unwrap_or_default();
    for r in replicas.iter().filter(|r| r.alive) {
        shard_stats.merge(&r.shard.stats());
    }
    let completed = chain.completed.len();
    let mut ttft = Histogram::default();
    let mut tokens = 0u64;
    let mut reference_match = true;
    let mut first_start: Option<Time> = None;
    let mut last_finish: Time = 0;
    for c in &chain.completed {
        ttft.record(c.ttft);
        tokens += c.tokens.len() as u64;
        first_start = Some(first_start.map_or(c.started, |f: Time| f.min(c.started)));
        last_finish = last_finish.max(c.finished);
        let prompt = &prompts.iter().find(|(id, _)| *id == c.request).expect("known request").1;
        reference_match &= c.tokens == cfg.model.reference_generate(prompt, cfg.gen_len);
    }
    let tokens_per_sec = match first_start {
        Some(f) if last_finish > f => tokens as f64 * SECOND as f64 / (last_finish - f) as f64,
        _ => 0.0,
    };
    RouteOutcome {
        requests: cfg.requests,
        completed,
        failed: cfg.requests - completed,
        ttft,
        tokens,
        tokens_per_sec,
        repairs: chain.stats.repairs,
        duplicate_appends: shard_stats.duplicate_appends,
        evictions: shard_stats.sessions_evicted,
        kv_peak: shard_stats.kv_peak,
        reference_match,
        dht_holders,
        shard_stats,
    }
}
