//! Reusable experiment scenarios shared by `benches/` and `examples/`.
//!
//! Each function builds a deterministic deployment matching one of the
//! paper's evaluation settings (DESIGN.md §6) and returns the handles the
//! harness needs.

use crate::identity::PeerId;
use crate::netsim::link::PathProfile;
use crate::netsim::nat::NatType;
use crate::netsim::topology::{LinkProfile, TopologyBuilder};
use crate::netsim::{Net, World, MICRO, MILLI, SECOND};
use crate::node::{App, LatticaNode, NodeConfig, NodeEvent};
use crate::protocols::Ctx;
use crate::rpc::{RpcEvent, Status};
use std::cell::RefCell;
use std::rc::Rc;

pub type Node = Rc<RefCell<LatticaNode>>;

/// The paper's Table 1 network scenarios, plus two WAN stress scenarios
/// that exercise the congestion-control subsystem (the netsim's loss and
/// bounded-queue modeling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetScenario {
    /// Client and server colocated on one host.
    Local,
    /// Same rack/LAN: 0.25 ms one-way, 10 Gbps.
    SameRegionLan,
    /// Same region across the metro: 10 ms one-way.
    SameRegionWan,
    /// Across continents: 75 ms one-way, 1 Gbps.
    InterContinent,
    /// Lossy inter-continent path: 75 ms one-way, 1 Gbps, 2 % random
    /// loss. RTO-driven recovery collapses here; RACK + CC is the axis.
    LossyWan,
    /// Bufferbloat: 1 Gbps metro path behind a 250 ms drop-tail queue,
    /// with a trace of random loss — the high-BDP congestion scenario.
    Bufferbloat,
}

impl NetScenario {
    pub fn label(&self) -> &'static str {
        match self {
            NetScenario::Local => "Local (same host)",
            NetScenario::SameRegionLan => "Same region (LAN)",
            NetScenario::SameRegionWan => "Same region (WAN)",
            NetScenario::InterContinent => "Inter-continent (WAN)",
            NetScenario::LossyWan => "Lossy WAN (2% loss)",
            NetScenario::Bufferbloat => "Bufferbloat (250ms queue)",
        }
    }

    pub const ALL: [NetScenario; 6] = [
        NetScenario::Local,
        NetScenario::SameRegionLan,
        NetScenario::SameRegionWan,
        NetScenario::InterContinent,
        NetScenario::LossyWan,
        NetScenario::Bufferbloat,
    ];
}

/// Two public nodes (client, server) under a Table 1 scenario.
/// The paper's testbed: 4-core, 8 GB machines on 10 Gbps networks.
pub fn table1_world(s: NetScenario, seed: u64) -> (World, Node, Node) {
    table1_world_cc(s, seed, crate::transport::CcAlgorithm::Cubic)
}

/// [`table1_world`] with an explicit congestion-control algorithm on both
/// nodes (the benches compare CUBIC/NewReno against the seed's fixed
/// window on the WAN stress scenarios).
pub fn table1_world_cc(
    s: NetScenario,
    seed: u64,
    cc: crate::transport::CcAlgorithm,
) -> (World, Node, Node) {
    let mut t = TopologyBuilder::new(2);
    match s {
        NetScenario::Local => {
            // Loopback: sub-50 µs RTT; the per-call cost is stack overhead.
            t.set_loopback(PathProfile::new(15 * MICRO, 5 * MICRO, 0.0));
        }
        NetScenario::SameRegionLan => {
            t.intra(0, PathProfile::new(250 * MICRO, 50 * MICRO, 0.0));
        }
        NetScenario::SameRegionWan => {
            t.intra(0, PathProfile::new(10 * MILLI, MILLI, 0.0001));
        }
        NetScenario::InterContinent => {
            t.path(0, 1, PathProfile::new(75 * MILLI, 3 * MILLI, 0.001));
        }
        NetScenario::LossyWan => {
            t.path(0, 1, PathProfile::new(75 * MILLI, 3 * MILLI, 0.02));
        }
        NetScenario::Bufferbloat => {
            t.intra(0, PathProfile::new(10 * MILLI, MILLI, 0.0005));
        }
    }
    let link = match s {
        // 1 Gbps WAN egress.
        NetScenario::InterContinent | NetScenario::LossyWan => LinkProfile::FIBER,
        // 1 Gbps behind a deep drop-tail queue.
        NetScenario::Bufferbloat => LinkProfile::FIBER.with_queue(250 * MILLI),
        _ => LinkProfile::DATACENTER, // 10 Gbps
    };
    let h_server = t.public_host(0, link);
    let (h_client, same_host) = match s {
        NetScenario::Local => (h_server, true),
        NetScenario::InterContinent | NetScenario::LossyWan => (t.public_host(1, link), false),
        _ => (t.public_host(0, link), false),
    };
    let mut world = World::new(t.build(seed));
    let server = LatticaNode::spawn(&mut world, h_server, {
        let mut c = NodeConfig::with_seed(seed * 10 + 1);
        c.label = "server".into();
        c.cc = cc;
        c
    });
    let client = LatticaNode::spawn(&mut world, h_client, {
        let mut c = NodeConfig::with_seed(seed * 10 + 2);
        c.port = if same_host { 4002 } else { 4001 };
        c.label = "client".into();
        c.cc = cc;
        c
    });
    let server_ma = server.borrow().listen_addr();
    client.borrow_mut().dial(&mut world.net, &server_ma).unwrap();
    world.run_for(2 * SECOND);
    assert!(
        client.borrow().swarm.is_connected(&server.borrow().peer_id()),
        "scenario setup failed to connect"
    );
    (world, client, server)
}

/// Echo RPC app: responds to `bench` service with a payload of
/// `response_size` bytes.
pub struct EchoApp {
    pub response_size: usize,
}

impl App for EchoApp {
    fn handle(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        ev: NodeEvent,
    ) -> Option<NodeEvent> {
        match ev {
            NodeEvent::Rpc(RpcEvent::Request { service, reply, .. }) if service == "bench" => {
                let mut ctx = Ctx::new(&mut node.swarm, net);
                let body = vec![0xA5u8; self.response_size];
                let _ = node.rpc.respond(&mut ctx, reply, Status::Ok, body);
                None
            }
            other => Some(other),
        }
    }
}

/// Measured NAT-type distribution for the traversal experiment. Mirrors
/// published measurements of consumer NAT behaviour (cone-heavy with a
/// substantial symmetric share) and is chosen so the *emergent* direct
/// success rate lands near the paper's ~70 %.
pub const NAT_DISTRIBUTION: [(Option<NatType>, f64); 5] = [
    (None, 0.08),                               // publicly reachable
    (Some(NatType::FullCone), 0.12),
    (Some(NatType::RestrictedCone), 0.13),
    (Some(NatType::PortRestrictedCone), 0.37),
    (Some(NatType::Symmetric), 0.30),
];

/// Sample a NAT type from the distribution.
pub fn sample_nat(rng: &mut crate::util::Rng) -> Option<NatType> {
    let weights: Vec<f64> = NAT_DISTRIBUTION.iter().map(|(_, w)| *w).collect();
    NAT_DISTRIBUTION[rng.choose_weighted(&weights)].0
}

/// Expected punch success for a sampled pair (the Ford-matrix oracle used
/// to sanity-check the measured rate).
pub fn oracle_pair_success(a: Option<NatType>, b: Option<NatType>) -> bool {
    match (a, b) {
        (None, _) | (_, None) => true,
        (Some(x), Some(y)) => NatType::punch_compatible(x, y),
    }
}

/// A mesh of `n` public nodes in one region bootstrapped through node 0.
pub fn bootstrap_mesh(n: usize, seed: u64, link: LinkProfile) -> (World, Vec<Node>) {
    bootstrap_mesh_on(n, seed, link, None)
}

/// [`bootstrap_mesh`] with an optional override of the intra-region path
/// (e.g. a lossy WAN between geo-distributed clusters).
pub fn bootstrap_mesh_on(
    n: usize,
    seed: u64,
    link: LinkProfile,
    path: Option<PathProfile>,
) -> (World, Vec<Node>) {
    let mut t = TopologyBuilder::paper_regions();
    if let Some(p) = path {
        t.intra(0, p);
    }
    let hosts: Vec<u32> = (0..n).map(|_| t.public_host(0, link)).collect();
    let mut world = World::new(t.build(seed));
    let nodes: Vec<Node> = hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            LatticaNode::spawn(&mut world, h, NodeConfig::with_seed(seed * 1000 + i as u64))
        })
        .collect();
    let entry0 = crate::protocols::kad::PeerEntry {
        id: nodes[0].borrow().peer_id(),
        host: hosts[0],
        port: 4001,
    };
    for node in nodes.iter().skip(1) {
        node.borrow_mut().bootstrap(&mut world.net, entry0.clone());
    }
    world.run_for(3 * SECOND);
    (world, nodes)
}

/// Drain a node's events, returning them.
pub fn drain(node: &Node) -> Vec<NodeEvent> {
    node.borrow_mut().drain_events()
}

/// Find the peer id of a node.
pub fn peer_of(node: &Node) -> PeerId {
    node.borrow().peer_id()
}
