//! Reusable experiment scenarios shared by `benches/` and `examples/`.
//!
//! Each function builds a deterministic deployment matching one of the
//! paper's evaluation settings (DESIGN.md §6) and returns the handles the
//! harness needs.

pub mod inference;
pub mod nat_mesh;
pub mod overload;
pub mod planet;

use crate::identity::PeerId;
use crate::netsim::link::PathProfile;
use crate::netsim::nat::NatType;
use crate::netsim::topology::{LinkProfile, TopologyBuilder};
use crate::netsim::{QueueKind, Time, World, MICRO, MILLI, SECOND};
use crate::node::{LatticaNode, NodeConfig, NodeEvent};
use crate::protocols::Ctx;
use crate::rpc::{Outcome, Service, Stub, StubDone};
use crate::util::buf::Buf;
use std::cell::RefCell;
use std::rc::Rc;

pub use inference::{route_inference, RouteOutcome, RouteScenarioConfig};
pub use nat_mesh::{
    nat_mesh, FailoverOutcome, NatMeshConfig, NatMeshOutcome, NatPairRow, RelayRow,
};
pub use overload::{overload_scenario, OverloadConfig, OverloadOutcome, OverloadRow};
pub use planet::{
    planet_scale, BackgroundNode, BackgroundStats, PlanetConfig, PlanetOutcome, RoutingOracle,
};

pub type Node = Rc<RefCell<LatticaNode>>;

/// The paper's Table 1 network scenarios, plus two WAN stress scenarios
/// that exercise the congestion-control subsystem (the netsim's loss and
/// bounded-queue modeling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetScenario {
    /// Client and server colocated on one host.
    Local,
    /// Same rack/LAN: 0.25 ms one-way, 10 Gbps.
    SameRegionLan,
    /// Same region across the metro: 10 ms one-way.
    SameRegionWan,
    /// Across continents: 75 ms one-way, 1 Gbps.
    InterContinent,
    /// Lossy inter-continent path: 75 ms one-way, 1 Gbps, 2 % random
    /// loss. RTO-driven recovery collapses here; RACK + CC is the axis.
    LossyWan,
    /// Bufferbloat: 1 Gbps metro path behind a 250 ms drop-tail queue,
    /// with a trace of random loss — the high-BDP congestion scenario.
    Bufferbloat,
}

impl NetScenario {
    pub fn label(&self) -> &'static str {
        match self {
            NetScenario::Local => "Local (same host)",
            NetScenario::SameRegionLan => "Same region (LAN)",
            NetScenario::SameRegionWan => "Same region (WAN)",
            NetScenario::InterContinent => "Inter-continent (WAN)",
            NetScenario::LossyWan => "Lossy WAN (2% loss)",
            NetScenario::Bufferbloat => "Bufferbloat (250ms queue)",
        }
    }

    pub const ALL: [NetScenario; 6] = [
        NetScenario::Local,
        NetScenario::SameRegionLan,
        NetScenario::SameRegionWan,
        NetScenario::InterContinent,
        NetScenario::LossyWan,
        NetScenario::Bufferbloat,
    ];
}

/// Two public nodes (client, server) under a Table 1 scenario.
/// The paper's testbed: 4-core, 8 GB machines on 10 Gbps networks.
pub fn table1_world(s: NetScenario, seed: u64) -> (World, Node, Node) {
    table1_world_cc(s, seed, crate::transport::CcAlgorithm::Cubic)
}

/// [`table1_world`] with an explicit congestion-control algorithm on both
/// nodes (the benches compare CUBIC/NewReno against the seed's fixed
/// window on the WAN stress scenarios).
pub fn table1_world_cc(
    s: NetScenario,
    seed: u64,
    cc: crate::transport::CcAlgorithm,
) -> (World, Node, Node) {
    let mut t = TopologyBuilder::new(2);
    match s {
        NetScenario::Local => {
            // Loopback: sub-50 µs RTT; the per-call cost is stack overhead.
            t.set_loopback(PathProfile::new(15 * MICRO, 5 * MICRO, 0.0));
        }
        NetScenario::SameRegionLan => {
            t.intra(0, PathProfile::new(250 * MICRO, 50 * MICRO, 0.0));
        }
        NetScenario::SameRegionWan => {
            t.intra(0, PathProfile::new(10 * MILLI, MILLI, 0.0001));
        }
        NetScenario::InterContinent => {
            t.path(0, 1, PathProfile::new(75 * MILLI, 3 * MILLI, 0.001));
        }
        NetScenario::LossyWan => {
            t.path(0, 1, PathProfile::new(75 * MILLI, 3 * MILLI, 0.02));
        }
        NetScenario::Bufferbloat => {
            t.intra(0, PathProfile::new(10 * MILLI, MILLI, 0.0005));
        }
    }
    let link = match s {
        // 1 Gbps WAN egress.
        NetScenario::InterContinent | NetScenario::LossyWan => LinkProfile::FIBER,
        // 1 Gbps behind a deep drop-tail queue.
        NetScenario::Bufferbloat => LinkProfile::FIBER.with_queue(250 * MILLI),
        _ => LinkProfile::DATACENTER, // 10 Gbps
    };
    let h_server = t.public_host(0, link);
    let (h_client, same_host) = match s {
        NetScenario::Local => (h_server, true),
        NetScenario::InterContinent | NetScenario::LossyWan => (t.public_host(1, link), false),
        _ => (t.public_host(0, link), false),
    };
    let mut world = World::new(t.build(seed));
    let server = LatticaNode::spawn(&mut world, h_server, {
        let mut c = NodeConfig::with_seed(seed * 10 + 1);
        c.label = "server".into();
        c.cc = cc;
        c
    });
    let client = LatticaNode::spawn(&mut world, h_client, {
        let mut c = NodeConfig::with_seed(seed * 10 + 2);
        c.port = if same_host { 4002 } else { 4001 };
        c.label = "client".into();
        c.cc = cc;
        c
    });
    let server_ma = server.borrow().listen_addr();
    client.borrow_mut().dial(&mut world.net, &server_ma).unwrap();
    world.run_for(2 * SECOND);
    assert!(
        client.borrow().swarm.is_connected(&server.borrow().peer_id()),
        "scenario setup failed to connect"
    );
    (world, client, server)
}

/// Echo RPC service for benches: every `bench.echo` call answers with a
/// payload of `response_size` bytes. Register with
/// [`LatticaNode::register_service`].
pub fn echo_service(response_size: usize) -> Service {
    // One shared response buffer: each reply bumps a refcount instead of
    // allocating (matches the zero-copy send path the bench measures).
    let body: Buf = vec![0xA5u8; response_size].into();
    Service::new("bench").unary("echo", move |_node, _net, _ctx, _payload| {
        Outcome::Reply(body.clone())
    })
}

/// Drive the world until the stub op issued here completes (or `timeout`
/// virtual time passes). Convenience for linear example code; events the
/// stub does not own are discarded, so only use it where no other
/// consumer is polling this node's events.
pub fn stub_call_blocking(
    world: &mut World,
    node: &Node,
    stub: &mut Stub,
    method: &str,
    payload: impl Into<Buf>,
    timeout: Time,
) -> Option<StubDone> {
    let op = {
        let mut n = node.borrow_mut();
        stub.call(&mut n, &mut world.net, method, payload)
    };
    let deadline = world.net.now() + timeout;
    loop {
        {
            let evs = node.borrow_mut().drain_events();
            let mut n = node.borrow_mut();
            for ev in &evs {
                stub.on_node_event(&mut n, &mut world.net, ev);
            }
            stub.tick(&mut n, &mut world.net);
        }
        while let Some(done) = stub.poll_done() {
            if done.op == op {
                return Some(done);
            }
        }
        if world.net.now() >= deadline {
            return None;
        }
        world.run_for(5 * MILLI);
    }
}

/// Measured NAT-type distribution for the traversal experiment. Mirrors
/// published measurements of consumer NAT behaviour (cone-heavy with a
/// substantial symmetric share) and is chosen so the *emergent* direct
/// success rate lands near the paper's ~70 %.
pub const NAT_DISTRIBUTION: [(Option<NatType>, f64); 5] = [
    (None, 0.08),                               // publicly reachable
    (Some(NatType::FullCone), 0.12),
    (Some(NatType::RestrictedCone), 0.13),
    (Some(NatType::PortRestrictedCone), 0.37),
    (Some(NatType::Symmetric), 0.30),
];

/// Sample a NAT type from the distribution.
pub fn sample_nat(rng: &mut crate::util::Rng) -> Option<NatType> {
    let weights: Vec<f64> = NAT_DISTRIBUTION.iter().map(|(_, w)| *w).collect();
    NAT_DISTRIBUTION[rng.choose_weighted(&weights)].0
}

/// Expected punch success for a sampled pair (the Ford-matrix oracle used
/// to sanity-check the measured rate).
pub fn oracle_pair_success(a: Option<NatType>, b: Option<NatType>) -> bool {
    match (a, b) {
        (None, _) | (_, None) => true,
        (Some(x), Some(y)) => NatType::punch_compatible(x, y),
    }
}

/// A mesh of `n` public nodes in one region bootstrapped through node 0.
pub fn bootstrap_mesh(n: usize, seed: u64, link: LinkProfile) -> (World, Vec<Node>) {
    bootstrap_mesh_kind(n, seed, link, None, QueueKind::default())
}

/// [`bootstrap_mesh`] with an optional override of the intra-region path
/// (e.g. a lossy WAN between geo-distributed clusters).
pub fn bootstrap_mesh_on(
    n: usize,
    seed: u64,
    link: LinkProfile,
    path: Option<PathProfile>,
) -> (World, Vec<Node>) {
    bootstrap_mesh_kind(n, seed, link, path, QueueKind::default())
}

/// [`bootstrap_mesh_on`] with an explicit event-queue implementation —
/// the harness behind the heap-vs-wheel trace-equivalence test.
pub fn bootstrap_mesh_kind(
    n: usize,
    seed: u64,
    link: LinkProfile,
    path: Option<PathProfile>,
    queue: QueueKind,
) -> (World, Vec<Node>) {
    let mut t = TopologyBuilder::paper_regions();
    t.set_queue_kind(queue);
    if let Some(p) = path {
        t.intra(0, p);
    }
    let hosts: Vec<u32> = (0..n).map(|_| t.public_host(0, link)).collect();
    let mut world = World::new(t.build(seed));
    let nodes: Vec<Node> = hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            LatticaNode::spawn(&mut world, h, NodeConfig::with_seed(seed * 1000 + i as u64))
        })
        .collect();
    let entry0 = crate::protocols::kad::PeerEntry {
        id: nodes[0].borrow().peer_id(),
        host: hosts[0],
        port: 4001,
    };
    for node in nodes.iter().skip(1) {
        node.borrow_mut().bootstrap(&mut world.net, entry0.clone());
    }
    world.run_for(3 * SECOND);
    (world, nodes)
}

// ---------------------------------------------------------------------------
// Churn scenarios
// ---------------------------------------------------------------------------

/// A [`bootstrap_mesh`]-style deployment that can stop, crash and restart
/// nodes mid-run under a [`ChurnPlan`] — the harness behind the
/// `dht_churn` hardening suite and `BENCH_dht_churn.json`.
pub struct ChurnMesh {
    pub world: World,
    pub hosts: Vec<u32>,
    /// Index-aligned with `hosts`; `None` while a node is down.
    pub nodes: Vec<Option<Node>>,
    /// Per-node restart count — bumped on every rejoin so callers can
    /// tell a respawned instance from the one that issued earlier work
    /// (query ids restart from 1 on respawn).
    pub incarnation: Vec<u64>,
    bootstrap_entry: crate::protocols::kad::PeerEntry,
    seed: u64,
    /// Kad counters of nodes that have been stopped (so scenario-wide
    /// aggregation doesn't lose their traffic history).
    graveyard_stats: crate::protocols::kad::KadStats,
    pub joins: u64,
    pub leaves: u64,
    pub crashes: u64,
}

/// Build an `n`-node single-region mesh bootstrapped through node 0 (the
/// same deployment as [`bootstrap_mesh`]), with churn-management handles.
/// Node identities are deterministic in `(seed, index)`, so a restarted
/// node keeps its PeerId and address.
pub fn churn_mesh(n: usize, seed: u64, link: LinkProfile) -> ChurnMesh {
    churn_mesh_kind(n, seed, link, QueueKind::default())
}

/// [`churn_mesh`] with an explicit event-queue implementation (see
/// [`bootstrap_mesh_kind`]).
pub fn churn_mesh_kind(n: usize, seed: u64, link: LinkProfile, queue: QueueKind) -> ChurnMesh {
    let (world, nodes) = bootstrap_mesh_kind(n, seed, link, None, queue);
    let hosts: Vec<u32> = nodes
        .iter()
        .map(|nd| nd.borrow().swarm.local_addr.host)
        .collect();
    let bootstrap_entry = crate::protocols::kad::PeerEntry {
        id: nodes[0].borrow().peer_id(),
        host: hosts[0],
        port: 4001,
    };
    ChurnMesh {
        world,
        hosts,
        incarnation: vec![0; n],
        nodes: nodes.into_iter().map(Some).collect(),
        bootstrap_entry,
        seed,
        graveyard_stats: crate::protocols::kad::KadStats::default(),
        joins: 0,
        leaves: 0,
        crashes: 0,
    }
}

impl ChurnMesh {
    /// Indices of nodes currently up.
    pub fn live(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.is_some())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn is_up(&self, i: usize) -> bool {
        self.nodes[i].is_some()
    }

    /// Apply one churn event: clean leave, crash, or (re)join.
    pub fn apply(&mut self, ev: &crate::netsim::ChurnEvent) {
        use crate::netsim::ChurnAction;
        match ev.action {
            ChurnAction::Leave | ChurnAction::Crash => {
                if let Some(node) = self.nodes[ev.node].take() {
                    let clean = ev.action == ChurnAction::Leave;
                    let eid = {
                        let mut n = node.borrow_mut();
                        self.graveyard_stats.merge(&n.kad.stats);
                        n.shutdown(&mut self.world.net, clean);
                        n.endpoint_id()
                    };
                    self.world.remove_endpoint(eid);
                    if clean {
                        self.leaves += 1;
                    } else {
                        self.crashes += 1;
                    }
                }
            }
            ChurnAction::Join => {
                if self.nodes[ev.node].is_none() {
                    let cfg = NodeConfig::with_seed(self.seed * 1000 + ev.node as u64);
                    let node =
                        LatticaNode::spawn(&mut self.world, self.hosts[ev.node], cfg);
                    node.borrow_mut()
                        .bootstrap(&mut self.world.net, self.bootstrap_entry.clone());
                    self.nodes[ev.node] = Some(node);
                    self.incarnation[ev.node] += 1;
                    self.joins += 1;
                }
            }
        }
    }

    /// Run to `deadline`, applying due churn events at their exact virtual
    /// times (deterministic: same plan + same seed ⇒ same trace).
    pub fn run_with_churn(
        &mut self,
        plan: &mut crate::netsim::ChurnPlan,
        deadline: crate::netsim::Time,
    ) {
        loop {
            match plan.peek().map(|e| e.at) {
                Some(at) if at <= deadline => {
                    self.world.run_until(at);
                    while let Some(ev) = plan.pop_due(self.world.net.now()) {
                        self.apply(&ev);
                    }
                }
                _ => {
                    self.world.run_until(deadline);
                    return;
                }
            }
        }
    }

    /// Scenario-wide kad counters: live nodes plus everything already
    /// stopped.
    pub fn kad_stats(&self) -> crate::protocols::kad::KadStats {
        let mut s = self.graveyard_stats.clone();
        for node in self.nodes.iter().flatten() {
            s.merge(&node.borrow().kad.stats);
        }
        s
    }
}

/// Result of [`run_churn_lookups`].
pub struct ChurnLookupOutcome {
    pub stats: crate::metrics::DhtLookupStats,
    pub kad: crate::protocols::kad::KadStats,
    pub joins: u64,
    pub leaves: u64,
    pub crashes: u64,
    pub live_at_end: usize,
}

/// Drive a `get_providers` workload over a churning mesh.
///
/// Nodes `1..=publishers` each publish one provider key (they must be
/// within the plan's protected prefix so the content stays live), then for
/// `duration` virtual time a random live node looks up a random published
/// key every `lookup_interval`, while `plan` stops/crashes/restarts the
/// unprotected nodes. A lookup succeeds if it returns at least one live
/// publisher. Fully deterministic in `(mesh seed, plan, seed)`.
pub fn run_churn_lookups(
    mesh: &mut ChurnMesh,
    plan: &mut crate::netsim::ChurnPlan,
    publishers: usize,
    lookup_interval: crate::netsim::Time,
    duration: crate::netsim::Time,
    seed: u64,
) -> ChurnLookupOutcome {
    use std::collections::HashMap;
    let mut rng = crate::util::Rng::new(seed ^ 0x10_0C_AB_5E);
    // Deterministic content keys, one per publisher.
    let keys: Vec<[u8; 32]> = (0..publishers)
        .map(|_| {
            let mut k = [0u8; 32];
            rng.fill_bytes(&mut k);
            k
        })
        .collect();
    let publisher_ids: Vec<PeerId> = (1..=publishers)
        .map(|i| mesh.nodes[i].as_ref().expect("publisher down at start").borrow().peer_id())
        .collect();
    for (i, key) in keys.iter().enumerate() {
        let node = mesh.nodes[1 + i].as_ref().unwrap().clone();
        let mut nd = node.borrow_mut();
        let LatticaNode { swarm, kad, .. } = &mut *nd;
        let mut ctx = Ctx::new(swarm, &mut mesh.world.net);
        kad.provide(&mut ctx, *key);
    }
    // Let the announce queries land before measuring.
    let settle_until = mesh.world.net.now() + 3 * SECOND;
    mesh.run_with_churn(plan, settle_until);

    let mut stats = crate::metrics::DhtLookupStats::default();
    // (node index, query id) → (issue time, node incarnation at issue).
    // The incarnation guards against a respawned node's fresh query ids
    // colliding with a dead instance's outstanding lookups.
    let mut outstanding: HashMap<(usize, u64), (crate::netsim::Time, u64)> = HashMap::new();
    let collect = |mesh: &mut ChurnMesh,
                   outstanding: &mut HashMap<(usize, u64), (crate::netsim::Time, u64)>,
                   stats: &mut crate::metrics::DhtLookupStats| {
        let now = mesh.world.net.now();
        for i in mesh.live() {
            let node = mesh.nodes[i].as_ref().unwrap().clone();
            for ev in node.borrow_mut().drain_events() {
                if let NodeEvent::Kad(crate::protocols::kad::KadEvent::QueryFinished {
                    query_id,
                    providers,
                    hops,
                    ..
                }) = ev
                {
                    let matches_issue = outstanding
                        .get(&(i, query_id))
                        .is_some_and(|&(_, inc)| inc == mesh.incarnation[i]);
                    if matches_issue {
                        let (t0, _) = outstanding.remove(&(i, query_id)).unwrap();
                        let success =
                            providers.iter().any(|p| publisher_ids.contains(&p.id));
                        stats.record_lookup(success, hops, now - t0);
                    }
                }
            }
        }
    };

    // Completions are only observable at drain time, so poll in sub-steps
    // much finer than the lookup cadence — this bounds the latency
    // measurement error to `collect_step` instead of `lookup_interval`.
    let collect_step = (lookup_interval / 10).max(crate::netsim::MILLI);
    let end = mesh.world.net.now() + duration;
    while mesh.world.net.now() < end {
        let issue_at = (mesh.world.net.now() + lookup_interval).min(end);
        while mesh.world.net.now() < issue_at {
            let sub = (mesh.world.net.now() + collect_step).min(issue_at);
            mesh.run_with_churn(plan, sub);
            // Lookups issued by a node that has since gone down (or been
            // replaced by a respawned instance) can't finish: count them
            // aborted rather than failed.
            let before = outstanding.len();
            outstanding.retain(|&(i, _), &mut (_, inc)| {
                mesh.is_up(i) && mesh.incarnation[i] == inc
            });
            stats.aborted += (before - outstanding.len()) as u64;
            collect(mesh, &mut outstanding, &mut stats);
        }
        let live = mesh.live();
        if !live.is_empty() {
            let src = live[rng.gen_index(live.len())];
            let key = keys[rng.gen_index(keys.len())];
            let node = mesh.nodes[src].as_ref().unwrap().clone();
            let qid = {
                let mut nd = node.borrow_mut();
                let LatticaNode { swarm, kad, .. } = &mut *nd;
                let mut ctx = Ctx::new(swarm, &mut mesh.world.net);
                kad.get_providers(&mut ctx, key)
            };
            stats.attempted += 1;
            outstanding.insert((src, qid), (mesh.world.net.now(), mesh.incarnation[src]));
        }
    }
    // Grace period: let stragglers finish (their failover timeouts are
    // bounded), still under churn.
    let grace_end = mesh.world.net.now() + 15 * SECOND;
    while mesh.world.net.now() < grace_end && !outstanding.is_empty() {
        let step_to = (mesh.world.net.now() + collect_step).min(grace_end);
        mesh.run_with_churn(plan, step_to);
        let before = outstanding.len();
        outstanding.retain(|&(i, _), &mut (_, inc)| {
            mesh.is_up(i) && mesh.incarnation[i] == inc
        });
        stats.aborted += (before - outstanding.len()) as u64;
        collect(mesh, &mut outstanding, &mut stats);
    }
    let kad = mesh.kad_stats();
    // Tracked (registered) requests are the staleness denominator: a
    // dial-failed request never reached a stream but still hit a stale
    // routing entry.
    stats.requests_sent = kad.requests_tracked;
    stats.requests_stale = kad.requests_timed_out + kad.requests_failed;
    ChurnLookupOutcome {
        stats,
        kad,
        joins: mesh.joins,
        leaves: mesh.leaves,
        crashes: mesh.crashes,
        live_at_end: mesh.live().len(),
    }
}

/// The canonical churn scenario, shared by the acceptance test
/// (`tests/dht_churn.rs`) and the bench emitting `BENCH_dht_churn.json`
/// so the CI-gated ≥95% bar and the published rows measure the same
/// deployment: an `n`-node mesh with 4 protected publishers, one
/// `get_providers` lookup per virtual second for `duration_secs`, churn
/// starting after a 5 s lead-in. `half_life_secs == 0` disables churn
/// (the control arm).
pub fn churn_scenario(
    n: usize,
    half_life_secs: u64,
    duration_secs: u64,
    seed: u64,
) -> ChurnLookupOutcome {
    const PUBLISHERS: usize = 4;
    let mut mesh = churn_mesh(n, seed, LinkProfile::FIBER);
    let duration = duration_secs * SECOND;
    let mut plan = if half_life_secs == 0 {
        crate::netsim::ChurnPlan::empty()
    } else {
        crate::netsim::ChurnPlan::poisson(
            &crate::netsim::ChurnConfig {
                nodes: n,
                protected: 1 + PUBLISHERS,
                start: mesh.world.net.now() + 5 * SECOND,
                end: mesh.world.net.now() + 5 * SECOND + duration,
                session_half_life: half_life_secs * SECOND,
                downtime_mean: 10 * SECOND,
                crash_fraction: 0.5,
            },
            seed,
        )
    };
    run_churn_lookups(&mut mesh, &mut plan, PUBLISHERS, SECOND, duration, seed)
}

// ---------------------------------------------------------------------------
// Model-synchronization scenarios (Fig. 1(3))
// ---------------------------------------------------------------------------

/// How replicas obtain checkpoints.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// Parameter-server baseline: every replica pulls everything from the
    /// trainer; no DHT discovery, no re-seeding.
    Central,
    /// Swarm: replicas announce themselves as seeders mid-download and
    /// discover each other via `kad::get_providers`.
    Swarm,
}

/// Configuration for [`model_sync_scenario`].
#[derive(Clone, Copy, Debug)]
pub struct ModelSyncConfig {
    /// Inference replicas (the mesh is `replicas + 1` nodes with the
    /// trainer).
    pub replicas: usize,
    pub checkpoints: usize,
    pub blob_bytes: usize,
    /// Fraction of the blob rewritten in place between versions, applied
    /// as two contiguous bands (localized layer updates — the realistic
    /// checkpoint-churn shape).
    pub churn: f64,
    pub mode: SyncMode,
    /// Keep the previous version's chunks as a reuse cache (delta sync).
    /// Off = replicas flush old blocks first, modelling a system that
    /// ships whole checkpoints.
    pub delta: bool,
    /// Mix NATted replicas into the mesh (2/5 public, 3/5 behind cone /
    /// port-restricted / symmetric NATs, round-robin).
    pub nat_mixed: bool,
    /// Fixed chunk size for publishing (bytes). 0 = the publisher's
    /// default content-defined chunking. Small fixed chunks (e.g. 256 B
    /// over a 2.5 MB blob → 10k chunks) stress the per-chunk control
    /// plane, which is what the control-ratio bench measures.
    pub chunk_bytes: usize,
    /// Compact control plane on every node (range-coded bitswap chunk
    /// sets, batched HAVEs, gossip lazy push). Off = legacy encodings —
    /// the bench A/B baseline.
    pub compact_control: bool,
    pub seed: u64,
    /// Per-version sync deadline (virtual seconds).
    pub timeout_secs: u64,
}

/// Outcome of a model-distribution run.
pub struct ModelSyncOutcome {
    pub stats: crate::metrics::SyncStats,
    /// Every replica assembled a byte-identical blob for every version.
    pub all_identical: bool,
    /// All versions reached all replicas within the deadline.
    pub completed: bool,
    /// `DeltaManifest::added_bytes` announced for each version ≥ 2.
    pub delta_bytes_announced: Vec<u64>,
    /// Duplicate blocks dropped by replicas (late answers, endgame).
    pub duplicate_blocks: u64,
    /// Bytes served by replica nodes (the re-seeding evidence).
    pub replica_bytes_served: u64,
    /// Control-plane bytes by category across the whole mesh, against
    /// delivered payload bytes (the bytes-of-control-per-delivered-byte
    /// metric).
    pub control: crate::metrics::ControlPlaneStats,
}

/// Build the mesh, publish `checkpoints` versions of a churned blob from
/// the trainer, and drive every replica's `sync_blob` until each version
/// replicates. Fully deterministic in the config.
pub fn model_sync_scenario(cfg: &ModelSyncConfig) -> ModelSyncOutcome {
    use crate::content::{Blockstore, Chunking, DagManifest, DeltaManifest};
    use crate::model::{model_topic, CheckpointPublisher};
    use crate::wire::Message;

    let mut t = TopologyBuilder::paper_regions();
    // The trainer sits behind a constrained egress (one training site
    // serving a fleet — the inter-site-bandwidth bottleneck this whole
    // subsystem exists for); replicas are well-connected edge sites.
    let trainer_host = t.public_host(0, LinkProfile::BROADBAND);
    let replica_hosts: Vec<u32> = (0..cfg.replicas)
        .map(|i| {
            let region = i % 3;
            if !cfg.nat_mixed || i % 5 < 2 {
                t.public_host(region, LinkProfile::FIBER)
            } else {
                let nat_type = match i % 5 {
                    2 => NatType::FullCone,
                    3 => NatType::PortRestrictedCone,
                    _ => NatType::Symmetric,
                };
                let nat = t.nat(region, nat_type, LinkProfile::FIBER);
                t.natted_host(nat, LinkProfile::UNLIMITED)
            }
        })
        .collect();
    let mut world = World::new(t.build(cfg.seed));
    let trainer = LatticaNode::spawn(&mut world, trainer_host, {
        let mut c = NodeConfig::with_seed(cfg.seed * 1000);
        c.compact_control = cfg.compact_control;
        c.label = "trainer".into();
        c
    });
    let replicas: Vec<Node> = replica_hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            LatticaNode::spawn(&mut world, h, {
                let mut c = NodeConfig::with_seed(cfg.seed * 1000 + 1 + i as u64);
                c.swarm_sync = cfg.mode == SyncMode::Swarm;
                c.compact_control = cfg.compact_control;
                c.label = format!("replica-{i}");
                c
            })
        })
        .collect();
    let trainer_peer = trainer.borrow().peer_id();
    if cfg.mode == SyncMode::Swarm {
        // Seeder upload policy: the swarm reciprocates, so the publisher
        // chokes deeply-indebted leechers — its egress stays ~O(1) in the
        // replica count instead of scaling with demand.
        trainer.borrow_mut().bitswap.serve_choking = true;
    }
    let entry = crate::protocols::kad::PeerEntry {
        id: trainer_peer,
        host: trainer_host,
        port: 4001,
    };
    for r in &replicas {
        r.borrow_mut().bootstrap(&mut world.net, entry.clone());
    }
    world.run_for(3 * SECOND);
    let topic = model_topic("policy");
    for nd in std::iter::once(&trainer).chain(replicas.iter()) {
        let mut n = nd.borrow_mut();
        let LatticaNode { swarm, gossip, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        gossip.subscribe(&mut ctx, &topic);
    }
    world.run_for(SECOND);

    let trainer_egress = |trainer: &Node| -> u64 {
        trainer
            .borrow()
            .bitswap
            .ledgers
            .values()
            .map(|l| l.bytes_sent)
            .sum()
    };
    let replica_ingress = |r: &Node| -> u64 {
        r.borrow()
            .bitswap
            .ledgers
            .values()
            .map(|l| l.bytes_received)
            .sum()
    };

    // The trainer's model-sync control plane is a registered service:
    // replicas that miss the gossip announcement can pull the latest
    // checkpoint pointer via `model.latest`.
    let publisher = Rc::new(RefCell::new(if cfg.chunk_bytes > 0 {
        CheckpointPublisher::with_chunking("policy", Chunking::Fixed(cfg.chunk_bytes))
    } else {
        CheckpointPublisher::new("policy")
    }));
    trainer
        .borrow_mut()
        .register_service(CheckpointPublisher::service(publisher.clone()));
    let mut rng = crate::util::Rng::new(cfg.seed ^ 0xB10B);
    let mut blob = rng.gen_bytes(cfg.blob_bytes);
    let mut stats = crate::metrics::SyncStats {
        replicas: cfg.replicas as u64,
        blob_bytes: cfg.blob_bytes as u64,
        ..Default::default()
    };
    let mut all_identical = true;
    let mut completed = true;
    let mut delta_bytes_announced = Vec::new();

    for v in 1..=cfg.checkpoints {
        if v > 1 {
            // In-place churn: two contiguous bands totalling cfg.churn.
            let band = ((cfg.blob_bytes as f64 * cfg.churn) / 2.0) as usize;
            if band > 0 && band < cfg.blob_bytes {
                for _ in 0..2 {
                    let start = rng.gen_index(cfg.blob_bytes - band);
                    let patch = rng.gen_bytes(band);
                    blob[start..start + band].copy_from_slice(&patch);
                }
            }
            if !cfg.delta {
                // Full-sync baseline: no chunk reuse across versions.
                for r in &replicas {
                    r.borrow_mut().blockstore = Blockstore::new();
                }
            }
        }
        let egress_before = trainer_egress(&trainer);
        let ingress_before: Vec<u64> = replicas.iter().map(replica_ingress).collect();
        let (root, ann) = {
            let mut tr = trainer.borrow_mut();
            publisher
                .borrow_mut()
                .publish_blob(&mut tr, &mut world.net, v as u64, &blob)
        };
        if v > 1 {
            let announced = ann
                .delta
                .and_then(|d| {
                    let tr = trainer.borrow();
                    let block = tr.blockstore.get(&d.delta_block)?;
                    DeltaManifest::decode(&block).ok()
                })
                .map(|d| d.added_bytes)
                .unwrap_or(cfg.blob_bytes as u64);
            delta_bytes_announced.push(announced);
        }
        let t0 = world.net.now();
        let deadline = t0 + cfg.timeout_secs * SECOND;
        let mut done: Vec<bool> = vec![false; cfg.replicas];
        while world.net.now() < deadline && done.iter().any(|d| !d) {
            world.run_for(50 * MILLI);
            for (i, r) in replicas.iter().enumerate() {
                if done[i] {
                    continue;
                }
                let mut n = r.borrow_mut();
                n.drain_events();
                if n.sync_blob(&mut world.net, root, &[trainer_peer]) {
                    done[i] = true;
                    stats.latency.record(world.net.now() - t0);
                }
            }
            trainer.borrow_mut().drain_events();
        }
        if done.iter().any(|d| !d) {
            completed = false;
        }
        for r in &replicas {
            let n = r.borrow();
            let ok = DagManifest::load(&n.blockstore, &root)
                .and_then(|m| m.assemble(&n.blockstore))
                .map(|b| b == blob)
                .unwrap_or(false);
            all_identical &= ok;
        }
        // Let endgame stragglers and announces settle, THEN measure, so
        // every byte of this version's traffic is attributed to it.
        world.run_for(SECOND);
        let egress_v = trainer_egress(&trainer) - egress_before;
        let fetched_v: u64 = replicas
            .iter()
            .zip(&ingress_before)
            .map(|(r, &before)| replica_ingress(r) - before)
            .sum();
        stats.record_version(egress_v, fetched_v);
    }
    let duplicate_blocks = replicas
        .iter()
        .map(|r| r.borrow().bitswap.stats.duplicate_blocks)
        .sum();
    let replica_bytes_served = replicas
        .iter()
        .map(|r| r.borrow().bitswap.stats.bytes_served)
        .sum();
    // Bytes-of-control-per-delivered-byte: every ACK, bitswap metadata
    // frame, gossip frame and kad message across the mesh, against the
    // payload bytes the replicas actually received. (ACK bytes come from
    // live connections' transport stats — both A/B arms measure the same
    // way, so the comparison is apples to apples.)
    let mut control = crate::metrics::ControlPlaneStats::default();
    for nd in std::iter::once(&trainer).chain(replicas.iter()) {
        let n = nd.borrow();
        control.ack_bytes += n.swarm.transport_health().ack_bytes_sent;
        control.bitswap_meta_bytes += n.bitswap.stats.meta_bytes_sent;
        control.gossip_meta_bytes += n.gossip.stats.bytes_sent;
        control.kad_bytes += n.kad.stats.bytes_sent;
        control.delivered_bytes += n.bitswap.stats.bytes_received;
    }
    ModelSyncOutcome {
        stats,
        all_identical,
        completed,
        delta_bytes_announced,
        duplicate_blocks,
        replica_bytes_served,
        control,
    }
}

/// Drain a node's events, returning them.
pub fn drain(node: &Node) -> Vec<NodeEvent> {
    node.borrow_mut().drain_events()
}

/// Find the peer id of a node.
pub fn peer_of(node: &Node) -> PeerId {
    node.borrow().peer_id()
}
