//! Metastable-overload scenario: a replicated service driven far past
//! capacity by a mixed client fleet (retries + hedging enabled — the
//! amplifier configuration), surviving on admission control, weighted
//! fair queueing and server pushback.
//!
//! The failure mode this reproduces is *metastable overload*: once a
//! service saturates, client retries and hedges multiply the offered
//! load, rejection work itself saturates the server, and goodput stays
//! collapsed even after the original surge ends. The defenses under
//! test:
//!
//! * token-bucket admission sheds excess *before payload decode*
//!   ([`crate::rpc::Admission`]), so a rejected request costs a header
//!   parse, not a dispatch;
//! * the worker queue ([`crate::rpc::ServiceQueue`]) sheds
//!   oldest-useless-first and answers shed entries with
//!   [`crate::rpc::Status::Overloaded`] + a retry-after hint;
//! * stubs honor pushback: no retry before the hint, failover to a
//!   replica that is not shedding, hedges suppressed
//!   ([`crate::rpc::Stub`]).
//!
//! Three phases run back to back — `measure` (offered = nominal
//! capacity, establishing measured capacity), `surge` (offered =
//! `surge_mult` × capacity), `recover` (offered back under capacity) —
//! and each phase yields an [`OverloadRow`]. The acceptance bars
//! (surge goodput ≥ 80 % of measured capacity, ≥ 90 % of sheds
//! pre-decode, recovery without operator action) are asserted by
//! `tests/service_api.rs` and the `rpc_throughput` bench, which both
//! drive this same deployment.

use crate::metrics::{Histogram, RouterStats, StubStats};
use crate::netsim::link::PathProfile;
use crate::netsim::topology::{LinkProfile, TopologyBuilder};
use crate::netsim::{Time, World, MICRO, MILLI, SECOND};
use crate::node::{LatticaNode, NodeConfig};
use crate::rpc::{
    AdmissionPolicy, CallOptions, HedgePolicy, Outcome, Queued, Reply, RetryPolicy, Service,
    ServiceQueue, Status, Stub,
};
use crate::util::buf::Buf;
use std::cell::RefCell;
use std::rc::Rc;

use super::Node;

/// Deployment knobs; [`OverloadConfig::default`] is the canonical
/// configuration shared by the acceptance test and the bench.
#[derive(Clone, Copy, Debug)]
pub struct OverloadConfig {
    /// Replicas of the overloaded service.
    pub servers: usize,
    /// Client nodes, each with its own retrying + hedging stub.
    pub clients: usize,
    /// Worker slots per server (concurrent handlers).
    pub concurrency: usize,
    /// Per-request handler time.
    pub service_time: Time,
    /// Worker-queue depth per server.
    pub queue_capacity: usize,
    pub measure_secs: u64,
    pub surge_secs: u64,
    pub recover_secs: u64,
    /// Surge offered load as a multiple of nominal capacity.
    pub surge_mult: f64,
    pub seed: u64,
}

impl Default for OverloadConfig {
    fn default() -> OverloadConfig {
        OverloadConfig {
            servers: 2,
            clients: 4,
            concurrency: 4,
            service_time: 5 * MILLI,
            queue_capacity: 32,
            measure_secs: 3,
            surge_secs: 3,
            recover_secs: 3,
            surge_mult: 10.0,
            seed: 42,
        }
    }
}

/// One phase of the run.
#[derive(Clone, Copy, Debug)]
pub struct OverloadRow {
    pub phase: &'static str,
    /// Open-loop offered load this phase.
    pub offered_qps: f64,
    /// `Ok` completions per second of phase time.
    pub goodput_qps: f64,
    pub ok: u64,
    /// Logical calls that finished with a failure status this phase.
    pub rejected: u64,
    /// Server-side requests shed before payload decode (phase delta).
    pub shed_predecode: u64,
    /// Server-side requests shed from the worker queue (phase delta:
    /// capacity overflow + stale drops).
    pub shed_queue: u64,
    /// p99 latency of the calls that were admitted and served.
    pub p99_admitted_ns: u64,
}

/// Aggregate result; assertion bars live with the callers.
pub struct OverloadOutcome {
    pub rows: Vec<OverloadRow>,
    /// Goodput measured in the `measure` phase — the capacity baseline
    /// the surge phase is judged against.
    pub capacity_qps: f64,
    /// `servers × concurrency / service_time` — what the worker pools
    /// can serve in aggregate.
    pub nominal_capacity_qps: f64,
    /// Totals across the whole run (all servers).
    pub shed_predecode: u64,
    pub shed_queue: u64,
    /// Replies answered by the orphan path (dropped without sending).
    pub replies_dropped: u64,
    /// Aggregate client-side stub counters.
    pub stub: StubStats,
    /// Aggregate server-side router counters (shed overlay included).
    pub router: RouterStats,
}

fn add_stub(a: &mut StubStats, b: &StubStats) {
    a.ops += b.ops;
    a.ok += b.ok;
    a.failed += b.failed;
    a.attempts += b.attempts;
    a.retries += b.retries;
    a.hedges += b.hedges;
    a.hedge_wins += b.hedge_wins;
    a.failovers += b.failovers;
    a.cancelled += b.cancelled;
    a.deadline_expired += b.deadline_expired;
    a.overloaded += b.overloaded;
    a.hedges_suppressed += b.hedges_suppressed;
}

fn add_router(a: &mut RouterStats, b: &RouterStats) {
    a.served += b.served;
    a.failed += b.failed;
    a.deferred += b.deferred;
    a.unknown_service += b.unknown_service;
    a.unknown_method += b.unknown_method;
    a.expired += b.expired;
    a.stream_items += b.stream_items;
    a.shed_predecode += b.shed_predecode;
}

type WorkQueue = Rc<RefCell<ServiceQueue<Reply>>>;

/// Run the scenario; fully deterministic in the config.
pub fn overload_scenario(cfg: &OverloadConfig) -> OverloadOutcome {
    // One-region LAN: every shed and every retry is a round trip of
    // ~0.5 ms, so the client fleet can genuinely hammer the servers.
    let mut t = TopologyBuilder::new(1);
    t.intra(0, PathProfile::new(250 * MICRO, 50 * MICRO, 0.0));
    let server_hosts: Vec<u32> = (0..cfg.servers)
        .map(|_| t.public_host(0, LinkProfile::DATACENTER))
        .collect();
    let client_hosts: Vec<u32> = (0..cfg.clients)
        .map(|_| t.public_host(0, LinkProfile::DATACENTER))
        .collect();
    let mut world = World::new(t.build(cfg.seed));
    let servers: Vec<Node> = server_hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            LatticaNode::spawn(&mut world, h, {
                let mut c = NodeConfig::with_seed(cfg.seed * 100 + 1 + i as u64);
                c.label = format!("shard-{i}");
                c
            })
        })
        .collect();
    let clients: Vec<Node> = client_hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            LatticaNode::spawn(&mut world, h, {
                let mut c = NodeConfig::with_seed(cfg.seed * 100 + 50 + i as u64);
                c.label = format!("client-{i}");
                c
            })
        })
        .collect();

    // Admission sized to what the workers can actually serve: the bucket
    // is the front door saying "no" cheaply so the queue never has to.
    let per_server_rate = cfg.concurrency as f64 * SECOND as f64 / cfg.service_time as f64;
    let queues: Vec<WorkQueue> = (0..cfg.servers)
        .map(|_| {
            Rc::new(RefCell::new(ServiceQueue::new(
                cfg.queue_capacity,
                cfg.service_time,
            )))
        })
        .collect();
    for (s, q) in servers.iter().zip(&queues) {
        let queue = q.clone();
        let svc = Service::new("shard")
            .with_admission(AdmissionPolicy::rate(
                per_server_rate,
                (cfg.concurrency * 4) as f64,
            ))
            .unary("work", move |node, net, ctx, _payload| {
                let now = net.now();
                let (shed, hint) = {
                    let mut q = queue.borrow_mut();
                    let shed = q.push(now, ctx.peer, ctx.deadline, ctx.reply_handle());
                    let backlog = q.len().max(1) as u64;
                    (shed, q.ewma_handle().saturating_mul(backlog).max(MILLI))
                };
                for e in shed {
                    let _ = e.item.overloaded(node, net, hint, "worker queue full");
                }
                Outcome::Deferred
            });
        s.borrow_mut().register_service(svc);
    }

    // Every client connects to every replica up front; the run measures
    // overload behaviour, not dialing.
    for c in &clients {
        for s in &servers {
            let ma = s.borrow().listen_addr();
            c.borrow_mut().dial(&mut world.net, &ma).unwrap();
        }
    }
    world.run_for(2 * SECOND);
    for c in &clients {
        for s in &servers {
            assert!(
                c.borrow().swarm.is_connected(&s.borrow().peer_id()),
                "overload scenario setup failed to connect"
            );
        }
    }

    // The amplifier fleet: retries AND hedging on — the configuration
    // that melts a service with no pushback handling.
    let opts = CallOptions {
        deadline: 500 * MILLI,
        attempt_timeout: Some(200 * MILLI),
        retry: RetryPolicy {
            max_attempts: 3,
            base_backoff: 10 * MILLI,
            max_backoff: 200 * MILLI,
            jitter: 0.5,
            retry_on_error: false,
        },
        hedge: HedgePolicy::on(),
    };
    let server_peers: Vec<_> = servers.iter().map(|s| s.borrow().peer_id()).collect();
    let mut stubs: Vec<Stub> = (0..cfg.clients)
        .map(|_| Stub::new("shard", server_peers.clone()).with_options(opts))
        .collect();

    // Per-server worker pool: each slot holds (finish time, queued item).
    let mut workers: Vec<Vec<Option<(Time, Queued<Reply>)>>> = (0..cfg.servers)
        .map(|_| {
            let mut w = Vec::new();
            w.resize_with(cfg.concurrency, || None);
            w
        })
        .collect();

    let nominal = cfg.servers as f64 * per_server_rate;
    let payload: Buf = vec![0x42u8; 64].into();
    let response: Buf = vec![0x24u8; 64].into();
    let shed_totals = |servers: &[Node], queues: &[WorkQueue]| -> (u64, u64) {
        let pre = servers
            .iter()
            .map(|s| s.borrow().rpc.admission.stats.shed_predecode)
            .sum();
        let q = queues
            .iter()
            .map(|q| {
                let st = q.borrow().stats;
                st.shed_capacity + st.shed_stale
            })
            .sum();
        (pre, q)
    };

    let mut rows = Vec::new();
    let mut rr = 0usize;
    let phases: Vec<(&'static str, f64, u64)> = vec![
        ("measure", nominal, cfg.measure_secs),
        ("surge", nominal * cfg.surge_mult, cfg.surge_secs),
        ("recover", nominal * 0.75, cfg.recover_secs),
    ];
    for (phase, offered_qps, secs) in phases {
        let (pre0, q0) = shed_totals(&servers, &queues);
        let interval = ((SECOND as f64 / offered_qps) as Time).max(1);
        let mut next_issue = world.net.now();
        let phase_end = world.net.now() + secs * SECOND;
        let mut ok = 0u64;
        let mut rejected = 0u64;
        let mut lat = Histogram::new();
        // Drain the phase's own tail too: stop issuing at phase_end,
        // keep serving until in-flight ops resolve (bounded by the call
        // deadline), so completions are attributed where they belong.
        let mut drain_until = phase_end + opts.deadline;
        loop {
            let now = world.net.now();
            if now >= drain_until {
                break;
            }
            if now < phase_end {
                while next_issue <= now {
                    let ci = rr % cfg.clients;
                    rr += 1;
                    let mut n = clients[ci].borrow_mut();
                    stubs[ci].call(&mut n, &mut world.net, "work", payload.clone());
                    next_issue += interval;
                }
            }
            world.run_for(MILLI);
            let now = world.net.now();
            // Servers: complete finished work, pull new work from the
            // queue, answer entries the queue shed as stale.
            for (si, s) in servers.iter().enumerate() {
                s.borrow_mut().drain_events();
                let mut n = s.borrow_mut();
                for slot in &mut workers[si] {
                    if let Some((finish, item)) = slot.take() {
                        if finish > now {
                            *slot = Some((finish, item));
                            continue;
                        }
                        queues[si]
                            .borrow_mut()
                            .note_handle_time(now.saturating_sub(item.enqueued_at));
                        let _ = item.item.ok(&mut n, &mut world.net, response.clone());
                    }
                    let (serve, stale) = queues[si].borrow_mut().pop(now);
                    for e in stale {
                        let hint = queues[si].borrow().ewma_handle().max(MILLI);
                        let _ = e
                            .item
                            .overloaded(&mut n, &mut world.net, hint, "shed stale in queue");
                    }
                    if let Some(item) = serve {
                        *slot = Some((now + cfg.service_time, item));
                    }
                }
            }
            // Clients: feed stub events, drive timers, count completions.
            let mut all_idle = true;
            for (ci, c) in clients.iter().enumerate() {
                let evs = c.borrow_mut().drain_events();
                {
                    let mut n = c.borrow_mut();
                    for ev in &evs {
                        stubs[ci].on_node_event(&mut n, &mut world.net, ev);
                    }
                    stubs[ci].tick(&mut n, &mut world.net);
                }
                while let Some(d) = stubs[ci].poll_done() {
                    if d.status == Status::Ok {
                        ok += 1;
                        lat.record(d.rtt);
                    } else {
                        rejected += 1;
                    }
                }
                all_idle &= stubs[ci].in_flight() == 0;
            }
            if now >= phase_end && all_idle {
                drain_until = now;
            }
        }
        let (pre1, q1) = shed_totals(&servers, &queues);
        rows.push(OverloadRow {
            phase,
            offered_qps,
            goodput_qps: ok as f64 / secs as f64,
            ok,
            rejected,
            shed_predecode: pre1 - pre0,
            shed_queue: q1 - q0,
            p99_admitted_ns: lat.percentile(99.0),
        });
    }

    let (shed_predecode, shed_queue) = shed_totals(&servers, &queues);
    let mut stub = StubStats::default();
    for s in &stubs {
        add_stub(&mut stub, &s.stats);
    }
    let mut router = RouterStats::default();
    let mut replies_dropped = 0;
    for s in &servers {
        let n = s.borrow();
        add_router(&mut router, &n.router_stats());
        replies_dropped += n.rpc.replies_dropped;
    }
    OverloadOutcome {
        capacity_qps: rows[0].goodput_qps,
        nominal_capacity_qps: nominal,
        rows,
        shed_predecode,
        shed_queue,
        replies_dropped,
        stub,
        router,
    }
}
