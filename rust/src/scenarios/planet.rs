//! Planet-scale DHT scenario: lazy node materialization.
//!
//! A 100k-node world cannot afford a full [`LatticaNode`] (swarm, kad,
//! bitswap, RPC, CRDT store, per-protocol timers) per node — nor does it
//! need one: in a lookup-driven workload only a few hundred nodes are ever
//! touched by traffic. This module splits the deployment:
//!
//! * A handful of **core** nodes run the real full stack and issue the
//!   measured lookups and gossip publishes.
//! * Everyone else is a [`BackgroundNode`]: a bound port plus a keypair.
//!   Nothing else exists until the first datagram arrives, at which point
//!   the node materializes a real [`Swarm`] (kad runs over authenticated
//!   Noise streams, so a fake can't handshake) and answers kad requests
//!   from a shared [`RoutingOracle`] instead of a per-node routing table.
//!
//! The oracle holds every node's *real* precomputed identity (advertised
//! ids must match the handshake-authenticated key) sorted by id, and
//! serves exact XOR k-closest sets by trie descent over the sorted array.
//! Fidelity limits are documented in DESIGN.md §Simulator scale.

use crate::identity::Keypair;
use crate::metrics::PlanetScaleStats;
use crate::multiaddr::SimAddr;
use crate::netsim::topology::{LinkProfile, TopologyBuilder};
use crate::netsim::{Endpoint, EndpointId, Net, World, SECOND};
use crate::node::{run_until, LatticaNode, NodeConfig, NodeEvent};
use crate::protocols::gossip::{GossipMsg, GOSSIP_PROTO, M_PUBLISH, M_SUBSCRIBE};
use crate::protocols::kad::{
    KadEvent, KadMsg, PeerEntry, K, KAD_PROTO, M_FIND_NODE, M_GET_PROVIDERS, M_GET_RECORD,
    M_REPLY,
};
use crate::protocols::Ctx;
use crate::swarm::{Swarm, SwarmConfig, SwarmEvent, TIMER_SWARM_TICK};
use crate::util::Rng;
use crate::wire::Message;
use std::cell::RefCell;
use std::collections::HashSet;
use std::rc::Rc;

/// Every planet node listens here (core and background alike).
pub const PLANET_PORT: u16 = 4001;
/// Gossip topic the cores publish telemetry on; materialized background
/// nodes subscribe so publishes actually fan out into the swarm.
pub const PLANET_TOPIC: &str = "planet/telemetry";

/// Keypair seed for planet node `i` — the same `(seed, index)` convention
/// as `bootstrap_mesh`, so core identities and oracle identities agree.
pub fn node_seed(seed: u64, index: usize) -> u64 {
    seed.wrapping_mul(1000).wrapping_add(index as u64)
}

// ---------------------------------------------------------------------------
// Routing oracle
// ---------------------------------------------------------------------------

/// One precomputed identity in the oracle.
pub struct OracleNode {
    pub entry: PeerEntry,
    pub keypair: Keypair,
}

/// Global view of every node identity, sorted by id for exact XOR
/// k-closest queries. Background nodes answer FIND_NODE from this instead
/// of maintaining 100k individual routing tables.
pub struct RoutingOracle {
    /// By simulation index (node `i` lives on `hosts[i]`).
    nodes: Vec<OracleNode>,
    /// Simulation indices sorted by id bytes (big-endian numeric order,
    /// which makes XOR-close keys contiguous).
    order: Vec<u32>,
}

#[inline]
fn bit_of(key: &[u8; 32], bit: usize) -> u8 {
    (key[bit >> 3] >> (7 - (bit & 7))) & 1
}

impl RoutingOracle {
    /// Precompute identities for `hosts.len()` nodes. The x25519 keypair
    /// derivation is the dominant cost (~100 µs/node release), a one-time
    /// setup charge even at 100k.
    pub fn build(seed: u64, hosts: &[u32], port: u16) -> RoutingOracle {
        let nodes: Vec<OracleNode> = hosts
            .iter()
            .enumerate()
            .map(|(i, &host)| {
                let keypair = Keypair::from_seed(node_seed(seed, i));
                let entry = PeerEntry { id: keypair.peer_id(), host, port };
                OracleNode { entry, keypair }
            })
            .collect();
        let mut order: Vec<u32> = (0..nodes.len() as u32).collect();
        order.sort_by(|&a, &b| {
            nodes[a as usize]
                .entry
                .id
                .as_bytes()
                .cmp(nodes[b as usize].entry.id.as_bytes())
        });
        RoutingOracle { nodes, order }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, index: usize) -> &OracleNode {
        &self.nodes[index]
    }

    pub fn entry(&self, index: usize) -> &PeerEntry {
        &self.nodes[index].entry
    }

    /// The exact `n` closest node entries to `target` in XOR metric,
    /// closest first. Trie descent over the sorted id array: at each bit,
    /// the half matching the target's bit is strictly closer than the
    /// other half, so visiting match-first yields exact XOR order without
    /// scanning all N keys.
    pub fn closest(&self, target: &[u8; 32], n: usize) -> Vec<PeerEntry> {
        let mut picked: Vec<u32> = Vec::with_capacity(n);
        self.descend(0, self.order.len(), 0, target, n, &mut picked);
        picked
            .into_iter()
            .map(|i| self.nodes[i as usize].entry.clone())
            .collect()
    }

    fn descend(
        &self,
        lo: usize,
        hi: usize,
        bit: usize,
        target: &[u8; 32],
        n: usize,
        out: &mut Vec<u32>,
    ) {
        if lo >= hi || out.len() >= n {
            return;
        }
        if hi - lo == 1 || bit >= 256 {
            for &idx in &self.order[lo..hi] {
                if out.len() >= n {
                    break;
                }
                out.push(idx);
            }
            return;
        }
        let mid = lo
            + self.order[lo..hi].partition_point(|&i| {
                bit_of(self.nodes[i as usize].entry.id.as_bytes(), bit) == 0
            });
        if bit_of(target, bit) == 0 {
            self.descend(lo, mid, bit + 1, target, n, out);
            self.descend(mid, hi, bit + 1, target, n, out);
        } else {
            self.descend(mid, hi, bit + 1, target, n, out);
            self.descend(lo, mid, bit + 1, target, n, out);
        }
    }
}

// ---------------------------------------------------------------------------
// Background node
// ---------------------------------------------------------------------------

/// Shared counters across all background nodes in a scenario.
#[derive(Clone, Debug, Default)]
pub struct BackgroundStats {
    /// Background nodes that received traffic and built a swarm.
    pub materialized: u64,
    /// Kad requests answered from the oracle.
    pub kad_served: u64,
    /// Gossip publishes received.
    pub gossip_received: u64,
}

/// A lazily materialized endpoint: until the first datagram arrives it is
/// just a bound port, a keypair and an `Rc` to the oracle (~100 bytes). On
/// first traffic it builds a real [`Swarm`] — inbound connections complete
/// the authenticated handshake against the oracle-advertised identity —
/// and then answers kad lookups with oracle k-closest sets and joins the
/// gossip mesh as a leaf subscriber.
pub struct BackgroundNode {
    endpoint_id: EndpointId,
    addr: SimAddr,
    keypair: Keypair,
    oracle: Rc<RoutingOracle>,
    stats: Rc<RefCell<BackgroundStats>>,
    /// `None` until first inbound traffic.
    swarm: Option<Box<Swarm>>,
    /// Peers we already sent our gossip subscription to.
    greeted: HashSet<crate::identity::PeerId>,
}

impl BackgroundNode {
    /// Register node `index` of the oracle as a background endpoint.
    pub fn spawn(
        world: &mut World,
        oracle: Rc<RoutingOracle>,
        index: usize,
        stats: Rc<RefCell<BackgroundStats>>,
    ) -> (Rc<RefCell<BackgroundNode>>, EndpointId) {
        let on = oracle.node(index);
        let addr = SimAddr::new(on.entry.host, on.entry.port);
        let keypair = on.keypair.clone();
        let eid = world.next_endpoint_id();
        let rc = Rc::new(RefCell::new(BackgroundNode {
            endpoint_id: eid,
            addr,
            keypair,
            oracle,
            stats,
            swarm: None,
            greeted: HashSet::new(),
        }));
        let got = world.add_endpoint(rc.clone());
        debug_assert_eq!(got, eid);
        world.net.bind(eid, addr).expect("bind background port");
        (rc, eid)
    }

    pub fn is_materialized(&self) -> bool {
        self.swarm.is_some()
    }

    /// Drain swarm events: answer kad requests from the oracle, subscribe
    /// to the planet gossip topic on new connections, count publishes.
    fn pump(&mut self, net: &mut Net) {
        let Some(swarm) = self.swarm.as_mut() else { return };
        loop {
            let Some(ev) = swarm.poll_event() else { break };
            match ev {
                SwarmEvent::ConnEstablished { peer, .. } => {
                    if self.greeted.insert(peer) {
                        let mut ctx = Ctx::new(swarm, net);
                        let sub = GossipMsg {
                            kind: M_SUBSCRIBE,
                            topic: PLANET_TOPIC.to_string(),
                            ..Default::default()
                        };
                        // Best-effort: the stream stays open, matching how
                        // full nodes hold one gossip stream per peer.
                        if let Ok((cid, stream)) = ctx.open_stream(&peer, GOSSIP_PROTO) {
                            let _ = ctx.send(cid, stream, &sub.encode());
                        }
                    }
                }
                SwarmEvent::StreamMsg { cid, stream, msg } => {
                    let proto = swarm.stream_proto(cid, stream).unwrap_or_default();
                    if proto == KAD_PROTO {
                        let Ok(req) = KadMsg::decode(&msg) else { continue };
                        if matches!(req.kind, M_FIND_NODE | M_GET_PROVIDERS | M_GET_RECORD) {
                            let mut key = [0u8; 32];
                            if req.key.len() == 32 {
                                key.copy_from_slice(&req.key);
                            }
                            let reply = KadMsg {
                                kind: M_REPLY,
                                key: req.key.clone(),
                                closer: self.oracle.closest(&key, K),
                                ..Default::default()
                            };
                            let _ = swarm.send_msg(net, cid, stream, &reply.encode());
                            swarm.finish_stream(net, cid, stream);
                            self.stats.borrow_mut().kad_served += 1;
                        }
                        // PUT/ADD_PROVIDER carry no reply on the real
                        // responder either; background nodes drop the
                        // payload (fidelity limit, see DESIGN.md).
                    } else if proto == GOSSIP_PROTO {
                        if let Ok(m) = GossipMsg::decode(&msg) {
                            if m.kind == M_PUBLISH {
                                self.stats.borrow_mut().gossip_received += 1;
                            }
                        }
                    }
                }
                _ => {}
            }
        }
    }
}

impl Endpoint for BackgroundNode {
    fn on_datagram(&mut self, net: &mut Net, from: SimAddr, to: SimAddr, payload: Vec<u8>) {
        if self.swarm.is_none() {
            self.stats.borrow_mut().materialized += 1;
            let rng = net.rng.fork();
            self.swarm = Some(Box::new(Swarm::new(
                self.keypair.clone(),
                self.endpoint_id,
                self.addr,
                SwarmConfig::default(),
                rng,
            )));
        }
        self.swarm
            .as_mut()
            .unwrap()
            .handle_datagram(net, from, to, payload);
        self.pump(net);
    }

    fn on_timer(&mut self, net: &mut Net, token: u64) {
        if token == TIMER_SWARM_TICK {
            if let Some(swarm) = self.swarm.as_mut() {
                swarm.on_timer(net, token);
            }
            self.pump(net);
        }
    }
}

// ---------------------------------------------------------------------------
// The scenario
// ---------------------------------------------------------------------------

/// Deployment shape for [`planet_scale`].
#[derive(Clone, Debug)]
pub struct PlanetConfig {
    /// Total node count (cores + background).
    pub nodes: usize,
    /// Full-stack nodes issuing the measured workload.
    pub cores: usize,
    /// Measured FIND_NODE lookups (targets are live background nodes).
    pub lookups: usize,
    /// Background churn toggles (down if up, up if down) spread across
    /// the lookup phase.
    pub churn_toggles: usize,
    pub seed: u64,
}

impl PlanetConfig {
    /// Canonical shape for an `n`-node arm of the scaling curve.
    pub fn sized(nodes: usize, lookups: usize, seed: u64) -> PlanetConfig {
        let cores = (nodes / 8).clamp(2, 8);
        PlanetConfig {
            nodes,
            cores,
            lookups,
            churn_toggles: lookups / 2,
            seed,
        }
    }
}

/// Everything a scaling-curve row needs (plus the gauges that make
/// "bounded memory" measurable rather than asserted).
#[derive(Clone, Debug)]
pub struct PlanetOutcome {
    pub stats: PlanetScaleStats,
    /// Real wall-clock of the whole scenario (setup + run), milliseconds.
    pub wall_clock_ms: u64,
    pub peak_queue_depth: u64,
    pub peak_inflight_datagrams: u64,
    pub peak_inflight_payload_bytes: u64,
    pub events_processed: u64,
    pub events_dropped_stale: u64,
    /// Background nodes that ever materialized a swarm (the laziness
    /// gauge: should stay far below `background_total`).
    pub materialized: u64,
    pub background_total: usize,
    pub kad_served: u64,
    pub gossip_background_received: u64,
    pub gossip_core_received: u64,
    pub churn_downs: u64,
    pub churn_ups: u64,
}

struct BgSlot {
    /// Simulation index into the oracle.
    index: usize,
    eid: EndpointId,
    addr: SimAddr,
    live: bool,
}

/// Run one planet-scale arm: `cores` full nodes bootstrap against each
/// other plus a sample of background identities, then issue sequential
/// FIND_NODE lookups for live background nodes while seeded churn toggles
/// background endpoints and each lookup is chased by a gossip publish.
/// Fully deterministic in `cfg` (modulo the wall-clock field).
pub fn planet_scale(cfg: &PlanetConfig) -> PlanetOutcome {
    assert!(cfg.cores >= 2 && cfg.nodes > cfg.cores * 2, "bad shape: {cfg:?}");
    let wall = std::time::Instant::now();

    // Topology: nodes round-robin across the three paper regions.
    let mut t = TopologyBuilder::paper_regions();
    let hosts: Vec<u32> = (0..cfg.nodes)
        .map(|i| t.public_host(i % 3, LinkProfile::FIBER))
        .collect();
    let oracle = Rc::new(RoutingOracle::build(cfg.seed, &hosts, PLANET_PORT));
    let mut world = World::new(t.build(cfg.seed));
    let bg_stats = Rc::new(RefCell::new(BackgroundStats::default()));

    // Cores are oracle indices 0..cores — LatticaNode derives its keypair
    // from the same node_seed convention, so identities line up.
    let cores: Vec<Rc<RefCell<LatticaNode>>> = (0..cfg.cores)
        .map(|i| {
            LatticaNode::spawn(&mut world, hosts[i], NodeConfig::with_seed(node_seed(cfg.seed, i)))
        })
        .collect();
    debug_assert!(cores
        .iter()
        .enumerate()
        .all(|(i, c)| c.borrow().peer_id() == oracle.entry(i).id));

    let mut bg: Vec<BgSlot> = Vec::with_capacity(cfg.nodes - cfg.cores);
    for index in cfg.cores..cfg.nodes {
        let (_, eid) = BackgroundNode::spawn(&mut world, oracle.clone(), index, bg_stats.clone());
        bg.push(BgSlot {
            index,
            eid,
            addr: SimAddr::new(hosts[index], PLANET_PORT),
            live: true,
        });
    }

    // Seed each core with the other cores plus a few random background
    // identities, subscribe it to the telemetry topic, and self-lookup.
    let mut rng = Rng::new(cfg.seed ^ 0x70A9_E7_5C_A1E5);
    for (i, core) in cores.iter().enumerate() {
        let mut nd = core.borrow_mut();
        let node = &mut *nd;
        let mut ctx = Ctx::new(&mut node.swarm, &mut world.net);
        for (j, _) in cores.iter().enumerate() {
            if j != i {
                node.kad.add_address(&mut ctx, oracle.entry(j).clone());
            }
        }
        for _ in 0..8 {
            let r = cfg.cores + rng.gen_index(cfg.nodes - cfg.cores);
            node.kad.add_address(&mut ctx, oracle.entry(r).clone());
        }
        node.gossip.subscribe(&mut ctx, PLANET_TOPIC);
        let key = *node.kad.table.local.as_bytes();
        node.kad.find_node(&mut ctx, key);
    }
    world.run_for(3 * SECOND);

    // Lookup phase with interleaved churn toggles and gossip publishes.
    let mut stats = PlanetScaleStats {
        nodes: cfg.nodes as u64,
        ..PlanetScaleStats::default()
    };
    let mut gossip_core_received = 0u64;
    let (mut churn_downs, mut churn_ups) = (0u64, 0u64);
    let toggle_every = if cfg.churn_toggles == 0 {
        usize::MAX
    } else {
        (cfg.lookups / cfg.churn_toggles).max(1)
    };
    let mut toggles_left = cfg.churn_toggles;

    for l in 0..cfg.lookups {
        if l > 0 && l % toggle_every == 0 && toggles_left > 0 {
            toggles_left -= 1;
            let slot = &mut bg[rng.gen_index(bg.len())];
            if slot.live {
                world.remove_endpoint(slot.eid);
                world.net.unbind(slot.addr);
                slot.live = false;
                churn_downs += 1;
            } else {
                let (_, eid) =
                    BackgroundNode::spawn(&mut world, oracle.clone(), slot.index, bg_stats.clone());
                slot.eid = eid;
                slot.live = true;
                churn_ups += 1;
            }
        }

        // A live background target (bounded retry keeps this total even if
        // churn took most of a tiny deployment down).
        let mut target = None;
        for _ in 0..64 {
            let x = rng.gen_index(bg.len());
            if bg[x].live {
                target = Some(x);
                break;
            }
        }
        let Some(tx) = target else { continue };
        let target_id = oracle.entry(bg[tx].index).id;
        let key = *target_id.as_bytes();

        let c = rng.gen_index(cfg.cores);
        let _ = cores[c].borrow_mut().drain_events();
        let t0 = world.net.now();
        let qid = {
            let mut nd = cores[c].borrow_mut();
            let node = &mut *nd;
            let mut ctx = Ctx::new(&mut node.swarm, &mut world.net);
            node.kad.find_node(&mut ctx, key)
        };
        stats.attempted += 1;
        let mut result: Option<(u32, bool)> = None;
        run_until(&mut world, 20 * SECOND, || {
            if result.is_none() {
                let mut nd = cores[c].borrow_mut();
                for e in nd.drain_events() {
                    match e {
                        NodeEvent::Kad(KadEvent::QueryFinished {
                            query_id,
                            hops,
                            closest,
                            ..
                        }) if query_id == qid => {
                            let hit = closest.iter().any(|p| p.id == target_id);
                            result = Some((hops, hit));
                        }
                        NodeEvent::Gossip(_) => gossip_core_received += 1,
                        _ => {}
                    }
                }
            }
            result.is_some()
        });
        if let Some((hops, hit)) = result {
            stats.record(hit, hops, world.net.now() - t0);
        }

        // Chase every lookup with a telemetry publish from a random core.
        {
            let mut nd = cores[rng.gen_index(cfg.cores)].borrow_mut();
            let node = &mut *nd;
            let mut ctx = Ctx::new(&mut node.swarm, &mut world.net);
            node.gossip.publish(&mut ctx, PLANET_TOPIC, vec![l as u8]);
        }
    }
    world.run_for(2 * SECOND);

    for core in &cores {
        for e in core.borrow_mut().drain_events() {
            if matches!(e, NodeEvent::Gossip(_)) {
                gossip_core_received += 1;
            }
        }
    }

    let b = bg_stats.borrow();
    let ns = &world.net.stats;
    PlanetOutcome {
        wall_clock_ms: wall.elapsed().as_millis() as u64,
        peak_queue_depth: ns.peak_queue_depth,
        peak_inflight_datagrams: ns.peak_inflight_datagrams,
        peak_inflight_payload_bytes: ns.peak_inflight_payload_bytes,
        events_processed: ns.events_processed,
        events_dropped_stale: ns.events_dropped_stale,
        materialized: b.materialized,
        background_total: bg.len(),
        kad_served: b.kad_served,
        gossip_background_received: b.gossip_received,
        gossip_core_received,
        churn_downs,
        churn_ups,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::kad::xor_distance;

    #[test]
    fn oracle_identities_match_keypair_seeds() {
        let hosts: Vec<u32> = (0..10).collect();
        let o = RoutingOracle::build(7, &hosts, PLANET_PORT);
        assert_eq!(o.len(), 10);
        for i in 0..10 {
            let kp = Keypair::from_seed(node_seed(7, i));
            assert_eq!(o.entry(i).id, kp.peer_id());
            assert_eq!(o.entry(i).host, i as u32);
        }
    }

    #[test]
    fn oracle_closest_matches_brute_force() {
        let hosts: Vec<u32> = (0..50).collect();
        let o = RoutingOracle::build(99, &hosts, PLANET_PORT);
        let mut rng = Rng::new(12345);
        // Random targets plus exact member keys (distance-zero hits).
        let mut targets: Vec<[u8; 32]> = (0..10)
            .map(|_| {
                let mut k = [0u8; 32];
                rng.fill_bytes(&mut k);
                k
            })
            .collect();
        targets.push(*o.entry(0).id.as_bytes());
        targets.push(*o.entry(31).id.as_bytes());
        for target in &targets {
            for n in [1usize, 7, 20, 50, 80] {
                let got = o.closest(target, n);
                let mut want: Vec<PeerEntry> =
                    (0..o.len()).map(|i| o.entry(i).clone()).collect();
                want.sort_by_key(|e| xor_distance(e.id.as_bytes(), target));
                want.truncate(n);
                assert_eq!(
                    got.iter().map(|e| e.id).collect::<Vec<_>>(),
                    want.iter().map(|e| e.id).collect::<Vec<_>>(),
                    "n={n}"
                );
            }
        }
    }

    #[test]
    fn background_nodes_stay_cold_without_traffic() {
        let mut t = TopologyBuilder::paper_regions();
        let hosts: Vec<u32> = (0..20).map(|i| t.public_host(i % 3, LinkProfile::FIBER)).collect();
        let oracle = Rc::new(RoutingOracle::build(3, &hosts, PLANET_PORT));
        let mut world = World::new(t.build(3));
        let stats = Rc::new(RefCell::new(BackgroundStats::default()));
        let mut rcs = Vec::new();
        for i in 0..20 {
            let (rc, _) = BackgroundNode::spawn(&mut world, oracle.clone(), i, stats.clone());
            rcs.push(rc);
        }
        world.run_for(10 * SECOND);
        assert_eq!(stats.borrow().materialized, 0);
        assert!(rcs.iter().all(|r| !r.borrow().is_materialized()));
        // No timers, no events: a cold deployment costs nothing per tick.
        assert_eq!(world.net.stats.events_processed, 0);
    }

    #[test]
    fn single_dial_materializes_one() {
        let mut t = TopologyBuilder::paper_regions();
        let hosts: Vec<u32> = (0..21).map(|i| t.public_host(i % 3, LinkProfile::FIBER)).collect();
        let oracle = Rc::new(RoutingOracle::build(11, &hosts, PLANET_PORT));
        let mut world = World::new(t.build(11));
        let stats = Rc::new(RefCell::new(BackgroundStats::default()));
        for i in 1..21 {
            BackgroundNode::spawn(&mut world, oracle.clone(), i, stats.clone());
        }
        let core =
            LatticaNode::spawn(&mut world, hosts[0], NodeConfig::with_seed(node_seed(11, 0)));
        let target = oracle.entry(5).to_multiaddr();
        core.borrow_mut().dial(&mut world.net, &target).unwrap();
        world.run_for(2 * SECOND);
        // Exactly the dialed node materialized; the other 19 stayed cold.
        assert_eq!(stats.borrow().materialized, 1);
    }

    #[test]
    fn tiny_planet_lookups_succeed() {
        let out = planet_scale(&PlanetConfig {
            nodes: 36,
            cores: 4,
            lookups: 6,
            churn_toggles: 2,
            seed: 42,
        });
        assert_eq!(out.stats.attempted, 6);
        assert!(
            out.stats.success_rate() >= 0.8,
            "success {:.2}, hops mean {:.1}",
            out.stats.success_rate(),
            out.stats.mean_hops()
        );
        // Traffic materialized some background nodes (at this tiny size a
        // few K-wide lookups may touch nearly all of them; the strict
        // laziness bound is covered by `single_dial_materializes_one`).
        assert!(out.materialized > 0);
        assert!(out.materialized <= out.background_total as u64);
        assert!(out.kad_served > 0);
        assert!(out.peak_queue_depth > 0);
        assert!(out.churn_downs + out.churn_ups > 0);
    }

    #[test]
    fn planet_scale_is_deterministic() {
        let cfg = PlanetConfig {
            nodes: 30,
            cores: 3,
            lookups: 4,
            churn_toggles: 1,
            seed: 1234,
        };
        let a = planet_scale(&cfg);
        let b = planet_scale(&cfg);
        assert_eq!(a.stats.attempted, b.stats.attempted);
        assert_eq!(a.stats.succeeded, b.stats.succeeded);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.materialized, b.materialized);
        assert_eq!(a.kad_served, b.kad_served);
    }
}
