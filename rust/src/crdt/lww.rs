//! Last-writer-wins register with (timestamp, replica) tie-breaking.

use super::{Crdt, ReplicaId};
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct LwwRegister {
    pub value: Vec<u8>,
    pub timestamp: u64,
    pub replica: ReplicaId,
}

impl LwwRegister {
    pub fn new() -> LwwRegister {
        LwwRegister::default()
    }

    /// Set the value at logical time `ts` (caller supplies a monotonic
    /// clock — virtual time or a Lamport counter).
    pub fn set(&mut self, value: Vec<u8>, ts: u64, replica: ReplicaId) {
        if (ts, replica) >= (self.timestamp, self.replica) {
            self.value = value;
            self.timestamp = ts;
            self.replica = replica;
        }
    }

    pub fn get(&self) -> &[u8] {
        &self.value
    }
}

impl Crdt for LwwRegister {
    fn merge(&mut self, other: &Self) {
        if (other.timestamp, other.replica) > (self.timestamp, self.replica) {
            *self = other.clone();
        }
    }
}

impl Message for LwwRegister {
    fn encode_to(&self, w: &mut PbWriter) {
        w.bytes(1, &self.value);
        w.uint(2, self.timestamp);
        w.uint(3, self.replica);
    }

    fn decode(buf: &[u8]) -> Result<LwwRegister> {
        let mut r = LwwRegister::new();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => r.value = f.as_bytes()?.to_vec(),
                2 => r.timestamp = f.as_u64(),
                3 => r.replica = f.as_u64(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn later_write_wins() {
        let mut a = LwwRegister::new();
        a.set(b"v1".to_vec(), 10, 1);
        let mut b = LwwRegister::new();
        b.set(b"v2".to_vec(), 20, 2);
        a.merge(&b);
        assert_eq!(a.get(), b"v2");
        // Merging an older value changes nothing.
        let mut old = LwwRegister::new();
        old.set(b"v0".to_vec(), 5, 3);
        a.merge(&old);
        assert_eq!(a.get(), b"v2");
    }

    #[test]
    fn replica_breaks_timestamp_ties() {
        let mut a = LwwRegister::new();
        a.set(b"low".to_vec(), 10, 1);
        let mut b = LwwRegister::new();
        b.set(b"high".to_vec(), 10, 2);
        let mut m1 = a.clone();
        m1.merge(&b);
        let mut m2 = b.clone();
        m2.merge(&a);
        assert_eq!(m1, m2, "tie-break must be symmetric");
        assert_eq!(m1.get(), b"high");
    }

    #[test]
    fn wire_roundtrip() {
        let mut r = LwwRegister::new();
        r.set(b"payload".to_vec(), 123, 7);
        assert_eq!(LwwRegister::decode(&r.encode()).unwrap(), r);
    }
}
