//! Observed-remove set: add wins over concurrent remove.
//!
//! Each add creates a unique tag (replica, counter); removal tombstones
//! the observed tags only, so a concurrent re-add survives.

use super::{Crdt, ReplicaId};
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};

type Tag = (ReplicaId, u64);

#[derive(Clone, Debug, Default, PartialEq)]
pub struct OrSet {
    /// element → live tags
    elements: BTreeMap<Vec<u8>, BTreeSet<Tag>>,
    /// tombstoned tags (per element, kept so merges can't resurrect)
    tombstones: BTreeMap<Vec<u8>, BTreeSet<Tag>>,
    counter: u64,
}

impl OrSet {
    pub fn new() -> OrSet {
        OrSet::default()
    }

    pub fn add(&mut self, replica: ReplicaId, element: &[u8]) {
        self.counter += 1;
        let tag = (replica, self.counter);
        self.elements.entry(element.to_vec()).or_default().insert(tag);
    }

    /// Remove: tombstones every currently observed tag.
    pub fn remove(&mut self, element: &[u8]) {
        if let Some(tags) = self.elements.get_mut(element) {
            let dead: BTreeSet<Tag> = std::mem::take(tags);
            self.tombstones
                .entry(element.to_vec())
                .or_default()
                .extend(dead);
        }
    }

    pub fn contains(&self, element: &[u8]) -> bool {
        self.elements.get(element).map_or(false, |t| !t.is_empty())
    }

    pub fn iter(&self) -> impl Iterator<Item = &Vec<u8>> {
        self.elements
            .iter()
            .filter(|(_, t)| !t.is_empty())
            .map(|(e, _)| e)
    }

    pub fn len(&self) -> usize {
        self.elements.values().filter(|t| !t.is_empty()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Crdt for OrSet {
    fn merge(&mut self, other: &Self) {
        // Union tombstones first.
        for (e, ts) in &other.tombstones {
            self.tombstones.entry(e.clone()).or_default().extend(ts.iter().copied());
        }
        // Union live tags, minus anything tombstoned anywhere.
        for (e, ts) in &other.elements {
            self.elements.entry(e.clone()).or_default().extend(ts.iter().copied());
        }
        for (e, ts) in &mut self.elements {
            if let Some(dead) = self.tombstones.get(e) {
                ts.retain(|t| !dead.contains(t));
            }
        }
        self.counter = self.counter.max(other.counter);
    }
}

impl Message for OrSet {
    fn encode_to(&self, w: &mut PbWriter) {
        let write_map = |w: &mut PbWriter, field: u32, map: &BTreeMap<Vec<u8>, BTreeSet<Tag>>| {
            for (e, tags) in map {
                let mut inner = PbWriter::new();
                inner.bytes_always(1, e);
                for (r, c) in tags {
                    let mut tag = PbWriter::new();
                    tag.uint(1, *r);
                    tag.uint(2, *c);
                    inner.bytes_always(2, &tag.finish());
                }
                w.bytes_always(field, &inner.finish());
            }
        };
        write_map(w, 1, &self.elements);
        write_map(w, 2, &self.tombstones);
        w.uint(3, self.counter);
    }

    fn decode(buf: &[u8]) -> Result<OrSet> {
        let mut s = OrSet::new();
        let read_entry = |data: &[u8]| -> Result<(Vec<u8>, BTreeSet<Tag>)> {
            let mut elem = Vec::new();
            let mut tags = BTreeSet::new();
            PbReader::new(data).for_each(|g| {
                match g.number {
                    1 => elem = g.as_bytes()?.to_vec(),
                    2 => {
                        let mut r = 0u64;
                        let mut c = 0u64;
                        PbReader::new(g.as_bytes()?).for_each(|t| {
                            match t.number {
                                1 => r = t.as_u64(),
                                2 => c = t.as_u64(),
                                _ => {}
                            }
                            Ok(())
                        })?;
                        tags.insert((r, c));
                    }
                    _ => {}
                }
                Ok(())
            })?;
            Ok((elem, tags))
        };
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => {
                    let (e, t) = read_entry(f.as_bytes()?)?;
                    s.elements.insert(e, t);
                }
                2 => {
                    let (e, t) = read_entry(f.as_bytes()?)?;
                    s.tombstones.insert(e, t);
                }
                3 => s.counter = f.as_u64(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_remove_contains() {
        let mut s = OrSet::new();
        s.add(1, b"x");
        assert!(s.contains(b"x"));
        s.remove(b"x");
        assert!(!s.contains(b"x"));
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn add_wins_over_concurrent_remove() {
        let mut a = OrSet::new();
        a.add(1, b"item");
        let mut b = a.clone();
        // A removes; B concurrently re-adds with a fresh tag.
        a.remove(b"item");
        b.add(2, b"item");
        let mut m1 = a.clone();
        m1.merge(&b);
        let mut m2 = b.clone();
        m2.merge(&a);
        assert_eq!(m1, m2);
        assert!(m1.contains(b"item"), "add must win");
    }

    #[test]
    fn removed_stays_removed_after_remerge() {
        let mut a = OrSet::new();
        a.add(1, b"x");
        let old = a.clone();
        a.remove(b"x");
        // Merging the pre-remove state back must not resurrect x.
        a.merge(&old);
        assert!(!a.contains(b"x"));
    }

    #[test]
    fn convergence_random_ops() {
        let mut rng = crate::util::Rng::new(12);
        for _ in 0..20 {
            let mut replicas: Vec<OrSet> = (0..3).map(|_| OrSet::new()).collect();
            for _ in 0..30 {
                let r = rng.gen_index(3);
                let elem = [b'a' + rng.gen_range(5) as u8];
                if rng.gen_bool(0.7) {
                    replicas[r].add(r as u64, &elem);
                } else {
                    replicas[r].remove(&elem);
                }
            }
            // Full pairwise merge until fixpoint.
            for _ in 0..3 {
                for i in 0..3 {
                    for j in 0..3 {
                        if i != j {
                            let other = replicas[j].clone();
                            replicas[i].merge(&other);
                        }
                    }
                }
            }
            let s0: Vec<_> = replicas[0].iter().cloned().collect();
            for r in &replicas[1..] {
                let s: Vec<_> = r.iter().cloned().collect();
                assert_eq!(s, s0, "replicas diverged");
            }
        }
    }

    #[test]
    fn wire_roundtrip() {
        let mut s = OrSet::new();
        s.add(1, b"alpha");
        s.add(2, b"beta");
        s.remove(b"alpha");
        let dec = OrSet::decode(&s.encode()).unwrap();
        assert_eq!(dec, s);
        assert!(!dec.contains(b"alpha"));
        assert!(dec.contains(b"beta"));
    }
}
