//! The replicated store: named CRDT instances + verifiable state digest.

use super::counter::{GCounter, PnCounter};
use super::lww::LwwRegister;
use super::orset::OrSet;
use super::Crdt;
use crate::crypto::sha256::Sha256;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A value in the store.
#[derive(Clone, Debug, PartialEq)]
pub enum CrdtValue {
    GCounter(GCounter),
    PnCounter(PnCounter),
    Lww(LwwRegister),
    OrSet(OrSet),
}

impl CrdtValue {
    fn kind(&self) -> u64 {
        match self {
            CrdtValue::GCounter(_) => 1,
            CrdtValue::PnCounter(_) => 2,
            CrdtValue::Lww(_) => 3,
            CrdtValue::OrSet(_) => 4,
        }
    }

    fn body(&self) -> Vec<u8> {
        match self {
            CrdtValue::GCounter(c) => c.encode(),
            CrdtValue::PnCounter(c) => c.encode(),
            CrdtValue::Lww(r) => r.encode(),
            CrdtValue::OrSet(s) => s.encode(),
        }
    }

    fn from_parts(kind: u64, body: &[u8]) -> Result<CrdtValue> {
        Ok(match kind {
            1 => CrdtValue::GCounter(GCounter::decode(body)?),
            2 => CrdtValue::PnCounter(PnCounter::decode(body)?),
            3 => CrdtValue::Lww(LwwRegister::decode(body)?),
            4 => CrdtValue::OrSet(OrSet::decode(body)?),
            k => bail!("unknown crdt kind {k}"),
        })
    }

    fn merge(&mut self, other: &CrdtValue) -> Result<()> {
        match (self, other) {
            (CrdtValue::GCounter(a), CrdtValue::GCounter(b)) => a.merge(b),
            (CrdtValue::PnCounter(a), CrdtValue::PnCounter(b)) => a.merge(b),
            (CrdtValue::Lww(a), CrdtValue::Lww(b)) => a.merge(b),
            (CrdtValue::OrSet(a), CrdtValue::OrSet(b)) => a.merge(b),
            _ => bail!("type mismatch merging CRDT"),
        }
        Ok(())
    }
}

/// Named CRDT instances with digest-based convergence checks.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CrdtStore {
    entries: BTreeMap<String, CrdtValue>,
}

impl CrdtStore {
    pub fn new() -> CrdtStore {
        CrdtStore::default()
    }

    pub fn gcounter(&mut self, key: &str) -> &mut GCounter {
        match self
            .entries
            .entry(key.to_string())
            .or_insert_with(|| CrdtValue::GCounter(GCounter::new()))
        {
            CrdtValue::GCounter(c) => c,
            _ => panic!("{key} is not a gcounter"),
        }
    }

    pub fn pncounter(&mut self, key: &str) -> &mut PnCounter {
        match self
            .entries
            .entry(key.to_string())
            .or_insert_with(|| CrdtValue::PnCounter(PnCounter::new()))
        {
            CrdtValue::PnCounter(c) => c,
            _ => panic!("{key} is not a pncounter"),
        }
    }

    pub fn lww(&mut self, key: &str) -> &mut LwwRegister {
        match self
            .entries
            .entry(key.to_string())
            .or_insert_with(|| CrdtValue::Lww(LwwRegister::new()))
        {
            CrdtValue::Lww(r) => r,
            _ => panic!("{key} is not a lww register"),
        }
    }

    pub fn orset(&mut self, key: &str) -> &mut OrSet {
        match self
            .entries
            .entry(key.to_string())
            .or_insert_with(|| CrdtValue::OrSet(OrSet::new()))
        {
            CrdtValue::OrSet(s) => s,
            _ => panic!("{key} is not an orset"),
        }
    }

    pub fn get(&self, key: &str) -> Option<&CrdtValue> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Deterministic digest over the full state: equal digests ⇒ converged
    /// (the "verifiable" replication check; BTreeMap gives canonical order).
    pub fn digest(&self) -> [u8; 32] {
        let mut h = Sha256::new();
        for (k, v) in &self.entries {
            h.update((k.len() as u64).to_be_bytes());
            h.update(k.as_bytes());
            h.update([v.kind() as u8]);
            let body = v.body();
            h.update((body.len() as u64).to_be_bytes());
            h.update(&body);
        }
        h.finalize().into()
    }

    /// Per-key digests (anti-entropy sends only differing keys).
    pub fn key_digests(&self) -> BTreeMap<String, [u8; 32]> {
        self.entries
            .iter()
            .map(|(k, v)| {
                let mut h = Sha256::new();
                h.update([v.kind() as u8]);
                h.update(v.body());
                (k.clone(), h.finalize().into())
            })
            .collect()
    }

    /// Merge another store's (possibly partial) state.
    pub fn merge(&mut self, other: &CrdtStore) -> Result<()> {
        for (k, v) in &other.entries {
            match self.entries.get_mut(k) {
                Some(mine) => mine.merge(v)?,
                None => {
                    self.entries.insert(k.clone(), v.clone());
                }
            }
        }
        Ok(())
    }

    /// Extract a sub-store containing only `keys` (for delta shipping).
    pub fn extract(&self, keys: &[String]) -> CrdtStore {
        CrdtStore {
            entries: keys
                .iter()
                .filter_map(|k| self.entries.get(k).map(|v| (k.clone(), v.clone())))
                .collect(),
        }
    }
}

impl Message for CrdtStore {
    fn encode_to(&self, w: &mut PbWriter) {
        for (k, v) in &self.entries {
            let mut inner = PbWriter::new();
            inner.string(1, k);
            inner.uint(2, v.kind());
            inner.bytes_always(3, &v.body());
            w.bytes_always(1, &inner.finish());
        }
    }

    fn decode(buf: &[u8]) -> Result<CrdtStore> {
        let mut s = CrdtStore::new();
        PbReader::new(buf).for_each(|f| {
            if f.number == 1 {
                let mut key = String::new();
                let mut kind = 0u64;
                let mut body = Vec::new();
                PbReader::new(f.as_bytes()?).for_each(|g| {
                    match g.number {
                        1 => key = g.as_string()?,
                        2 => kind = g.as_u64(),
                        3 => body = g.as_bytes()?.to_vec(),
                        _ => {}
                    }
                    Ok(())
                })?;
                s.entries.insert(key, CrdtValue::from_parts(kind, &body)?);
            }
            Ok(())
        })?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_accessors_and_digest() {
        let mut s = CrdtStore::new();
        s.gcounter("epochs").increment(1, 3);
        s.lww("leader").set(b"node-7".to_vec(), 100, 1);
        s.orset("members").add(1, b"alice");
        assert_eq!(s.len(), 3);
        let d1 = s.digest();
        s.gcounter("epochs").increment(1, 1);
        assert_ne!(s.digest(), d1, "digest tracks state");
    }

    #[test]
    fn stores_converge_and_digests_agree() {
        let mut a = CrdtStore::new();
        let mut b = CrdtStore::new();
        a.gcounter("c").increment(1, 5);
        b.gcounter("c").increment(2, 7);
        a.orset("s").add(1, b"x");
        b.orset("s").add(2, b"y");
        b.lww("r").set(b"vb".to_vec(), 9, 2);

        let a0 = a.clone();
        a.merge(&b).unwrap();
        b.merge(&a0).unwrap();
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.gcounter("c").value(), 12);
        assert!(a.orset("s").contains(b"x") && a.orset("s").contains(b"y"));
    }

    #[test]
    fn partial_sync_via_key_digests() {
        let mut a = CrdtStore::new();
        let mut b = CrdtStore::new();
        a.gcounter("same").increment(1, 1);
        b.gcounter("same").increment(1, 1);
        a.gcounter("diff").increment(1, 5);
        b.gcounter("diff").increment(2, 9);

        let da = a.key_digests();
        let db = b.key_digests();
        let differing: Vec<String> = da
            .iter()
            .filter(|(k, d)| db.get(*k) != Some(d))
            .map(|(k, _)| k.clone())
            .collect();
        assert_eq!(differing, vec!["diff".to_string()]);
        let delta = b.extract(&differing);
        assert_eq!(delta.len(), 1);
        a.merge(&delta).unwrap();
        assert_eq!(a.gcounter("diff").value(), 14);
    }

    #[test]
    fn type_mismatch_rejected() {
        let mut a = CrdtStore::new();
        a.gcounter("k").increment(1, 1);
        let mut b = CrdtStore::new();
        b.lww("k").set(b"v".to_vec(), 1, 1);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn wire_roundtrip() {
        let mut s = CrdtStore::new();
        s.pncounter("pn").increment(3, 10);
        s.pncounter("pn").decrement(3, 4);
        s.orset("set").add(1, b"e");
        let dec = CrdtStore::decode(&s.encode()).unwrap();
        assert_eq!(dec, s);
        assert_eq!(dec.digest(), s.digest());
    }
}
