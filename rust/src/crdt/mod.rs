//! Conflict-free replicated data types and the replicated store.
//!
//! The paper's "decentralized data store based on CRDTs" (§1, §2): nodes
//! mutate locally, exchange state via anti-entropy, and converge without
//! coordination. Implemented types: [`GCounter`], [`PnCounter`],
//! [`LwwRegister`], [`OrSet`]. [`store::CrdtStore`] holds named instances,
//! exposes a Merkle-style state digest for cheap "are we converged?"
//! checks, and encodes full or partial state for the sync protocol
//! (`node::crdt_sync`).

pub mod counter;
pub mod lww;
pub mod orset;
pub mod store;

pub use counter::{GCounter, PnCounter};
pub use lww::LwwRegister;
pub use orset::OrSet;
pub use store::{CrdtStore, CrdtValue};

/// Replica identifier (the node's PeerId digest works; tests use ints).
pub type ReplicaId = u64;

/// State-based CRDT: merge must be commutative, associative, idempotent.
pub trait Crdt: Clone {
    fn merge(&mut self, other: &Self);
}
