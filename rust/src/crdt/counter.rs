//! Grow-only and PN counters.

use super::{Crdt, ReplicaId};
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::BTreeMap;

/// Grow-only counter: per-replica maxima.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GCounter {
    pub counts: BTreeMap<ReplicaId, u64>,
}

impl GCounter {
    pub fn new() -> GCounter {
        GCounter::default()
    }

    pub fn increment(&mut self, replica: ReplicaId, by: u64) {
        *self.counts.entry(replica).or_default() += by;
    }

    pub fn value(&self) -> u64 {
        self.counts.values().sum()
    }
}

impl Crdt for GCounter {
    fn merge(&mut self, other: &Self) {
        for (r, v) in &other.counts {
            let e = self.counts.entry(*r).or_default();
            *e = (*e).max(*v);
        }
    }
}

impl Message for GCounter {
    fn encode_to(&self, w: &mut PbWriter) {
        for (r, v) in &self.counts {
            let mut inner = PbWriter::new();
            inner.uint(1, *r);
            inner.uint(2, *v);
            w.bytes_always(1, &inner.finish());
        }
    }

    fn decode(buf: &[u8]) -> Result<GCounter> {
        let mut c = GCounter::new();
        PbReader::new(buf).for_each(|f| {
            if f.number == 1 {
                let mut r = 0u64;
                let mut v = 0u64;
                PbReader::new(f.as_bytes()?).for_each(|g| {
                    match g.number {
                        1 => r = g.as_u64(),
                        2 => v = g.as_u64(),
                        _ => {}
                    }
                    Ok(())
                })?;
                c.counts.insert(r, v);
            }
            Ok(())
        })?;
        Ok(c)
    }
}

/// Increment/decrement counter: two grow-only counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PnCounter {
    pub pos: GCounter,
    pub neg: GCounter,
}

impl PnCounter {
    pub fn new() -> PnCounter {
        PnCounter::default()
    }

    pub fn increment(&mut self, replica: ReplicaId, by: u64) {
        self.pos.increment(replica, by);
    }

    pub fn decrement(&mut self, replica: ReplicaId, by: u64) {
        self.neg.increment(replica, by);
    }

    pub fn value(&self) -> i64 {
        self.pos.value() as i64 - self.neg.value() as i64
    }
}

impl Crdt for PnCounter {
    fn merge(&mut self, other: &Self) {
        self.pos.merge(&other.pos);
        self.neg.merge(&other.neg);
    }
}

impl Message for PnCounter {
    fn encode_to(&self, w: &mut PbWriter) {
        w.message(1, &self.pos);
        w.message(2, &self.neg);
    }

    fn decode(buf: &[u8]) -> Result<PnCounter> {
        let mut c = PnCounter::new();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => c.pos = f.as_message()?,
                2 => c.neg = f.as_message()?,
                _ => {}
            }
            Ok(())
        })?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcounter_converges() {
        let mut a = GCounter::new();
        let mut b = GCounter::new();
        a.increment(1, 5);
        b.increment(2, 3);
        a.increment(1, 2);
        let mut a2 = a.clone();
        a2.merge(&b);
        let mut b2 = b.clone();
        b2.merge(&a);
        assert_eq!(a2, b2);
        assert_eq!(a2.value(), 10);
    }

    #[test]
    fn merge_idempotent_commutative_associative() {
        let mut rng = crate::util::Rng::new(8);
        let mk = |rng: &mut crate::util::Rng| {
            let mut c = GCounter::new();
            for _ in 0..5 {
                c.increment(rng.gen_range(4), rng.gen_range(10) + 1);
            }
            c
        };
        for _ in 0..50 {
            let (a, b, c) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            // idempotent
            let mut x = a.clone();
            x.merge(&a);
            assert_eq!(x, a);
            // commutative
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba);
            // associative
            let mut abc1 = ab.clone();
            abc1.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut abc2 = a.clone();
            abc2.merge(&bc);
            assert_eq!(abc1, abc2);
        }
    }

    #[test]
    fn pncounter_tracks_both_directions() {
        let mut a = PnCounter::new();
        a.increment(1, 10);
        a.decrement(1, 4);
        let mut b = PnCounter::new();
        b.decrement(2, 3);
        a.merge(&b);
        assert_eq!(a.value(), 3);
    }

    #[test]
    fn wire_roundtrip() {
        let mut c = PnCounter::new();
        c.increment(42, 7);
        c.decrement(9, 2);
        let dec = PnCounter::decode(&c.encode()).unwrap();
        assert_eq!(dec, c);
        assert_eq!(dec.value(), 5);
    }
}
