//! Model artifact distribution: parameter serialization, versioned
//! publication as CID-addressed chunks, gossip announcements and fetching.
//!
//! This is Fig. 1(3): the training cluster publishes each checkpoint as a
//! content-addressed blob; inference clusters hear the announcement on the
//! gossip topic, resolve providers, Bitswap the chunks and hot-swap.

use crate::content::{Cid, DagManifest, DEFAULT_CHUNK_SIZE};
use crate::netsim::Net;
use crate::node::LatticaNode;
use crate::runtime::{Manifest, Tensor};
use crate::util::varint;
use anyhow::{Context, Result};

/// Gossip topic for checkpoint announcements of a named model.
pub fn model_topic(name: &str) -> String {
    format!("/lattica/models/{name}")
}

/// Announcement payload: version + root CID.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelAnnouncement {
    pub name: String,
    pub version: u64,
    pub root: Cid,
}

impl ModelAnnouncement {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::put_length_prefixed(&mut out, self.name.as_bytes());
        varint::put_uvarint(&mut out, self.version);
        out.extend_from_slice(self.root.as_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ModelAnnouncement> {
        let mut r = varint::Reader::new(buf);
        let name = String::from_utf8(r.length_prefixed()?.to_vec())?;
        let version = r.uvarint()?;
        let root = Cid::from_bytes(r.take(32)?)?;
        Ok(ModelAnnouncement { name, version, root })
    }
}

/// Serialize a parameter list into one blob (count-prefixed tensors).
pub fn encode_params(params: &[Tensor]) -> Vec<u8> {
    let mut out = Vec::new();
    varint::put_uvarint(&mut out, params.len() as u64);
    for p in params {
        varint::put_length_prefixed(&mut out, &p.encode());
    }
    out
}

/// Decode a parameter blob, checking shapes against the manifest.
pub fn decode_params(manifest: &Manifest, blob: &[u8]) -> Result<Vec<Tensor>> {
    let mut r = varint::Reader::new(blob);
    let n = r.uvarint()? as usize;
    anyhow::ensure!(
        n == manifest.params.len(),
        "param count {n} != manifest {}",
        manifest.params.len()
    );
    let mut out = Vec::with_capacity(n);
    for spec in &manifest.params {
        let t = Tensor::decode(r.length_prefixed()?)
            .with_context(|| format!("decoding param {}", spec.name))?;
        anyhow::ensure!(
            t.shape == spec.shape,
            "param {} shape {:?} != manifest {:?}",
            spec.name,
            t.shape,
            spec.shape
        );
        out.push(t);
    }
    Ok(out)
}

/// Publish a checkpoint from a node: chunks + DHT provide + gossip announce.
/// Returns the root CID.
pub fn publish_checkpoint(
    node: &mut LatticaNode,
    net: &mut Net,
    name: &str,
    version: u64,
    params: &[Tensor],
) -> Cid {
    let blob = encode_params(params);
    let root = node.publish_blob(net, name, version, &blob, DEFAULT_CHUNK_SIZE);
    let ann = ModelAnnouncement {
        name: name.to_string(),
        version,
        root,
    };
    let topic = model_topic(name);
    let mut ctx = crate::protocols::Ctx::new(&mut node.swarm, net);
    node.gossip.publish(&mut ctx, &topic, ann.encode());
    root
}

/// Reassemble a fetched checkpoint into tensors.
pub fn load_checkpoint(
    node: &LatticaNode,
    manifest: &Manifest,
    root: &Cid,
) -> Result<Vec<Tensor>> {
    let dag = DagManifest::load(&node.blockstore, root)?;
    let blob = dag.assemble(&node.blockstore)?;
    decode_params(manifest, &blob)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::DType;

    #[test]
    fn announcement_roundtrip() {
        let a = ModelAnnouncement {
            name: "gpt-mini".into(),
            version: 12,
            root: Cid::of(b"manifest"),
        };
        assert_eq!(ModelAnnouncement::decode(&a.encode()).unwrap(), a);
    }

    #[test]
    fn params_roundtrip_without_manifest_check() {
        let params = vec![
            Tensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]),
            Tensor::from_f32(&[3], &[5.0, 6.0, 7.0]),
        ];
        let blob = encode_params(&params);
        // Manual decode (no manifest available in unit scope).
        let mut r = varint::Reader::new(&blob);
        assert_eq!(r.uvarint().unwrap(), 2);
        let t0 = Tensor::decode(r.length_prefixed().unwrap()).unwrap();
        assert_eq!(t0, params[0]);
        let t1 = Tensor::decode(r.length_prefixed().unwrap()).unwrap();
        assert_eq!(t1, params[1]);
        assert_eq!(t1.dtype, DType::F32);
    }
}
