//! Model artifact distribution: parameter serialization, versioned
//! publication as CID-addressed chunks, gossip announcements and fetching.
//!
//! This is Fig. 1(3): the training cluster publishes each checkpoint as a
//! content-addressed blob; inference clusters hear the announcement on the
//! gossip topic, resolve providers, Bitswap the chunks and hot-swap.

use crate::content::{Chunking, Cid, DagManifest, DeltaManifest, CDC_CHECKPOINT, DEFAULT_CHUNK_SIZE};
use crate::netsim::Net;
use crate::node::LatticaNode;
use crate::protocols::Ctx;
use crate::rpc::{Outcome, Service, Status};
use crate::runtime::{Manifest, Tensor};
use crate::util::varint;
use crate::wire::Message;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::rc::Rc;

/// Service name of the model-sync control plane.
pub const MODEL_SERVICE: &str = "model";

/// Gossip topic for checkpoint announcements of a named model.
pub fn model_topic(name: &str) -> String {
    format!("/lattica/models/{name}")
}

/// Delta availability advertised with a checkpoint: subscribers holding
/// `base_root` complete only need the delta manifest's `added` chunks.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeltaInfo {
    pub base_version: u64,
    /// Root of the base version's manifest.
    pub base_root: Cid,
    /// CID of the stored [`DeltaManifest`] block.
    pub delta_block: Cid,
}

/// Announcement payload: version + root CID (+ optional delta pointer).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelAnnouncement {
    pub name: String,
    pub version: u64,
    pub root: Cid,
    pub delta: Option<DeltaInfo>,
}

impl ModelAnnouncement {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::put_length_prefixed(&mut out, self.name.as_bytes());
        varint::put_uvarint(&mut out, self.version);
        out.extend_from_slice(self.root.as_bytes());
        match &self.delta {
            None => out.push(0),
            Some(d) => {
                out.push(1);
                varint::put_uvarint(&mut out, d.base_version);
                out.extend_from_slice(d.base_root.as_bytes());
                out.extend_from_slice(d.delta_block.as_bytes());
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ModelAnnouncement> {
        let mut r = varint::Reader::new(buf);
        let name = String::from_utf8(r.length_prefixed()?.to_vec())?;
        let version = r.uvarint()?;
        let root = Cid::from_bytes(r.take(32)?)?;
        // The delta flag is optional for compatibility with pre-delta
        // announcements (a missing byte means "no delta"), but a present
        // flag must be well-formed — corruption is an error, not a silent
        // fallback to full fetch.
        let delta = match r.take(1) {
            Err(_) => None,
            Ok(&[0]) => None,
            Ok(&[1]) => Some(DeltaInfo {
                base_version: r.uvarint()?,
                base_root: Cid::from_bytes(r.take(32)?)?,
                delta_block: Cid::from_bytes(r.take(32)?)?,
            }),
            Ok(b) => anyhow::bail!("bad delta flag {b:?}"),
        };
        Ok(ModelAnnouncement { name, version, root, delta })
    }
}

/// Serialize a parameter list into one blob (count-prefixed tensors).
pub fn encode_params(params: &[Tensor]) -> Vec<u8> {
    let mut out = Vec::new();
    varint::put_uvarint(&mut out, params.len() as u64);
    for p in params {
        varint::put_length_prefixed(&mut out, &p.encode());
    }
    out
}

/// Decode a parameter blob, checking shapes against the manifest.
pub fn decode_params(manifest: &Manifest, blob: &[u8]) -> Result<Vec<Tensor>> {
    let mut r = varint::Reader::new(blob);
    let n = r.uvarint()? as usize;
    anyhow::ensure!(
        n == manifest.params.len(),
        "param count {n} != manifest {}",
        manifest.params.len()
    );
    let mut out = Vec::with_capacity(n);
    for spec in &manifest.params {
        let t = Tensor::decode(r.length_prefixed()?)
            .with_context(|| format!("decoding param {}", spec.name))?;
        anyhow::ensure!(
            t.shape == spec.shape,
            "param {} shape {:?} != manifest {:?}",
            spec.name,
            t.shape,
            spec.shape
        );
        out.push(t);
    }
    Ok(out)
}

/// Versioned checkpoint publisher: CDC-chunks each checkpoint so
/// unchanged chunks keep their CIDs across versions, stores a
/// [`DeltaManifest`] naming exactly what changed, and gossips an
/// announcement carrying both the full root and the delta pointer.
/// Subscribers that retained version v's chunks automatically fetch only
/// the delta for v+1 (content addressing makes the reuse implicit; the
/// delta manifest makes it checkable).
pub struct CheckpointPublisher {
    pub name: String,
    pub chunking: Chunking,
    /// Last published (version, root) — the delta base.
    last: Option<(u64, Cid)>,
    /// Last announcement gossiped, re-served over the control service so
    /// replicas that missed the gossip can pull it.
    pub last_announcement: Option<ModelAnnouncement>,
}

impl CheckpointPublisher {
    pub fn new(name: &str) -> CheckpointPublisher {
        CheckpointPublisher {
            name: name.to_string(),
            chunking: Chunking::Cdc(CDC_CHECKPOINT),
            last: None,
            last_announcement: None,
        }
    }

    pub fn with_chunking(name: &str, chunking: Chunking) -> CheckpointPublisher {
        CheckpointPublisher {
            chunking,
            ..CheckpointPublisher::new(name)
        }
    }

    /// Publish one checkpoint blob: chunk + store + DHT provide + delta
    /// manifest + gossip announce. Returns (root, announcement).
    pub fn publish_blob(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        version: u64,
        blob: &[u8],
    ) -> (Cid, ModelAnnouncement) {
        let root = node.publish_blob_chunked(net, &self.name, version, blob, self.chunking);
        let delta = self.last.and_then(|(base_version, base_root)| {
            let base = DagManifest::load(&node.blockstore, &base_root).ok()?;
            let next = DagManifest::load(&node.blockstore, &root).ok()?;
            let d = DeltaManifest::diff(&base, base_root, &next, root, &node.blockstore);
            let delta_block = node.blockstore.put(d.encode());
            node.bitswap.choke_exempt.insert(delta_block);
            Some(DeltaInfo {
                base_version,
                base_root,
                delta_block,
            })
        });
        self.last = Some((version, root));
        let ann = ModelAnnouncement {
            name: self.name.clone(),
            version,
            root,
            delta,
        };
        self.last_announcement = Some(ann.clone());
        let topic = model_topic(&self.name);
        let mut ctx = Ctx::new(&mut node.swarm, net);
        node.gossip.publish(&mut ctx, &topic, ann.encode());
        (root, ann)
    }

    /// Expose the model-sync control path as a registered [`Service`].
    ///
    /// Gossip is the push path for checkpoint announcements; this is the
    /// pull path: `latest` (payload = model name, or empty for "whatever
    /// this publisher serves") returns the most recent
    /// [`ModelAnnouncement`], so a replica that joined after the gossip
    /// burst — or whose subscription lapsed — can catch up with one unary
    /// call through a [`crate::rpc::Stub`] instead of waiting for the
    /// next version.
    pub fn service(publisher: Rc<RefCell<CheckpointPublisher>>) -> Service {
        Service::new(MODEL_SERVICE).unary("latest", move |_node, _net, _ctx, payload| {
            let p = publisher.borrow();
            let want = String::from_utf8_lossy(&payload);
            if !payload.is_empty() && want != p.name {
                return Outcome::fail(
                    Status::NotFound,
                    format!("this publisher serves {:?}, not {want:?}", p.name),
                );
            }
            match &p.last_announcement {
                Some(ann) => Outcome::reply(ann.encode()),
                None => Outcome::fail(
                    Status::Unavailable,
                    format!("no checkpoint of {:?} published yet", p.name),
                ),
            }
        })
    }

    /// [`CheckpointPublisher::publish_blob`] over a tensor parameter list.
    pub fn publish_params(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        version: u64,
        params: &[Tensor],
    ) -> (Cid, ModelAnnouncement) {
        let blob = encode_params(params);
        self.publish_blob(node, net, version, &blob)
    }
}

/// Publish a checkpoint from a node: chunks + DHT provide + gossip announce.
/// Returns the root CID. One-shot (no delta base); long-lived trainers
/// should hold a [`CheckpointPublisher`] instead.
pub fn publish_checkpoint(
    node: &mut LatticaNode,
    net: &mut Net,
    name: &str,
    version: u64,
    params: &[Tensor],
) -> Cid {
    let mut p =
        CheckpointPublisher::with_chunking(name, Chunking::Fixed(DEFAULT_CHUNK_SIZE));
    p.publish_params(node, net, version, params).0
}

/// Reassemble a fetched checkpoint into tensors.
pub fn load_checkpoint(
    node: &LatticaNode,
    manifest: &Manifest,
    root: &Cid,
) -> Result<Vec<Tensor>> {
    let dag = DagManifest::load(&node.blockstore, root)?;
    let blob = dag.assemble(&node.blockstore)?;
    decode_params(manifest, &blob)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::DType;

    #[test]
    fn announcement_roundtrip() {
        let a = ModelAnnouncement {
            name: "gpt-mini".into(),
            version: 12,
            root: Cid::of(b"manifest"),
            delta: None,
        };
        assert_eq!(ModelAnnouncement::decode(&a.encode()).unwrap(), a);
        let with_delta = ModelAnnouncement {
            delta: Some(DeltaInfo {
                base_version: 11,
                base_root: Cid::of(b"base"),
                delta_block: Cid::of(b"delta"),
            }),
            ..a
        };
        assert_eq!(
            ModelAnnouncement::decode(&with_delta.encode()).unwrap(),
            with_delta
        );
        // Pre-delta encodings (no flag byte) still decode.
        let mut legacy = Vec::new();
        varint::put_length_prefixed(&mut legacy, b"m");
        varint::put_uvarint(&mut legacy, 3);
        legacy.extend_from_slice(Cid::of(b"r").as_bytes());
        let d = ModelAnnouncement::decode(&legacy).unwrap();
        assert_eq!(d.version, 3);
        assert!(d.delta.is_none());
    }

    #[test]
    fn params_roundtrip_without_manifest_check() {
        let params = vec![
            Tensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]),
            Tensor::from_f32(&[3], &[5.0, 6.0, 7.0]),
        ];
        let blob = encode_params(&params);
        // Manual decode (no manifest available in unit scope).
        let mut r = varint::Reader::new(&blob);
        assert_eq!(r.uvarint().unwrap(), 2);
        let t0 = Tensor::decode(r.length_prefixed().unwrap()).unwrap();
        assert_eq!(t0, params[0]);
        let t1 = Tensor::decode(r.length_prefixed().unwrap()).unwrap();
        assert_eq!(t1, params[1]);
        assert_eq!(t1.dtype, DType::F32);
    }
}
