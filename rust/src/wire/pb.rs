//! Protobuf wire-format encoder/decoder.
//!
//! Two hot-path mechanisms keep encode/decode allocation-free:
//!
//! * a thread-local pool of encode buffers ([`PbWriter::pooled`] /
//!   [`encode_pooled`]) so steady-state message encoding reuses capacity
//!   instead of allocating a fresh `Vec` per message, and
//! * offset-carrying decode ([`Field::data_start`] + [`Message::decode_buf`])
//!   so length-delimited fields can be returned as zero-copy [`Buf`] slices
//!   of the receive buffer instead of `to_vec()` copies.

use crate::util::buf::Buf;
use crate::util::varint;
use anyhow::{bail, Result};
use std::cell::RefCell;

/// Protobuf wire types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireType {
    Varint = 0,
    Fixed64 = 1,
    Len = 2,
    Fixed32 = 5,
}

impl WireType {
    fn from_u8(v: u8) -> Result<WireType> {
        Ok(match v {
            0 => WireType::Varint,
            1 => WireType::Fixed64,
            2 => WireType::Len,
            5 => WireType::Fixed32,
            _ => bail!("unsupported wire type {v}"),
        })
    }
}

/// Streaming encoder. Fields must be written in any order; callers use
/// ascending field numbers by convention (canonical form for digests).
#[derive(Default)]
pub struct PbWriter {
    pub buf: Vec<u8>,
}

/// Thread-local pool of encode buffers. Buffers enter via
/// [`PbWriter::recycle`] and are reused by [`PbWriter::pooled`]; capacity is
/// bounded so one huge message cannot pin memory forever.
const POOL_MAX_BUFFERS: usize = 16;
const POOL_MAX_CAPACITY: usize = 1 << 20;

thread_local! {
    static ENCODE_POOL: RefCell<Vec<Vec<u8>>> = RefCell::new(Vec::new());
}

fn pool_take() -> Vec<u8> {
    ENCODE_POOL.with(|p| p.borrow_mut().pop()).unwrap_or_default()
}

fn pool_put(buf: Vec<u8>) {
    if buf.capacity() == 0 || buf.capacity() > POOL_MAX_CAPACITY {
        return;
    }
    ENCODE_POOL.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < POOL_MAX_BUFFERS {
            p.push(buf);
        }
    });
}

/// Encode `m` into a pooled buffer, hand the bytes to `f`, then return the
/// buffer to the pool. Steady-state cost: zero allocations. The bytes are
/// only valid inside `f`; callers that need to keep them must copy (or
/// encode into an owned [`Buf`] instead).
pub fn encode_pooled<M: Message, R>(m: &M, f: impl FnOnce(&[u8]) -> R) -> R {
    let mut w = PbWriter::pooled();
    m.encode_to(&mut w);
    let r = f(&w.buf);
    w.recycle();
    r
}

impl PbWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reuse an existing buffer (hot-path allocation avoidance).
    pub fn with_buf(mut buf: Vec<u8>) -> Self {
        buf.clear();
        PbWriter { buf }
    }

    /// Writer backed by a recycled thread-local buffer; pair with
    /// [`PbWriter::recycle`] (or use [`encode_pooled`]).
    pub fn pooled() -> Self {
        PbWriter::with_buf(pool_take())
    }

    /// Return this writer's buffer to the thread-local pool.
    pub fn recycle(self) {
        pool_put(self.buf);
    }

    #[inline]
    fn tag(&mut self, field: u32, wt: WireType) {
        varint::put_uvarint(&mut self.buf, ((field as u64) << 3) | wt as u64);
    }

    /// `uint64` / `uint32` / `bool` / enum field. Zero is skipped (proto3 default).
    #[inline]
    pub fn uint(&mut self, field: u32, v: u64) {
        if v != 0 {
            self.tag(field, WireType::Varint);
            varint::put_uvarint(&mut self.buf, v);
        }
    }

    /// Like [`uint`] but always emitted, even when zero.
    #[inline]
    pub fn uint_always(&mut self, field: u32, v: u64) {
        self.tag(field, WireType::Varint);
        varint::put_uvarint(&mut self.buf, v);
    }

    /// `sint64` (zigzag).
    #[inline]
    pub fn sint(&mut self, field: u32, v: i64) {
        if v != 0 {
            self.tag(field, WireType::Varint);
            varint::put_uvarint(&mut self.buf, varint::zigzag_encode(v));
        }
    }

    /// `bool`.
    #[inline]
    pub fn boolean(&mut self, field: u32, v: bool) {
        self.uint(field, v as u64);
    }

    /// `bytes` / `string`. Empty is skipped.
    #[inline]
    pub fn bytes(&mut self, field: u32, v: &[u8]) {
        if !v.is_empty() {
            self.tag(field, WireType::Len);
            varint::put_length_prefixed(&mut self.buf, v);
        }
    }

    /// Like [`bytes`] but always emitted, even when empty.
    #[inline]
    pub fn bytes_always(&mut self, field: u32, v: &[u8]) {
        self.tag(field, WireType::Len);
        varint::put_length_prefixed(&mut self.buf, v);
    }

    /// `string`.
    #[inline]
    pub fn string(&mut self, field: u32, v: &str) {
        self.bytes(field, v.as_bytes());
    }

    /// `double`.
    #[inline]
    pub fn double(&mut self, field: u32, v: f64) {
        if v != 0.0 {
            self.tag(field, WireType::Fixed64);
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// `fixed32`.
    #[inline]
    pub fn fixed32(&mut self, field: u32, v: u32) {
        if v != 0 {
            self.tag(field, WireType::Fixed32);
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Embedded message.
    pub fn message<M: Message>(&mut self, field: u32, m: &M) {
        let inner = m.encode();
        self.tag(field, WireType::Len);
        varint::put_length_prefixed(&mut self.buf, &inner);
    }

    /// Repeated embedded messages.
    pub fn messages<M: Message>(&mut self, field: u32, ms: &[M]) {
        for m in ms {
            self.message(field, m);
        }
    }

    /// Repeated bytes/strings.
    pub fn bytes_list<T: AsRef<[u8]>>(&mut self, field: u32, vs: &[T]) {
        for v in vs {
            self.tag(field, WireType::Len);
            varint::put_length_prefixed(&mut self.buf, v.as_ref());
        }
    }

    /// Packed repeated uint64.
    pub fn packed_uints(&mut self, field: u32, vs: &[u64]) {
        if vs.is_empty() {
            return;
        }
        let mut tmp = Vec::with_capacity(vs.len() * 2);
        for &v in vs {
            varint::put_uvarint(&mut tmp, v);
        }
        self.tag(field, WireType::Len);
        varint::put_length_prefixed(&mut self.buf, &tmp);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// One decoded field.
pub struct Field<'a> {
    pub number: u32,
    pub wire_type: WireType,
    pub varint: u64,
    pub data: &'a [u8],
    /// Byte offset of `data` within the buffer the reader was built over.
    /// Lets [`Message::decode_buf`] implementations turn length-delimited
    /// fields into zero-copy [`Buf`] slices: `buf.slice(f.data_start..f.data_start + f.data.len())`.
    pub data_start: usize,
}

impl<'a> Field<'a> {
    pub fn as_u64(&self) -> u64 {
        self.varint
    }

    pub fn as_u32(&self) -> u32 {
        self.varint as u32
    }

    pub fn as_bool(&self) -> bool {
        self.varint != 0
    }

    pub fn as_sint(&self) -> i64 {
        varint::zigzag_decode(self.varint)
    }

    pub fn as_bytes(&self) -> Result<&'a [u8]> {
        if self.wire_type != WireType::Len {
            bail!("field {} is not length-delimited", self.number);
        }
        Ok(self.data)
    }

    pub fn as_string(&self) -> Result<String> {
        Ok(std::str::from_utf8(self.as_bytes()?)?.to_string())
    }

    pub fn as_double(&self) -> Result<f64> {
        if self.wire_type != WireType::Fixed64 {
            bail!("field {} is not fixed64", self.number);
        }
        Ok(f64::from_le_bytes(self.data.try_into()?))
    }

    pub fn as_message<M: Message>(&self) -> Result<M> {
        M::decode(self.as_bytes()?)
    }

    pub fn packed_uints(&self) -> Result<Vec<u64>> {
        let mut r = varint::Reader::new(self.as_bytes()?);
        let mut out = Vec::new();
        while !r.is_empty() {
            out.push(r.uvarint()?);
        }
        Ok(out)
    }
}

/// Field-iterating decoder.
pub struct PbReader<'a> {
    r: varint::Reader<'a>,
}

impl<'a> PbReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        PbReader {
            r: varint::Reader::new(buf),
        }
    }

    /// Next field, or None at end.
    pub fn next_field(&mut self) -> Result<Option<Field<'a>>> {
        if self.r.is_empty() {
            return Ok(None);
        }
        let key = self.r.uvarint()?;
        let number = (key >> 3) as u32;
        if number == 0 {
            bail!("field number 0 is invalid");
        }
        let wire_type = WireType::from_u8((key & 7) as u8)?;
        let (varint_val, data): (u64, &[u8]) = match wire_type {
            WireType::Varint => (self.r.uvarint()?, &[]),
            WireType::Fixed64 => {
                let d = self.r.take(8)?;
                (u64::from_le_bytes(d.try_into()?), d)
            }
            WireType::Fixed32 => {
                let d = self.r.take(4)?;
                (u32::from_le_bytes(d.try_into()?) as u64, d)
            }
            WireType::Len => {
                let d = self.r.length_prefixed()?;
                (0, d)
            }
        };
        Ok(Some(Field {
            number,
            wire_type,
            varint: varint_val,
            data,
            data_start: self.r.pos - data.len(),
        }))
    }

    /// Drive a closure over every field.
    pub fn for_each(mut self, mut f: impl FnMut(Field<'a>) -> Result<()>) -> Result<()> {
        while let Some(field) = self.next_field()? {
            f(field)?;
        }
        Ok(())
    }
}

/// A protobuf-style message.
pub trait Message: Sized {
    fn encode_to(&self, w: &mut PbWriter);

    fn decode(buf: &[u8]) -> Result<Self>;

    /// Decode from a shared buffer. The default delegates to [`decode`];
    /// messages with large payload fields override this to keep those
    /// fields as zero-copy slices of `buf` (see `RpcMsg`, `BitswapMsg`,
    /// `Frame`).
    ///
    /// [`decode`]: Message::decode
    fn decode_buf(buf: &Buf) -> Result<Self> {
        Self::decode(buf.as_slice())
    }

    fn encode(&self) -> Vec<u8> {
        let mut w = PbWriter::new();
        self.encode_to(&mut w);
        w.finish()
    }

    /// Encode into an owned shared buffer (for zero-copy send paths that
    /// hold onto the encoded bytes).
    fn encode_buf(&self) -> Buf {
        Buf::from_vec(self.encode())
    }

    /// Encode with a varint length prefix (stream framing).
    fn encode_framed(&self) -> Vec<u8> {
        let body = self.encode();
        let mut out = Vec::with_capacity(body.len() + 5);
        varint::put_length_prefixed(&mut out, &body);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Default, PartialEq, Clone)]
    struct Inner {
        id: u64,
        tag: String,
    }

    impl Message for Inner {
        fn encode_to(&self, w: &mut PbWriter) {
            w.uint(1, self.id);
            w.string(2, &self.tag);
        }

        fn decode(buf: &[u8]) -> Result<Self> {
            let mut m = Inner::default();
            PbReader::new(buf).for_each(|f| {
                match f.number {
                    1 => m.id = f.as_u64(),
                    2 => m.tag = f.as_string()?,
                    _ => {}
                }
                Ok(())
            })?;
            Ok(m)
        }
    }

    #[derive(Debug, Default, PartialEq)]
    struct Outer {
        kind: u64,
        neg: i64,
        flag: bool,
        payload: Vec<u8>,
        score: f64,
        inners: Vec<Inner>,
        ids: Vec<u64>,
        names: Vec<String>,
    }

    impl Message for Outer {
        fn encode_to(&self, w: &mut PbWriter) {
            w.uint(1, self.kind);
            w.sint(2, self.neg);
            w.boolean(3, self.flag);
            w.bytes(4, &self.payload);
            w.double(5, self.score);
            w.messages(6, &self.inners);
            w.packed_uints(7, &self.ids);
            w.bytes_list(8, &self.names);
        }

        fn decode(buf: &[u8]) -> Result<Self> {
            let mut m = Outer::default();
            PbReader::new(buf).for_each(|f| {
                match f.number {
                    1 => m.kind = f.as_u64(),
                    2 => m.neg = f.as_sint(),
                    3 => m.flag = f.as_bool(),
                    4 => m.payload = f.as_bytes()?.to_vec(),
                    5 => m.score = f.as_double()?,
                    6 => m.inners.push(f.as_message()?),
                    7 => m.ids = f.packed_uints()?,
                    8 => m.names.push(f.as_string()?),
                    _ => {}
                }
                Ok(())
            })?;
            Ok(m)
        }
    }

    #[test]
    fn roundtrip_full() {
        let m = Outer {
            kind: 7,
            neg: -12345,
            flag: true,
            payload: vec![1, 2, 3, 0, 255],
            score: 0.25,
            inners: vec![
                Inner { id: 1, tag: "a".into() },
                Inner { id: 2, tag: "b".into() },
            ],
            ids: vec![0, 1, 300, u64::MAX],
            names: vec!["x".into(), "yz".into()],
        };
        let enc = m.encode();
        assert_eq!(Outer::decode(&enc).unwrap(), m);
    }

    #[test]
    fn defaults_encode_empty() {
        let m = Outer::default();
        assert!(m.encode().is_empty());
        assert_eq!(Outer::decode(&[]).unwrap(), m);
    }

    #[test]
    fn unknown_fields_skipped() {
        // Encode with extra field 99, decode as Inner.
        let mut w = PbWriter::new();
        w.uint(1, 5);
        w.string(99, "future");
        w.double(98, 1.5);
        w.string(2, "t");
        let m = Inner::decode(&w.finish()).unwrap();
        assert_eq!(m, Inner { id: 5, tag: "t".into() });
    }

    #[test]
    fn wire_compat_manual_bytes() {
        // field 1 varint 150 == 08 96 01 (canonical protobuf example)
        let mut w = PbWriter::new();
        w.uint(1, 150);
        assert_eq!(w.finish(), vec![0x08, 0x96, 0x01]);
        // field 2 string "testing" == 12 07 74 65 73 74 69 6e 67
        let mut w = PbWriter::new();
        w.string(2, "testing");
        assert_eq!(
            w.finish(),
            vec![0x12, 0x07, 0x74, 0x65, 0x73, 0x74, 0x69, 0x6e, 0x67]
        );
    }

    #[test]
    fn truncated_message_fails() {
        let m = Inner { id: 300, tag: "hello".into() };
        let enc = m.encode();
        assert!(Inner::decode(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn framed_roundtrip() {
        let m = Inner { id: 9, tag: "fr".into() };
        let framed = m.encode_framed();
        let mut r = varint::Reader::new(&framed);
        let body = r.length_prefixed().unwrap();
        assert_eq!(Inner::decode(body).unwrap(), m);
    }

    #[test]
    fn pooled_encoding_matches_and_reuses() {
        let m = Inner { id: 300, tag: "pooled".into() };
        let plain = m.encode();
        let pooled = encode_pooled(&m, |b| b.to_vec());
        assert_eq!(plain, pooled);
        // Second pooled encode reuses the recycled buffer (behavioral check:
        // output identical; capacity reuse is observable via no growth).
        let again = encode_pooled(&m, |b| b.to_vec());
        assert_eq!(plain, again);
        assert_eq!(m.encode_buf(), plain);
    }

    #[test]
    fn field_data_start_locates_payload() {
        let mut w = PbWriter::new();
        w.uint(1, 7);
        w.bytes(4, b"payload-bytes");
        let enc = w.finish();
        let buf = Buf::from_vec(enc);
        let mut r = PbReader::new(buf.as_slice());
        let mut found = false;
        while let Some(f) = r.next_field().unwrap() {
            if f.number == 4 {
                let z = buf.slice(f.data_start..f.data_start + f.data.len());
                assert_eq!(z, b"payload-bytes");
                assert_eq!(z.as_slice(), f.data);
                found = true;
            }
        }
        assert!(found);
    }

    #[test]
    fn decode_buf_default_matches_decode() {
        let m = Inner { id: 12, tag: "x".into() };
        let buf = m.encode_buf();
        assert_eq!(Inner::decode_buf(&buf).unwrap(), m);
    }

    #[test]
    fn wrong_wire_type_rejected() {
        let mut w = PbWriter::new();
        w.uint(4, 1); // field 4 expected Len in Outer::payload accessor
        let buf = w.finish();
        let mut r = PbReader::new(&buf);
        let f = r.next_field().unwrap().unwrap();
        assert!(f.as_bytes().is_err());
    }
}
