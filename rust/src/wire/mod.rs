//! Protobuf wire format (proto3 subset), hand-rolled.
//!
//! The paper specifies a "Protobuf-based RPC mechanism" (§2); with no codegen
//! available offline we implement the wire format directly: varint (type 0),
//! 64-bit (type 1), length-delimited (type 2) and 32-bit (type 5) fields.
//! Message structs throughout the codebase implement [`Message`] with
//! hand-written field mappings, which keeps the on-wire cost model identical
//! to real protobuf.
//!
//! Hot paths encode through the thread-local buffer pool ([`encode_pooled`])
//! and decode payload-bearing fields as zero-copy [`crate::util::Buf`]
//! slices via [`Message::decode_buf`].

pub mod pb;
pub mod ranges;

pub use pb::{encode_pooled, Message, PbReader, PbWriter, WireType};
pub use ranges::{BloomDigest, RangeSet, BLOOM_BYTES};
