//! Compact set encodings for the control plane.
//!
//! [`RangeSet`] is a run-length codec over sorted `u64` index sets:
//! alternating gap/run varints walking upward from zero — the same shape
//! as the QUIC-style alternating run/gap encoding in
//! `transport::Frame.ack_ranges`, but anchored at the low end so dense
//! prefixes (the common "I want chunks 0..n" case) collapse to a few
//! bytes. [`BloomDigest`] is a fixed 32-byte bloom filter for unordered
//! id sets where exact membership is not required (gossip IHAVE
//! advertisements).
//!
//! Both encodings are deliberately self-delimiting-free: they are always
//! carried inside a length-delimited protobuf field, so decode consumes
//! the whole buffer.

use crate::util::rng::mix64;
use crate::util::varint::{get_uvarint, put_uvarint, uvarint_len};
use anyhow::{bail, Result};

/// A set of `u64` values stored as sorted, merged, inclusive ranges.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RangeSet {
    /// Inclusive `(start, end)` ranges, ascending, gap ≥ 2 between them.
    ranges: Vec<(u64, u64)>,
}

impl RangeSet {
    pub fn new() -> RangeSet {
        RangeSet::default()
    }

    /// Insert one value, merging adjacent/overlapping ranges.
    pub fn insert(&mut self, v: u64) {
        let pos = self
            .ranges
            .partition_point(|&(_, e)| e.saturating_add(1) < v);
        if pos < self.ranges.len() {
            let (s, e) = self.ranges[pos];
            if v >= s && v <= e {
                return; // already present
            }
            if v.checked_add(1) == Some(s) {
                self.ranges[pos].0 = v;
                return; // gap to the previous range was ≥ 2, no merge
            }
            if e.checked_add(1) == Some(v) {
                self.ranges[pos].1 = v;
                // May now touch the following range.
                if pos + 1 < self.ranges.len() && self.ranges[pos + 1].0.saturating_sub(1) <= v {
                    self.ranges[pos].1 = self.ranges[pos + 1].1;
                    self.ranges.remove(pos + 1);
                }
                return;
            }
        }
        self.ranges.insert(pos, (v, v));
    }

    pub fn contains(&self, v: u64) -> bool {
        let pos = self.ranges.partition_point(|&(_, e)| e < v);
        self.ranges.get(pos).is_some_and(|&(s, _)| v >= s)
    }

    /// Number of values in the set (saturating).
    pub fn len(&self) -> u64 {
        self.ranges
            .iter()
            .fold(0u64, |n, &(s, e)| n.saturating_add(e - s + 1))
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    pub fn ranges(&self) -> &[(u64, u64)] {
        &self.ranges
    }

    /// Iterate the values in ascending order. Callers must bound the
    /// set first (a hostile 3-byte encoding can describe 2^64 values).
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.ranges.iter().flat_map(|&(s, e)| s..=e)
    }

    /// Encoded size in bytes (exact, without encoding).
    pub fn encoded_len(&self) -> usize {
        let mut cursor = 0u64;
        let mut n = 0usize;
        for &(s, e) in &self.ranges {
            n += uvarint_len(s - cursor) + uvarint_len(e - s);
            cursor = e.saturating_add(2);
        }
        n
    }

    /// Encode as alternating gap/run varints from a cursor starting at
    /// zero: per range, `gap = start - cursor` then `run = end - start`;
    /// the cursor then advances to `end + 2` (merged ranges are ≥ 2
    /// apart, so gaps never go negative). The empty set encodes to zero
    /// bytes.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let mut cursor = 0u64;
        for &(s, e) in &self.ranges {
            put_uvarint(out, s - cursor);
            put_uvarint(out, e - s);
            cursor = e.saturating_add(2);
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }

    /// Decode a full buffer of alternating gap/run varints. Rejects
    /// truncated varints, odd trailing values and overflowing ranges.
    /// Allocation is bounded by the input: every range costs ≥ 2 bytes.
    pub fn decode(buf: &[u8]) -> Result<RangeSet> {
        let mut ranges = Vec::with_capacity(buf.len() / 2);
        let mut rest = buf;
        let mut cursor = 0u64;
        while !rest.is_empty() {
            let (gap, n) = get_uvarint(rest)?;
            rest = &rest[n..];
            if rest.is_empty() {
                bail!("range set: gap without run");
            }
            let (run, n) = get_uvarint(rest)?;
            rest = &rest[n..];
            let Some(start) = cursor.checked_add(gap) else {
                bail!("range set: start overflows");
            };
            let Some(end) = start.checked_add(run) else {
                bail!("range set: end overflows");
            };
            ranges.push((start, end));
            cursor = end.saturating_add(2);
            if cursor <= end {
                // end + 2 wrapped: nothing further can be encoded.
                if !rest.is_empty() {
                    bail!("range set: values past u64::MAX");
                }
            }
        }
        Ok(RangeSet { ranges })
    }
}

impl FromIterator<u64> for RangeSet {
    fn from_iter<T: IntoIterator<Item = u64>>(iter: T) -> RangeSet {
        let mut vals: Vec<u64> = iter.into_iter().collect();
        vals.sort_unstable();
        let mut set = RangeSet::new();
        for v in vals {
            // Sorted input always extends the tail: O(n) total.
            set.insert(v);
        }
        set
    }
}

/// Fixed-size bloom filter over opaque byte ids (256 bits, 3 hashes).
/// At the gossip history-window sizes it digests (≤ ~32 ids) the false
/// positive rate stays under ~0.2%; false positives only cost a missed
/// lazy pull, never correctness (IHAVE ids are re-advertised).
pub const BLOOM_BYTES: usize = 32;

#[derive(Clone, Copy, PartialEq, Eq)]
pub struct BloomDigest {
    bits: [u8; BLOOM_BYTES],
}

impl Default for BloomDigest {
    fn default() -> Self {
        BloomDigest::new()
    }
}

impl std::fmt::Debug for BloomDigest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BloomDigest({} bits set)", self.popcount())
    }
}

impl BloomDigest {
    pub fn new() -> BloomDigest {
        BloomDigest { bits: [0; BLOOM_BYTES] }
    }

    fn hash(id: &[u8]) -> u64 {
        let mut h = 0x9E37_79B9_7F4A_7C15u64 ^ (id.len() as u64);
        for chunk in id.chunks(8) {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            h = mix64(h ^ u64::from_le_bytes(w));
        }
        h
    }

    fn bit_positions(id: &[u8]) -> [usize; 3] {
        let h = Self::hash(id);
        let mut out = [0usize; 3];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = (mix64(h.wrapping_add(i as u64)) % (BLOOM_BYTES as u64 * 8)) as usize;
        }
        out
    }

    pub fn insert(&mut self, id: &[u8]) {
        for bit in Self::bit_positions(id) {
            self.bits[bit / 8] |= 1 << (bit % 8);
        }
    }

    pub fn contains(&self, id: &[u8]) -> bool {
        Self::bit_positions(id)
            .iter()
            .all(|&bit| self.bits[bit / 8] & (1 << (bit % 8)) != 0)
    }

    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&b| b == 0)
    }

    fn popcount(&self) -> u32 {
        self.bits.iter().map(|b| b.count_ones()).sum()
    }

    pub fn as_bytes(&self) -> &[u8; BLOOM_BYTES] {
        &self.bits
    }

    /// Strict decode: exactly [`BLOOM_BYTES`] bytes.
    pub fn from_bytes(buf: &[u8]) -> Result<BloomDigest> {
        if buf.len() != BLOOM_BYTES {
            bail!("bloom digest must be {BLOOM_BYTES} bytes, got {}", buf.len());
        }
        let mut bits = [0u8; BLOOM_BYTES];
        bits.copy_from_slice(buf);
        Ok(BloomDigest { bits })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set_of(vals: &[u64]) -> RangeSet {
        vals.iter().copied().collect()
    }

    #[test]
    fn insert_merges_and_contains() {
        let mut s = RangeSet::new();
        for v in [5, 3, 4, 10, 11, 9, 1] {
            s.insert(v);
        }
        assert_eq!(s.ranges(), &[(1, 1), (3, 5), (9, 11)]);
        assert_eq!(s.len(), 7);
        for v in [1, 3, 4, 5, 9, 10, 11] {
            assert!(s.contains(v), "missing {v}");
        }
        for v in [0, 2, 6, 8, 12, u64::MAX] {
            assert!(!s.contains(v), "phantom {v}");
        }
        s.insert(2); // bridges (1,1) and (3,5)
        assert_eq!(s.ranges(), &[(1, 5), (9, 11)]);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![1, 2, 3, 4, 5, 9, 10, 11]);
    }

    #[test]
    fn roundtrip_edge_shapes() {
        for s in [
            RangeSet::new(),
            set_of(&[0]),
            set_of(&[u64::MAX]),
            set_of(&[0, u64::MAX]),
            set_of(&[7, 8, 9, 100, 200, 201]),
            (0..10_000).collect::<RangeSet>(),
        ] {
            let enc = s.encode();
            assert_eq!(enc.len(), s.encoded_len());
            assert_eq!(RangeSet::decode(&enc).unwrap(), s, "roundtrip failed");
        }
        assert!(RangeSet::new().encode().is_empty());
    }

    /// The wire-size pin from the issue: 10k dense indexes in ≤ 64 bytes
    /// (the codec does it in 3: gap 0, run 9999).
    #[test]
    fn wire_size_pins() {
        let dense: RangeSet = (0..10_000u64).collect();
        assert_eq!(dense.encode().len(), 3);
        assert!(dense.encode().len() <= 64);

        // 10k indexes with every 100th missing: 100 ranges, 3 B each.
        let holes: RangeSet = (0..10_000u64).filter(|v| v % 100 != 99).collect();
        assert_eq!(holes.ranges().len(), 100);
        assert!(holes.encode().len() <= 300, "got {}", holes.encode().len());

        // Worst case — fully sparse alternating — still ~2 B per value
        // vs 32 B per CID.
        let sparse: RangeSet = (0..1_000u64).map(|v| v * 2).collect();
        assert!(sparse.encode().len() <= 2 * 1_000);
    }

    #[test]
    fn decode_rejects_hostile_input() {
        // Truncated varint.
        assert!(RangeSet::decode(&[0x80]).is_err());
        // Gap without run.
        assert!(RangeSet::decode(&[0x05]).is_err());
        // Start overflow: gap = u64::MAX after a first range.
        let mut evil = set_of(&[1]).encode();
        evil.extend_from_slice(&[0xFF; 9]);
        evil.push(0x01); // 10-byte varint ≈ u64::MAX
        evil.push(0x00);
        assert!(RangeSet::decode(&evil).is_err());
        // Trailing data after a range ending at u64::MAX.
        let mut evil = set_of(&[u64::MAX]).encode();
        evil.extend_from_slice(&[0x00, 0x00]);
        assert!(RangeSet::decode(&evil).is_err());
    }

    #[test]
    fn bloom_no_false_negatives_and_bounded_fp() {
        let mut b = BloomDigest::new();
        let ids: Vec<Vec<u8>> = (0u64..32).map(|i| i.to_le_bytes().to_vec()).collect();
        for id in &ids {
            b.insert(id);
        }
        for id in &ids {
            assert!(b.contains(id), "false negative");
        }
        let fps = (1000u64..11_000)
            .filter(|i| b.contains(&i.to_le_bytes()))
            .count();
        // 32 entries / 256 bits / k=3 → expected fp ≈ 0.2%; allow 10x.
        assert!(fps < 200, "false positive rate too high: {fps}/10000");
        assert_eq!(BloomDigest::from_bytes(b.as_bytes()).unwrap(), b);
        assert!(BloomDigest::from_bytes(&[0u8; 31]).is_err());
        assert!(BloomDigest::new().is_empty());
        assert!(!b.is_empty());
    }
}
