//! `artifacts/manifest.json` parsing: model config, parameter layout and
//! artifact signatures emitted by `python/compile/aot.py`.

use crate::runtime::tensor::DType;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_layer: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub path: PathBuf,
    pub inputs: Vec<InputSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub params: Vec<ParamSpec>,
    pub n_layer_params: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text)?;
        let cfg = j.req("config")?;
        let get = |k: &str| -> Result<usize> {
            Ok(cfg.req(k)?.as_u64().context("not a number")? as usize)
        };
        let config = ModelConfig {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_head: get("n_head")?,
            n_layer: get("n_layer")?,
            d_ff: get("d_ff")?,
            seq_len: get("seq_len")?,
            batch: get("batch")?,
        };
        let mut params = Vec::new();
        for p in j.req("params")?.as_arr().context("params not array")? {
            params.push(ParamSpec {
                name: p.req("name")?.as_str().context("name")?.to_string(),
                shape: p
                    .req("shape")?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|v| v.as_u64().unwrap_or(0) as usize)
                    .collect(),
            });
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let mut inputs = Vec::new();
            for i in a.req("inputs")?.as_arr().context("inputs")? {
                inputs.push(InputSpec {
                    shape: i
                        .req("shape")?
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|v| v.as_u64().unwrap_or(0) as usize)
                        .collect(),
                    dtype: DType::parse(i.req("dtype")?.as_str().context("dtype")?)?,
                });
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    path: dir.join(a.req("path")?.as_str().context("path")?),
                    inputs,
                },
            );
        }
        Ok(Manifest {
            dir,
            config,
            params,
            n_layer_params: j.req("n_layer_params")?.as_u64().context("nlp")? as usize,
            artifacts,
        })
    }

    /// Total parameter element count.
    pub fn param_elements(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Flat index range of layer `i`'s parameters.
    pub fn layer_param_range(&self, layer: usize) -> (usize, usize) {
        let start = 2 + layer * self.n_layer_params;
        (start, start + self.n_layer_params)
    }

    /// Load the initial parameters written by aot.py as tensors.
    pub fn load_init_params(&self) -> Result<Vec<crate::runtime::Tensor>> {
        let blob = std::fs::read(self.dir.join("init_params.bin"))
            .context("reading init_params.bin")?;
        let mut out = Vec::with_capacity(self.params.len());
        let mut pos = 0usize;
        for p in &self.params {
            let bytes = p.len() * 4;
            anyhow::ensure!(pos + bytes <= blob.len(), "init_params.bin truncated");
            out.push(crate::runtime::Tensor {
                dtype: DType::F32,
                shape: p.shape.clone(),
                data: blob[pos..pos + bytes].to_vec(),
            });
            pos += bytes;
        }
        anyhow::ensure!(pos == blob.len(), "init_params.bin has trailing bytes");
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.config.n_layer >= 1);
        assert_eq!(m.params.len(), 2 + m.config.n_layer * m.n_layer_params + 3);
        assert_eq!(m.params[0].name, "wte");
        assert_eq!(m.params[0].shape, vec![m.config.vocab, m.config.d_model]);
        for name in ["embed", "layer_fwd", "logits", "train_step", "eval_loss"] {
            let a = m.artifacts.get(name).expect(name);
            assert!(a.path.exists(), "{:?} missing", a.path);
        }
        // train_step signature: 3 * params + step + batch.
        let ts = &m.artifacts["train_step"];
        assert_eq!(ts.inputs.len(), 3 * m.params.len() + 2);
        // Initial params blob parses and matches shapes.
        let init = m.load_init_params().unwrap();
        assert_eq!(init.len(), m.params.len());
        assert_eq!(init[0].shape, m.params[0].shape);
    }
}
