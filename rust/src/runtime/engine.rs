//! The execution engine: a PJRT CPU client plus compiled artifacts.
//!
//! Each artifact is compiled once at load; `run` feeds tensors and returns
//! the output tuple as tensors. Execution is synchronous; callers on the
//! simulated event loop account its wall-clock cost as virtual service
//! time (see `shard`/`trainer`).

use super::manifest::Manifest;
use super::pjrt;
use super::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::HashMap;

pub struct Engine {
    pub manifest: Manifest,
    client: pjrt::PjRtClient,
    executables: HashMap<String, pjrt::PjRtLoadedExecutable>,
    /// Cumulative wall-clock spent executing, per artifact (profiling).
    pub exec_nanos: HashMap<String, u64>,
    pub exec_counts: HashMap<String, u64>,
}

impl Engine {
    /// Load every artifact in the manifest directory and compile it.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client =
            pjrt::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt client: {e:?}"))?;
        let mut executables = HashMap::new();
        for (name, spec) in &manifest.artifacts {
            let proto = pjrt::HloModuleProto::from_text_file(
                spec.path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {name}: {e:?}"))?;
            let comp = pjrt::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Engine {
            manifest,
            client,
            executables,
            exec_nanos: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute an artifact. Inputs must match the manifest signature.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?;
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(spec.inputs.iter()).enumerate() {
            anyhow::ensure!(
                t.shape == s.shape && t.dtype == s.dtype,
                "{name}: input {i} mismatch: got {:?}/{:?}, want {:?}/{:?}",
                t.shape,
                t.dtype,
                s.shape,
                s.dtype
            );
        }
        let exe = self.executables.get(name).unwrap();
        let start = std::time::Instant::now();
        let lits: Vec<pjrt::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<pjrt::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name}: {e:?}"))?;
        let outs: Vec<Tensor> = parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<_>>()?;
        let dt = start.elapsed().as_nanos() as u64;
        *self.exec_nanos.entry(name.to_string()).or_default() += dt;
        *self.exec_counts.entry(name.to_string()).or_default() += 1;
        Ok(outs)
    }

    /// Mean execution wall time for an artifact, if measured.
    pub fn mean_exec_nanos(&self, name: &str) -> Option<u64> {
        let total = *self.exec_nanos.get(name)?;
        let count = *self.exec_counts.get(name)?;
        (count > 0).then(|| total / count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::DType;

    fn engine() -> Option<Engine> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::load(dir).expect("engine load"))
    }

    #[test]
    fn loads_and_reports_platform() {
        let Some(e) = engine() else { return };
        assert_eq!(e.platform(), "cpu");
        assert!(e.has("train_step") && e.has("layer_fwd"));
    }

    #[test]
    fn embed_layer_logits_pipeline_runs() {
        let Some(mut e) = engine() else { return };
        let cfg = e.manifest.config.clone();
        let params = e.manifest.load_init_params().unwrap();

        let tokens: Vec<i32> = (0..cfg.seq_len as i32).map(|i| i % cfg.vocab as i32).collect();
        let tok = Tensor::from_i32(&[1, cfg.seq_len], &tokens);
        let out = e
            .run("embed", &[tok, params[0].clone(), params[1].clone()])
            .unwrap();
        assert_eq!(out.len(), 1);
        let mut hidden = out.into_iter().next().unwrap();
        assert_eq!(hidden.shape, vec![1, cfg.seq_len, cfg.d_model]);

        for layer in 0..cfg.n_layer {
            let (a, b) = e.manifest.layer_param_range(layer);
            let mut inputs = vec![hidden.clone()];
            inputs.extend(params[a..b].iter().cloned());
            hidden = e.run("layer_fwd", &inputs).unwrap().into_iter().next().unwrap();
        }
        let n = params.len();
        let out = e
            .run(
                "logits",
                &[
                    hidden,
                    params[n - 3].clone(),
                    params[n - 2].clone(),
                    params[n - 1].clone(),
                ],
            )
            .unwrap();
        let logits = &out[0];
        assert_eq!(logits.shape, vec![1, cfg.vocab]);
        let vals = logits.as_f32().unwrap();
        assert!(vals.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn train_step_decreases_loss() {
        let Some(mut e) = engine() else { return };
        let cfg = e.manifest.config.clone();
        let mut params = e.manifest.load_init_params().unwrap();
        let mut m: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::zeros(DType::F32, &p.shape))
            .collect();
        let mut v = m.clone();
        let mut step = Tensor::scalar_i32(0);
        let n = params.len();

        let mut rng = crate::util::Rng::new(99);
        let mut first = None;
        let mut last = 0.0f32;
        for _ in 0..12 {
            // Synthetic arithmetic-sequence batch (same task as the paper
            // driver): x[t] = (start + delta*t) mod vocab.
            let mut batch = Vec::with_capacity(cfg.batch * (cfg.seq_len + 1));
            for _ in 0..cfg.batch {
                let start = rng.gen_range(cfg.vocab as u64) as i32;
                let delta = 1 + rng.gen_range(4) as i32;
                for t in 0..=cfg.seq_len as i32 {
                    batch.push((start + delta * t).rem_euclid(cfg.vocab as i32));
                }
            }
            let batch_t = Tensor::from_i32(&[cfg.batch, cfg.seq_len + 1], &batch);
            let mut inputs = Vec::with_capacity(3 * n + 2);
            inputs.extend(params.iter().cloned());
            inputs.extend(m.iter().cloned());
            inputs.extend(v.iter().cloned());
            inputs.push(step.clone());
            inputs.push(batch_t);
            let outs = e.run("train_step", &inputs).unwrap();
            assert_eq!(outs.len(), 3 * n + 2);
            params = outs[..n].to_vec();
            m = outs[n..2 * n].to_vec();
            v = outs[2 * n..3 * n].to_vec();
            step = outs[3 * n].clone();
            let loss = outs[3 * n + 1].as_f32().unwrap()[0];
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
        }
        assert_eq!(step.as_i32().unwrap()[0], 12);
        assert!(
            last < first.unwrap(),
            "loss did not decrease: {} → {}",
            first.unwrap(),
            last
        );
        assert!(e.mean_exec_nanos("train_step").unwrap() > 0);
    }
}
