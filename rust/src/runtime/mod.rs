//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only bridge between the Rust request path and the Python
//! build path: `make artifacts` lowers the L2 JAX model (with its L1 Pallas
//! kernels) to `artifacts/*.hlo.txt`, and [`Engine`] compiles each once on
//! the PJRT CPU client. Python never runs at request time.
//!
//! [`Tensor`] is the in-network representation of array data (it is what
//! travels inside content blocks and RPC messages); conversions to/from
//! [`pjrt::Literal`] happen only at the execution boundary. The `pjrt`
//! module is a host-side facade: literals are fully functional, while
//! compile/execute report unavailability until an XLA runtime is vendored.

pub mod pjrt;
pub mod tensor;
pub mod manifest;
pub mod engine;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest};
pub use tensor::{DType, Tensor};
