//! Network-portable tensors: shape + dtype + little-endian bytes.
//!
//! The serialized form is what model publication chunks into CID-addressed
//! blocks and what RPC streams carry between inference shards:
//!
//! ```text
//! [dtype: u8][rank: varint][dims: varint*...][data: raw little-endian]
//! ```

use super::pjrt;
use crate::util::varint;
use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 1,
    I32 = 2,
}

impl DType {
    pub fn size(&self) -> usize {
        4
    }

    fn from_u8(v: u8) -> Result<DType> {
        Ok(match v {
            1 => DType::F32,
            2 => DType::I32,
            _ => bail!("unknown dtype {v}"),
        })
    }

    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" | "f32" => DType::F32,
            "int32" | "i32" => DType::I32,
            _ => bail!("unsupported dtype {s:?}"),
        })
    }
}

/// A dense tensor in host memory.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    /// Raw little-endian element bytes.
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            dtype,
            shape: shape.to_vec(),
            data: vec![0u8; n * dtype.size()],
        }
    }

    pub fn from_f32(shape: &[usize], values: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor {
            dtype: DType::F32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_i32(shape: &[usize], values: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor {
            dtype: DType::I32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::from_i32(&[], &[v])
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::from_f32(&[], &[v])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        anyhow::ensure!(self.dtype == DType::F32, "tensor is not f32");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        anyhow::ensure!(self.dtype == DType::I32, "tensor is not i32");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Serialize for transport/storage.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() + 16);
        out.push(self.dtype as u8);
        varint::put_uvarint(&mut out, self.shape.len() as u64);
        for &d in &self.shape {
            varint::put_uvarint(&mut out, d as u64);
        }
        out.extend_from_slice(&self.data);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Tensor> {
        let mut r = varint::Reader::new(buf);
        let dt = DType::from_u8(*buf.first().context("empty tensor buffer")?)?;
        r.pos = 1;
        let rank = r.uvarint()? as usize;
        anyhow::ensure!(rank <= 8, "rank {rank} too large");
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.uvarint()? as usize);
        }
        let n: usize = shape.iter().product();
        let data = r.take(n * dt.size())?.to_vec();
        anyhow::ensure!(r.is_empty(), "trailing bytes in tensor");
        Ok(Tensor {
            dtype: dt,
            shape,
            data,
        })
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> Result<pjrt::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match self.dtype {
            DType::F32 => {
                let v = self.as_f32()?;
                pjrt::Literal::vec1(&v)
            }
            DType::I32 => {
                let v = self.as_i32()?;
                pjrt::Literal::vec1(&v)
            }
        };
        lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    /// Convert from an XLA literal.
    pub fn from_literal(lit: &pjrt::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            pjrt::ElementType::F32 => {
                let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                Ok(Tensor::from_f32(&dims, &v))
            }
            pjrt::ElementType::S32 => {
                let v: Vec<i32> = lit.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                Ok(Tensor::from_i32(&dims, &v))
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.5]);
        let enc = t.encode();
        assert_eq!(Tensor::decode(&enc).unwrap(), t);
        let t = Tensor::from_i32(&[4], &[-1, 0, 7, i32::MAX]);
        assert_eq!(Tensor::decode(&t.encode()).unwrap(), t);
        let t = Tensor::scalar_f32(3.25);
        assert_eq!(Tensor::decode(&t.encode()).unwrap(), t);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Tensor::decode(&[]).is_err());
        assert!(Tensor::decode(&[9, 1, 4]).is_err()); // bad dtype
        let t = Tensor::from_f32(&[4], &[0.0; 4]);
        let enc = t.encode();
        assert!(Tensor::decode(&enc[..enc.len() - 1]).is_err()); // truncated
        let mut extra = enc.clone();
        extra.push(0);
        assert!(Tensor::decode(&extra).is_err()); // trailing
    }

    #[test]
    fn accessors() {
        let t = Tensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.byte_len(), 16);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], &[1.0, -2.0, 3.5, 0.0, 9.0, -0.25]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
        let t = Tensor::from_i32(&[1, 4], &[5, 6, 7, 8]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }
}
