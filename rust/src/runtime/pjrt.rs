//! Host-side PJRT facade.
//!
//! The offline build has no XLA/PJRT runtime, so this module provides the
//! same API shape the engine codes against: [`Literal`] is a fully
//! functional host tensor container (used by [`super::tensor::Tensor`] for
//! conversions), while compilation/execution entry points return a runtime
//! error. Artifacts are absent in this environment, so `Engine::load` fails
//! cleanly before any execution is attempted; when a real PJRT backend is
//! vendored it can replace this module without touching the engine.

use std::fmt;

/// Error type mirroring the PJRT binding's debug-printable errors.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PjRtError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime not available in this offline build"
    )))
}

/// Element types the engine exchanges with the runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F64,
    S64,
    U8,
    Pred,
}

/// Marker for element types storable in a [`Literal`].
pub trait Element: Copy {
    const TY: ElementType;
    fn write_le(&self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

impl Element for f32 {
    const TY: ElementType = ElementType::F32;
    fn write_le(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> f32 {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl Element for i32 {
    const TY: ElementType = ElementType::S32;
    fn write_le(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> i32 {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// Array shape: element type + dimensions.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host tensor (dense, little-endian 4-byte elements).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: Element>(values: &[T]) -> Literal {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            v.write_le(&mut data);
        }
        Literal {
            ty: T::TY,
            dims: vec![values.len() as i64],
            data,
        }
    }

    /// Reshape without changing the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        let have: i64 = self.dims.iter().product();
        if want != have {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count {} != {}",
                self.dims, dims, have, want
            )));
        }
        Ok(Literal {
            ty: self.ty,
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape {
            ty: self.ty,
            dims: self.dims.clone(),
        })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self.data.chunks_exact(4).map(T::read_le).collect())
    }

    /// Unpack a tuple literal (stub: execution never produces one offline).
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable("to_tuple")
    }
}

/// A compiled-module handle (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation wrapping an HLO module (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// The PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_container_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, -2.5, 3.0, 0.0, 9.0, 4.5]);
        let lit = lit.reshape(&[2, 3]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.0, 0.0, 9.0, 4.5]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[5]).is_err());
    }

    #[test]
    fn execution_unavailable_offline() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(client.compile(&XlaComputation).is_err());
    }
}
