//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via splitmix64 — the standard construction for
//! reproducible simulation. Every stochastic decision in the simulator
//! (latency jitter, loss, NAT port allocation, peer sampling) draws from an
//! explicitly threaded [`Rng`] so experiment runs are exactly replayable.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One-shot splitmix64 step: a cheap, well-mixed pure hash of a u64
/// (used for deterministic tie-breaking, e.g. the Bitswap scheduler).
#[inline]
pub(crate) fn mix64(x: u64) -> u64 {
    let mut state = x;
    splitmix64(&mut state)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-node streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless method.
        let mut m = (self.next_u64() as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine here).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = (self.gen_f64()).max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with mean `mean`.
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.gen_f64()).max(1e-300).ln()
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Random byte vector of length `n`.
    pub fn gen_bytes(&mut self, n: usize) -> Vec<u8> {
        let mut v = vec![0u8; n];
        self.fill_bytes(&mut v);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly (None if empty).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.gen_index(xs.len())])
        }
    }

    /// Weighted index sample; weights must be non-negative, not all zero.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn range_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_index(10)] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn bool_probability_sane() {
        let mut r = Rng::new(5);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.3)).count();
        let p = hits as f64 / 100_000.0;
        assert!((p - 0.3).abs() < 0.02, "p = {p}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.05, "var = {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn fill_bytes_various_lengths() {
        let mut r = Rng::new(31);
        for n in [0usize, 1, 7, 8, 9, 16, 31] {
            let v = r.gen_bytes(n);
            assert_eq!(v.len(), n);
        }
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(41);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.choose_weighted(&[0.1, 0.0, 0.9])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
