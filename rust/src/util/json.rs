//! Minimal JSON parser/writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json` emitted by
//! `python/compile/aot.py`), node configuration files and metrics reports.
//! Supports the full JSON grammar except for exotic number forms beyond f64.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Required object field, with a useful error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing field {key:?}"))
    }

    /// Convenience constructors.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.pos)
            .copied()
            .context("unexpected end of JSON")
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                self.b[self.pos] as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.pos..self.pos + 4)
                                    .context("truncated \\u escape")?,
                            )?;
                            self.pos += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            // Surrogate pairs: handle the high half.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.pos) == Some(&b'\\')
                                    && self.b.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        self.b
                                            .get(self.pos + 2..self.pos + 6)
                                            .context("truncated surrogate")?,
                                    )?;
                                    self.pos += 6;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).context("bad surrogate pair")?
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                char::from_u32(cp).context("bad codepoint")?
                            };
                            out.push(ch);
                        }
                        _ => bail!("bad escape {:?}", e as char),
                    }
                }
                c if c < 0x20 => bail!("raw control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => bail!("invalid UTF-8 lead byte"),
                        };
                        let end = start + width;
                        let s = std::str::from_utf8(
                            self.b.get(start..end).context("truncated UTF-8")?,
                        )?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self
            .b
            .get(self.pos)
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().context("bad number")?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' found {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' found {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"name":"shard_fwd","shapes":[[4,128,256],[256]],"dtype":"f32","n":3,"ok":true,"x":null}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\"\\ A 😀");
        // Writer escapes control chars and re-parses.
        let s = Json::Str("line1\nline2\u{1}".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str().unwrap(), "line1\nline2\u{1}");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo wörld ≈\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ≈");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("42 garbage").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integers_print_exactly() {
        assert_eq!(Json::Num(10000.0).to_string(), "10000");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
