//! Hex encoding/decoding for CIDs, PeerIds and debug output.

use anyhow::{bail, Result};

const TABLE: &[u8; 16] = b"0123456789abcdef";

/// Encode bytes to lowercase hex.
pub fn encode(data: &[u8]) -> String {
    let mut s = String::with_capacity(data.len() * 2);
    for &b in data {
        s.push(TABLE[(b >> 4) as usize] as char);
        s.push(TABLE[(b & 0xf) as usize] as char);
    }
    s
}

/// Short prefix for display (`deadbeef…`).
pub fn encode_prefix(data: &[u8], n: usize) -> String {
    let full = encode(data);
    if full.len() > n {
        format!("{}..", &full[..n])
    } else {
        full
    }
}

fn nibble(c: u8) -> Result<u8> {
    match c {
        b'0'..=b'9' => Ok(c - b'0'),
        b'a'..=b'f' => Ok(c - b'a' + 10),
        b'A'..=b'F' => Ok(c - b'A' + 10),
        _ => bail!("invalid hex character {:?}", c as char),
    }
}

/// Decode a hex string (case-insensitive, even length).
pub fn decode(s: &str) -> Result<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        bail!("odd hex length {}", b.len());
    }
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((nibble(pair[0])? << 4) | nibble(pair[1])?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data = [0x00, 0x01, 0x7f, 0x80, 0xff, 0xde, 0xad];
        let s = encode(&data);
        assert_eq!(s, "00017f80ffdead");
        assert_eq!(decode(&s).unwrap(), data);
    }

    #[test]
    fn uppercase_accepted() {
        assert_eq!(decode("DEADBEEF").unwrap(), [0xde, 0xad, 0xbe, 0xef]);
    }

    #[test]
    fn invalid_rejected() {
        assert!(decode("0g").is_err());
        assert!(decode("abc").is_err());
    }

    #[test]
    fn empty_ok() {
        assert_eq!(encode(&[]), "");
        assert_eq!(decode("").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn prefix_display() {
        assert_eq!(encode_prefix(&[0xde, 0xad, 0xbe, 0xef], 4), "dead..");
        assert_eq!(encode_prefix(&[0xde], 4), "de");
    }
}
