//! Tiny argument parser for the `lattica` binary, examples and benches.
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of arguments.
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map_or(false, |n| !n.starts_with("--"))
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} must be an integer, got {s:?}")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.opt_u64(name, default as u64)? as usize)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} must be a number, got {s:?}")),
        }
    }

    /// First positional arg (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn require_subcommand(&self, usage: &str) -> Result<&str> {
        match self.subcommand() {
            Some(s) => Ok(s),
            None => bail!("missing subcommand\nusage: {usage}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("node extra --seed 42 --role=trainer --verbose");
        assert_eq!(a.subcommand(), Some("node"));
        assert_eq!(a.opt("seed"), Some("42"));
        assert_eq!(a.opt("role"), Some("trainer"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["node", "extra"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--n 100 --p 0.5");
        assert_eq!(a.opt_u64("n", 1).unwrap(), 100);
        assert_eq!(a.opt_f64("p", 0.0).unwrap(), 0.5);
        assert_eq!(a.opt_u64("missing", 7).unwrap(), 7);
        assert!(parse("--n abc").opt_u64("n", 1).is_err());
    }

    #[test]
    fn flag_before_flag() {
        let a = parse("--a --b");
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    fn option_consumes_next_nonflag() {
        let a = parse("--out file.txt --quiet");
        assert_eq!(a.opt("out"), Some("file.txt"));
        assert!(a.flag("quiet"));
    }
}
