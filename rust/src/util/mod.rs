//! Small self-contained utilities shared by every layer.
//!
//! The offline build environment provides no `rand`, `serde`, `clap` or
//! `criterion`, so this module carries from-scratch equivalents: a fast
//! seedable RNG, varint/hex/json codecs, an argument parser and a logger.

pub mod rng;
pub mod varint;
pub mod hex;
pub mod json;
pub mod cli;
pub mod logging;
pub mod buf;
pub mod bytes;
pub mod timefmt;

pub use buf::Buf;
pub use rng::Rng;
