//! LEB128 unsigned varints — the integer encoding used by the Protobuf wire
//! format (`wire`), multiaddr/multihash framing (`multiaddr`, `content`) and
//! length-prefixed stream messages.

use anyhow::{bail, Result};

/// Append `v` as an unsigned LEB128 varint.
#[inline]
pub fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Encoded size in bytes of `v`.
#[inline]
pub fn uvarint_len(v: u64) -> usize {
    if v == 0 {
        1
    } else {
        (64 - v.leading_zeros() as usize + 6) / 7
    }
}

/// Decode a varint from the front of `buf`, returning `(value, bytes_read)`.
#[inline]
pub fn get_uvarint(buf: &[u8]) -> Result<(u64, usize)> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    for (i, &b) in buf.iter().enumerate() {
        if shift >= 64 {
            bail!("varint overflows u64");
        }
        // Reject bits that would be shifted out of range.
        if shift == 63 && (b & 0x7e) != 0 {
            bail!("varint overflows u64");
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            if i >= 10 {
                bail!("varint too long");
            }
            return Ok((v, i + 1));
        }
        shift += 7;
    }
    bail!("varint truncated ({} bytes)", buf.len());
}

/// ZigZag encoding for signed integers (Protobuf `sint64`).
#[inline]
pub fn zigzag_encode(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// ZigZag decoding.
#[inline]
pub fn zigzag_decode(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// A cursor for reading varint-framed data.
pub struct Reader<'a> {
    pub buf: &'a [u8],
    pub pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    pub fn uvarint(&mut self) -> Result<u64> {
        let (v, n) = get_uvarint(&self.buf[self.pos..])?;
        self.pos += n;
        Ok(v)
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("short read: want {n}, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a varint length prefix then that many bytes.
    pub fn length_prefixed(&mut self) -> Result<&'a [u8]> {
        let n = self.uvarint()? as usize;
        self.take(n)
    }
}

/// Append a varint length prefix followed by `data`.
pub fn put_length_prefixed(out: &mut Vec<u8>, data: &[u8]) {
    put_uvarint(out, data.len() as u64);
    out.extend_from_slice(data);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exhaustive_boundaries() {
        let cases = [
            0u64,
            1,
            127,
            128,
            129,
            16_383,
            16_384,
            u32::MAX as u64,
            u64::MAX - 1,
            u64::MAX,
        ];
        for &v in &cases {
            let mut buf = Vec::new();
            put_uvarint(&mut buf, v);
            assert_eq!(buf.len(), uvarint_len(v), "len mismatch for {v}");
            let (got, n) = get_uvarint(&buf).unwrap();
            assert_eq!(got, v);
            assert_eq!(n, buf.len());
        }
    }

    #[test]
    fn roundtrip_random() {
        let mut r = crate::util::Rng::new(17);
        for _ in 0..10_000 {
            let shift = r.gen_range(64) as u32;
            let v = r.next_u64() >> shift;
            let mut buf = Vec::new();
            put_uvarint(&mut buf, v);
            let (got, _) = get_uvarint(&buf).unwrap();
            assert_eq!(got, v);
        }
    }

    #[test]
    fn truncated_fails() {
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 300);
        assert!(get_uvarint(&buf[..1]).is_err());
        assert!(get_uvarint(&[]).is_err());
    }

    #[test]
    fn overlong_fails() {
        // 11 continuation bytes is always invalid for u64.
        let buf = [0x80u8; 11];
        assert!(get_uvarint(&buf).is_err());
        // Value with bit 64+ set.
        let buf = [0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x7f];
        assert!(get_uvarint(&buf).is_err());
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [-1i64, 0, 1, -64, 63, i64::MIN, i64::MAX, -123_456_789] {
            assert_eq!(zigzag_decode(zigzag_encode(v)), v);
        }
        assert_eq!(zigzag_encode(0), 0);
        assert_eq!(zigzag_encode(-1), 1);
        assert_eq!(zigzag_encode(1), 2);
    }

    #[test]
    fn reader_length_prefixed() {
        let mut buf = Vec::new();
        put_length_prefixed(&mut buf, b"hello");
        put_length_prefixed(&mut buf, b"");
        put_length_prefixed(&mut buf, b"world!");
        let mut r = Reader::new(&buf);
        assert_eq!(r.length_prefixed().unwrap(), b"hello");
        assert_eq!(r.length_prefixed().unwrap(), b"");
        assert_eq!(r.length_prefixed().unwrap(), b"world!");
        assert!(r.is_empty());
    }

    #[test]
    fn reader_short_read_fails() {
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 100);
        buf.extend_from_slice(&[0u8; 10]);
        let mut r = Reader::new(&buf);
        assert!(r.length_prefixed().is_err());
    }
}
