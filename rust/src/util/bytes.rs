//! Byte-buffer helpers: big-endian integer read/write used by framing
//! layers (mux, transport, rpc).

use anyhow::{bail, Result};

pub fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_be_bytes());
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_be_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_be_bytes());
}

pub fn get_u16(buf: &[u8], pos: usize) -> Result<u16> {
    match buf.get(pos..pos + 2) {
        Some(s) => Ok(u16::from_be_bytes([s[0], s[1]])),
        None => bail!("short buffer reading u16 at {pos}"),
    }
}

pub fn get_u32(buf: &[u8], pos: usize) -> Result<u32> {
    match buf.get(pos..pos + 4) {
        Some(s) => Ok(u32::from_be_bytes([s[0], s[1], s[2], s[3]])),
        None => bail!("short buffer reading u32 at {pos}"),
    }
}

pub fn get_u64(buf: &[u8], pos: usize) -> Result<u64> {
    match buf.get(pos..pos + 8) {
        Some(s) => Ok(u64::from_be_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ])),
        None => bail!("short buffer reading u64 at {pos}"),
    }
}

/// Constant-time equality (for MAC verification).
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc = 0u8;
    for (x, y) in a.iter().zip(b.iter()) {
        acc |= x ^ y;
    }
    acc == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips() {
        let mut b = Vec::new();
        put_u16(&mut b, 0xBEEF);
        put_u32(&mut b, 0xDEADBEEF);
        put_u64(&mut b, 0x0123456789ABCDEF);
        assert_eq!(get_u16(&b, 0).unwrap(), 0xBEEF);
        assert_eq!(get_u32(&b, 2).unwrap(), 0xDEADBEEF);
        assert_eq!(get_u64(&b, 6).unwrap(), 0x0123456789ABCDEF);
    }

    #[test]
    fn short_reads_fail() {
        assert!(get_u32(&[1, 2, 3], 0).is_err());
        assert!(get_u16(&[1, 2], 1).is_err());
    }

    #[test]
    fn ct_eq_works() {
        assert!(ct_eq(b"abc", b"abc"));
        assert!(!ct_eq(b"abc", b"abd"));
        assert!(!ct_eq(b"abc", b"ab"));
        assert!(ct_eq(b"", b""));
    }
}
