//! `Buf`: a cheaply cloneable, sliceable, immutable byte buffer — the unit
//! of payload ownership on the data path (an `Arc`-backed `bytes::Bytes`
//! analogue with no external dependency).
//!
//! Every layer that moves payload bytes (wire decode, stream reassembly,
//! RPC events, Bitswap block serving) hands out `Buf` slices instead of
//! copying sub-ranges into fresh `Vec`s: a clone or slice is a reference
//! count bump plus two integers. See DESIGN.md §Buffer ownership for the
//! layer-by-layer contract.

use std::borrow::Borrow;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::ops::{Bound, Deref, RangeBounds};
use std::sync::{Arc, OnceLock};

/// Shared, immutable view into reference-counted bytes.
#[derive(Clone)]
pub struct Buf {
    data: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

fn shared_empty() -> Arc<Vec<u8>> {
    static EMPTY: OnceLock<Arc<Vec<u8>>> = OnceLock::new();
    EMPTY.get_or_init(|| Arc::new(Vec::new())).clone()
}

impl Buf {
    /// The empty buffer (no allocation; a shared static).
    pub fn new() -> Buf {
        Buf {
            data: shared_empty(),
            off: 0,
            len: 0,
        }
    }

    /// Take ownership of a `Vec` without copying its contents.
    pub fn from_vec(v: Vec<u8>) -> Buf {
        let len = v.len();
        Buf {
            data: Arc::new(v),
            off: 0,
            len,
        }
    }

    /// Copy a slice into a new buffer (the one copy at an ownership
    /// boundary; everything downstream is zero-copy).
    pub fn copy_from_slice(s: &[u8]) -> Buf {
        Buf::from_vec(s.to_vec())
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data[self.off..self.off + self.len]
    }

    /// Zero-copy sub-view: bumps the reference count, never copies.
    ///
    /// Panics if the range is out of bounds (mirroring slice indexing).
    pub fn slice(&self, range: impl RangeBounds<usize>) -> Buf {
        let start = match range.start_bound() {
            Bound::Included(&n) => n,
            Bound::Excluded(&n) => n + 1,
            Bound::Unbounded => 0,
        };
        let end = match range.end_bound() {
            Bound::Included(&n) => n + 1,
            Bound::Excluded(&n) => n,
            Bound::Unbounded => self.len,
        };
        assert!(
            start <= end && end <= self.len,
            "slice {start}..{end} out of range for Buf of len {}",
            self.len
        );
        Buf {
            data: self.data.clone(),
            off: self.off + start,
            len: end - start,
        }
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }

    /// Recover the backing `Vec` without copying when this view covers the
    /// whole allocation and holds the only reference; copies otherwise.
    pub fn into_vec(self) -> Vec<u8> {
        if self.off == 0 && self.len == self.data.len() {
            match Arc::try_unwrap(self.data) {
                Ok(v) => v,
                Err(arc) => arc[..].to_vec(),
            }
        } else {
            self.as_slice().to_vec()
        }
    }

    /// Number of live references to the backing allocation (diagnostics).
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.data)
    }

    /// Whether this view holds the only reference to the backing allocation
    /// (in-place mutation via [`Buf::make_mut`] is then possible).
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.data) == 1
    }

    /// Mutable access to this view's bytes, available only when the backing
    /// allocation is uniquely owned (the in-place AEAD decrypt path).
    pub fn make_mut(&mut self) -> Option<&mut [u8]> {
        let (off, len) = (self.off, self.len);
        Arc::get_mut(&mut self.data).map(move |v| &mut v[off..off + len])
    }

    /// Shrink this view to its first `len` bytes in place.
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate {len} beyond Buf of len {}", self.len);
        self.len = len;
    }
}

impl Default for Buf {
    fn default() -> Buf {
        Buf::new()
    }
}

impl Deref for Buf {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Buf {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl Borrow<[u8]> for Buf {
    fn borrow(&self) -> &[u8] {
        self.as_slice()
    }
}

impl fmt::Debug for Buf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Buf({} B: ", self.len)?;
        for (i, b) in self.as_slice().iter().take(16).enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{b:02x}")?;
        }
        if self.len > 16 {
            write!(f, " …")?;
        }
        write!(f, ")")
    }
}

impl From<Vec<u8>> for Buf {
    fn from(v: Vec<u8>) -> Buf {
        Buf::from_vec(v)
    }
}

impl From<&[u8]> for Buf {
    fn from(s: &[u8]) -> Buf {
        Buf::copy_from_slice(s)
    }
}

impl From<&Vec<u8>> for Buf {
    fn from(v: &Vec<u8>) -> Buf {
        Buf::copy_from_slice(v)
    }
}

impl<const N: usize> From<&[u8; N]> for Buf {
    fn from(a: &[u8; N]) -> Buf {
        Buf::copy_from_slice(a)
    }
}

impl PartialEq for Buf {
    fn eq(&self, other: &Buf) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Buf {}

impl Hash for Buf {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.as_slice().hash(state);
    }
}

impl PartialEq<[u8]> for Buf {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for Buf {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<u8>> for Buf {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Buf> for Vec<u8> {
    fn eq(&self, other: &Buf) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<const N: usize> PartialEq<[u8; N]> for Buf {
    fn eq(&self, other: &[u8; N]) -> bool {
        self.as_slice() == other
    }
}

impl<const N: usize> PartialEq<&[u8; N]> for Buf {
    fn eq(&self, other: &&[u8; N]) -> bool {
        self.as_slice() == *other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_default() {
        let b = Buf::new();
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        assert_eq!(b.as_slice(), b"");
        assert_eq!(Buf::default(), b);
    }

    #[test]
    fn from_vec_and_slice() {
        let b = Buf::from_vec(vec![1, 2, 3]);
        assert_eq!(b, [1u8, 2, 3]);
        let c: Buf = (&[4u8, 5][..]).into();
        assert_eq!(c.to_vec(), vec![4, 5]);
        let d: Buf = b"xy".into();
        assert_eq!(d, b"xy");
    }

    #[test]
    fn slicing_is_zero_copy() {
        let b = Buf::from_vec((0..100u8).collect());
        let s = b.slice(10..20);
        assert_eq!(s.len(), 10);
        assert_eq!(s[0], 10);
        assert_eq!(b.ref_count(), 2, "slice shares the allocation");
        let s2 = s.slice(5..);
        assert_eq!(s2.as_slice(), &[15, 16, 17, 18, 19]);
        assert_eq!(b.ref_count(), 3);
        drop((s, s2));
        assert_eq!(b.ref_count(), 1);
    }

    #[test]
    fn slice_bounds() {
        let b = Buf::from_vec(vec![1, 2, 3]);
        assert_eq!(b.slice(..), b);
        assert_eq!(b.slice(3..).len(), 0);
        assert_eq!(b.slice(..=1), [1u8, 2]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn slice_oob_panics() {
        Buf::from_vec(vec![1]).slice(..2);
    }

    #[test]
    fn into_vec_reclaims_unique_allocation() {
        let v = vec![7u8; 32];
        let ptr = v.as_ptr();
        let b = Buf::from_vec(v);
        let back = b.into_vec();
        assert_eq!(back.as_ptr(), ptr, "unique full view moves, not copies");
        // A shared view copies (the original stays intact).
        let b = Buf::from_vec(back);
        let keep = b.clone();
        let copied = b.into_vec();
        assert_eq!(copied, keep.to_vec());
        assert_eq!(keep.ref_count(), 1);
    }

    #[test]
    fn make_mut_only_when_unique() {
        let mut b = Buf::from_vec(vec![1, 2, 3, 4]).slice(1..);
        assert!(b.is_unique());
        b.make_mut().unwrap()[0] = 9;
        assert_eq!(b, [9u8, 3, 4]);
        b.truncate(2);
        assert_eq!(b, [9u8, 3]);
        let keep = b.clone();
        assert!(!b.is_unique());
        assert!(b.make_mut().is_none(), "shared view must not be mutable");
        assert_eq!(keep, [9u8, 3]);
    }

    #[test]
    fn equality_and_hash() {
        use std::collections::HashSet;
        let a = Buf::from_vec(vec![1, 2, 3]);
        let b = Buf::from_vec(vec![0, 1, 2, 3, 4]).slice(1..4);
        assert_eq!(a, b);
        assert_eq!(a, vec![1u8, 2, 3]);
        assert_eq!(vec![1u8, 2, 3], a);
        assert_eq!(a, [1u8, 2, 3]);
        assert_eq!(a, &[1u8, 2, 3]);
        let mut set = HashSet::new();
        set.insert(a);
        assert!(set.contains(&b));
    }

    #[test]
    fn deref_and_indexing() {
        let b = Buf::from_vec(vec![9, 8, 7]);
        assert_eq!(&b[1..], &[8, 7]);
        assert_eq!(b.iter().sum::<u8>(), 24);
        fn takes_slice(s: &[u8]) -> usize {
            s.len()
        }
        assert_eq!(takes_slice(&b), 3);
    }
}
