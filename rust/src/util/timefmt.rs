//! Formatting helpers for virtual-time durations and byte counts used by
//! metrics reports and bench output.

/// Format nanoseconds human-readably (`1.25ms`, `3.4s`).
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Format a byte count (`1.5 KiB`, `3.2 MiB`).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut i = 0;
    while v >= 1024.0 && i < UNITS.len() - 1 {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[i])
    }
}

/// Format a rate in ops/sec (`10.0k`, `1.2M`).
pub fn fmt_rate(r: f64) -> String {
    if r >= 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}k", r / 1e3)
    } else {
        format!("{r:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(fmt_ns(12), "12ns");
        assert_eq!(fmt_ns(1_500), "1.50us");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.00s");
    }

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(64 * 1024 * 1024), "64.00 MiB");
    }

    #[test]
    fn rates() {
        assert_eq!(fmt_rate(10.0), "10.0");
        assert_eq!(fmt_rate(10_000.0), "10.00k");
        assert_eq!(fmt_rate(2_000_000.0), "2.00M");
    }
}
