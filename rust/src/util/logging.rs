//! Minimal stderr logger with a level filter from `LATTICA_LOG`
//! (error|warn|info|debug|trace). Self-contained (no `log` crate): use the
//! crate-level `log_error!` … `log_trace!` macros, installed via [`init`].

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Set the maximum level that will be emitted.
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether a record at `level` would be emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record (used by the `log_*!` macros; not called directly).
pub fn write(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}: {}", level.label(), target, args);
    }
}

/// Configure the level filter from `LATTICA_LOG` (idempotent, default `warn`).
pub fn init() {
    let level = match std::env::var("LATTICA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    set_max_level(level);
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::write(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::write(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::write(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::write(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::write(
            $crate::util::logging::Level::Trace,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_idempotent_and_filters() {
        init();
        init();
        crate::log_warn!("logging smoke test");
        assert!(enabled(Level::Error));
        set_max_level(Level::Debug);
        assert!(enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
        set_max_level(Level::Warn);
    }
}
