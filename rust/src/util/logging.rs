//! Minimal `log`-crate backend writing to stderr, with a level filter from
//! `LATTICA_LOG` (error|warn|info|debug|trace). Install with [`init`].

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{lvl}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent). Level from `LATTICA_LOG`, default `warn`.
pub fn init() {
    let level = match std::env::var("LATTICA_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Warn,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_idempotent() {
        super::init();
        super::init();
        log::warn!("logging smoke test");
    }
}
