//! Server-side service layer: named handlers dispatched inline from the
//! node pump.
//!
//! The paper's central critique of prior systems is that they couple ML
//! logic with networking code; the seed's `App` trait reproduced exactly
//! that — every application hand-matched raw `RpcEvent::Request` events.
//! A [`ServiceRouter`] replaces those match arms with registration:
//!
//! ```ignore
//! node.register_service(
//!     Service::new("greeter").unary("hello", |_node, _net, _ctx, payload| {
//!         Outcome::reply(format!("hello, {}!", String::from_utf8_lossy(&payload)))
//!     }),
//! );
//! ```
//!
//! Handlers run inline in the node pump (no polling latency) and receive a
//! [`RequestCtx`] carrying the peer identity, the request's absolute
//! deadline as propagated from the wire, the traffic class, and a typed
//! reply handle for deferred responses (server-side proxying / nested
//! calls). Requests whose deadline passed before dispatch are dropped
//! without invoking any handler; nested calls made from a handler should
//! budget with [`RequestCtx::remaining`] so the shrunken deadline is
//! inherited downstream.

use crate::identity::PeerId;
use crate::metrics::RouterStats;
use crate::netsim::{Net, Time};
use crate::node::LatticaNode;
use crate::protocols::Ctx;
use crate::rpc::{AdmissionPolicy, OrphanQueue, ReplyHandle, RpcEvent, Status, StreamHandle};
use crate::transport::TrafficClass;
use crate::util::buf::Buf;
use anyhow::Result;
use std::collections::HashMap;

/// Per-request context handed to unary handlers.
#[derive(Clone, Debug)]
pub struct RequestCtx {
    /// Authenticated identity of the caller.
    pub peer: PeerId,
    pub service: String,
    pub method: String,
    /// Absolute deadline propagated from the wire. Work past this point
    /// is wasted; nested calls should be budgeted with
    /// [`RequestCtx::remaining`].
    pub deadline: Time,
    /// Scheduling class the request arrived under.
    pub class: TrafficClass,
    reply: ReplyHandle,
    /// Set once [`RequestCtx::reply_handle`] is taken; the router then
    /// suppresses any inline outcome so the request cannot be answered
    /// twice.
    taken: std::cell::Cell<bool>,
    /// Where a dropped-without-responding [`Reply`] reports itself (the
    /// node's RPC layer answers `Unavailable` on its behalf).
    orphans: OrphanQueue,
}

impl RequestCtx {
    /// Budget left before the caller gives up.
    pub fn remaining(&self, now: Time) -> Time {
        self.deadline.saturating_sub(now)
    }

    pub fn expired(&self, now: Time) -> bool {
        self.deadline <= now
    }

    /// Take a typed reply handle for a deferred response. Once taken, the
    /// handle is the single path to a response: the router ignores any
    /// inline [`Outcome::Reply`]/[`Outcome::Fail`] the handler also
    /// returns (counted in [`RouterStats::deferred`]), so a request can
    /// never be answered twice from the server side.
    pub fn reply_handle(&self) -> Reply {
        self.taken.set(true);
        Reply {
            handle: self.reply,
            deadline: self.deadline,
            orphans: self.orphans.clone(),
            sent: false,
        }
    }

    /// Whether the reply handle has been taken (deferred response).
    pub fn reply_taken(&self) -> bool {
        self.taken.get()
    }
}

/// Typed reply handle for deferred responses. Consuming methods take
/// `self` by value, so the handle sends at most one response; taking it
/// makes the router skip its inline response (see
/// [`RequestCtx::reply_handle`]). A handle dropped without responding
/// does *not* leave the caller waiting out its deadline: `Drop` reports
/// the orphan and the node pump answers `Unavailable("reply dropped")`
/// on the handler's behalf, so callers fail over immediately.
#[derive(Debug)]
pub struct Reply {
    handle: ReplyHandle,
    /// Deadline of the originating request (for budget math when the
    /// response is produced later).
    pub deadline: Time,
    orphans: OrphanQueue,
    /// A response went out through this handle (suppresses the orphan
    /// report on drop).
    sent: bool,
}

impl Reply {
    pub fn ok(self, node: &mut LatticaNode, net: &mut Net, payload: impl Into<Buf>) -> Result<()> {
        self.send(node, net, Status::Ok, payload, "")
    }

    pub fn err(
        self,
        node: &mut LatticaNode,
        net: &mut Net,
        status: Status,
        detail: &str,
    ) -> Result<()> {
        self.send(node, net, status, Buf::new(), detail)
    }

    pub fn send(
        mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        status: Status,
        payload: impl Into<Buf>,
        detail: &str,
    ) -> Result<()> {
        self.sent = true;
        let LatticaNode { swarm, rpc, .. } = node;
        let mut ctx = Ctx::new(swarm, net);
        rpc.respond_detail(&mut ctx, self.handle, status, payload, detail)
    }

    /// Refuse with [`Status::Overloaded`] plus a retry-after hint —
    /// server pushback for work shed *after* admission (queue overflow,
    /// worker saturation). The caller's stub fails over or backs off
    /// instead of retrying in place.
    pub fn overloaded(
        mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        retry_after: Time,
        detail: &str,
    ) -> Result<()> {
        self.sent = true;
        let LatticaNode { swarm, rpc, .. } = node;
        let mut ctx = Ctx::new(swarm, net);
        rpc.respond_pushback(&mut ctx, self.handle, retry_after, detail)
    }
}

impl Drop for Reply {
    fn drop(&mut self) {
        if !self.sent {
            self.orphans.borrow_mut().push(self.handle);
        }
    }
}

/// What a unary handler decided.
pub enum Outcome {
    /// Respond `Ok` with this payload now.
    Reply(Buf),
    /// Respond with a failure status + detail now.
    Fail(Status, String),
    /// The handler took [`RequestCtx::reply_handle`] and will respond
    /// later (e.g. after a nested call completes).
    Deferred,
}

impl Outcome {
    pub fn reply(payload: impl Into<Buf>) -> Outcome {
        Outcome::Reply(payload.into())
    }

    pub fn fail(status: Status, detail: impl Into<String>) -> Outcome {
        Outcome::Fail(status, detail.into())
    }
}

/// Boxed unary method handler.
pub type UnaryHandler = Box<dyn FnMut(&mut LatticaNode, &mut Net, &RequestCtx, Buf) -> Outcome>;

/// Handler for a service's inbound RPC streams. Credit-based backpressure
/// stays at the RPC layer (consuming an item grants credits back to the
/// sender); the handler just observes the flow.
pub trait StreamHandler {
    fn on_open(
        &mut self,
        _node: &mut LatticaNode,
        _net: &mut Net,
        _peer: PeerId,
        _method: &str,
        _handle: StreamHandle,
    ) {
    }

    fn on_item(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        handle: StreamHandle,
        seq: u64,
        payload: Buf,
    );

    fn on_end(&mut self, _node: &mut LatticaNode, _net: &mut Net, _handle: StreamHandle) {}
}

/// A named service: unary methods registered by name plus an optional
/// stream handler. Built fluently and registered with
/// [`LatticaNode::register_service`].
pub struct Service {
    name: String,
    unary: HashMap<String, UnaryHandler>,
    stream: Option<Box<dyn StreamHandler>>,
    admission: Option<AdmissionPolicy>,
}

impl Service {
    pub fn new(name: &str) -> Service {
        Service {
            name: name.to_string(),
            unary: HashMap::new(),
            stream: None,
            admission: None,
        }
    }

    /// Register a unary method handler.
    pub fn unary(
        mut self,
        method: &str,
        h: impl FnMut(&mut LatticaNode, &mut Net, &RequestCtx, Buf) -> Outcome + 'static,
    ) -> Service {
        self.unary.insert(method.to_string(), Box::new(h));
        self
    }

    /// Attach the handler for this service's inbound streams.
    pub fn streaming(mut self, h: impl StreamHandler + 'static) -> Service {
        self.stream = Some(Box::new(h));
        self
    }

    /// Attach a token-bucket admission policy: requests beyond it are
    /// answered [`Status::Overloaded`] from the header, before payload
    /// decode or dispatch (see [`crate::rpc::admission`]).
    pub fn with_admission(mut self, p: AdmissionPolicy) -> Service {
        self.admission = Some(p);
        self
    }

    pub(crate) fn take_admission(&mut self) -> Option<AdmissionPolicy> {
        self.admission.take()
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Routes RPC events to registered services. Owned by the node; dispatch
/// runs inline in the pump, so handlers add no polling latency. Events the
/// router does not own (client-side responses, streams of unregistered
/// services) pass through to the app / external poller untouched.
#[derive(Default)]
pub struct ServiceRouter {
    services: HashMap<String, Service>,
    /// Inbound streams adopted by a registered service.
    streams: HashMap<StreamHandle, String>,
    pub stats: RouterStats,
}

impl ServiceRouter {
    pub fn new() -> ServiceRouter {
        ServiceRouter::default()
    }

    pub fn register(&mut self, svc: Service) {
        self.services.insert(svc.name.clone(), svc);
    }

    pub fn has_service(&self, name: &str) -> bool {
        self.services.contains_key(name)
    }

    /// Fold another router's registrations into this one (used by the node
    /// pump when a handler registered services mid-dispatch).
    pub fn merge(&mut self, other: ServiceRouter) {
        for (name, svc) in other.services {
            self.services.insert(name, svc);
        }
        for (h, s) in other.streams {
            self.streams.insert(h, s);
        }
    }

    /// Dispatch one RPC event. Returns `None` if consumed, or the event
    /// back if no registered service owns it.
    pub fn dispatch(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        ev: RpcEvent,
    ) -> Option<RpcEvent> {
        match ev {
            RpcEvent::Request {
                peer,
                service,
                method,
                payload,
                deadline,
                reply,
            } => {
                // Belt and braces: the RPC layer already drops requests
                // that arrive expired; this covers budget exhausted
                // between decode and dispatch.
                if deadline <= net.now() {
                    self.stats.expired += 1;
                    return None;
                }
                let Some(svc) = self.services.get_mut(&service) else {
                    self.stats.unknown_service += 1;
                    respond(
                        node,
                        net,
                        reply,
                        Status::NotFound,
                        Buf::new(),
                        &format!("unknown service {service:?}"),
                    );
                    return None;
                };
                let Some(h) = svc.unary.get_mut(&method) else {
                    self.stats.unknown_method += 1;
                    respond(
                        node,
                        net,
                        reply,
                        Status::NotFound,
                        Buf::new(),
                        &format!("unknown method {method:?} on service {service:?}"),
                    );
                    return None;
                };
                let rctx = RequestCtx {
                    peer,
                    service,
                    method,
                    deadline,
                    class: TrafficClass::Unary,
                    reply,
                    taken: std::cell::Cell::new(false),
                    orphans: node.rpc.orphan_queue(),
                };
                let outcome = h(node, net, &rctx, payload);
                if rctx.reply_taken() {
                    // The taken handle is the single response path; an
                    // inline outcome on top would double-respond, so it
                    // is dropped.
                    self.stats.deferred += 1;
                    return None;
                }
                match outcome {
                    Outcome::Reply(body) => {
                        self.stats.served += 1;
                        respond(node, net, reply, Status::Ok, body, "");
                    }
                    Outcome::Fail(status, detail) => {
                        self.stats.failed += 1;
                        respond(node, net, reply, status, Buf::new(), &detail);
                    }
                    Outcome::Deferred => {
                        self.stats.deferred += 1;
                    }
                }
                None
            }
            RpcEvent::StreamOpened {
                peer,
                service,
                method,
                handle,
            } => match self.services.get_mut(&service) {
                Some(svc) if svc.stream.is_some() => {
                    self.streams.insert(handle, service.clone());
                    if let Some(h) = svc.stream.as_mut() {
                        h.on_open(node, net, peer, &method, handle);
                    }
                    None
                }
                _ => Some(RpcEvent::StreamOpened {
                    peer,
                    service,
                    method,
                    handle,
                }),
            },
            RpcEvent::StreamItem {
                handle,
                seq,
                payload,
            } => {
                // Disjoint-field borrows (streams vs services) keep this
                // allocation-free: items are the tensor data plane.
                let Some(owner) = self.streams.get(&handle) else {
                    return Some(RpcEvent::StreamItem {
                        handle,
                        seq,
                        payload,
                    });
                };
                if let Some(h) = self.services.get_mut(owner).and_then(|s| s.stream.as_mut()) {
                    self.stats.stream_items += 1;
                    h.on_item(node, net, handle, seq, payload);
                }
                None
            }
            RpcEvent::StreamEnded { handle } => {
                let Some(owner) = self.streams.remove(&handle) else {
                    return Some(RpcEvent::StreamEnded { handle });
                };
                if let Some(h) = self.services.get_mut(&owner).and_then(|s| s.stream.as_mut()) {
                    h.on_end(node, net, handle);
                }
                None
            }
            // Client-side events (responses, failures, send credits) are
            // the stub's business; pass them through.
            other => Some(other),
        }
    }
}

fn respond(
    node: &mut LatticaNode,
    net: &mut Net,
    reply: ReplyHandle,
    status: Status,
    payload: Buf,
    detail: &str,
) {
    let LatticaNode { swarm, rpc, .. } = node;
    let mut ctx = Ctx::new(swarm, net);
    if let Err(e) = rpc.respond_detail(&mut ctx, reply, status, payload, detail) {
        crate::log_debug!("rpc respond failed: {e}");
    }
}
