//! Server-side request queue: weighted fair queueing across peers plus
//! deadline-aware shedding.
//!
//! Admission control (see [`admission`]) bounds how much work gets *in*;
//! this queue decides what happens to admitted work while the service's
//! workers are busy. Two policies compose:
//!
//! * **WFQ across peers** — each peer gets its own lane and a
//!   deficit-round-robin share proportional to its weight, so a client
//!   offering 10× the load of its neighbours still gets only its fair
//!   share of service slots (the excess queues in — and is shed from —
//!   its own lane). This layers *above* the transport's strict-priority
//!   [`TrafficClass`] scheduler: the transport decides whose bytes move,
//!   this queue decides whose requests run.
//! * **Oldest-useless-first drop** — the queue tracks an EWMA of the
//!   service's handle time; an entry whose remaining budget cannot cover
//!   it can no longer be answered in time, so it is shed first (at push
//!   when over capacity, and lazily at pop), before any fresh request is
//!   touched. Serving stale work is how overload goes metastable: every
//!   timed-out response was paid for in full and earns a retry.
//!
//! [`admission`]: crate::rpc::admission
//! [`TrafficClass`]: crate::transport::TrafficClass

use crate::identity::PeerId;
use crate::netsim::Time;
use std::collections::{BTreeMap, VecDeque};

/// EWMA gain 1/8, TCP-SRTT style: new = 7/8·old + 1/8·sample.
const EWMA_SHIFT: u32 = 3;

/// One queued request plus the metadata the drop policy needs.
#[derive(Debug)]
pub struct Queued<T> {
    pub item: T,
    pub peer: PeerId,
    /// Absolute deadline propagated from the wire.
    pub deadline: Time,
    pub enqueued_at: Time,
}

#[derive(Debug)]
struct PeerLane<T> {
    queue: VecDeque<Queued<T>>,
    weight: u32,
    /// Deficit-round-robin credit left in the current round.
    deficit: u32,
    in_order: bool,
}

impl<T> PeerLane<T> {
    fn new(weight: u32) -> PeerLane<T> {
        PeerLane {
            queue: VecDeque::new(),
            weight,
            deficit: 0,
            in_order: false,
        }
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub pushed: u64,
    /// Entries handed to a worker.
    pub served: u64,
    /// Entries shed because their remaining budget could not cover the
    /// EWMA handle time (oldest-useless-first).
    pub shed_stale: u64,
    /// Entries shed because the queue was full and nothing was stale —
    /// taken from the longest lane, i.e. the peer over its fair share.
    pub shed_capacity: u64,
}

/// Bounded multi-lane queue; see module docs. Lanes are keyed by peer in
/// a `BTreeMap` so every tie-break is deterministic under the simulator.
#[derive(Debug)]
pub struct ServiceQueue<T> {
    lanes: BTreeMap<PeerId, PeerLane<T>>,
    /// Active-lane rotation for deficit round robin.
    order: VecDeque<PeerId>,
    len: usize,
    capacity: usize,
    ewma_handle: Time,
    pub stats: QueueStats,
}

impl<T> ServiceQueue<T> {
    /// `capacity` bounds total queued entries; `initial_handle_time`
    /// seeds the EWMA before the first sample (pick the service's
    /// expected per-request cost; 0 disables staleness shedding until a
    /// sample arrives).
    pub fn new(capacity: usize, initial_handle_time: Time) -> ServiceQueue<T> {
        ServiceQueue {
            lanes: BTreeMap::new(),
            order: VecDeque::new(),
            len: 0,
            capacity: capacity.max(1),
            ewma_handle: initial_handle_time,
            stats: QueueStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Current handle-time estimate (ns).
    pub fn ewma_handle(&self) -> Time {
        self.ewma_handle
    }

    /// Fold a measured handle time into the EWMA.
    pub fn note_handle_time(&mut self, sample: Time) {
        if self.ewma_handle == 0 {
            self.ewma_handle = sample;
        } else {
            self.ewma_handle =
                self.ewma_handle - (self.ewma_handle >> EWMA_SHIFT) + (sample >> EWMA_SHIFT);
        }
    }

    /// WFQ weight for a peer (default 1; higher = larger share).
    pub fn set_weight(&mut self, peer: PeerId, weight: u32) {
        self.lanes
            .entry(peer)
            .or_insert_with(|| PeerLane::new(1))
            .weight = weight.max(1);
    }

    /// Enqueue; returns the entries shed to stay within capacity (answer
    /// them `Overloaded` — silently dropping a deferred reply would leave
    /// its caller waiting). The entry just pushed may itself be among
    /// the shed ones.
    pub fn push(&mut self, now: Time, peer: PeerId, deadline: Time, item: T) -> Vec<Queued<T>> {
        let lane = self.lanes.entry(peer).or_insert_with(|| PeerLane::new(1));
        lane.queue.push_back(Queued {
            item,
            peer,
            deadline,
            enqueued_at: now,
        });
        if !lane.in_order {
            lane.in_order = true;
            self.order.push_back(peer);
        }
        self.len += 1;
        self.stats.pushed += 1;
        let mut shed = Vec::new();
        while self.len > self.capacity {
            match self.shed_one(now) {
                Some(q) => shed.push(q),
                None => break,
            }
        }
        shed
    }

    /// Next entry to serve under DRR, plus any entries shed on the way
    /// because they became useless (remaining budget < EWMA handle time).
    pub fn pop(&mut self, now: Time) -> (Option<Queued<T>>, Vec<Queued<T>>) {
        let mut shed = Vec::new();
        let horizon = now.saturating_add(self.ewma_handle);
        while let Some(&p) = self.order.front() {
            let lane = self.lanes.get_mut(&p).expect("lane for ordered peer");
            // Lazily shed entries that can no longer make their deadline.
            while lane.queue.front().is_some_and(|q| q.deadline <= horizon) {
                shed.push(lane.queue.pop_front().unwrap());
                self.len -= 1;
                self.stats.shed_stale += 1;
            }
            if lane.queue.is_empty() {
                lane.in_order = false;
                lane.deficit = 0;
                self.order.pop_front();
                continue;
            }
            if lane.deficit == 0 {
                lane.deficit = lane.weight;
            }
            let q = lane.queue.pop_front().unwrap();
            self.len -= 1;
            lane.deficit -= 1;
            self.stats.served += 1;
            // Quantum spent or lane drained: rotate to the next peer.
            if lane.deficit == 0 || lane.queue.is_empty() {
                lane.deficit = 0;
                self.order.pop_front();
                if self.lanes.get(&p).map_or(false, |l| !l.queue.is_empty()) {
                    self.order.push_back(p);
                } else if let Some(l) = self.lanes.get_mut(&p) {
                    l.in_order = false;
                }
            }
            return (Some(q), shed);
        }
        (None, shed)
    }

    /// Shed one entry: prefer the stalest useless one (earliest deadline
    /// among lane fronts that can't cover the EWMA handle time); if every
    /// front is still viable, take from the longest lane — the peer most
    /// over its share.
    fn shed_one(&mut self, now: Time) -> Option<Queued<T>> {
        let horizon = now.saturating_add(self.ewma_handle);
        let mut stale_pick: Option<(PeerId, Time)> = None;
        let mut long_pick: Option<(PeerId, usize)> = None;
        for (p, lane) in &self.lanes {
            let Some(front) = lane.queue.front() else { continue };
            if front.deadline <= horizon
                && stale_pick.map_or(true, |(_, d)| front.deadline < d)
            {
                stale_pick = Some((*p, front.deadline));
            }
            if long_pick.map_or(true, |(_, l)| lane.queue.len() > l) {
                long_pick = Some((*p, lane.queue.len()));
            }
        }
        let (peer, stale) = match (stale_pick, long_pick) {
            (Some((p, _)), _) => (p, true),
            (None, Some((p, _))) => (p, false),
            (None, None) => return None,
        };
        let lane = self.lanes.get_mut(&peer)?;
        let q = lane.queue.pop_front()?;
        self.len -= 1;
        if stale {
            self.stats.shed_stale += 1;
        } else {
            self.stats.shed_capacity += 1;
        }
        // Lane order bookkeeping happens lazily in `pop` (empty lanes are
        // skipped and retired there).
        Some(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{MILLI, SECOND};

    fn peer(n: u8) -> PeerId {
        PeerId([n; 32])
    }

    #[test]
    fn drr_splits_service_evenly_under_asymmetric_load() {
        // Peer 1 offers 10× the load of peer 2 at equal weight; while both
        // stay backlogged, service alternates — equal goodput.
        let mut q: ServiceQueue<u32> = ServiceQueue::new(1000, 0);
        let now = SECOND;
        let deadline = now + 10 * SECOND;
        for i in 0..100 {
            q.push(now, peer(1), deadline, i);
        }
        for i in 0..10 {
            q.push(now, peer(2), deadline, 1000 + i);
        }
        let mut served = [0u32; 2];
        for _ in 0..20 {
            let (got, shed) = q.pop(now);
            assert!(shed.is_empty());
            let got = got.unwrap();
            served[if got.peer == peer(1) { 0 } else { 1 }] += 1;
        }
        assert_eq!(served, [10, 10], "equal weights → equal share while backlogged");
        // Once the light peer drains, the heavy one gets the leftovers.
        let mut rest = 0;
        while let (Some(got), _) = q.pop(now) {
            assert_eq!(got.peer, peer(1));
            rest += 1;
        }
        assert_eq!(rest, 90);
    }

    #[test]
    fn weights_skew_the_split() {
        let mut q: ServiceQueue<u32> = ServiceQueue::new(1000, 0);
        q.set_weight(peer(1), 3);
        let now = SECOND;
        let deadline = now + 10 * SECOND;
        for i in 0..40 {
            q.push(now, peer(1), deadline, i);
            q.push(now, peer(2), deadline, 100 + i);
        }
        let mut served = [0u32; 2];
        for _ in 0..40 {
            let (got, _) = q.pop(now);
            served[if got.unwrap().peer == peer(1) { 0 } else { 1 }] += 1;
        }
        assert_eq!(served, [30, 10], "3:1 weights → 3:1 service");
    }

    #[test]
    fn overflow_sheds_stalest_useless_entry_first() {
        let mut q: ServiceQueue<u32> = ServiceQueue::new(3, 100 * MILLI);
        let now = SECOND;
        // Entry 0 has 50ms of budget left — under the 100ms EWMA it can
        // no longer be answered in time. Entries 1/2 are fresh.
        q.push(now, peer(1), now + 50 * MILLI, 0);
        q.push(now, peer(2), now + SECOND, 1);
        q.push(now, peer(3), now + SECOND, 2);
        let shed = q.push(now, peer(4), now + SECOND, 3);
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].item, 0, "the useless entry goes first, not the newest");
        assert_eq!(q.stats.shed_stale, 1);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn overflow_without_stale_entries_sheds_from_longest_lane() {
        let mut q: ServiceQueue<u32> = ServiceQueue::new(4, 0);
        let now = SECOND;
        let deadline = now + 10 * SECOND;
        q.push(now, peer(1), deadline, 0);
        q.push(now, peer(1), deadline, 1);
        q.push(now, peer(1), deadline, 2);
        q.push(now, peer(2), deadline, 10);
        let shed = q.push(now, peer(2), deadline, 11);
        assert_eq!(shed.len(), 1);
        assert_eq!(
            shed[0].peer,
            peer(1),
            "the hog's lane pays for the overflow, not the fair peer"
        );
        assert_eq!(q.stats.shed_capacity, 1);
    }

    #[test]
    fn pop_sheds_entries_that_went_stale_while_queued() {
        let mut q: ServiceQueue<u32> = ServiceQueue::new(10, 100 * MILLI);
        let t0 = SECOND;
        q.push(t0, peer(1), t0 + 150 * MILLI, 0);
        q.push(t0, peer(1), t0 + 10 * SECOND, 1);
        // 100ms later entry 0 has 50ms of budget — below the EWMA.
        let (got, shed) = q.pop(t0 + 100 * MILLI);
        assert_eq!(got.unwrap().item, 1, "fresh entry served");
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].item, 0, "stale entry shed, not served");
    }

    #[test]
    fn ewma_tracks_handle_time() {
        let mut q: ServiceQueue<u32> = ServiceQueue::new(10, 0);
        q.note_handle_time(8 * MILLI);
        assert_eq!(q.ewma_handle(), 8 * MILLI, "first sample seeds the EWMA");
        q.note_handle_time(16 * MILLI);
        assert_eq!(q.ewma_handle(), 9 * MILLI, "7/8·8ms + 1/8·16ms");
    }
}
