//! Dual-plane RPC (§2 "RPC and Streaming for Training and Inference").
//!
//! * **Unary plane** (`/lattica/rpc/1`) — request/response for control
//!   operations (health, shard placement, version queries). One stream per
//!   call. Deadlines ride the wire ([`RpcMsg::deadline_ns`]): a server
//!   drops a request whose deadline already passed instead of doing dead
//!   work, and handlers propagate the shrunken budget into nested calls.
//! * **Streaming plane** (`/lattica/rpc-stream/1`) — long-lived flows for
//!   tensors. Application-level credit grants ride on top of the
//!   transport's byte-level flow control, so a slow consumer throttles the
//!   producer at message granularity (the paper's "adaptive backpressure").
//!
//! Applications do not speak this layer directly: servers register typed
//! handlers on a [`ServiceRouter`] (see [`service`]) and clients call
//! through a [`Stub`] (see [`stub`]) that layers per-call deadlines,
//! idempotent retries, hedging and multi-target failover on top of the
//! raw unary plane.

pub mod admission;
pub mod queue;
pub mod service;
pub mod stub;

pub use admission::{Admission, AdmissionPolicy, AdmissionStats, Admit};
pub use queue::{Queued, QueueStats, ServiceQueue};
pub use service::{Outcome, Reply, RequestCtx, Service, ServiceRouter, StreamHandler};
pub use stub::{CallOptions, HedgePolicy, RetryPolicy, Stub, StubDone};

use crate::identity::PeerId;
use crate::netsim::{Time, SECOND};
use crate::protocols::Ctx;
use crate::transport::TrafficClass;
use crate::util::buf::Buf;
use crate::wire::{encode_pooled, Message, PbReader, PbWriter};
use anyhow::Result;
use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::rc::Rc;

pub const RPC_PROTO: &str = "/lattica/rpc/1";
pub const RPC_STREAM_PROTO: &str = "/lattica/rpc-stream/1";

/// Default unary deadline.
pub const CALL_TIMEOUT: Time = 10 * SECOND;
/// Initial message credits granted to a stream sender.
pub const INITIAL_CREDITS: u32 = 16;
/// Grant more credits once the receiver consumed this many.
pub const CREDIT_BATCH: u32 = 8;

const M_REQUEST: u64 = 1;
const M_RESPONSE: u64 = 2;
const M_STREAM_OPEN: u64 = 3;
const M_STREAM_ITEM: u64 = 4;
const M_STREAM_CREDIT: u64 = 5;
const M_STREAM_END: u64 = 6;

/// Response status.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Ok = 0,
    NotFound = 1,
    Error = 2,
    Unavailable = 3,
    /// The server deliberately shed this request (admission control or
    /// queue overflow). Unlike `Unavailable`, retrying the same target
    /// in place is counterproductive: stubs fail over to another replica
    /// and floor any wait at the response's `retry_after_ns` hint.
    /// Legacy peers decode this as `Error` (unknown → `Error`), which is
    /// also non-retryable — degraded but safe.
    Overloaded = 4,
}

impl Status {
    fn from_u64(v: u64) -> Status {
        match v {
            0 => Status::Ok,
            1 => Status::NotFound,
            3 => Status::Unavailable,
            4 => Status::Overloaded,
            _ => Status::Error,
        }
    }
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct RpcMsg {
    pub kind: u64,
    pub service: String,
    pub method: String,
    /// Payload bytes, shared zero-copy between the caller, the encoder and
    /// (on receive) the transport's decrypted packet buffer.
    pub payload: Buf,
    pub status: u64,
    /// STREAM_*: item sequence or credit count.
    pub seq: u64,
    /// REQUEST: absolute virtual-time deadline (ns). 0 = unspecified
    /// (legacy encodings), which servers widen to [`CALL_TIMEOUT`]. The
    /// simulator has a global clock, so an absolute deadline is exact; a
    /// real deployment would carry the remaining budget instead (gRPC's
    /// `grpc-timeout`) plus a skew bound — the semantics pinned by the
    /// tests are identical.
    pub deadline_ns: u64,
    /// RESPONSE with non-Ok status: human-readable failure detail, so
    /// errors surface with context instead of a bare status code.
    pub error_detail: String,
    /// RESPONSE with `Overloaded` status: server pushback hint — how
    /// long (ns) the caller should wait before offering this service
    /// more load. 0 = no hint (and the field is skipped on the wire, so
    /// legacy encodings stay byte-identical).
    pub retry_after_ns: u64,
}

impl Message for RpcMsg {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.string(2, &self.service);
        w.string(3, &self.method);
        w.bytes(4, &self.payload);
        w.uint(5, self.status);
        w.uint(6, self.seq);
        // Fields 7/8/9 are skipped when default, so peers predating each
        // field see byte-identical encodings for messages that don't use
        // them.
        w.uint(7, self.deadline_ns);
        w.string(8, &self.error_detail);
        w.uint(9, self.retry_after_ns);
    }

    fn decode(buf: &[u8]) -> Result<RpcMsg> {
        let mut m = RpcMsg::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                4 => m.payload = Buf::copy_from_slice(f.as_bytes()?),
                other => decode_common_field(&mut m, other, &f)?,
            }
            Ok(())
        })?;
        Ok(m)
    }

    /// Zero-copy decode: the payload becomes a slice of `buf` instead of a
    /// fresh allocation (the per-call copy the paper's QPS table is most
    /// sensitive to).
    fn decode_buf(buf: &Buf) -> Result<RpcMsg> {
        let mut m = RpcMsg::default();
        PbReader::new(buf.as_slice()).for_each(|f| {
            match f.number {
                4 => {
                    f.as_bytes()?; // wire-type check
                    m.payload = buf.slice(f.data_start..f.data_start + f.data.len());
                }
                other => decode_common_field(&mut m, other, &f)?,
            }
            Ok(())
        })?;
        Ok(m)
    }
}

/// Shared decode arms for every field except 4 (`payload`).
fn decode_common_field(m: &mut RpcMsg, number: u32, f: &crate::wire::pb::Field<'_>) -> Result<()> {
    match number {
        1 => m.kind = f.as_u64(),
        2 => m.service = f.as_string()?,
        3 => m.method = f.as_string()?,
        5 => m.status = f.as_u64(),
        6 => m.seq = f.as_u64(),
        7 => m.deadline_ns = f.as_u64(),
        8 => m.error_detail = f.as_string()?,
        9 => m.retry_after_ns = f.as_u64(),
        _ => {}
    }
    Ok(())
}

/// Messages whose encoded form exceeds this ride the zero-copy send path
/// (`Ctx::send_buf`); smaller ones use the pooled encoder + framing copy,
/// which is cheaper than two queue entries.
const LARGE_MSG: usize = 512;

/// Encode and send an RPC message, choosing pooled-copy or shared-buffer
/// transport according to payload size.
fn send_rpc_msg(ctx: &mut Ctx, conn: u64, stream: u64, msg: &RpcMsg) -> Result<()> {
    if msg.payload.len() > LARGE_MSG {
        ctx.send_buf(conn, stream, msg.encode_buf())
    } else {
        encode_pooled(msg, |b| ctx.send(conn, stream, b))
    }
}

/// Handle identifying an in-progress inbound request (for replies).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ReplyHandle {
    pub conn: u64,
    pub stream: u64,
}

/// Handle identifying an RPC stream (either direction).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StreamHandle {
    pub conn: u64,
    pub stream: u64,
}

#[derive(Debug)]
pub enum RpcEvent {
    /// Server side: a unary request arrived; reply via [`RpcNode::respond`].
    /// Normally consumed by the node's [`ServiceRouter`]; only surfaces to
    /// the app/poller for services with no registered handler.
    Request {
        peer: PeerId,
        service: String,
        method: String,
        payload: Buf,
        /// Absolute deadline propagated from the wire (or the default
        /// widened locally for legacy requests). Already-expired requests
        /// are dropped before this event is emitted.
        deadline: Time,
        reply: ReplyHandle,
    },
    /// Client side: a unary call finished.
    Response {
        call_id: u64,
        status: Status,
        payload: Buf,
        /// Failure detail from the server (empty on Ok).
        detail: String,
        /// Round-trip time of this call.
        rtt: Time,
        /// Server pushback hint on `Overloaded` responses (0 = none).
        retry_after: Time,
    },
    /// Client side: call failed locally (timeout / disconnect).
    CallFailed { call_id: u64, reason: String },
    /// Server side: peer opened an RPC stream.
    StreamOpened {
        peer: PeerId,
        service: String,
        method: String,
        handle: StreamHandle,
    },
    /// An item arrived on an RPC stream.
    StreamItem {
        handle: StreamHandle,
        seq: u64,
        payload: Buf,
    },
    /// Stream finished cleanly.
    StreamEnded { handle: StreamHandle },
    /// Sender: more credits granted (can send again).
    CreditsAvailable { handle: StreamHandle, credits: u32 },
}

struct PendingCall {
    call_id: u64,
    deadline: Time,
    sent_at: Time,
}

struct StreamState {
    /// Credits we may still spend sending.
    send_credits: u32,
    /// Items received since the last credit grant.
    recv_since_grant: u32,
    /// Outbound items waiting for credits.
    backlog: VecDeque<Buf>,
    next_seq: u64,
    ended: bool,
}

/// Header-only decode of an inbound unary frame: every field *except*
/// the payload. The payload's byte range is recorded but not sliced, so
/// admission control can reject a request without the payload ever being
/// materialized (the "shed before decode" fast path — the rejected
/// request costs one header parse, not a payload decode plus a handler).
fn peek_unary(buf: &Buf) -> Result<(RpcMsg, Option<(usize, usize)>)> {
    let mut m = RpcMsg::default();
    let mut payload = None;
    PbReader::new(buf.as_slice()).for_each(|f| {
        match f.number {
            4 => {
                f.as_bytes()?; // wire-type check only
                payload = Some((f.data_start, f.data.len()));
            }
            other => decode_common_field(&mut m, other, &f)?,
        }
        Ok(())
    })?;
    Ok((m, payload))
}

/// Shared queue of deferred [`ReplyHandle`]s whose [`service::Reply`] was
/// dropped without responding; the node pump drains it and answers
/// `Unavailable("reply dropped")` so callers fail over immediately.
pub(crate) type OrphanQueue = Rc<RefCell<Vec<ReplyHandle>>>;

/// Per-node RPC state.
pub struct RpcNode {
    /// (conn, stream) → pending unary call.
    calls: HashMap<(u64, u64), PendingCall>,
    /// call id → (conn, stream), for O(1) cancellation.
    call_index: HashMap<u64, (u64, u64)>,
    /// Min-heap of call deadlines: (deadline, conn, stream). Entries are
    /// lazily invalidated — a popped entry whose call already completed (or
    /// whose deadline no longer matches) is skipped — so `tick` is
    /// O(expired · log n) instead of a linear scan of every pending call.
    deadlines: BinaryHeap<Reverse<(Time, u64, u64)>>,
    next_call_id: u64,
    streams: HashMap<StreamHandle, StreamState>,
    events: VecDeque<RpcEvent>,
    /// Token-bucket admission control consulted from the request header,
    /// before the payload is touched (see [`admission`]).
    pub admission: Admission,
    /// Deferred replies dropped without a response (see [`OrphanQueue`]).
    orphans: OrphanQueue,
    /// Counters for metrics.
    pub calls_sent: u64,
    pub calls_served: u64,
    /// Inbound requests dropped because their wire deadline had already
    /// passed on arrival (no handler was invoked for them).
    pub expired_dropped: u64,
    /// Inbound requests whose payload was actually materialized (i.e.
    /// that survived the pre-decode admission check). Together with
    /// [`AdmissionStats::shed_predecode`] this pins that rejection skips
    /// payload decode.
    pub requests_decoded: u64,
    /// Deferred replies that were dropped without responding and
    /// answered `Unavailable` by the pump on the handler's behalf.
    pub replies_dropped: u64,
}

impl Default for RpcNode {
    fn default() -> Self {
        Self::new()
    }
}

impl RpcNode {
    pub fn new() -> RpcNode {
        RpcNode {
            calls: HashMap::new(),
            call_index: HashMap::new(),
            deadlines: BinaryHeap::new(),
            next_call_id: 1,
            streams: HashMap::new(),
            events: VecDeque::new(),
            admission: Admission::default(),
            orphans: Rc::new(RefCell::new(Vec::new())),
            calls_sent: 0,
            calls_served: 0,
            expired_dropped: 0,
            requests_decoded: 0,
            replies_dropped: 0,
        }
    }

    /// Shared handle to the orphaned-reply queue (cloned into every
    /// [`service::Reply`] so its `Drop` can report back).
    pub(crate) fn orphan_queue(&self) -> OrphanQueue {
        self.orphans.clone()
    }

    /// Drain reply handles whose `Reply` was dropped without responding.
    pub(crate) fn take_orphaned(&mut self) -> Vec<ReplyHandle> {
        std::mem::take(&mut *self.orphans.borrow_mut())
    }

    pub fn poll_event(&mut self) -> Option<RpcEvent> {
        self.events.pop_front()
    }

    // ------------------------------------------------------------------
    // Unary plane
    // ------------------------------------------------------------------

    /// Issue a unary call with the default [`CALL_TIMEOUT`] budget. The
    /// payload is owned zero-copy: pass a `Vec<u8>` or [`Buf`] to avoid
    /// copying (a `&[u8]` is copied once at this boundary).
    pub fn call(
        &mut self,
        ctx: &mut Ctx,
        peer: &PeerId,
        service: &str,
        method: &str,
        payload: impl Into<Buf>,
    ) -> Result<u64> {
        self.call_opts(ctx, peer, service, method, payload, CALL_TIMEOUT)
    }

    /// Issue a unary call with an explicit time budget. Returns the call
    /// id. The absolute deadline `now + budget` is armed locally *and*
    /// stamped on the wire, so the server can drop the request if it
    /// arrives too late and handlers can propagate the remaining budget
    /// into nested calls.
    pub fn call_opts(
        &mut self,
        ctx: &mut Ctx,
        peer: &PeerId,
        service: &str,
        method: &str,
        payload: impl Into<Buf>,
        budget: Time,
    ) -> Result<u64> {
        let (conn, stream) = ctx.open_stream_class(peer, RPC_PROTO, TrafficClass::Unary)?;
        let deadline = ctx.now() + budget;
        let msg = RpcMsg {
            kind: M_REQUEST,
            service: service.to_string(),
            method: method.to_string(),
            payload: payload.into(),
            deadline_ns: deadline,
            ..Default::default()
        };
        send_rpc_msg(ctx, conn, stream, &msg)?;
        let call_id = self.next_call_id;
        self.next_call_id += 1;
        self.calls.insert(
            (conn, stream),
            PendingCall {
                call_id,
                deadline,
                sent_at: ctx.now(),
            },
        );
        self.call_index.insert(call_id, (conn, stream));
        self.deadlines.push(Reverse((deadline, conn, stream)));
        self.calls_sent += 1;
        Ok(call_id)
    }

    /// Abandon a pending call without surfacing an event (hedged calls
    /// cancel the losing attempt on first win). Returns false if the call
    /// already completed.
    pub fn cancel(&mut self, ctx: &mut Ctx, call_id: u64) -> bool {
        let Some(slot) = self.call_index.remove(&call_id) else {
            return false;
        };
        self.calls.remove(&slot);
        ctx.reset(slot.0, slot.1, "cancelled");
        true
    }

    /// Server side: reply to an inbound request.
    pub fn respond(
        &mut self,
        ctx: &mut Ctx,
        reply: ReplyHandle,
        status: Status,
        payload: impl Into<Buf>,
    ) -> Result<()> {
        self.respond_detail(ctx, reply, status, payload, "")
    }

    /// [`RpcNode::respond`] with a failure detail string that rides the
    /// wire and surfaces in the caller's [`RpcEvent::Response`].
    pub fn respond_detail(
        &mut self,
        ctx: &mut Ctx,
        reply: ReplyHandle,
        status: Status,
        payload: impl Into<Buf>,
        detail: &str,
    ) -> Result<()> {
        let msg = RpcMsg {
            kind: M_RESPONSE,
            status: status as u64,
            payload: payload.into(),
            error_detail: detail.to_string(),
            ..Default::default()
        };
        send_rpc_msg(ctx, reply.conn, reply.stream, &msg)?;
        ctx.finish(reply.conn, reply.stream);
        self.calls_served += 1;
        Ok(())
    }

    /// Refuse a request with [`Status::Overloaded`] plus a retry-after
    /// hint (server pushback). Not counted as served: no handler ran.
    pub fn respond_pushback(
        &mut self,
        ctx: &mut Ctx,
        reply: ReplyHandle,
        retry_after: Time,
        detail: &str,
    ) -> Result<()> {
        let msg = RpcMsg {
            kind: M_RESPONSE,
            status: Status::Overloaded as u64,
            error_detail: detail.to_string(),
            retry_after_ns: retry_after,
            ..Default::default()
        };
        send_rpc_msg(ctx, reply.conn, reply.stream, &msg)?;
        ctx.finish(reply.conn, reply.stream);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Streaming plane
    // ------------------------------------------------------------------

    /// Open an RPC stream to a peer for `service` (no method label).
    pub fn open_rpc_stream(
        &mut self,
        ctx: &mut Ctx,
        peer: &PeerId,
        service: &str,
    ) -> Result<StreamHandle> {
        self.open_rpc_stream_method(ctx, peer, service, "")
    }

    /// Open an RPC stream to a peer for `service`/`method`. The method
    /// name rides the STREAM_OPEN frame so the server's router can
    /// dispatch by method as well as service.
    pub fn open_rpc_stream_method(
        &mut self,
        ctx: &mut Ctx,
        peer: &PeerId,
        service: &str,
        method: &str,
    ) -> Result<StreamHandle> {
        let (conn, stream) = ctx.open_stream_class(peer, RPC_STREAM_PROTO, TrafficClass::Streaming)?;
        let msg = RpcMsg {
            kind: M_STREAM_OPEN,
            service: service.to_string(),
            method: method.to_string(),
            ..Default::default()
        };
        send_rpc_msg(ctx, conn, stream, &msg)?;
        let handle = StreamHandle { conn, stream };
        self.streams.insert(
            handle,
            StreamState {
                send_credits: INITIAL_CREDITS,
                recv_since_grant: 0,
                backlog: VecDeque::new(),
                next_seq: 0,
                ended: false,
            },
        );
        Ok(handle)
    }

    /// Send an item; queued if out of credits. Returns the backlog depth
    /// (the producer's backpressure signal — "writers monitor queue depth").
    /// The payload is owned zero-copy end-to-end: a queued or sent item
    /// shares the caller's buffer.
    pub fn send_item(&mut self, ctx: &mut Ctx, handle: StreamHandle, payload: impl Into<Buf>) -> usize {
        let Some(s) = self.streams.get_mut(&handle) else { return 0 };
        s.backlog.push_back(payload.into());
        Self::drain_backlog(ctx, handle, s);
        s.backlog.len()
    }

    fn drain_backlog(ctx: &mut Ctx, handle: StreamHandle, s: &mut StreamState) {
        while s.send_credits > 0 && !s.backlog.is_empty() {
            let payload = s.backlog.pop_front().unwrap();
            let msg = RpcMsg {
                kind: M_STREAM_ITEM,
                payload,
                seq: s.next_seq,
                ..Default::default()
            };
            s.next_seq += 1;
            s.send_credits -= 1;
            let _ = send_rpc_msg(ctx, handle.conn, handle.stream, &msg);
        }
    }

    /// Close a stream cleanly (after the backlog drains).
    pub fn end_stream(&mut self, ctx: &mut Ctx, handle: StreamHandle) {
        if let Some(s) = self.streams.get_mut(&handle) {
            s.ended = true;
            if s.backlog.is_empty() {
                let msg = RpcMsg {
                    kind: M_STREAM_END,
                    ..Default::default()
                };
                let _ = send_rpc_msg(ctx, handle.conn, handle.stream, &msg);
                ctx.finish(handle.conn, handle.stream);
            }
        }
    }

    /// Outstanding backlog for a stream (backpressure introspection).
    pub fn backlog(&self, handle: StreamHandle) -> usize {
        self.streams.get(&handle).map_or(0, |s| s.backlog.len())
    }

    // ------------------------------------------------------------------
    // Node hooks
    // ------------------------------------------------------------------

    /// Inbound message on an `/lattica/rpc/1` stream. Decoded header
    /// first: an expired or admission-rejected request is disposed of
    /// without its payload ever being sliced out of `msg`; for admitted
    /// traffic the payload is then materialized zero-copy.
    pub fn handle_unary_msg(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        conn: u64,
        stream: u64,
        msg: &Buf,
    ) -> Result<()> {
        let (m, payload_range) = peek_unary(msg)?;
        let slice_payload = |range: Option<(usize, usize)>| match range {
            Some((start, len)) => msg.slice(start..start + len),
            None => Buf::default(),
        };
        match m.kind {
            M_REQUEST => {
                let now = ctx.now();
                // Legacy requests (no deadline on the wire) get the
                // default budget measured from arrival.
                let deadline = if m.deadline_ns > 0 {
                    m.deadline_ns
                } else {
                    now + CALL_TIMEOUT
                };
                if deadline <= now {
                    // The caller has already given up: doing the work and
                    // sending a reply nobody reads is pure waste. Drop
                    // before any handler runs.
                    self.expired_dropped += 1;
                    ctx.reset(conn, stream, "deadline expired");
                    return Ok(());
                }
                // Admission control, still header-only: an overloaded
                // service answers from here — no payload decode, no
                // router dispatch, no handler.
                if let Admit::Shed { retry_after } = self.admission.check(now, &m.service, &peer) {
                    return self.respond_pushback(
                        ctx,
                        ReplyHandle { conn, stream },
                        retry_after,
                        &format!("service {:?} overloaded", m.service),
                    );
                }
                self.requests_decoded += 1;
                self.events.push_back(RpcEvent::Request {
                    peer,
                    service: m.service,
                    method: m.method,
                    payload: slice_payload(payload_range),
                    deadline,
                    reply: ReplyHandle { conn, stream },
                });
            }
            M_RESPONSE => {
                if let Some(call) = self.calls.remove(&(conn, stream)) {
                    self.call_index.remove(&call.call_id);
                    self.events.push_back(RpcEvent::Response {
                        call_id: call.call_id,
                        status: Status::from_u64(m.status),
                        payload: slice_payload(payload_range),
                        detail: m.error_detail,
                        rtt: ctx.now().saturating_sub(call.sent_at),
                        retry_after: m.retry_after_ns,
                    });
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Inbound message on an `/lattica/rpc-stream/1` stream.
    pub fn handle_stream_msg(
        &mut self,
        ctx: &mut Ctx,
        peer: PeerId,
        conn: u64,
        stream: u64,
        msg: &Buf,
    ) -> Result<()> {
        let handle = StreamHandle { conn, stream };
        let m = RpcMsg::decode_buf(msg)?;
        match m.kind {
            M_STREAM_OPEN => {
                self.streams.insert(
                    handle,
                    StreamState {
                        send_credits: INITIAL_CREDITS,
                        recv_since_grant: 0,
                        backlog: VecDeque::new(),
                        next_seq: 0,
                        ended: false,
                    },
                );
                self.events.push_back(RpcEvent::StreamOpened {
                    peer,
                    service: m.service,
                    method: m.method,
                    handle,
                });
            }
            M_STREAM_ITEM => {
                self.events.push_back(RpcEvent::StreamItem {
                    handle,
                    seq: m.seq,
                    payload: m.payload,
                });
                // Zero-copy note: in this in-process simulation the payload
                // is moved, not copied, from the transport reassembly buffer.
                if let Some(s) = self.streams.get_mut(&handle) {
                    s.recv_since_grant += 1;
                    if s.recv_since_grant >= CREDIT_BATCH {
                        let grant = RpcMsg {
                            kind: M_STREAM_CREDIT,
                            seq: s.recv_since_grant as u64,
                            ..Default::default()
                        };
                        s.recv_since_grant = 0;
                        let _ = encode_pooled(&grant, |b| ctx.send(conn, stream, b));
                    }
                }
            }
            M_STREAM_CREDIT => {
                if let Some(s) = self.streams.get_mut(&handle) {
                    s.send_credits += m.seq as u32;
                    Self::drain_backlog(ctx, handle, s);
                    let credits = s.send_credits;
                    if s.ended && s.backlog.is_empty() {
                        let end = RpcMsg {
                            kind: M_STREAM_END,
                            ..Default::default()
                        };
                        let _ = encode_pooled(&end, |b| ctx.send(conn, stream, b));
                        ctx.finish(conn, stream);
                    } else if credits > 0 {
                        self.events.push_back(RpcEvent::CreditsAvailable {
                            handle,
                            credits,
                        });
                    }
                }
            }
            M_STREAM_END => {
                self.streams.remove(&handle);
                self.events.push_back(RpcEvent::StreamEnded { handle });
            }
            _ => {}
        }
        Ok(())
    }

    /// Tick: expire overdue calls. Pops the deadline min-heap instead of
    /// scanning every pending call; entries for completed calls are
    /// discarded lazily.
    pub fn tick(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        while let Some(&Reverse((deadline, conn, stream))) = self.deadlines.peek() {
            if deadline > now {
                break;
            }
            self.deadlines.pop();
            // Stale heap entry: the call completed (or this slot was reused
            // with a different deadline) — skip.
            let live = self
                .calls
                .get(&(conn, stream))
                .map_or(false, |c| c.deadline == deadline);
            if !live {
                continue;
            }
            let call = self.calls.remove(&(conn, stream)).unwrap();
            self.call_index.remove(&call.call_id);
            ctx.reset(conn, stream, "call timeout");
            self.events.push_back(RpcEvent::CallFailed {
                call_id: call.call_id,
                reason: "timeout".into(),
            });
        }
    }

    /// Connection closed: fail its calls and streams.
    pub fn on_conn_closed(&mut self, conn: u64) {
        let dead_calls: Vec<(u64, u64)> = self
            .calls
            .keys()
            .filter(|(c, _)| *c == conn)
            .copied()
            .collect();
        for key in dead_calls {
            let call = self.calls.remove(&key).unwrap();
            self.call_index.remove(&call.call_id);
            self.events.push_back(RpcEvent::CallFailed {
                call_id: call.call_id,
                reason: "connection closed".into(),
            });
        }
        let dead_streams: Vec<StreamHandle> = self
            .streams
            .keys()
            .filter(|h| h.conn == conn)
            .copied()
            .collect();
        for h in dead_streams {
            self.streams.remove(&h);
            self.events.push_back(RpcEvent::StreamEnded { handle: h });
        }
    }

    pub fn pending_calls(&self) -> usize {
        self.calls.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip() {
        let m = RpcMsg {
            kind: M_REQUEST,
            service: "inference".into(),
            method: "forward".into(),
            payload: vec![1, 2, 3].into(),
            status: 0,
            seq: 9,
            deadline_ns: 123_456_789,
            error_detail: "shard 2 unavailable".into(),
            retry_after_ns: 250_000_000,
        };
        assert_eq!(RpcMsg::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn legacy_encoding_without_deadline_fields_decodes() {
        // A pre-deadline_ns peer encodes only fields 1–6. Decode must
        // succeed with the new fields at their defaults.
        let mut w = PbWriter::new();
        w.uint(1, M_REQUEST);
        w.string(2, "inference");
        w.string(3, "forward");
        w.bytes(4, &[9, 9, 9]);
        w.uint(5, 0);
        w.uint(6, 4);
        let legacy = w.finish();
        let m = RpcMsg::decode(&legacy).unwrap();
        assert_eq!(m.service, "inference");
        assert_eq!(m.deadline_ns, 0, "missing field 7 must default to 0");
        assert!(m.error_detail.is_empty());
        assert_eq!(m.retry_after_ns, 0, "missing field 9 must default to 0");
        // And the reverse: a message that doesn't use the new fields
        // encodes byte-identically to the legacy form.
        let modern = RpcMsg {
            kind: M_REQUEST,
            service: "inference".into(),
            method: "forward".into(),
            payload: vec![9, 9, 9].into(),
            seq: 4,
            ..Default::default()
        };
        assert_eq!(modern.encode(), legacy);
    }

    #[test]
    fn pushback_frame_roundtrips_and_pins_field_nine() {
        // An Overloaded response carries the hint in field 9; a
        // handcrafted writer producing the same fields must be
        // byte-identical (pins the wire format).
        let resp = RpcMsg {
            kind: M_RESPONSE,
            status: Status::Overloaded as u64,
            error_detail: "service \"shard\" overloaded".into(),
            retry_after_ns: 250_000_000,
            ..Default::default()
        };
        let mut w = PbWriter::new();
        w.uint(1, M_RESPONSE);
        w.uint(5, 4);
        w.string(8, "service \"shard\" overloaded");
        w.uint(9, 250_000_000);
        assert_eq!(resp.encode(), w.finish());
        let d = RpcMsg::decode(&resp.encode()).unwrap();
        assert_eq!(Status::from_u64(d.status), Status::Overloaded);
        assert_eq!(d.retry_after_ns, 250_000_000);
    }

    #[test]
    fn peek_unary_reads_header_without_materializing_payload() {
        let m = RpcMsg {
            kind: M_REQUEST,
            service: "shard".into(),
            method: "forward".into(),
            payload: vec![0x5Au8; 2048].into(),
            deadline_ns: 77,
            ..Default::default()
        };
        let wire = m.encode_buf();
        let (h, range) = peek_unary(&wire).unwrap();
        assert_eq!(h.service, "shard");
        assert_eq!(h.method, "forward");
        assert_eq!(h.deadline_ns, 77);
        assert!(h.payload.is_empty(), "peek leaves the payload untouched");
        assert_eq!(
            wire.ref_count(),
            1,
            "no payload slice was taken from the wire buffer"
        );
        let (start, len) = range.unwrap();
        assert_eq!(wire.slice(start..start + len).as_slice(), &[0x5Au8; 2048][..]);
    }

    #[test]
    fn decode_buf_payload_is_zero_copy() {
        let m = RpcMsg {
            kind: M_RESPONSE,
            payload: vec![0xA5u8; 4096].into(),
            ..Default::default()
        };
        let wire = m.encode_buf();
        let d = RpcMsg::decode_buf(&wire).unwrap();
        assert_eq!(d, m);
        assert_eq!(wire.ref_count(), 2, "payload shares the wire buffer");
    }

    #[test]
    fn status_mapping() {
        assert_eq!(Status::from_u64(0), Status::Ok);
        assert_eq!(Status::from_u64(1), Status::NotFound);
        assert_eq!(Status::from_u64(3), Status::Unavailable);
        assert_eq!(Status::from_u64(4), Status::Overloaded);
        assert_eq!(Status::from_u64(99), Status::Error);
    }
}
