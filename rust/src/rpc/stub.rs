//! Client-side typed stub: per-call deadlines, idempotent retries with
//! exponential backoff + jitter, hedged requests after an adaptive p95
//! delay with cancel-on-first-win, and multi-target failover across a
//! provider list.
//!
//! A [`Stub`] is a client handle to one remote service. One *logical
//! call* (an "op") can fan out into several *wire attempts*; the stub
//! tracks them, cancels losers, and surfaces exactly one [`StubDone`]
//! per op:
//!
//! ```ignore
//! let mut stub = Stub::new("shard", vec![replica_a, replica_b]);
//! let op = stub.call(&mut node, &mut net, "forward", req.encode());
//! // drive loop:
//! for ev in node_events { stub.on_node_event(&mut node, &mut net, &ev); }
//! stub.tick(&mut node, &mut net);
//! while let Some(done) = stub.poll_done() { /* done.status, done.payload */ }
//! ```
//!
//! Retry/hedge/failover state machine (per op):
//!
//! * the first attempt goes to the stub's *preferred* target (the last
//!   one that answered `Ok`, so failover is sticky and later ops don't
//!   re-pay the discovery cost of a dead replica);
//! * a retryable failure (`Unavailable`, local timeout, connection loss)
//!   schedules the next attempt on the *next* target after an
//!   exponential backoff with jitter;
//! * with hedging enabled, a speculative second attempt is issued after
//!   an adaptive delay (p95 of recent RTTs; a configured initial delay
//!   until enough samples exist). First `Ok` wins; every other in-flight
//!   attempt is cancelled at the RPC layer;
//! * `Overloaded` is server pushback, not a transient fault: the target
//!   is marked shedding until its `retry_after_ns` hint expires, retries
//!   prefer failing over to a replica that is *not* shedding, any wait
//!   is floored at the hint, hedging is suppressed while a target
//!   signals overload, and when every replica is shedding and the hint
//!   exceeds the remaining budget the op fails fast — retrying into a
//!   saturated server is the amplifier that makes overload metastable;
//! * non-retryable failures (`Error`, `NotFound`) and overall-deadline
//!   expiry finish the op immediately. Deadline expiry surfaces as
//!   `Unavailable` with a "deadline exceeded" detail.
//!
//! Each attempt's wire deadline is the *remaining* overall budget
//! (optionally clipped by `attempt_timeout`), so servers — including
//! nested calls made by their handlers — always observe the shrunken
//! budget, never a fresh one.

use crate::identity::PeerId;
use crate::metrics::StubStats;
use crate::netsim::{Net, Time, MILLI};
use crate::node::{LatticaNode, NodeEvent};
use crate::protocols::Ctx;
use crate::rpc::{RpcEvent, Status, CALL_TIMEOUT};
use crate::util::buf::Buf;
use crate::util::Rng;
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Retry policy for a logical call. The default ([`RetryPolicy::none`])
/// never retries — only mark calls retryable when they are idempotent.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total wire attempts allowed through the retry path (≥ 1). Hedged
    /// attempts are budgeted separately.
    pub max_attempts: u32,
    /// First backoff; doubles per retry.
    pub base_backoff: Time,
    pub max_backoff: Time,
    /// Multiplicative jitter fraction in `[0, 1]`: each backoff is scaled
    /// by a uniform factor from `1 - jitter/2` to `1 + jitter/2`, so
    /// synchronized callers decorrelate.
    pub jitter: f64,
    /// Also fail over on a served [`Status::Error`] response (not just
    /// `Unavailable`/local failures). For replicated idempotent services
    /// where one bad replica (stale params, local corruption) should not
    /// fail the call while a healthy sibling exists. `NotFound` (unknown
    /// service/method) always fails fast.
    pub retry_on_error: bool,
}

impl RetryPolicy {
    /// No retries (safe for non-idempotent methods).
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            base_backoff: 0,
            max_backoff: 0,
            jitter: 0.0,
            retry_on_error: false,
        }
    }

    /// Sensible default for idempotent methods: 3 attempts, 50 ms base
    /// backoff doubling to at most 2 s, 50 % jitter.
    pub fn idempotent() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            base_backoff: 50 * MILLI,
            max_backoff: 2000 * MILLI,
            jitter: 0.5,
            retry_on_error: false,
        }
    }
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy::none()
    }
}

/// Hedging policy: issue one speculative second attempt per op after an
/// adaptive delay, racing the primary.
#[derive(Clone, Copy, Debug)]
pub struct HedgePolicy {
    pub enabled: bool,
    /// Lower bound on the adaptive delay (avoid hedging everything on
    /// fast paths where p95 is tiny).
    pub min_delay: Time,
    /// Delay used until enough RTT samples exist for a p95 estimate.
    pub initial_delay: Time,
}

impl HedgePolicy {
    pub fn off() -> HedgePolicy {
        HedgePolicy {
            enabled: false,
            min_delay: 2 * MILLI,
            initial_delay: 100 * MILLI,
        }
    }

    pub fn on() -> HedgePolicy {
        HedgePolicy {
            enabled: true,
            ..HedgePolicy::off()
        }
    }
}

impl Default for HedgePolicy {
    fn default() -> HedgePolicy {
        HedgePolicy::off()
    }
}

/// Per-call options; [`Stub::call`] uses the stub's defaults.
#[derive(Clone, Copy, Debug)]
pub struct CallOptions {
    /// Overall budget for the logical call (all attempts included).
    pub deadline: Time,
    /// Per-attempt budget; `None` = whatever remains of `deadline`. Set
    /// this smaller than `deadline` so the retry path has room to act.
    pub attempt_timeout: Option<Time>,
    pub retry: RetryPolicy,
    pub hedge: HedgePolicy,
}

impl Default for CallOptions {
    fn default() -> CallOptions {
        CallOptions {
            deadline: CALL_TIMEOUT,
            attempt_timeout: None,
            retry: RetryPolicy::none(),
            hedge: HedgePolicy::off(),
        }
    }
}

/// Final outcome of one logical call.
#[derive(Clone, Debug)]
pub struct StubDone {
    /// Op id returned by [`Stub::call`].
    pub op: u64,
    /// `Ok`, or the final failure status (local deadline expiry and
    /// connection failures surface as `Unavailable`).
    pub status: Status,
    pub payload: Buf,
    /// Failure detail: the server's `error_detail` when one arrived, or
    /// a local reason ("deadline exceeded", "connection closed"…).
    pub detail: String,
    /// Logical-call latency (first issue → completion).
    pub rtt: Time,
    /// Wire attempts this op used.
    pub attempts: u32,
    /// The winning response came from a hedged attempt.
    pub hedge_won: bool,
}

struct Attempt {
    call_id: u64,
    /// Index into `targets`.
    target: usize,
    hedge: bool,
}

struct OpState {
    method: String,
    payload: Buf,
    started: Time,
    /// Absolute overall deadline.
    deadline: Time,
    opts: CallOptions,
    attempts_issued: u32,
    retries_done: u32,
    inflight: Vec<Attempt>,
    /// Backoff timer for the next retry attempt.
    retry_at: Option<Time>,
    hedge_at: Option<Time>,
    /// Target index the next attempt will use.
    next_target: usize,
    /// Target of the most recently issued attempt.
    last_target: Option<usize>,
    last_status: Status,
    last_detail: String,
}

/// Sliding window of recent op RTTs for the adaptive hedge delay.
#[derive(Default)]
struct LatWindow {
    samples: Vec<Time>,
    pos: usize,
}

const LAT_WINDOW: usize = 64;
/// Minimum samples before the p95 estimate is trusted.
const LAT_MIN_SAMPLES: usize = 8;
/// Floor applied when an `Overloaded` response carries no
/// `retry_after_ns` hint: treat the target as shedding for this long.
const PUSHBACK_FLOOR: Time = 200 * MILLI;

impl LatWindow {
    fn record(&mut self, t: Time) {
        if self.samples.len() < LAT_WINDOW {
            self.samples.push(t);
        } else {
            self.samples[self.pos] = t;
            self.pos = (self.pos + 1) % LAT_WINDOW;
        }
    }

    fn p95(&self) -> Option<Time> {
        if self.samples.len() < LAT_MIN_SAMPLES {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let idx = (sorted.len() * 95 / 100).min(sorted.len() - 1);
        Some(sorted[idx])
    }
}

/// Client handle to one remote service; see the module docs.
pub struct Stub {
    pub service: String,
    /// Provider list in preference order; attempts fail over across it.
    targets: Vec<PeerId>,
    /// Default options for [`Stub::call`].
    pub opts: CallOptions,
    /// Index of the target new ops try first (sticky failover).
    preferred: usize,
    next_op: u64,
    ops: BTreeMap<u64, OpState>,
    /// rpc call id → op id.
    by_call: HashMap<u64, u64>,
    lat: LatWindow,
    done: VecDeque<StubDone>,
    rng: Rng,
    /// Per-target pushback state: until when each peer said it is
    /// shedding (absolute time, from `Overloaded` + `retry_after_ns`).
    overload_until: HashMap<PeerId, Time>,
    pub stats: StubStats,
}

impl Stub {
    pub fn new(service: &str, targets: Vec<PeerId>) -> Stub {
        // Jitter seed derived from (service, targets): deterministic for a
        // given deployment, but different stubs draw different jitter, so
        // simultaneous failures don't produce synchronized retry storms.
        let mut seed = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
        for b in service.bytes() {
            seed = (seed ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        for t in &targets {
            for &b in t.as_bytes() {
                seed = (seed ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        Stub {
            service: service.to_string(),
            targets,
            opts: CallOptions::default(),
            preferred: 0,
            next_op: 1,
            ops: BTreeMap::new(),
            by_call: HashMap::new(),
            lat: LatWindow::default(),
            done: VecDeque::new(),
            rng: Rng::new(seed),
            overload_until: HashMap::new(),
            stats: StubStats::default(),
        }
    }

    pub fn with_options(mut self, opts: CallOptions) -> Stub {
        self.opts = opts;
        self
    }

    /// Replace the provider list (e.g. after fresh DHT discovery).
    pub fn set_targets(&mut self, targets: Vec<PeerId>) {
        self.targets = targets;
        self.preferred = 0;
    }

    pub fn targets(&self) -> &[PeerId] {
        &self.targets
    }

    /// Outstanding logical calls.
    pub fn in_flight(&self) -> usize {
        self.ops.len()
    }

    /// Issue a logical call with the stub's default options.
    pub fn call(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        method: &str,
        payload: impl Into<Buf>,
    ) -> u64 {
        let opts = self.opts;
        self.call_opts(node, net, method, payload, opts)
    }

    /// Issue a logical call with explicit options; returns the op id.
    /// The op always completes — success, failure or deadline — via
    /// [`Stub::poll_done`], provided events are fed and `tick` runs.
    pub fn call_opts(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        method: &str,
        payload: impl Into<Buf>,
        opts: CallOptions,
    ) -> u64 {
        let op = self.next_op;
        self.next_op += 1;
        self.stats.ops += 1;
        let now = net.now();
        let mut state = OpState {
            method: method.to_string(),
            payload: payload.into(),
            started: now,
            deadline: now + opts.deadline,
            opts,
            attempts_issued: 0,
            retries_done: 0,
            inflight: Vec::new(),
            retry_at: None,
            hedge_at: None,
            next_target: self.preferred.min(self.targets.len().saturating_sub(1)),
            last_target: None,
            last_status: Status::Unavailable,
            last_detail: String::new(),
        };
        if self.targets.is_empty() {
            state.last_detail = "no targets".into();
            self.ops.insert(op, state);
            self.finish(node, net, op, Status::Unavailable, Buf::new(), false);
            return op;
        }
        // Pushback-aware first attempt: keep the sticky target while it
        // is not shedding; otherwise pick the replica whose retry-after
        // window clears soonest.
        let n = self.targets.len();
        let sticky = state.next_target % n;
        let (idx, wait) = if !self.target_overloaded(now, sticky) {
            (sticky, 0)
        } else {
            self.best_failover(now, None).unwrap_or((sticky, 0))
        };
        state.next_target = idx;
        if wait == 0 {
            if opts.hedge.enabled {
                if self.any_overloaded(now) {
                    // No speculative load while a replica signals overload.
                    self.stats.hedges_suppressed += 1;
                } else {
                    state.hedge_at = Some(now + self.hedge_delay(&opts));
                }
            }
            self.ops.insert(op, state);
            self.issue_attempt(node, net, op, false);
        } else if now + wait >= state.deadline {
            // Every replica is shedding and the earliest window outlives
            // the budget: fail fast with zero wire attempts instead of
            // adding load a server already refused.
            state.last_status = Status::Overloaded;
            state.last_detail = "all targets overloaded (pushback)".into();
            self.ops.insert(op, state);
            self.finish(node, net, op, Status::Overloaded, Buf::new(), false);
        } else {
            // Every replica is shedding but the budget can cover the
            // wait: defer the first attempt until the window clears.
            if opts.hedge.enabled {
                self.stats.hedges_suppressed += 1;
            }
            state.retry_at = Some(now + wait);
            self.ops.insert(op, state);
        }
        op
    }

    /// Feed a node event; returns true if it belonged to this stub.
    pub fn on_node_event(&mut self, node: &mut LatticaNode, net: &mut Net, ev: &NodeEvent) -> bool {
        match ev {
            NodeEvent::Rpc(e) => self.on_rpc_event(node, net, e),
            _ => false,
        }
    }

    /// Feed an RPC event; returns true if it belonged to this stub.
    pub fn on_rpc_event(&mut self, node: &mut LatticaNode, net: &mut Net, ev: &RpcEvent) -> bool {
        match ev {
            RpcEvent::Response {
                call_id,
                status,
                payload,
                detail,
                retry_after,
                ..
            } => {
                let Some(&op) = self.by_call.get(call_id) else {
                    return false;
                };
                self.by_call.remove(call_id);
                let Some(state) = self.ops.get_mut(&op) else {
                    return true;
                };
                let attempt_idx = state.inflight.iter().position(|a| a.call_id == *call_id);
                let (hedge, won_target) = match attempt_idx {
                    Some(i) => {
                        let a = state.inflight.remove(i);
                        (a.hedge, Some(a.target))
                    }
                    None => (false, None),
                };
                let retry_on_error = state.opts.retry.retry_on_error;
                match status {
                    Status::Ok => {
                        // Sticky preference follows the replica that
                        // actually answered, not the last one tried.
                        if let Some(t) = won_target {
                            state.last_target = Some(t);
                        }
                        self.lat.record(net.now().saturating_sub(state.started));
                        self.finish(node, net, op, Status::Ok, payload.clone(), hedge);
                    }
                    Status::Overloaded => {
                        // Server pushback: remember until when this
                        // target said it is shedding, then prefer
                        // failover over retry-in-place.
                        self.stats.overloaded += 1;
                        let hint = if *retry_after > 0 {
                            *retry_after
                        } else {
                            PUSHBACK_FLOOR
                        };
                        if let Some(p) = won_target.and_then(|t| self.targets.get(t)).copied() {
                            let until = net.now() + hint;
                            let e = self.overload_until.entry(p).or_insert(0);
                            if *e < until {
                                *e = until;
                            }
                        }
                        self.note_overload(node, net, op, detail.clone());
                    }
                    Status::Unavailable => {
                        self.note_failure(node, net, op, Status::Unavailable, detail.clone());
                    }
                    Status::Error if retry_on_error => {
                        // Opt-in replica failover on served errors.
                        self.note_failure(node, net, op, Status::Error, detail.clone());
                    }
                    other => {
                        // Non-retryable: surface the server's verdict as-is.
                        let state = self.ops.get_mut(&op).unwrap();
                        state.last_status = *other;
                        state.last_detail = detail.clone();
                        self.finish(node, net, op, *other, payload.clone(), false);
                    }
                }
                true
            }
            RpcEvent::CallFailed { call_id, reason } => {
                let Some(&op) = self.by_call.get(call_id) else {
                    return false;
                };
                self.by_call.remove(call_id);
                if let Some(state) = self.ops.get_mut(&op) {
                    state.inflight.retain(|a| a.call_id != *call_id);
                    self.note_failure(node, net, op, Status::Unavailable, reason.clone());
                }
                true
            }
            _ => false,
        }
    }

    /// Drive timers: overall deadlines, retry backoffs, hedge launches.
    /// Call once per event-loop iteration.
    pub fn tick(&mut self, node: &mut LatticaNode, net: &mut Net) {
        let now = net.now();
        let op_ids: Vec<u64> = self.ops.keys().copied().collect();
        for op in op_ids {
            let Some(state) = self.ops.get(&op) else { continue };
            if now >= state.deadline {
                let detail = if state.last_detail.is_empty() {
                    "deadline exceeded".to_string()
                } else {
                    format!("deadline exceeded (last error: {})", state.last_detail)
                };
                self.stats.deadline_expired += 1;
                if let Some(s) = self.ops.get_mut(&op) {
                    s.last_detail = detail;
                }
                self.finish(node, net, op, Status::Unavailable, Buf::new(), false);
                continue;
            }
            if state.retry_at.is_some_and(|t| now >= t) {
                if let Some(s) = self.ops.get_mut(&op) {
                    s.retry_at = None;
                    s.retries_done += 1;
                }
                self.stats.retries += 1;
                self.issue_attempt(node, net, op, false);
                continue;
            }
            let hedge_due = state.hedge_at.is_some_and(|t| now >= t)
                && state.inflight.len() == 1
                && !state.inflight[0].hedge;
            if hedge_due {
                if self.any_overloaded(now) {
                    // Speculative duplicates are pure amplification while
                    // any replica is shedding: drop the hedge entirely.
                    if let Some(s) = self.ops.get_mut(&op) {
                        s.hedge_at = None;
                    }
                    self.stats.hedges_suppressed += 1;
                    continue;
                }
                if let Some(s) = self.ops.get_mut(&op) {
                    s.hedge_at = None;
                    // Hedge races a *different* target when one exists.
                    s.next_target = (s.next_target + 1) % self.targets.len().max(1);
                }
                self.stats.hedges += 1;
                self.issue_attempt(node, net, op, true);
            }
        }
    }

    /// Next completed logical call, if any.
    pub fn poll_done(&mut self) -> Option<StubDone> {
        self.done.pop_front()
    }

    // ------------------------------------------------------------------

    fn hedge_delay(&self, opts: &CallOptions) -> Time {
        self.lat
            .p95()
            .map(|t| t.max(opts.hedge.min_delay))
            .unwrap_or(opts.hedge.initial_delay)
    }

    /// Issue one wire attempt for `op` to its current target.
    fn issue_attempt(&mut self, node: &mut LatticaNode, net: &mut Net, op: u64, hedge: bool) {
        if self.targets.is_empty() {
            self.note_failure(node, net, op, Status::Unavailable, "no targets".into());
            return;
        }
        let Some(state) = self.ops.get_mut(&op) else { return };
        let now = net.now();
        let target = state.next_target % self.targets.len();
        let peer = self.targets[target];
        let remaining = state.deadline.saturating_sub(now);
        let budget = match state.opts.attempt_timeout {
            Some(t) => t.min(remaining),
            None => remaining,
        };
        if state.last_target.is_some_and(|t| t != target) {
            self.stats.failovers += 1;
        }
        state.last_target = Some(target);
        state.attempts_issued += 1;
        self.stats.attempts += 1;
        let res = {
            let LatticaNode { swarm, rpc, .. } = node;
            let mut ctx = Ctx::new(swarm, net);
            rpc.call_opts(
                &mut ctx,
                &peer,
                &self.service,
                &state.method,
                state.payload.clone(),
                budget,
            )
        };
        match res {
            Ok(call_id) => {
                state.inflight.push(Attempt {
                    call_id,
                    target,
                    hedge,
                });
                self.by_call.insert(call_id, op);
            }
            Err(e) => {
                // Could not even send (no route, dial refused): treat as a
                // retryable failure of this target.
                self.note_failure(node, net, op, Status::Unavailable, e.to_string());
            }
        }
    }

    /// Whether `targets[idx]` is inside a pushback window.
    fn target_overloaded(&self, now: Time, idx: usize) -> bool {
        self.targets
            .get(idx)
            .and_then(|p| self.overload_until.get(p))
            .is_some_and(|&t| t > now)
    }

    /// Whether any target is inside a pushback window (hedge gate).
    fn any_overloaded(&self, now: Time) -> bool {
        (0..self.targets.len()).any(|i| self.target_overloaded(now, i))
    }

    /// Target with the shortest remaining pushback wait (0 for a clear
    /// one); at equal waits, a target different from `exclude` wins.
    fn best_failover(&self, now: Time, exclude: Option<usize>) -> Option<(usize, Time)> {
        self.targets
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let wait = self
                    .overload_until
                    .get(p)
                    .map_or(0, |&t| t.saturating_sub(now));
                (i, wait)
            })
            .min_by_key(|&(i, wait)| (wait, Some(i) == exclude, i))
    }

    fn jittered_backoff(&mut self, retry: RetryPolicy, retries_done: u32) -> Time {
        let mut backoff = retry
            .base_backoff
            .saturating_mul(1u64 << retries_done.min(20))
            .min(retry.max_backoff.max(retry.base_backoff));
        if retry.jitter > 0.0 && backoff > 0 {
            let f = 1.0 - retry.jitter / 2.0 + retry.jitter * self.rng.gen_f64();
            backoff = (backoff as f64 * f) as Time;
        }
        backoff
    }

    /// React to server pushback. Unlike [`Stub::note_failure`] (retry
    /// next target after plain backoff), pushback (a) never hedges, (b)
    /// prefers a replica that is not shedding, (c) floors the wait at
    /// the server's hint when every replica is shedding, and (d) fails
    /// fast when that floored wait cannot fit the remaining budget — a
    /// permanently-shedding target sees at most the one attempt that
    /// taught us it is shedding.
    fn note_overload(&mut self, node: &mut LatticaNode, net: &mut Net, op: u64, detail: String) {
        let now = net.now();
        let info = {
            let Some(state) = self.ops.get_mut(&op) else { return };
            state.last_status = Status::Overloaded;
            state.last_detail = detail;
            if state.hedge_at.take().is_some() {
                self.stats.hedges_suppressed += 1;
            }
            if state.inflight.is_empty() {
                Some((
                    state.opts.retry,
                    state.deadline,
                    state.retries_done,
                    state.last_target,
                ))
            } else {
                // A racing attempt may still win; just stop hedging.
                None
            }
        };
        let Some((retry, deadline, retries_done, shed_target)) = info else {
            return;
        };
        let can_retry =
            retries_done + 1 < retry.max_attempts && now < deadline && !self.targets.is_empty();
        if can_retry {
            if let Some((alt, wait)) = self.best_failover(now, shed_target) {
                let backoff = self.jittered_backoff(retry, retries_done).max(wait);
                if now + backoff < deadline {
                    let state = self.ops.get_mut(&op).expect("op checked above");
                    state.next_target = alt;
                    state.retry_at = Some(now + backoff);
                    return;
                }
            }
        }
        // Out of attempts, or the floored wait outlives the budget:
        // surface the server's verdict now instead of burning the rest
        // of the caller's deadline against a shedding service.
        self.finish(node, net, op, Status::Overloaded, Buf::new(), false);
    }

    /// Record a retryable failure; schedule the next attempt on the next
    /// target, or finish the op if attempts/budget are exhausted.
    fn note_failure(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        op: u64,
        status: Status,
        detail: String,
    ) {
        let now = net.now();
        let Some(state) = self.ops.get_mut(&op) else { return };
        state.last_status = status;
        state.last_detail = detail;
        // Another attempt (e.g. the hedge) is still racing: let it run.
        if !state.inflight.is_empty() {
            return;
        }
        let retry = state.opts.retry;
        // `retries_done` counts backoff-path reissues only, so hedged
        // attempts never consume the retry budget.
        let deadline_passed = now >= state.deadline;
        let can_retry = state.retries_done + 1 < retry.max_attempts
            && !deadline_passed
            && !self.targets.is_empty();
        if !can_retry {
            let status = if deadline_passed {
                // Normalize budget exhaustion regardless of which timer
                // observed it first (the RPC layer's coarse proto tick
                // can beat Stub::tick to the punch): same status, same
                // detail shape, same counter as the tick path.
                self.stats.deadline_expired += 1;
                if let Some(s) = self.ops.get_mut(&op) {
                    if s.last_detail.is_empty() {
                        s.last_detail = "deadline exceeded".to_string();
                    } else if !s.last_detail.contains("deadline exceeded") {
                        s.last_detail =
                            format!("deadline exceeded (last error: {})", s.last_detail);
                    }
                }
                Status::Unavailable
            } else {
                state.last_status
            };
            self.finish(node, net, op, status, Buf::new(), false);
            return;
        }
        // Fail over to the next target for the retry.
        state.next_target = (state.next_target + 1) % self.targets.len().max(1);
        let mut backoff = retry
            .base_backoff
            .saturating_mul(1u64 << state.retries_done.min(20))
            .min(retry.max_backoff.max(retry.base_backoff));
        if retry.jitter > 0.0 && backoff > 0 {
            let f = 1.0 - retry.jitter / 2.0 + retry.jitter * self.rng.gen_f64();
            backoff = (backoff as f64 * f) as Time;
        }
        state.retry_at = Some(now + backoff);
    }

    /// Complete an op: cancel losing attempts, emit the `StubDone`.
    fn finish(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        op: u64,
        status: Status,
        payload: Buf,
        hedge_won: bool,
    ) {
        let Some(state) = self.ops.remove(&op) else { return };
        for a in &state.inflight {
            self.by_call.remove(&a.call_id);
            let LatticaNode { swarm, rpc, .. } = &mut *node;
            let mut ctx = Ctx::new(swarm, net);
            if rpc.cancel(&mut ctx, a.call_id) {
                self.stats.cancelled += 1;
            }
        }
        match status {
            Status::Ok => {
                self.stats.ok += 1;
                if hedge_won {
                    self.stats.hedge_wins += 1;
                }
                if let Some(t) = state.last_target {
                    self.preferred = t;
                }
            }
            _ => self.stats.failed += 1,
        }
        self.done.push_back(StubDone {
            op,
            status,
            payload,
            detail: if status == Status::Ok {
                String::new()
            } else {
                state.last_detail.clone()
            },
            rtt: net.now().saturating_sub(state.started),
            attempts: state.attempts_issued,
            hedge_won,
        });
    }
}
