//! Token-bucket admission control for the unary RPC plane.
//!
//! A service driven past capacity must shed load *cheaply* — before the
//! payload is decoded and long before a handler runs — or the work of
//! rejecting requests itself becomes the bottleneck (the metastable-
//! failure amplifier the overload scenario reproduces). The RPC layer
//! consults [`Admission::check`] from the request header alone: a
//! service-wide token bucket bounds sustained intake, an optional
//! per-peer bucket stops one hot client from draining the shared bucket,
//! and a rejected request is answered [`Status::Overloaded`] with a
//! `retry_after_ns` hint derived from the bucket's refill rate (or
//! pinned by the policy), so well-behaved stubs back off instead of
//! retrying into the saturation.
//!
//! [`Status::Overloaded`]: crate::rpc::Status::Overloaded

use crate::identity::PeerId;
use crate::netsim::{Time, SECOND};
use std::collections::HashMap;

/// Cap on the derived retry-after hint (a near-zero refill rate would
/// otherwise tell clients to go away for hours).
const MAX_RETRY_AFTER: Time = 30 * SECOND;
/// Evict idle per-peer buckets past this population.
const MAX_PEER_BUCKETS: usize = 8192;
/// A peer bucket untouched for this long is idle and reclaimable.
const PEER_BUCKET_IDLE: Time = 10 * SECOND;

/// Admission policy for one service.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Sustained admission rate for the whole service, requests/second.
    pub rate: f64,
    /// Bucket depth in requests (burst allowance above the sustained
    /// rate; also the bucket's initial fill).
    pub burst: f64,
    /// Optional per-peer rate cap (requests/second); 0 disables the
    /// per-peer buckets.
    pub peer_rate: f64,
    /// Per-peer bucket depth.
    pub peer_burst: f64,
    /// Fixed pushback hint attached to `Overloaded` responses. 0 derives
    /// the hint from the bucket: the time until one token accrues.
    pub retry_after: Time,
}

impl AdmissionPolicy {
    /// Service-wide bucket only.
    pub fn rate(rate: f64, burst: f64) -> AdmissionPolicy {
        AdmissionPolicy {
            rate,
            burst,
            peer_rate: 0.0,
            peer_burst: 0.0,
            retry_after: 0,
        }
    }

    /// Add a per-peer cap on top of the service-wide bucket.
    pub fn with_peer_rate(mut self, rate: f64, burst: f64) -> AdmissionPolicy {
        self.peer_rate = rate;
        self.peer_burst = burst;
        self
    }

    /// Pin the pushback hint instead of deriving it from the refill rate.
    pub fn with_retry_after(mut self, t: Time) -> AdmissionPolicy {
        self.retry_after = t;
        self
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct TokenBucket {
    tokens: f64,
    last: Time,
}

impl TokenBucket {
    fn full(now: Time, burst: f64) -> TokenBucket {
        TokenBucket { tokens: burst, last: now }
    }

    /// Take one token, or report how long until one accrues.
    fn try_take(&mut self, now: Time, rate: f64, burst: f64) -> Result<(), Time> {
        let dt = now.saturating_sub(self.last) as f64 / SECOND as f64;
        self.last = now;
        self.tokens = (self.tokens + dt * rate).min(burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            return Ok(());
        }
        if rate <= 0.0 {
            return Err(MAX_RETRY_AFTER);
        }
        let wait = ((1.0 - self.tokens) / rate) * SECOND as f64;
        Err((wait as Time).min(MAX_RETRY_AFTER).max(1))
    }

    fn refund(&mut self, burst: f64) {
        self.tokens = (self.tokens + 1.0).min(burst);
    }
}

/// Admission verdict for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    Ok,
    /// Reject with `Status::Overloaded`; the hint rides the wire as
    /// `retry_after_ns`.
    Shed { retry_after: Time },
}

/// Counters; surfaced through [`RouterStats::shed_predecode`] so
/// operators read sheds alongside the dispatch counters.
///
/// [`RouterStats::shed_predecode`]: crate::metrics::RouterStats::shed_predecode
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Requests admitted through a configured policy.
    pub admitted: u64,
    /// Requests rejected before payload decode.
    pub shed_predecode: u64,
}

/// Per-node admission state: one policy + bucket pair per service.
#[derive(Default)]
pub struct Admission {
    policies: HashMap<String, AdmissionPolicy>,
    service_buckets: HashMap<String, TokenBucket>,
    peer_buckets: HashMap<(String, PeerId), TokenBucket>,
    pub stats: AdmissionStats,
}

impl Admission {
    pub fn set_policy(&mut self, service: &str, p: AdmissionPolicy) {
        self.policies.insert(service.to_string(), p);
        self.service_buckets.remove(service);
    }

    pub fn clear_policy(&mut self, service: &str) {
        self.policies.remove(service);
        self.service_buckets.remove(service);
        self.peer_buckets.retain(|(s, _), _| s != service);
    }

    pub fn has_policy(&self, service: &str) -> bool {
        self.policies.contains_key(service)
    }

    /// Decide from the request header whether `peer`'s request for
    /// `service` gets in. Services without a policy always admit (and
    /// are not counted — admission is opt-in per service).
    pub fn check(&mut self, now: Time, service: &str, peer: &PeerId) -> Admit {
        if self.policies.is_empty() {
            return Admit::Ok;
        }
        let Some(p) = self.policies.get(service).copied() else {
            return Admit::Ok;
        };
        let bucket = self
            .service_buckets
            .entry(service.to_string())
            .or_insert_with(|| TokenBucket::full(now, p.burst));
        if let Err(wait) = bucket.try_take(now, p.rate, p.burst) {
            self.stats.shed_predecode += 1;
            let retry_after = if p.retry_after > 0 { p.retry_after } else { wait };
            return Admit::Shed { retry_after };
        }
        if p.peer_rate > 0.0 {
            let pb = self
                .peer_buckets
                .entry((service.to_string(), *peer))
                .or_insert_with(|| TokenBucket::full(now, p.peer_burst));
            if let Err(wait) = pb.try_take(now, p.peer_rate, p.peer_burst) {
                // Hand the service-wide token back: the request never got in.
                if let Some(b) = self.service_buckets.get_mut(service) {
                    b.refund(p.burst);
                }
                self.stats.shed_predecode += 1;
                let retry_after = if p.retry_after > 0 { p.retry_after } else { wait };
                return Admit::Shed { retry_after };
            }
            if self.peer_buckets.len() > MAX_PEER_BUCKETS {
                self.peer_buckets
                    .retain(|_, b| now.saturating_sub(b.last) < PEER_BUCKET_IDLE);
            }
        }
        self.stats.admitted += 1;
        Admit::Ok
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::MILLI;

    fn peer(n: u8) -> PeerId {
        PeerId([n; 32])
    }

    #[test]
    fn bucket_admits_burst_then_sheds() {
        let mut a = Admission::default();
        a.set_policy("shard", AdmissionPolicy::rate(100.0, 4.0));
        let now = SECOND;
        for _ in 0..4 {
            assert_eq!(a.check(now, "shard", &peer(1)), Admit::Ok);
        }
        let Admit::Shed { retry_after } = a.check(now, "shard", &peer(1)) else {
            panic!("5th request within the same instant must shed");
        };
        // One token accrues in 10ms at 100 req/s.
        assert_eq!(retry_after, 10 * MILLI);
        assert_eq!(a.stats.admitted, 4);
        assert_eq!(a.stats.shed_predecode, 1);
    }

    #[test]
    fn bucket_refills_over_time() {
        let mut a = Admission::default();
        a.set_policy("shard", AdmissionPolicy::rate(10.0, 1.0));
        assert_eq!(a.check(SECOND, "shard", &peer(1)), Admit::Ok);
        assert!(matches!(a.check(SECOND, "shard", &peer(1)), Admit::Shed { .. }));
        // 100ms later one token has accrued.
        assert_eq!(a.check(SECOND + 100 * MILLI, "shard", &peer(1)), Admit::Ok);
    }

    #[test]
    fn pinned_retry_after_overrides_derived_hint() {
        let mut a = Admission::default();
        a.set_policy(
            "shard",
            AdmissionPolicy::rate(0.0, 0.0).with_retry_after(2 * SECOND),
        );
        let Admit::Shed { retry_after } = a.check(SECOND, "shard", &peer(1)) else {
            panic!("rate 0 sheds everything");
        };
        assert_eq!(retry_after, 2 * SECOND);
    }

    #[test]
    fn per_peer_cap_protects_other_peers() {
        let mut a = Admission::default();
        a.set_policy(
            "shard",
            AdmissionPolicy::rate(1000.0, 1000.0).with_peer_rate(10.0, 2.0),
        );
        let now = SECOND;
        // The hot peer exhausts its own bucket, not the shared one.
        assert_eq!(a.check(now, "shard", &peer(1)), Admit::Ok);
        assert_eq!(a.check(now, "shard", &peer(1)), Admit::Ok);
        assert!(matches!(a.check(now, "shard", &peer(1)), Admit::Shed { .. }));
        // A quiet peer still gets in at the same instant.
        assert_eq!(a.check(now, "shard", &peer(2)), Admit::Ok);
    }

    #[test]
    fn services_without_policy_always_admit() {
        let mut a = Admission::default();
        assert_eq!(a.check(SECOND, "anything", &peer(1)), Admit::Ok);
        a.set_policy("shard", AdmissionPolicy::rate(0.0, 0.0));
        assert_eq!(a.check(SECOND, "other", &peer(1)), Admit::Ok);
        assert_eq!(a.stats.admitted, 0, "unpolicied services are not counted");
    }
}
