//! The event queue: a binary heap ordered by (time, sequence) so ties are
//! broken deterministically in insertion order.

use super::Time;
use std::collections::BinaryHeap;

/// Payload of a scheduled event.
#[derive(Debug)]
pub enum EventKind {
    /// Deliver a datagram to a bound endpoint.
    Deliver {
        dst_endpoint: usize,
        /// Source address as seen by the receiver (post-NAT).
        from: crate::multiaddr::SimAddr,
        /// Destination address it was sent to (the receiver's view).
        to: crate::multiaddr::SimAddr,
        payload: Vec<u8>,
    },
    /// Fire a timer registered by an endpoint.
    Timer { endpoint: usize, token: u64 },
    /// External stop marker used by `World::run_until`.
    Stop,
}

struct Entry {
    at: Time,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse ordering: BinaryHeap is a max-heap, we want earliest first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Min-heap of timed events with deterministic tie-breaking.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, at: Time, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, kind });
    }

    pub fn pop(&mut self) -> Option<(Time, EventKind)> {
        self.heap.pop().map(|e| (e.at, e.kind))
    }

    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.push(10, EventKind::Timer { endpoint: 0, token: 1 });
        q.push(5, EventKind::Timer { endpoint: 0, token: 2 });
        q.push(10, EventKind::Timer { endpoint: 0, token: 3 });
        let (t1, k1) = q.pop().unwrap();
        assert_eq!(t1, 5);
        assert!(matches!(k1, EventKind::Timer { token: 2, .. }));
        let (t2, k2) = q.pop().unwrap();
        assert_eq!(t2, 10);
        assert!(matches!(k2, EventKind::Timer { token: 1, .. }));
        let (_, k3) = q.pop().unwrap();
        assert!(matches!(k3, EventKind::Timer { token: 3, .. }));
        assert!(q.pop().is_none());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(100, EventKind::Stop);
        q.push(50, EventKind::Stop);
        assert_eq!(q.pop().unwrap().0, 50);
        q.push(25, EventKind::Stop);
        q.push(75, EventKind::Stop);
        assert_eq!(q.pop().unwrap().0, 25);
        assert_eq!(q.pop().unwrap().0, 75);
        assert_eq!(q.pop().unwrap().0, 100);
        assert!(q.is_empty());
    }
}
