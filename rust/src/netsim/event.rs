//! The event queue.
//!
//! Two implementations behind one facade, both ordered by `(time, sequence)`
//! so ties break deterministically in insertion order:
//!
//! * [`TimerWheel`] — the default: a hashed hierarchical timer wheel
//!   (tokio/Varghese-Lauck style). 11 levels × 64 slots cover the full
//!   64-bit nanosecond clock; insert and cancel are O(1), and advancing
//!   coalesces every same-timestamp event into one batch (pacing ticks and
//!   k-bucket refresh timers dominate the queue at scale, and they land on
//!   shared deadlines). Slot vectors are recycled through a spare pool so
//!   steady-state operation does not allocate per event.
//! * [`HeapQueue`] — the original `BinaryHeap`, kept as the reference
//!   implementation for the trace-equivalence suite (`tests/dht_churn.rs`
//!   runs a seeded churn scenario under both and compares dispatch
//!   digests).
//!
//! Determinism contract (identical for both): events pop in strictly
//! nondecreasing `at`; events with equal `at` pop in push order.

use super::Time;
use std::collections::{BinaryHeap, VecDeque};

/// Payload of a scheduled event.
#[derive(Debug)]
pub enum EventKind {
    /// Deliver a datagram to a bound endpoint.
    Deliver {
        dst_endpoint: usize,
        /// Source address as seen by the receiver (post-NAT).
        from: crate::multiaddr::SimAddr,
        /// Destination address it was sent to (the receiver's view).
        to: crate::multiaddr::SimAddr,
        payload: Vec<u8>,
    },
    /// Fire a timer registered by an endpoint.
    Timer { endpoint: usize, token: u64 },
    /// External stop marker used by `World::run_until`.
    Stop,
}

/// Which queue implementation a [`EventQueue`] runs on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueKind {
    #[default]
    Wheel,
    Heap,
}

struct Entry {
    at: Time,
    seq: u64,
    kind: EventKind,
}

// ---------------------------------------------------------------------------
// Reference implementation: binary heap
// ---------------------------------------------------------------------------

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse ordering: BinaryHeap is a max-heap, we want earliest first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Min-heap of timed events with deterministic tie-breaking.
#[derive(Default)]
struct HeapQueue {
    heap: BinaryHeap<Entry>,
}

impl HeapQueue {
    fn push(&mut self, e: Entry) {
        self.heap.push(e);
    }

    fn pop(&mut self) -> Option<Entry> {
        self.heap.pop()
    }

    fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

// ---------------------------------------------------------------------------
// Hierarchical timer wheel
// ---------------------------------------------------------------------------

const SLOT_BITS: usize = 6;
const SLOTS: usize = 1 << SLOT_BITS; // 64
const SLOT_MASK: u64 = (SLOTS - 1) as u64;
/// ceil(64 / 6) levels cover every representable deadline.
const LEVELS: usize = 11;
/// Cap on recycled slot vectors retained between bursts.
const SPARE_CAP: usize = 64;

/// Hashed hierarchical timer wheel.
///
/// Level `L` buckets deadlines by bits `[6L, 6L+6)` of their absolute time.
/// An entry lives at the *highest* level where its deadline differs from
/// the cursor, so each level-0 slot holds exactly one timestamp and a drain
/// of that slot is already in `(at, seq)` order — no per-slot sorting,
/// ever. Advancing walks the per-level occupancy bitmaps (one `u64` each)
/// to the next occupied slot, so an idle region of virtual time costs a
/// handful of bit-scans rather than per-tick work.
///
/// Invariants (maintained by `settle`):
/// * every wheel entry has `at > cursor`;
/// * at its level, an entry's slot index is strictly above the cursor's
///   slot index (higher-level blocks equal the cursor's);
/// * `due` holds only entries with `at <= cursor`, sorted by `(at, seq)`.
struct TimerWheel {
    /// `slots[level * SLOTS + slot]`; entries in push order.
    slots: Vec<Vec<Entry>>,
    /// Per-level occupancy bitmap.
    occupied: [u64; LEVELS],
    /// Time the wheel has been advanced to (start of the current slot).
    cursor: Time,
    /// Entries ready to pop, sorted by `(at, seq)`.
    due: VecDeque<Entry>,
    /// Total entries (wheel + due).
    len: usize,
    /// Recycled slot vectors: drained slots return their allocation here
    /// and fresh inserts reuse it — the per-datagram event allocation pool.
    spare: Vec<Vec<Entry>>,
}

impl TimerWheel {
    fn new() -> TimerWheel {
        TimerWheel {
            slots: std::iter::repeat_with(Vec::new)
                .take(LEVELS * SLOTS)
                .collect(),
            occupied: [0; LEVELS],
            cursor: 0,
            due: VecDeque::new(),
            len: 0,
            spare: Vec::new(),
        }
    }

    /// Level and slot for a deadline strictly after the cursor.
    #[inline]
    fn level_slot(cursor: Time, at: Time) -> (usize, usize) {
        debug_assert!(at > cursor);
        let highest_bit = 63 - (at ^ cursor).leading_zeros() as usize;
        let level = highest_bit / SLOT_BITS;
        let slot = ((at >> (level * SLOT_BITS)) & SLOT_MASK) as usize;
        (level, slot)
    }

    /// Occupancy mask of slots strictly above index `c`.
    #[inline]
    fn mask_above(c: u64) -> u64 {
        if c >= 63 {
            0
        } else {
            !0u64 << (c + 1)
        }
    }

    fn insert_wheel(&mut self, e: Entry) {
        let (level, slot) = Self::level_slot(self.cursor, e.at);
        let idx = level * SLOTS + slot;
        if self.slots[idx].capacity() == 0 {
            if let Some(v) = self.spare.pop() {
                self.slots[idx] = v;
            }
        }
        self.slots[idx].push(e);
        self.occupied[level] |= 1u64 << slot;
    }

    fn push(&mut self, e: Entry) {
        self.len += 1;
        if e.at <= self.cursor {
            // Late push (the world idled past the wheel position, then an
            // endpoint scheduled something near "now"). Keep `due` sorted;
            // the insert is stable, so equal timestamps stay in seq order.
            let i = self.due.partition_point(|d| d.at <= e.at);
            self.due.insert(i, e);
        } else {
            self.insert_wheel(e);
        }
    }

    /// Refill `due` from the wheel: advance the cursor to the earliest
    /// occupied slot (lowest level first — that is the global minimum) and
    /// drain it, cascading higher-level batches down.
    fn settle(&mut self) {
        'refill: while self.due.is_empty() && self.len > 0 {
            for level in 0..LEVELS {
                let shift = level * SLOT_BITS;
                let c = (self.cursor >> shift) & SLOT_MASK;
                let occ = self.occupied[level] & Self::mask_above(c);
                if occ == 0 {
                    continue;
                }
                let slot = occ.trailing_zeros() as u64;
                // Advance the cursor to the slot's base time: clear all
                // lower-level blocks, set this level's block to `slot`.
                let high = if shift + SLOT_BITS >= 64 {
                    0
                } else {
                    (self.cursor >> (shift + SLOT_BITS)) << (shift + SLOT_BITS)
                };
                self.cursor = high | (slot << shift);
                let idx = level * SLOTS + slot as usize;
                self.occupied[level] &= !(1u64 << slot);
                let mut entries = std::mem::take(&mut self.slots[idx]);
                if level == 0 {
                    // One exact timestamp per level-0 slot: the batch is
                    // already in (at, seq) order.
                    for e in entries.drain(..) {
                        debug_assert_eq!(e.at, self.cursor);
                        self.due.push_back(e);
                    }
                } else {
                    // Cascade: redistribute relative to the new cursor.
                    // Entries that land exactly on the cursor go straight
                    // to `due` (push order == seq order within the slot).
                    for e in entries.drain(..) {
                        if e.at == self.cursor {
                            self.due.push_back(e);
                        } else {
                            self.insert_wheel(e);
                        }
                    }
                }
                if self.spare.len() < SPARE_CAP {
                    self.spare.push(entries);
                }
                continue 'refill;
            }
            unreachable!("timer wheel: len > 0 but no occupied slot above cursor");
        }
    }

    fn pop(&mut self) -> Option<Entry> {
        self.settle();
        let e = self.due.pop_front()?;
        self.len -= 1;
        Some(e)
    }

    fn peek_time(&mut self) -> Option<Time> {
        self.settle();
        self.due.front().map(|e| e.at)
    }

    fn len(&self) -> usize {
        self.len
    }
}

// ---------------------------------------------------------------------------
// Facade
// ---------------------------------------------------------------------------

enum QueueImpl {
    Wheel(TimerWheel),
    Heap(HeapQueue),
}

/// Min-queue of timed events with deterministic tie-breaking. Defaults to
/// the timer wheel; [`EventQueue::new_heap`] keeps the reference heap
/// available for equivalence testing.
pub struct EventQueue {
    imp: QueueImpl,
    seq: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::with_kind(QueueKind::Wheel)
    }

    pub fn new_heap() -> EventQueue {
        EventQueue::with_kind(QueueKind::Heap)
    }

    pub fn with_kind(kind: QueueKind) -> EventQueue {
        let imp = match kind {
            QueueKind::Wheel => QueueImpl::Wheel(TimerWheel::new()),
            QueueKind::Heap => QueueImpl::Heap(HeapQueue::default()),
        };
        EventQueue { imp, seq: 0 }
    }

    pub fn kind(&self) -> QueueKind {
        match &self.imp {
            QueueImpl::Wheel(_) => QueueKind::Wheel,
            QueueImpl::Heap(_) => QueueKind::Heap,
        }
    }

    pub fn push(&mut self, at: Time, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        let e = Entry { at, seq, kind };
        match &mut self.imp {
            QueueImpl::Wheel(w) => w.push(e),
            QueueImpl::Heap(h) => h.push(e),
        }
    }

    pub fn pop(&mut self) -> Option<(Time, EventKind)> {
        let e = match &mut self.imp {
            QueueImpl::Wheel(w) => w.pop(),
            QueueImpl::Heap(h) => h.pop(),
        }?;
        Some((e.at, e.kind))
    }

    /// Earliest pending deadline. `&mut` because the wheel advances its
    /// cursor (and cascades batches) to find the minimum.
    pub fn peek_time(&mut self) -> Option<Time> {
        match &mut self.imp {
            QueueImpl::Wheel(w) => w.peek_time(),
            QueueImpl::Heap(h) => h.peek_time(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.imp {
            QueueImpl::Wheel(w) => w.len(),
            QueueImpl::Heap(h) => h.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::SECOND;

    fn queues() -> [EventQueue; 2] {
        [EventQueue::new(), EventQueue::new_heap()]
    }

    #[test]
    fn ordered_by_time_then_seq() {
        for mut q in queues() {
            q.push(10, EventKind::Timer { endpoint: 0, token: 1 });
            q.push(5, EventKind::Timer { endpoint: 0, token: 2 });
            q.push(10, EventKind::Timer { endpoint: 0, token: 3 });
            let (t1, k1) = q.pop().unwrap();
            assert_eq!(t1, 5);
            assert!(matches!(k1, EventKind::Timer { token: 2, .. }));
            let (t2, k2) = q.pop().unwrap();
            assert_eq!(t2, 10);
            assert!(matches!(k2, EventKind::Timer { token: 1, .. }));
            let (_, k3) = q.pop().unwrap();
            assert!(matches!(k3, EventKind::Timer { token: 3, .. }));
            assert!(q.pop().is_none());
        }
    }

    #[test]
    fn interleaved_push_pop() {
        for mut q in queues() {
            q.push(100, EventKind::Stop);
            q.push(50, EventKind::Stop);
            assert_eq!(q.pop().unwrap().0, 50);
            q.push(25, EventKind::Stop);
            q.push(75, EventKind::Stop);
            assert_eq!(q.pop().unwrap().0, 25);
            assert_eq!(q.pop().unwrap().0, 75);
            assert_eq!(q.pop().unwrap().0, 100);
            assert!(q.is_empty());
        }
    }

    #[test]
    fn wheel_cascades_across_levels() {
        let mut q = EventQueue::new();
        // Deadlines spanning every wheel level, pushed out of order.
        let times = [
            3 * 3600 * SECOND,
            1,
            SECOND,
            63,
            64,
            4096,
            4095,
            u64::MAX / 2,
            SECOND + 1,
        ];
        for (i, &t) in times.iter().enumerate() {
            q.push(t, EventKind::Timer { endpoint: i, token: t });
        }
        let mut sorted = times.to_vec();
        sorted.sort_unstable();
        for want in sorted {
            let (at, kind) = q.pop().unwrap();
            assert_eq!(at, want);
            assert!(matches!(kind, EventKind::Timer { token, .. } if token == want));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn push_behind_cursor_still_sorted() {
        let mut q = EventQueue::new();
        q.push(1000, EventKind::Stop);
        // Advance the wheel cursor to 1000 without consuming the event.
        assert_eq!(q.peek_time(), Some(1000));
        // A later push with an earlier deadline (the world idled past the
        // cursor, then an endpoint armed a short timer).
        q.push(500, EventKind::Timer { endpoint: 0, token: 500 });
        q.push(700, EventKind::Timer { endpoint: 0, token: 700 });
        assert_eq!(q.pop().unwrap().0, 500);
        assert_eq!(q.pop().unwrap().0, 700);
        assert_eq!(q.pop().unwrap().0, 1000);
    }

    #[test]
    fn same_tick_batch_preserves_push_order() {
        let mut q = EventQueue::new();
        // A far-future shared deadline: the batch cascades through several
        // levels and must still pop in push order.
        let t = 12 * 3600 * SECOND + 17;
        for token in 0..100u64 {
            q.push(t, EventKind::Timer { endpoint: 0, token });
        }
        for want in 0..100u64 {
            let (at, kind) = q.pop().unwrap();
            assert_eq!(at, t);
            assert!(matches!(kind, EventKind::Timer { token, .. } if token == want));
        }
    }

    /// Differential fuzz: the wheel must produce the exact pop sequence of
    /// the reference heap under an adversarial interleaving of pushes and
    /// pops with clustered and far-flung deadlines.
    #[test]
    fn wheel_matches_heap_differential() {
        let mut rng = crate::util::Rng::new(0xE7E7);
        let mut wheel = EventQueue::new();
        let mut heap = EventQueue::new_heap();
        let mut now = 0u64;
        let mut token = 0u64;
        for _ in 0..20_000 {
            if rng.gen_bool(0.55) || wheel.is_empty() {
                // Mix of near deadlines, clustered ticks and far jumps.
                let delay = match rng.gen_index(4) {
                    0 => rng.gen_range(64),
                    1 => 1000, // coalescing tick
                    2 => rng.gen_range(100_000),
                    _ => rng.gen_range(10 * SECOND),
                };
                let at = now + delay;
                wheel.push(at, EventKind::Timer { endpoint: 0, token });
                heap.push(at, EventKind::Timer { endpoint: 0, token });
                token += 1;
            } else {
                let a = wheel.pop().unwrap();
                let b = heap.pop().unwrap();
                assert_eq!(a.0, b.0, "pop time diverged");
                match (a.1, b.1) {
                    (
                        EventKind::Timer { token: ta, .. },
                        EventKind::Timer { token: tb, .. },
                    ) => assert_eq!(ta, tb, "pop order diverged at t={}", a.0),
                    _ => panic!("unexpected kinds"),
                }
                now = a.0;
            }
        }
        while let Some(a) = wheel.pop() {
            let b = heap.pop().unwrap();
            assert_eq!(a.0, b.0);
        }
        assert!(heap.pop().is_none());
    }
}
