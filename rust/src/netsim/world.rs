//! The dispatch loop: owns endpoints and drives events from [`Net`].
//!
//! Endpoint handles are *generational*: an [`EndpointId`] packs a 32-bit
//! slot index with a 32-bit generation. Removing an endpoint is O(1) — the
//! slot is tombstoned (generation bumped, index pushed on a free list) and
//! any events still queued for the old id are dropped at dispatch when
//! their generation no longer matches (counted in
//! `NetStats::events_dropped_stale`). Churn respawn reuses slots, so a
//! long-running scenario's endpoint table stays dense instead of growing
//! with every restart.

use super::event::EventKind;
use super::net::{EndpointId, Net};
use super::Time;
use crate::multiaddr::SimAddr;
use std::cell::RefCell;
use std::rc::Rc;

// The generation scheme packs (gen << 32 | index) into EndpointId = usize.
const _: () = assert!(std::mem::size_of::<usize>() >= 8, "needs 64-bit usize");

const INDEX_BITS: u32 = 32;
const INDEX_MASK: usize = (1 << INDEX_BITS) - 1;

#[inline]
fn pack(gen: u32, index: usize) -> EndpointId {
    debug_assert!(index <= INDEX_MASK);
    ((gen as usize) << INDEX_BITS) | index
}

#[inline]
fn unpack(id: EndpointId) -> (u32, usize) {
    ((id >> INDEX_BITS) as u32, id & INDEX_MASK)
}

/// A datagram-level endpoint: one per node network stack.
pub trait Endpoint {
    /// A datagram arrived. `from` is the sender as observed on the wire
    /// (post-NAT); `to` is the local bound address it was delivered to.
    fn on_datagram(&mut self, net: &mut Net, from: SimAddr, to: SimAddr, payload: Vec<u8>);

    /// A timer armed via [`Net::set_timer`] fired.
    fn on_timer(&mut self, net: &mut Net, token: u64);
}

/// One endpoint slot: the live generation plus the (possibly vacated)
/// endpoint. A slot whose `ep` is `None` is a tombstone awaiting reuse.
struct Slot {
    gen: u32,
    ep: Option<Rc<RefCell<dyn Endpoint>>>,
}

/// FNV-1a digest over the dispatched event stream — order, timestamps and
/// payload bytes. Two runs of the same seeded scenario are equivalent iff
/// their digests match; `tests/dht_churn.rs` uses this to pin the timer
/// wheel to the reference heap.
#[derive(Clone, Copy, Debug)]
pub struct TraceDigest(u64);

impl TraceDigest {
    fn new() -> TraceDigest {
        TraceDigest(0xcbf29ce484222325)
    }

    #[inline]
    fn mix_bytes(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.0 = h;
    }

    #[inline]
    fn mix_u64(&mut self, v: u64) {
        self.mix_bytes(&v.to_le_bytes());
    }

    fn record(&mut self, at: Time, kind: &EventKind) {
        self.mix_u64(at);
        match kind {
            EventKind::Deliver { dst_endpoint, from, to, payload } => {
                self.mix_u64(1);
                self.mix_u64(*dst_endpoint as u64);
                self.mix_u64(((from.host as u64) << 16) | from.port as u64);
                self.mix_u64(((to.host as u64) << 16) | to.port as u64);
                self.mix_u64(payload.len() as u64);
                self.mix_bytes(payload);
            }
            EventKind::Timer { endpoint, token } => {
                self.mix_u64(2);
                self.mix_u64(*endpoint as u64);
                self.mix_u64(*token);
            }
            EventKind::Stop => self.mix_u64(3),
        }
    }

    pub fn value(&self) -> u64 {
        self.0
    }
}

/// Owns the endpoint registry and the run loop.
pub struct World {
    pub net: Net,
    slots: Vec<Slot>,
    /// Vacated slot indices, reused LIFO.
    free: Vec<usize>,
    trace: TraceDigest,
}

impl World {
    pub fn new(net: Net) -> World {
        World {
            net,
            slots: Vec::new(),
            free: Vec::new(),
            trace: TraceDigest::new(),
        }
    }

    /// Register an endpoint; returns its id (used for binds and timers).
    /// Vacated slots are reused with a fresh generation.
    pub fn add_endpoint(&mut self, ep: Rc<RefCell<dyn Endpoint>>) -> EndpointId {
        if let Some(index) = self.free.pop() {
            let slot = &mut self.slots[index];
            debug_assert!(slot.ep.is_none());
            slot.ep = Some(ep);
            pack(slot.gen, index)
        } else {
            self.slots.push(Slot { gen: 0, ep: Some(ep) });
            pack(0, self.slots.len() - 1)
        }
    }

    /// The id the next [`World::add_endpoint`] call will return — lets a
    /// node construct subsystems that need their endpoint id before
    /// registration.
    pub fn next_endpoint_id(&self) -> EndpointId {
        match self.free.last() {
            Some(&index) => pack(self.slots[index].gen, index),
            None => pack(0, self.slots.len()),
        }
    }

    /// Remove an endpoint (a stopped or crashed node) in O(1): tombstone
    /// the slot and bump its generation. Events still queued for the old
    /// id are dropped at dispatch (`NetStats::events_dropped_stale`)
    /// rather than swept out of the queue.
    pub fn remove_endpoint(&mut self, id: EndpointId) {
        let (gen, index) = unpack(id);
        if let Some(slot) = self.slots.get_mut(index) {
            if slot.gen == gen && slot.ep.is_some() {
                slot.ep = None;
                slot.gen = slot.gen.wrapping_add(1);
                self.free.push(index);
            }
        }
    }

    pub fn endpoint(&self, id: EndpointId) -> Option<Rc<RefCell<dyn Endpoint>>> {
        let (gen, index) = unpack(id);
        let slot = self.slots.get(index)?;
        if slot.gen != gen {
            return None;
        }
        slot.ep.clone()
    }

    /// Number of live (non-tombstoned) endpoints.
    pub fn live_endpoints(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Digest of every event dispatched so far (see [`TraceDigest`]).
    pub fn trace_digest(&self) -> u64 {
        self.trace.value()
    }

    /// Dispatch one popped event. Stale endpoints (tombstoned or
    /// generation-bumped) swallow their events, counted in stats.
    fn dispatch(&mut self, at: Time, kind: EventKind) {
        self.trace.record(at, &kind);
        match kind {
            EventKind::Deliver { dst_endpoint, from, to, payload } => {
                self.net.stats.deliver_events += 1;
                self.net.note_payload_released(payload.len());
                match self.endpoint(dst_endpoint) {
                    Some(ep) => {
                        ep.borrow_mut().on_datagram(&mut self.net, from, to, payload)
                    }
                    None => self.net.stats.events_dropped_stale += 1,
                }
            }
            EventKind::Timer { endpoint, token } => {
                self.net.stats.timer_events += 1;
                match self.endpoint(endpoint) {
                    Some(ep) => ep.borrow_mut().on_timer(&mut self.net, token),
                    None => self.net.stats.events_dropped_stale += 1,
                }
            }
            EventKind::Stop => {}
        }
    }

    /// Process events until the queue is empty or the virtual clock passes
    /// `deadline`. Returns the number of events processed.
    pub fn run_until(&mut self, deadline: Time) -> u64 {
        let mut n = 0;
        while let Some(t) = self.net.queue.peek_time() {
            if t > deadline {
                break;
            }
            let (at, kind) = self.net.queue.pop().unwrap();
            self.net.set_now(at);
            self.net.stats.events_processed += 1;
            n += 1;
            if matches!(kind, EventKind::Stop) {
                self.trace.record(at, &kind);
                break;
            }
            self.dispatch(at, kind);
        }
        // Advance the clock to the deadline even if idle, so back-to-back
        // run_until calls observe monotonic time.
        if self.net.now() < deadline {
            self.net.set_now(deadline);
        }
        n
    }

    /// Run for a relative duration.
    pub fn run_for(&mut self, d: Time) -> u64 {
        self.run_until(self.net.now() + d)
    }

    /// Run until `deadline`, applying scheduled churn events at their exact
    /// virtual times. The world advances to each due event's timestamp,
    /// `apply` mutates the deployment (stop/crash/restart a node), and the
    /// run resumes — so churn interleaves with packet delivery
    /// deterministically (same plan ⇒ same trace).
    pub fn run_with_churn<F>(
        &mut self,
        plan: &mut super::churn::ChurnPlan,
        deadline: Time,
        mut apply: F,
    ) -> u64
    where
        F: FnMut(&mut World, &super::churn::ChurnEvent),
    {
        let mut n = 0;
        loop {
            match plan.peek().map(|e| e.at) {
                Some(at) if at <= deadline => {
                    n += self.run_until(at);
                    while let Some(ev) = plan.pop_due(self.net.now()) {
                        apply(self, &ev);
                    }
                }
                _ => {
                    n += self.run_until(deadline);
                    return n;
                }
            }
        }
    }

    /// Run until the queue drains completely (use with care: keepalive
    /// timers can make this unbounded — prefer `run_until`).
    pub fn run_to_quiescence(&mut self, max_events: u64) -> u64 {
        let mut n = 0;
        while n < max_events {
            let Some((at, kind)) = self.net.queue.pop() else {
                break;
            };
            self.net.set_now(at);
            self.net.stats.events_processed += 1;
            n += 1;
            if matches!(kind, EventKind::Stop) {
                self.trace.record(at, &kind);
                break;
            }
            self.dispatch(at, kind);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::topology::{LinkProfile, TopologyBuilder};
    use crate::netsim::{MILLI, SECOND};

    /// Sink endpoint: records datagrams without replying.
    struct Sink {
        received: Vec<(SimAddr, Vec<u8>)>,
    }

    impl Endpoint for Sink {
        fn on_datagram(&mut self, _net: &mut Net, from: SimAddr, _to: SimAddr, payload: Vec<u8>) {
            self.received.push((from, payload));
        }

        fn on_timer(&mut self, _net: &mut Net, _token: u64) {}
    }

    /// Echo endpoint: replies to every datagram, counts received.
    struct Echo {
        addr: SimAddr,
        received: Vec<(SimAddr, Vec<u8>)>,
        timers: Vec<u64>,
    }

    impl Endpoint for Echo {
        fn on_datagram(&mut self, net: &mut Net, from: SimAddr, _to: SimAddr, payload: Vec<u8>) {
            self.received.push((from, payload.clone()));
            let mut reply = b"echo:".to_vec();
            reply.extend_from_slice(&payload);
            net.send(self.addr, from, reply);
        }

        fn on_timer(&mut self, _net: &mut Net, token: u64) {
            self.timers.push(token);
        }
    }

    #[test]
    fn request_reply_through_world() {
        let mut t = TopologyBuilder::paper_regions();
        let a = t.public_host(0, LinkProfile::UNLIMITED);
        let b = t.public_host(1, LinkProfile::UNLIMITED);
        let mut world = World::new(t.build(5));

        let server = Rc::new(RefCell::new(Echo {
            addr: SimAddr::new(b, 80),
            received: vec![],
            timers: vec![],
        }));
        let client = Rc::new(RefCell::new(Sink { received: vec![] }));
        let sid = world.add_endpoint(server.clone());
        let cid = world.add_endpoint(client.clone());
        world.net.bind(sid, SimAddr::new(b, 80)).unwrap();
        world.net.bind(cid, SimAddr::new(a, 9000)).unwrap();

        world
            .net
            .send(SimAddr::new(a, 9000), SimAddr::new(b, 80), b"hi".to_vec());
        world.run_until(SECOND);

        assert_eq!(server.borrow().received.len(), 1);
        assert_eq!(client.borrow().received.len(), 1);
        assert_eq!(client.borrow().received[0].1, b"echo:hi");
        // RTT ≈ 2 × 10 ms.
        assert!(world.net.now() >= 20 * MILLI);
    }

    #[test]
    fn timers_fire_in_order() {
        let t = TopologyBuilder::new(1);
        let mut world = World::new(t.build(6));
        let ep = Rc::new(RefCell::new(Echo {
            addr: SimAddr::new(0, 0),
            received: vec![],
            timers: vec![],
        }));
        let id = world.add_endpoint(ep.clone());
        world.net.set_timer(id, 30 * MILLI, 3);
        world.net.set_timer(id, 10 * MILLI, 1);
        world.net.set_timer(id, 20 * MILLI, 2);
        world.run_until(SECOND);
        assert_eq!(ep.borrow().timers, vec![1, 2, 3]);
    }

    #[test]
    fn removed_endpoint_gets_nothing() {
        let mut t = TopologyBuilder::new(1);
        let a = t.public_host(0, LinkProfile::UNLIMITED);
        let b = t.public_host(0, LinkProfile::UNLIMITED);
        let mut world = World::new(t.build(7));
        let ep = Rc::new(RefCell::new(Echo {
            addr: SimAddr::new(b, 80),
            received: vec![],
            timers: vec![],
        }));
        let id = world.add_endpoint(ep.clone());
        world.net.bind(id, SimAddr::new(b, 80)).unwrap();
        world
            .net
            .send(SimAddr::new(a, 1), SimAddr::new(b, 80), b"x".to_vec());
        world.remove_endpoint(id);
        world.run_until(SECOND);
        assert!(ep.borrow().received.is_empty());
        // The in-flight delivery was dropped at dispatch and counted.
        assert_eq!(world.net.stats.events_dropped_stale, 1);
    }

    #[test]
    fn slot_reuse_bumps_generation() {
        let t = TopologyBuilder::new(1);
        let mut world = World::new(t.build(11));
        let mk = || {
            Rc::new(RefCell::new(Sink { received: vec![] }))
        };
        let a = world.add_endpoint(mk());
        let b = world.add_endpoint(mk());
        assert_ne!(a, b);
        world.remove_endpoint(a);
        assert!(world.endpoint(a).is_none(), "tombstoned id must not resolve");
        // The freed slot is predicted and reused with a new generation.
        let predicted = world.next_endpoint_id();
        let c = world.add_endpoint(mk());
        assert_eq!(predicted, c);
        assert_ne!(c, a, "reused slot must carry a fresh generation");
        assert!(world.endpoint(c).is_some());
        assert!(world.endpoint(a).is_none());
        assert_eq!(world.live_endpoints(), 2);
        // A timer armed on the dead id never reaches the new tenant.
        world.net.set_timer(a, MILLI, 7);
        world.run_until(SECOND);
        assert_eq!(world.net.stats.events_dropped_stale, 1);
    }

    #[test]
    fn trace_digest_is_deterministic() {
        let run = |seed: u64| {
            let mut t = TopologyBuilder::paper_regions();
            let a = t.public_host(0, LinkProfile::UNLIMITED);
            let b = t.public_host(1, LinkProfile::UNLIMITED);
            let mut world = World::new(t.build(seed));
            let server = Rc::new(RefCell::new(Echo {
                addr: SimAddr::new(b, 80),
                received: vec![],
                timers: vec![],
            }));
            let sid = world.add_endpoint(server);
            world.net.bind(sid, SimAddr::new(b, 80)).unwrap();
            for i in 0..20u16 {
                world
                    .net
                    .send(SimAddr::new(a, 9000), SimAddr::new(b, 80), vec![i as u8; 64]);
                world.net.set_timer(sid, MILLI * (i as u64 + 1), i as u64);
            }
            world.run_until(SECOND);
            world.trace_digest()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn clock_advances_to_deadline_when_idle() {
        let t = TopologyBuilder::new(1);
        let mut world = World::new(t.build(8));
        world.run_until(5 * SECOND);
        assert_eq!(world.net.now(), 5 * SECOND);
    }

    #[test]
    fn run_with_churn_applies_events_at_exact_times() {
        use crate::netsim::churn::{ChurnAction, ChurnConfig, ChurnPlan};
        let t = TopologyBuilder::new(1);
        let mut world = World::new(t.build(9));
        let mut plan = ChurnPlan::poisson(
            &ChurnConfig {
                nodes: 10,
                protected: 1,
                start: 100 * MILLI,
                end: 4 * SECOND,
                session_half_life: 500 * MILLI,
                downtime_mean: 200 * MILLI,
                crash_fraction: 0.5,
            },
            13,
        );
        let total = plan.len();
        assert!(total > 0);
        let mut applied: Vec<(crate::netsim::Time, usize, ChurnAction)> = Vec::new();
        world.run_with_churn(&mut plan, 10 * SECOND, |w, ev| {
            // The world clock sits exactly on the event's timestamp.
            assert_eq!(w.net.now(), ev.at);
            applied.push((ev.at, ev.node, ev.action));
        });
        assert_eq!(applied.len(), total, "every due event must be applied");
        assert_eq!(plan.remaining(), 0);
        assert!(applied.windows(2).all(|w| w[0].0 <= w[1].0));
        // The run still advances to the deadline afterwards.
        assert_eq!(world.net.now(), 10 * SECOND);
    }
}
