//! The dispatch loop: owns endpoints and drives events from [`Net`].

use super::event::EventKind;
use super::net::{EndpointId, Net};
use super::Time;
use crate::multiaddr::SimAddr;
use std::cell::RefCell;
use std::rc::Rc;

/// A datagram-level endpoint: one per node network stack.
pub trait Endpoint {
    /// A datagram arrived. `from` is the sender as observed on the wire
    /// (post-NAT); `to` is the local bound address it was delivered to.
    fn on_datagram(&mut self, net: &mut Net, from: SimAddr, to: SimAddr, payload: Vec<u8>);

    /// A timer armed via [`Net::set_timer`] fired.
    fn on_timer(&mut self, net: &mut Net, token: u64);
}

/// Owns the endpoint registry and the run loop.
pub struct World {
    pub net: Net,
    endpoints: Vec<Option<Rc<RefCell<dyn Endpoint>>>>,
}

impl World {
    pub fn new(net: Net) -> World {
        World {
            net,
            endpoints: Vec::new(),
        }
    }

    /// Register an endpoint; returns its id (used for binds and timers).
    pub fn add_endpoint(&mut self, ep: Rc<RefCell<dyn Endpoint>>) -> EndpointId {
        self.endpoints.push(Some(ep));
        self.endpoints.len() - 1
    }

    /// The id the next [`World::add_endpoint`] call will return — lets a
    /// node construct subsystems that need their endpoint id before
    /// registration.
    pub fn next_endpoint_id(&self) -> EndpointId {
        self.endpoints.len()
    }

    /// Remove an endpoint (simulating a crashed node); its pending events
    /// are silently dropped.
    pub fn remove_endpoint(&mut self, id: EndpointId) {
        if let Some(slot) = self.endpoints.get_mut(id) {
            *slot = None;
        }
    }

    pub fn endpoint(&self, id: EndpointId) -> Option<Rc<RefCell<dyn Endpoint>>> {
        self.endpoints.get(id).and_then(|e| e.clone())
    }

    /// Process events until the queue is empty or the virtual clock passes
    /// `deadline`. Returns the number of events processed.
    pub fn run_until(&mut self, deadline: Time) -> u64 {
        let mut n = 0;
        while let Some(t) = self.net.queue.peek_time() {
            if t > deadline {
                break;
            }
            let (at, kind) = self.net.queue.pop().unwrap();
            self.net.set_now(at);
            self.net.stats.events_processed += 1;
            n += 1;
            match kind {
                EventKind::Deliver {
                    dst_endpoint,
                    from,
                    to,
                    payload,
                } => {
                    self.net.stats.deliver_events += 1;
                    if let Some(ep) = self.endpoint(dst_endpoint) {
                        ep.borrow_mut().on_datagram(&mut self.net, from, to, payload);
                    }
                }
                EventKind::Timer { endpoint, token } => {
                    self.net.stats.timer_events += 1;
                    if let Some(ep) = self.endpoint(endpoint) {
                        ep.borrow_mut().on_timer(&mut self.net, token);
                    }
                }
                EventKind::Stop => break,
            }
        }
        // Advance the clock to the deadline even if idle, so back-to-back
        // run_until calls observe monotonic time.
        if self.net.now() < deadline {
            self.net.set_now(deadline);
        }
        n
    }

    /// Run for a relative duration.
    pub fn run_for(&mut self, d: Time) -> u64 {
        self.run_until(self.net.now() + d)
    }

    /// Run until `deadline`, applying scheduled churn events at their exact
    /// virtual times. The world advances to each due event's timestamp,
    /// `apply` mutates the deployment (stop/crash/restart a node), and the
    /// run resumes — so churn interleaves with packet delivery
    /// deterministically (same plan ⇒ same trace).
    pub fn run_with_churn<F>(
        &mut self,
        plan: &mut super::churn::ChurnPlan,
        deadline: Time,
        mut apply: F,
    ) -> u64
    where
        F: FnMut(&mut World, &super::churn::ChurnEvent),
    {
        let mut n = 0;
        loop {
            match plan.peek().map(|e| e.at) {
                Some(at) if at <= deadline => {
                    n += self.run_until(at);
                    while let Some(ev) = plan.pop_due(self.net.now()) {
                        apply(self, &ev);
                    }
                }
                _ => {
                    n += self.run_until(deadline);
                    return n;
                }
            }
        }
    }

    /// Run until the queue drains completely (use with care: keepalive
    /// timers can make this unbounded — prefer `run_until`).
    pub fn run_to_quiescence(&mut self, max_events: u64) -> u64 {
        let mut n = 0;
        while n < max_events {
            let Some((at, kind)) = self.net.queue.pop() else {
                break;
            };
            self.net.set_now(at);
            self.net.stats.events_processed += 1;
            n += 1;
            match kind {
                EventKind::Deliver {
                    dst_endpoint,
                    from,
                    to,
                    payload,
                } => {
                    if let Some(ep) = self.endpoint(dst_endpoint) {
                        ep.borrow_mut().on_datagram(&mut self.net, from, to, payload);
                    }
                }
                EventKind::Timer { endpoint, token } => {
                    if let Some(ep) = self.endpoint(endpoint) {
                        ep.borrow_mut().on_timer(&mut self.net, token);
                    }
                }
                EventKind::Stop => break,
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::topology::{LinkProfile, TopologyBuilder};
    use crate::netsim::{MILLI, SECOND};

    /// Sink endpoint: records datagrams without replying.
    struct Sink {
        received: Vec<(SimAddr, Vec<u8>)>,
    }

    impl Endpoint for Sink {
        fn on_datagram(&mut self, _net: &mut Net, from: SimAddr, _to: SimAddr, payload: Vec<u8>) {
            self.received.push((from, payload));
        }

        fn on_timer(&mut self, _net: &mut Net, _token: u64) {}
    }

    /// Echo endpoint: replies to every datagram, counts received.
    struct Echo {
        addr: SimAddr,
        received: Vec<(SimAddr, Vec<u8>)>,
        timers: Vec<u64>,
    }

    impl Endpoint for Echo {
        fn on_datagram(&mut self, net: &mut Net, from: SimAddr, _to: SimAddr, payload: Vec<u8>) {
            self.received.push((from, payload.clone()));
            let mut reply = b"echo:".to_vec();
            reply.extend_from_slice(&payload);
            net.send(self.addr, from, reply);
        }

        fn on_timer(&mut self, _net: &mut Net, token: u64) {
            self.timers.push(token);
        }
    }

    #[test]
    fn request_reply_through_world() {
        let mut t = TopologyBuilder::paper_regions();
        let a = t.public_host(0, LinkProfile::UNLIMITED);
        let b = t.public_host(1, LinkProfile::UNLIMITED);
        let mut world = World::new(t.build(5));

        let server = Rc::new(RefCell::new(Echo {
            addr: SimAddr::new(b, 80),
            received: vec![],
            timers: vec![],
        }));
        let client = Rc::new(RefCell::new(Sink { received: vec![] }));
        let sid = world.add_endpoint(server.clone());
        let cid = world.add_endpoint(client.clone());
        world.net.bind(sid, SimAddr::new(b, 80)).unwrap();
        world.net.bind(cid, SimAddr::new(a, 9000)).unwrap();

        world
            .net
            .send(SimAddr::new(a, 9000), SimAddr::new(b, 80), b"hi".to_vec());
        world.run_until(SECOND);

        assert_eq!(server.borrow().received.len(), 1);
        assert_eq!(client.borrow().received.len(), 1);
        assert_eq!(client.borrow().received[0].1, b"echo:hi");
        // RTT ≈ 2 × 10 ms.
        assert!(world.net.now() >= 20 * MILLI);
    }

    #[test]
    fn timers_fire_in_order() {
        let t = TopologyBuilder::new(1);
        let mut world = World::new(t.build(6));
        let ep = Rc::new(RefCell::new(Echo {
            addr: SimAddr::new(0, 0),
            received: vec![],
            timers: vec![],
        }));
        let id = world.add_endpoint(ep.clone());
        world.net.set_timer(id, 30 * MILLI, 3);
        world.net.set_timer(id, 10 * MILLI, 1);
        world.net.set_timer(id, 20 * MILLI, 2);
        world.run_until(SECOND);
        assert_eq!(ep.borrow().timers, vec![1, 2, 3]);
    }

    #[test]
    fn removed_endpoint_gets_nothing() {
        let mut t = TopologyBuilder::new(1);
        let a = t.public_host(0, LinkProfile::UNLIMITED);
        let b = t.public_host(0, LinkProfile::UNLIMITED);
        let mut world = World::new(t.build(7));
        let ep = Rc::new(RefCell::new(Echo {
            addr: SimAddr::new(b, 80),
            received: vec![],
            timers: vec![],
        }));
        let id = world.add_endpoint(ep.clone());
        world.net.bind(id, SimAddr::new(b, 80)).unwrap();
        world
            .net
            .send(SimAddr::new(a, 1), SimAddr::new(b, 80), b"x".to_vec());
        world.remove_endpoint(id);
        world.run_until(SECOND);
        assert!(ep.borrow().received.is_empty());
    }

    #[test]
    fn clock_advances_to_deadline_when_idle() {
        let t = TopologyBuilder::new(1);
        let mut world = World::new(t.build(8));
        world.run_until(5 * SECOND);
        assert_eq!(world.net.now(), 5 * SECOND);
    }

    #[test]
    fn run_with_churn_applies_events_at_exact_times() {
        use crate::netsim::churn::{ChurnAction, ChurnConfig, ChurnPlan};
        let t = TopologyBuilder::new(1);
        let mut world = World::new(t.build(9));
        let mut plan = ChurnPlan::poisson(
            &ChurnConfig {
                nodes: 10,
                protected: 1,
                start: 100 * MILLI,
                end: 4 * SECOND,
                session_half_life: 500 * MILLI,
                downtime_mean: 200 * MILLI,
                crash_fraction: 0.5,
            },
            13,
        );
        let total = plan.len();
        assert!(total > 0);
        let mut applied: Vec<(crate::netsim::Time, usize, ChurnAction)> = Vec::new();
        world.run_with_churn(&mut plan, 10 * SECOND, |w, ev| {
            // The world clock sits exactly on the event's timestamp.
            assert_eq!(w.net.now(), ev.at);
            applied.push((ev.at, ev.node, ev.action));
        });
        assert_eq!(applied.len(), total, "every due event must be applied");
        assert_eq!(plan.remaining(), 0);
        assert!(applied.windows(2).all(|w| w[0].0 <= w[1].0));
        // The run still advances to the deadline afterwards.
        assert_eq!(world.net.now(), 10 * SECOND);
    }
}
