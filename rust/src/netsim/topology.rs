//! Topology construction: regions, public hosts, NATed hosts, link profiles.
//!
//! A topology is a set of hosts placed in regions, with per-host access-link
//! rates and optional NAT attachment. The inter-region path matrix supplies
//! propagation delay/jitter/loss; presets mirror the paper's four Table 1
//! scenarios (same host, same-region LAN, same-region WAN, inter-continent).

use super::event::QueueKind;
use super::link::{PathProfile, Shaper};
use super::nat::{NatBox, NatType};
use super::net::EndpointId;
use super::{Time, MICRO, MILLI};

/// Region index into the path matrix.
pub type Region = usize;

/// Default access-link queue: ~50 ms of buffering (a shallow router).
pub const DEFAULT_QUEUE_NS: Time = 50 * MILLI;

/// Link profile presets for access links.
#[derive(Clone, Copy, Debug)]
pub struct LinkProfile {
    /// Uplink bytes/sec (0 = unlimited).
    pub up_bps: u64,
    /// Downlink bytes/sec (0 = unlimited).
    pub down_bps: u64,
    /// Queue depth (ns of serialization) before drop-tail; deep values
    /// model bufferbloat.
    pub queue_ns: Time,
}

impl LinkProfile {
    /// 10 Gbps symmetric (the paper's testbed NICs).
    pub const DATACENTER: LinkProfile = LinkProfile {
        up_bps: 1_250_000_000,
        down_bps: 1_250_000_000,
        queue_ns: DEFAULT_QUEUE_NS,
    };

    /// 1 Gbps symmetric (well-connected edge).
    pub const FIBER: LinkProfile = LinkProfile {
        up_bps: 125_000_000,
        down_bps: 125_000_000,
        queue_ns: DEFAULT_QUEUE_NS,
    };

    /// 100/40 Mbps consumer broadband.
    pub const BROADBAND: LinkProfile = LinkProfile {
        up_bps: 5_000_000,
        down_bps: 12_500_000,
        queue_ns: DEFAULT_QUEUE_NS,
    };

    /// Unlimited (control experiments).
    pub const UNLIMITED: LinkProfile = LinkProfile {
        up_bps: 0,
        down_bps: 0,
        queue_ns: DEFAULT_QUEUE_NS,
    };

    /// Same rates, different queue depth (e.g. a bufferbloated CPE).
    pub fn with_queue(mut self, queue_ns: Time) -> LinkProfile {
        self.queue_ns = queue_ns;
        self
    }
}

/// Per-host configuration.
#[derive(Clone, Debug)]
pub struct HostCfg {
    pub region: Region,
    pub link: LinkProfile,
    /// NAT this host sits behind, if any.
    pub nat: Option<usize>,
}

pub(crate) struct HostState {
    pub cfg: HostCfg,
    pub uplink: Shaper,
    pub downlink: Shaper,
    /// Loopback serialization: models per-packet stack/CPU cost for
    /// same-host traffic (real loopback is serialized by the kernel, not
    /// instantaneous). Default ≈400 MB/s effective RPC-stack throughput.
    pub lo: Shaper,
    pub next_ephemeral: u16,
    /// Set if this host id is a NAT's public face (owned by that NAT).
    pub nat_face: Option<usize>,
    /// Bound ports, sorted by port number for binary search. A host has a
    /// handful of listeners, so a dense sorted Vec beats a global hash map
    /// at scale (and drops with the host, no rehash churn).
    pub ports: Vec<(u16, EndpointId)>,
}

/// Declarative topology builder. Produces the host/NAT tables consumed by
/// [`super::net::Net`].
pub struct TopologyBuilder {
    pub(crate) hosts: Vec<HostState>,
    pub(crate) nats: Vec<NatBox>,
    pub(crate) paths: Vec<Vec<PathProfile>>,
    pub(crate) loopback: PathProfile,
    /// Same-host serialization rate (bytes/sec); see HostState::lo.
    pub loopback_bps: u64,
    /// Event-queue implementation for the built [`super::net::Net`]. The
    /// timer wheel is the default; the reference heap is kept for
    /// equivalence tests.
    pub(crate) queue_kind: QueueKind,
}

impl TopologyBuilder {
    /// Start a topology with `n_regions` regions and a default path matrix
    /// (filled by [`Self::path`] or [`Self::paths_preset`]).
    pub fn new(n_regions: usize) -> TopologyBuilder {
        let default = PathProfile::new(10 * MILLI, MILLI, 0.0);
        TopologyBuilder {
            hosts: Vec::new(),
            nats: Vec::new(),
            paths: vec![vec![default; n_regions]; n_regions],
            loopback: PathProfile::new(15 * MICRO, 5 * MICRO, 0.0),
            loopback_bps: 1_500_000_000,
            queue_kind: QueueKind::default(),
        }
    }

    /// Select the event-queue implementation (wheel by default; the heap
    /// survives for trace-equivalence testing).
    pub fn set_queue_kind(&mut self, kind: QueueKind) -> &mut Self {
        self.queue_kind = kind;
        self
    }

    /// Set the path profile between two regions (symmetric).
    pub fn path(&mut self, a: Region, b: Region, p: PathProfile) -> &mut Self {
        self.paths[a][b] = p;
        self.paths[b][a] = p;
        self
    }

    /// Intra-region path (different hosts, same region).
    pub fn intra(&mut self, r: Region, p: PathProfile) -> &mut Self {
        self.paths[r][r] = p;
        self
    }

    /// The Table 1 scenario matrix: region 0 = a LAN site, region 1 = same
    /// metro (WAN), region 2 = another continent.
    ///
    /// One-way delays: LAN 0.25 ms, same-region WAN 10 ms, inter-continent
    /// 75 ms (≈150 ms RTT).
    pub fn paper_regions() -> TopologyBuilder {
        let mut t = TopologyBuilder::new(3);
        t.intra(0, PathProfile::new(250 * MICRO, 50 * MICRO, 0.0));
        t.intra(1, PathProfile::new(10 * MILLI, MILLI, 0.0001));
        t.intra(2, PathProfile::new(10 * MILLI, MILLI, 0.0001));
        t.path(0, 1, PathProfile::new(10 * MILLI, MILLI, 0.0001));
        t.path(0, 2, PathProfile::new(75 * MILLI, 3 * MILLI, 0.001));
        t.path(1, 2, PathProfile::new(75 * MILLI, 3 * MILLI, 0.001));
        t
    }

    /// Shaper for one direction of an access link.
    fn shaper(bps: u64, queue_ns: Time) -> Shaper {
        let mut s = Shaper::new(bps);
        s.max_queue_ns = queue_ns;
        s
    }

    /// Add a publicly reachable host; returns its host id.
    pub fn public_host(&mut self, region: Region, link: LinkProfile) -> u32 {
        let id = self.hosts.len() as u32;
        self.hosts.push(HostState {
            cfg: HostCfg {
                region,
                link,
                nat: None,
            },
            uplink: Self::shaper(link.up_bps, link.queue_ns),
            downlink: Self::shaper(link.down_bps, link.queue_ns),
            lo: {
                let mut s = Shaper::new(self.loopback_bps);
                s.per_pkt_overhead = 12 * 1024;
                s
            },
            next_ephemeral: 49_152,
            nat_face: None,
            ports: Vec::new(),
        });
        id
    }

    /// Add a NAT device in `region`; returns the NAT id. The NAT's public
    /// face is itself a host (so it has an address and an access link).
    ///
    /// The box implements its RFC 4787 class *faithfully* (no filter
    /// misbehaviour, symmetric = random port allocation): the clean-theory
    /// configuration the Ford punch-matrix tests pin. Use
    /// [`TopologyBuilder::nat_realistic`] for measured-realism boxes.
    pub fn nat(&mut self, region: Region, nat_type: NatType, link: LinkProfile) -> usize {
        let alloc = match nat_type {
            NatType::Symmetric => super::nat::PortAlloc::Random,
            _ => super::nat::PortAlloc::Sequential { stride: 1 },
        };
        self.push_nat(region, nat_type, link, alloc, 0.0)
    }

    /// Add a NAT device with measured-realism behaviour: a per-class
    /// filter-misbehaviour probability ([`super::nat::default_misbehave`])
    /// and the population port-allocation mix for symmetric boxes
    /// ([`super::nat::sym_port_alloc`] — mostly sequential/predictable,
    /// a hard-wall random minority).
    pub fn nat_realistic(&mut self, region: Region, nat_type: NatType, link: LinkProfile) -> usize {
        let nat_id = self.nats.len();
        let alloc = match nat_type {
            NatType::Symmetric => super::nat::sym_port_alloc(nat_id as u64),
            _ => super::nat::PortAlloc::Sequential { stride: 1 },
        };
        self.push_nat(
            region,
            nat_type,
            link,
            alloc,
            super::nat::default_misbehave(nat_type),
        )
    }

    fn push_nat(
        &mut self,
        region: Region,
        nat_type: NatType,
        link: LinkProfile,
        alloc: super::nat::PortAlloc,
        misbehave: f64,
    ) -> usize {
        let face = self.public_host(region, link);
        let nat_id = self.nats.len();
        self.hosts[face as usize].nat_face = Some(nat_id);
        self.nats.push(
            NatBox::new(nat_type, face, 20_000 + (nat_id as u16 * 97) % 10_000)
                .with_port_alloc(alloc)
                .with_misbehave(misbehave),
        );
        nat_id
    }

    /// Add a host behind NAT `nat_id`; returns its host id. The private
    /// host's access link models the LAN behind the NAT (usually fast);
    /// the NAT face's link is the shared WAN access.
    pub fn natted_host(&mut self, nat_id: usize, link: LinkProfile) -> u32 {
        let region = self.hosts[self.nats[nat_id].public_host as usize].cfg.region;
        let id = self.hosts.len() as u32;
        self.hosts.push(HostState {
            cfg: HostCfg {
                region,
                link,
                nat: Some(nat_id),
            },
            uplink: Self::shaper(link.up_bps, link.queue_ns),
            downlink: Self::shaper(link.down_bps, link.queue_ns),
            lo: {
                let mut s = Shaper::new(self.loopback_bps);
                s.per_pkt_overhead = 12 * 1024;
                s
            },
            next_ephemeral: 49_152,
            nat_face: None,
            ports: Vec::new(),
        });
        id
    }

    /// Override the loopback profile (same-host delivery).
    pub fn set_loopback(&mut self, p: PathProfile) -> &mut Self {
        self.loopback = p;
        self
    }

    /// Consume into a [`super::net::Net`] with the given RNG seed.
    pub fn build(self, seed: u64) -> super::net::Net {
        super::net::Net::from_topology(self, seed)
    }

    /// Per-host one-way propagation profile between two hosts.
    pub(crate) fn path_between(&self, a: u32, b: u32) -> PathProfile {
        if a == b {
            return self.loopback;
        }
        let ra = self.hosts[a as usize].cfg.region;
        let rb = self.hosts[b as usize].cfg.region;
        self.paths[ra][rb]
    }

    /// Delay helper used by tests.
    pub fn expected_delay(&self, a: u32, b: u32) -> Time {
        self.path_between(a, b).delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_places_hosts() {
        let mut t = TopologyBuilder::paper_regions();
        let a = t.public_host(0, LinkProfile::DATACENTER);
        let b = t.public_host(2, LinkProfile::FIBER);
        let nat = t.nat(1, NatType::Symmetric, LinkProfile::BROADBAND);
        let c = t.natted_host(nat, LinkProfile::UNLIMITED);
        assert_eq!(a, 0);
        assert_eq!(b, 1);
        // NAT face is host 2, private host is 3.
        assert_eq!(c, 3);
        assert_eq!(t.hosts[2].nat_face, Some(nat));
        assert_eq!(t.hosts[c as usize].cfg.nat, Some(nat));
        assert_eq!(t.hosts[c as usize].cfg.region, 1);
    }

    #[test]
    fn path_matrix_symmetric_and_loopback() {
        let mut t = TopologyBuilder::paper_regions();
        let a = t.public_host(0, LinkProfile::UNLIMITED);
        let b = t.public_host(2, LinkProfile::UNLIMITED);
        assert_eq!(t.expected_delay(a, b), 75 * MILLI);
        assert_eq!(t.expected_delay(b, a), 75 * MILLI);
        assert_eq!(t.expected_delay(a, a), t.loopback.delay);
    }
}
