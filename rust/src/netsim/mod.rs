//! Deterministic discrete-event network simulator.
//!
//! This is the substrate substituting for the real Internet (DESIGN.md §3):
//! hosts exchange UDP-like datagrams through links with latency, bandwidth
//! and loss, and through NAT boxes implementing the four classical RFC 4787
//! behaviours. All stack layers above (transport, swarm, protocols, RPC) are
//! event-driven state machines scheduled by [`Net`]'s virtual clock, which
//! makes every experiment exactly reproducible from a seed.
//!
//! Key types:
//! * [`Net`] — event queue, virtual clock, topology, NAT state. Handlers
//!   receive `&mut Net` to send datagrams and arm timers.
//! * [`World`] — owns the endpoints (node state machines) and drives the
//!   dispatch loop.
//! * [`nat::NatBox`] — per-NAT translation and filtering state.
//! * [`topology::TopologyBuilder`] — declarative construction of regions,
//!   public hosts, NATed hosts and link profiles.

pub mod event;
pub mod nat;
pub mod link;
pub mod topology;
pub mod net;
pub mod world;
pub mod churn;

pub use churn::{ChurnAction, ChurnConfig, ChurnEvent, ChurnPlan};
pub use event::QueueKind;
pub use net::{EndpointId, Net, NetStats, Timer};
pub use topology::{HostCfg, LinkProfile, Region, TopologyBuilder};
pub use world::{Endpoint, World};

/// Virtual time in nanoseconds since simulation start.
pub type Time = u64;

pub const MICRO: Time = 1_000;
pub const MILLI: Time = 1_000_000;
pub const SECOND: Time = 1_000_000_000;
