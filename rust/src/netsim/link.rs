//! Link shaping: per-host uplink/downlink serialization, propagation delay
//! and random loss.
//!
//! The model is the standard store-and-forward pipeline:
//!
//! ```text
//! depart = max(now, uplink_free) + size/up_rate
//! arrive = depart + propagation(jittered) + size/down_rate (queued)
//! ```
//!
//! Both directions keep a `next_free` watermark so sustained transfers are
//! bandwidth-limited (this is what caps 256 KB RPC throughput in Table 1),
//! and a bounded queue ahead-of-line so overload turns into drops
//! (drop-tail) rather than unbounded queueing.

use super::Time;
use crate::util::Rng;

/// Per-direction shaping state.
#[derive(Clone, Debug)]
pub struct Shaper {
    /// Bytes per second.
    pub rate_bps: u64,
    /// Time the link becomes free for the next packet.
    next_free: Time,
    /// Maximum queueing ahead (in ns) before drop-tail.
    pub max_queue_ns: Time,
    /// Fixed per-packet cost expressed in equivalent bytes (models
    /// per-packet CPU/syscall overhead on loopback paths).
    pub per_pkt_overhead: usize,
}

impl Shaper {
    pub fn new(rate_bytes_per_sec: u64) -> Shaper {
        Shaper {
            rate_bps: rate_bytes_per_sec,
            next_free: 0,
            // Default ~50 ms of queue — a typical shallow router buffer.
            max_queue_ns: 50 * super::MILLI,
            per_pkt_overhead: 0,
        }
    }

    /// Serialization delay for `size` bytes.
    #[inline]
    pub fn tx_time(&self, size: usize) -> Time {
        if self.rate_bps == 0 {
            return 0; // unlimited
        }
        ((size + self.per_pkt_overhead) as u128 * super::SECOND as u128
            / self.rate_bps as u128) as Time
    }

    /// Try to enqueue a packet at `now`; returns the departure time or None
    /// if the queue is full (packet dropped).
    pub fn enqueue(&mut self, now: Time, size: usize) -> Option<Time> {
        let start = self.next_free.max(now);
        if start.saturating_sub(now) > self.max_queue_ns {
            return None; // drop-tail
        }
        let depart = start + self.tx_time(size);
        self.next_free = depart;
        Some(depart)
    }

    /// Current queue depth in ns (diagnostics, backpressure signals).
    pub fn queue_depth(&self, now: Time) -> Time {
        self.next_free.saturating_sub(now)
    }
}

/// Propagation + loss characteristics between two regions.
#[derive(Clone, Copy, Debug)]
pub struct PathProfile {
    /// One-way propagation delay.
    pub delay: Time,
    /// Random jitter bound (uniform in [0, jitter)).
    pub jitter: Time,
    /// Packet loss probability in [0,1).
    pub loss: f64,
}

impl PathProfile {
    pub fn new(delay: Time, jitter: Time, loss: f64) -> PathProfile {
        PathProfile { delay, jitter, loss }
    }

    /// Sample the one-way latency; None if the packet is lost.
    pub fn sample(&self, rng: &mut Rng) -> Option<Time> {
        if self.loss > 0.0 && rng.gen_bool(self.loss) {
            return None;
        }
        let j = if self.jitter > 0 {
            rng.gen_range(self.jitter)
        } else {
            0
        };
        Some(self.delay + j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{MILLI, SECOND};

    #[test]
    fn tx_time_scales_with_size() {
        let s = Shaper::new(1_000_000); // 1 MB/s
        assert_eq!(s.tx_time(1_000_000), SECOND);
        assert_eq!(s.tx_time(1000), SECOND / 1000);
        assert_eq!(Shaper::new(0).tx_time(1 << 20), 0);
    }

    #[test]
    fn serialization_backs_up() {
        let mut s = Shaper::new(1_000_000); // 1 MB/s → 1 ms per KB
        let d1 = s.enqueue(0, 1000).unwrap();
        let d2 = s.enqueue(0, 1000).unwrap();
        assert_eq!(d1, MILLI);
        assert_eq!(d2, 2 * MILLI);
        // After the link drains, no queueing.
        let d3 = s.enqueue(10 * MILLI, 1000).unwrap();
        assert_eq!(d3, 11 * MILLI);
    }

    #[test]
    fn drop_tail_when_queue_full() {
        let mut s = Shaper::new(1_000_000);
        s.max_queue_ns = 5 * MILLI;
        // Fill > 5 ms of queue with 1 ms packets.
        let mut drops = 0;
        for _ in 0..10 {
            if s.enqueue(0, 1000).is_none() {
                drops += 1;
            }
        }
        assert!(drops >= 4, "expected drop-tail, got {drops} drops");
    }

    #[test]
    fn path_loss_rate() {
        let p = PathProfile::new(MILLI, 0, 0.25);
        let mut rng = Rng::new(9);
        let lost = (0..100_000).filter(|_| p.sample(&mut rng).is_none()).count();
        let rate = lost as f64 / 100_000.0;
        assert!((rate - 0.25).abs() < 0.01, "loss rate {rate}");
    }

    #[test]
    fn jitter_within_bounds() {
        let p = PathProfile::new(10 * MILLI, 2 * MILLI, 0.0);
        let mut rng = Rng::new(10);
        for _ in 0..1000 {
            let d = p.sample(&mut rng).unwrap();
            assert!(d >= 10 * MILLI && d < 12 * MILLI);
        }
    }
}
