//! NAT boxes: translation + filtering per the RFC 4787 taxonomy, with
//! measured-realism extensions.
//!
//! Each NAT owns a public host address and translates between the private
//! endpoints behind it and the outside world. Hole-punch outcomes *emerge*
//! from these semantics — there is no "roll a die per punch" shortcut.
//! Three realism mechanisms push the emergent per-pair success rates toward
//! the large-scale measurement campaign of Trautwein et al. ("Challenging
//! Tribal Knowledge", PAPERS.md) instead of the clean Ford matrix:
//!
//! 1. **Filter misbehaviour.** A fraction of real NAT boxes filter more
//!    strictly than their advertised class (claimed endpoint-independent
//!    filtering behaving endpoint-dependent, broken mapping refresh, …).
//!    Each new per-peer filter entry created toward another NAT's public
//!    face is sampled "broken" with a per-class probability
//!    ([`default_misbehave`]); broken entries silently drop inbound packets
//!    that the class rules would admit. Flows toward genuinely public hosts
//!    (relays, rendezvous, servers) are unaffected — misbehaviour shows up
//!    exactly where the measurements see it: on punched paths.
//! 2. **Port-allocation modes.** Symmetric NATs are split into sequential
//!    allocators (predictable delta; the majority in measurements) and
//!    random allocators (a hard wall). Sequential symmetric NATs make
//!    birthday-paradox port prediction work: a peer spraying a window of
//!    ports above the observed endpoint will hit the fresh punch mapping.
//! 3. **Per-entry filter TTLs and timing.** Filter entries expire on their
//!    own idle TTL (not the mapping's), and inbound packets racing ahead of
//!    the receiver's own outbound punch are dropped — punch timing races
//!    and UDP mapping timeouts are first-class.
//!
//! The calibrated per-pair acceptance bands live in
//! [`punch_success_band`]; [`punch_trial`]/[`measure_punch_matrix`] run the
//! punch choreography against two real `NatBox`es (no nodes, no event
//! loop) so regression tests and the `nat_traversal` bench can measure the
//! emergent matrix in milliseconds.

use super::Time;
use crate::multiaddr::SimAddr;
use crate::util::Rng;
use std::collections::HashMap;

/// Classical NAT behaviour classes.
///
/// Mapping = how external ports are allocated for internal endpoints.
/// Filtering = which inbound packets are accepted.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NatType {
    /// Endpoint-independent mapping + endpoint-independent filtering.
    FullCone,
    /// Endpoint-independent mapping + address-dependent filtering.
    RestrictedCone,
    /// Endpoint-independent mapping + address-and-port-dependent filtering.
    PortRestrictedCone,
    /// Address-and-port-dependent mapping (fresh public port per remote
    /// endpoint) + address-and-port-dependent filtering.
    Symmetric,
}

impl NatType {
    pub fn label(&self) -> &'static str {
        match self {
            NatType::FullCone => "full-cone",
            NatType::RestrictedCone => "restricted-cone",
            NatType::PortRestrictedCone => "port-restricted",
            NatType::Symmetric => "symmetric",
        }
    }

    /// Whether UDP hole punching between two NAT types succeeds under the
    /// *idealised* Ford et al. (2005) §4 model: both sides know each
    /// other's observed endpoints, send simultaneously, and every box
    /// implements its class faithfully. Kept as the clean-theory oracle
    /// (scenario sanity checks); the measured-realism view is
    /// [`punch_success_band`] / [`punch_success_prob`].
    pub fn punch_compatible(a: NatType, b: NatType) -> bool {
        use NatType::*;
        match (a, b) {
            (Symmetric, Symmetric) => false,
            (Symmetric, PortRestrictedCone) | (PortRestrictedCone, Symmetric) => false,
            _ => true,
        }
    }
}

/// Default lifetime of an idle UDP mapping (conservative consumer-router
/// default; RFC 4787 REQ-5 floor is 2 min but measured boxes go this low).
pub const MAPPING_TTL: Time = 30 * super::SECOND;

/// Default idle lifetime of a *per-peer filter entry* inside a mapping.
/// Independent of the mapping's own TTL: a keepalive toward one peer must
/// not keep admitting every peer ever contacted through the mapping.
pub const FILTER_TTL: Time = 30 * super::SECOND;

/// Fraction of symmetric NATs that allocate ports randomly (a hard wall
/// for port prediction). The rest allocate sequentially with a small
/// stride, which birthday-paradox spraying defeats. Roughly matches the
/// predictable/unpredictable split reported by the measurement campaign.
pub const SYM_RANDOM_FRAC: f64 = 0.25;

/// Probability that a freshly created filter entry toward another NAT's
/// public face is "broken" (the box filters more strictly than its class
/// advertises). Calibration knob for the measured matrix.
pub fn default_misbehave(t: NatType) -> f64 {
    match t {
        NatType::FullCone => 0.02,
        NatType::RestrictedCone => 0.04,
        NatType::PortRestrictedCone => 0.08,
        NatType::Symmetric => 0.10,
    }
}

/// Calibrated acceptance band (lo, hi) for the emergent punch success rate
/// of a NAT-type pair, aligned with the Trautwein et al. campaign: cone
/// pairs succeed in the high 80s–90s (misbehaving boxes, not theory,
/// explain the misses), symmetric↔port-restricted succeeds only via port
/// prediction against sequential allocators, and symmetric↔symmetric is
/// rare alignment luck. Order-insensitive.
pub fn punch_success_band(a: NatType, b: NatType) -> (f64, f64) {
    use NatType::*;
    let key = |t: NatType| match t {
        FullCone => 0,
        RestrictedCone => 1,
        PortRestrictedCone => 2,
        Symmetric => 3,
    };
    let (x, y) = if key(a) <= key(b) { (a, b) } else { (b, a) };
    match (x, y) {
        (FullCone, FullCone) => (0.85, 1.0),
        (FullCone, RestrictedCone) => (0.85, 1.0),
        (FullCone, PortRestrictedCone) => (0.80, 1.0),
        (FullCone, Symmetric) => (0.70, 1.0),
        (RestrictedCone, RestrictedCone) => (0.80, 1.0),
        (RestrictedCone, PortRestrictedCone) => (0.75, 1.0),
        (RestrictedCone, Symmetric) => (0.62, 0.98),
        (PortRestrictedCone, PortRestrictedCone) => (0.72, 1.0),
        (PortRestrictedCone, Symmetric) => (0.25, 0.85),
        (Symmetric, Symmetric) => (0.0, 0.45),
        _ => unreachable!("pairs are ordered"),
    }
}

/// Midpoint of [`punch_success_band`] — the configured expected success
/// probability for a pair (what the bench reports next to measurements).
pub fn punch_success_prob(a: NatType, b: NatType) -> f64 {
    let (lo, hi) = punch_success_band(a, b);
    (lo + hi) / 2.0
}

/// How a NAT box allocates public ports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PortAlloc {
    /// Next free port counting up by `stride` (predictable — port
    /// prediction works against symmetric boxes of this kind).
    Sequential { stride: u16 },
    /// Uniform over the ephemeral range (unpredictable).
    Random,
}

#[derive(Clone, Copy, Debug)]
struct FilterEntry {
    last_seen: Time,
    /// Misbehaving box: this entry drops inbound packets its class rules
    /// would admit. Sampled once at creation (see module docs).
    broken: bool,
}

#[derive(Clone, Debug)]
struct Mapping {
    public_port: u16,
    /// Remote endpoints this internal endpoint has sent to, with per-entry
    /// idle timestamps (for filtering; entries expire on `filter_ttl`).
    peers: HashMap<SimAddr, FilterEntry>,
    last_used: Time,
}

impl Mapping {
    fn note_peer(&mut self, now: Time, remote: SimAddr, broken: bool) {
        self.peers
            .entry(remote)
            .and_modify(|e| e.last_seen = now)
            .or_insert(FilterEntry {
                last_seen: now,
                broken,
            });
    }
}

/// A NAT device translating for one or more private hosts.
pub struct NatBox {
    pub nat_type: NatType,
    pub public_host: u32,
    /// Endpoint-independent mappings: internal (host,port) → mapping.
    eim: HashMap<SimAddr, Mapping>,
    /// Endpoint-dependent mappings (symmetric): (internal, remote) → mapping.
    edm: HashMap<(SimAddr, SimAddr), Mapping>,
    /// Reverse: public port → internal endpoint (+ remote for symmetric).
    reverse: HashMap<u16, (SimAddr, Option<SimAddr>)>,
    next_port: u16,
    /// Whether hairpin (internal→internal via public addr) is supported.
    pub hairpin: bool,
    pub port_alloc: PortAlloc,
    /// Probability a fresh filter entry toward a NAT face is broken.
    pub misbehave: f64,
    pub mapping_ttl: Time,
    pub filter_ttl: Time,
}

impl NatBox {
    pub fn new(nat_type: NatType, public_host: u32, port_base: u16) -> NatBox {
        NatBox {
            nat_type,
            public_host,
            eim: HashMap::new(),
            edm: HashMap::new(),
            reverse: HashMap::new(),
            next_port: port_base.max(1024),
            hairpin: false,
            port_alloc: PortAlloc::Sequential { stride: 1 },
            misbehave: default_misbehave(nat_type),
            mapping_ttl: MAPPING_TTL,
            filter_ttl: FILTER_TTL,
        }
    }

    pub fn with_port_alloc(mut self, alloc: PortAlloc) -> NatBox {
        self.port_alloc = alloc;
        self
    }

    pub fn with_misbehave(mut self, p: f64) -> NatBox {
        self.misbehave = p;
        self
    }

    fn alloc_port(&mut self, rng: &mut Rng) -> u16 {
        match self.port_alloc {
            PortAlloc::Random => loop {
                let p = 10_000 + (rng.gen_range(50_000) as u16);
                if !self.reverse.contains_key(&p) {
                    return p;
                }
            },
            PortAlloc::Sequential { stride } => loop {
                let p = self.next_port;
                // Wrap back into the post-reserved range; the old
                // `wrapping_add(1).max(1024)` could re-issue `port_base`
                // itself after a wrap (and 1023 of its successors) because
                // `max` only clamped the wrapped value, not the sequence.
                self.next_port = match self.next_port.checked_add(stride.max(1)) {
                    Some(v) => v,
                    None => 1024,
                };
                if p >= 1024 && !self.reverse.contains_key(&p) {
                    return p;
                }
            },
        }
    }

    /// Translate an outbound packet. Returns the public source address.
    ///
    /// `remote_is_face` marks the destination as another NAT's public face
    /// (the simulator's stand-in for "this flow is a punch, not a plain
    /// client→server exchange"); fresh filter entries toward faces are
    /// where misbehaviour is sampled.
    pub fn translate_outbound(
        &mut self,
        now: Time,
        internal: SimAddr,
        remote: SimAddr,
        remote_is_face: bool,
        rng: &mut Rng,
    ) -> SimAddr {
        self.expire(now);
        let public_host = self.public_host;
        // Short-circuit before touching the RNG: legacy (misbehave = 0)
        // boxes must not perturb the seeded stream of existing scenarios.
        let broken = remote_is_face && self.misbehave > 0.0 && rng.gen_bool(self.misbehave);
        match self.nat_type {
            NatType::Symmetric => {
                let key = (internal, remote);
                if let Some(m) = self.edm.get_mut(&key) {
                    m.last_used = now;
                    m.note_peer(now, remote, broken);
                    return SimAddr::new(public_host, m.public_port);
                }
                let port = self.alloc_port(rng);
                let mut m = Mapping {
                    public_port: port,
                    peers: HashMap::new(),
                    last_used: now,
                };
                m.note_peer(now, remote, broken);
                self.edm.insert(key, m);
                self.reverse.insert(port, (internal, Some(remote)));
                SimAddr::new(public_host, port)
            }
            _ => {
                if let Some(m) = self.eim.get_mut(&internal) {
                    m.last_used = now;
                    m.note_peer(now, remote, broken);
                    return SimAddr::new(public_host, m.public_port);
                }
                let port = self.alloc_port(rng);
                let mut m = Mapping {
                    public_port: port,
                    peers: HashMap::new(),
                    last_used: now,
                };
                m.note_peer(now, remote, broken);
                self.eim.insert(internal, m);
                self.reverse.insert(port, (internal, None));
                SimAddr::new(public_host, port)
            }
        }
    }

    /// Translate an inbound packet addressed to `public` from `remote`.
    /// Returns the internal destination if the filter admits it.
    pub fn translate_inbound(
        &mut self,
        now: Time,
        remote: SimAddr,
        public: SimAddr,
    ) -> Option<SimAddr> {
        self.expire(now);
        debug_assert_eq!(public.host, self.public_host);
        let filter_ttl = self.filter_ttl;
        let (internal, bound_remote) = self.reverse.get(&public.port).copied()?;
        let mapping = match self.nat_type {
            NatType::Symmetric => self.edm.get_mut(&(internal, bound_remote?))?,
            _ => self.eim.get_mut(&internal)?,
        };
        let fresh = |e: &FilterEntry| now.saturating_sub(e.last_seen) < filter_ttl;
        let admitted = match self.nat_type {
            // Endpoint-independent filtering admits anyone — unless the
            // box misbehaves for this specific remote.
            NatType::FullCone => mapping
                .peers
                .get(&remote)
                .map_or(true, |e| !e.broken || !fresh(e)),
            NatType::RestrictedCone => mapping
                .peers
                .iter()
                .any(|(p, e)| p.host == remote.host && fresh(e) && !e.broken),
            NatType::PortRestrictedCone | NatType::Symmetric => mapping
                .peers
                .get(&remote)
                .is_some_and(|e| fresh(e) && !e.broken),
        };
        if admitted {
            mapping.last_used = now;
            if let Some(e) = mapping.peers.get_mut(&remote) {
                e.last_seen = now;
            }
            Some(internal)
        } else {
            None
        }
    }

    /// Drop idle mappings and idle per-peer filter entries. Filter entries
    /// expire on their own TTL: a long-lived keepalive mapping must not
    /// keep admitting peers last heard from hours ago.
    fn expire(&mut self, now: Time) {
        let ttl = self.mapping_ttl;
        let fttl = self.filter_ttl;
        let mut dead_ports = Vec::new();
        let sweep = |m: &mut Mapping| {
            m.peers
                .retain(|_, e| now.saturating_sub(e.last_seen) < fttl);
            now.saturating_sub(m.last_used) < ttl
        };
        self.eim.retain(|_, m| {
            let live = sweep(m);
            if !live {
                dead_ports.push(m.public_port);
            }
            live
        });
        self.edm.retain(|_, m| {
            let live = sweep(m);
            if !live {
                dead_ports.push(m.public_port);
            }
            live
        });
        for p in dead_ports {
            self.reverse.remove(&p);
        }
    }

    /// Number of live mappings (diagnostics).
    pub fn mapping_count(&self) -> usize {
        self.eim.len() + self.edm.len()
    }
}

/// Pick a port-allocation mode for a symmetric NAT deterministically from
/// an index: 25 % random (hard wall), the rest sequential with stride 1
/// or 2. Used by the topology builder and the punch harness so both see
/// the same population mix.
pub fn sym_port_alloc(index: u64) -> PortAlloc {
    match index % 4 {
        3 => PortAlloc::Random,
        1 => PortAlloc::Sequential { stride: 2 },
        _ => PortAlloc::Sequential { stride: 1 },
    }
}

// ---------------------------------------------------------------------------
// Punch-trial harness: two real NatBoxes, no nodes, no event loop.
// ---------------------------------------------------------------------------

/// One-way delay used by the harness (punch probes cross mid-flight).
const LAB_OWD: Time = 40 * super::MILLI;
/// Volley spacing (mirrors `SwarmConfig::punch_interval`).
const LAB_INTERVAL: Time = 50 * super::MILLI;
/// Volleys per trial (mirrors `SwarmConfig::punch_attempts`).
const LAB_VOLLEYS: u32 = 4;

/// Run one hole-punch trial between two NAT types and report whether a
/// path validated (a probe crossed one way and its response crossed back —
/// exactly the swarm's PATH_CHALLENGE/PATH_RESPONSE criterion).
///
/// The choreography mirrors the production stack: both sides first
/// contact a public relay (learning their observed endpoints), then
/// volley probes at each other's observed endpoint with jittered start
/// times; from the second volley on, each side also sprays `spray`
/// sequential ports above the target (birthday-paradox port prediction).
/// Background allocations from "other tenants" drift sequential
/// allocators between volleys, so prediction is probabilistic rather than
/// exact.
pub fn punch_trial(a: NatType, b: NatType, spray: u16, seed: u64) -> bool {
    let mut rng = Rng::new(seed ^ 0x9e3779b97f4a7c15);
    let pick_alloc = |t: NatType, r: &mut Rng| match t {
        NatType::Symmetric => sym_port_alloc(r.next_u64()),
        _ => PortAlloc::Sequential { stride: 1 },
    };
    let alloc_a = pick_alloc(a, &mut rng);
    let alloc_b = pick_alloc(b, &mut rng);
    let mut na = NatBox::new(a, 100, 20_000 + (rng.gen_range(1000) as u16)).with_port_alloc(alloc_a);
    let mut nb = NatBox::new(b, 200, 30_000 + (rng.gen_range(1000) as u16)).with_port_alloc(alloc_b);

    let a_int = SimAddr::new(1, 5000);
    let b_int = SimAddr::new(2, 5000);
    let relay = SimAddr::new(300, 4001);

    // Rendezvous: both sides talk to the relay and learn their observed
    // (public) endpoints. Plain client→server flows: no misbehaviour.
    let t0: Time = 0;
    let a_obs = na.translate_outbound(t0, a_int, relay, false, &mut rng);
    let b_obs = nb.translate_outbound(t0, b_int, relay, false, &mut rng);

    // Other tenants nudge sequential allocators before the punch.
    let mut noise = |n: &mut NatBox, r: &mut Rng, t: Time, salt: u16| {
        let k = r.gen_range(3) as u16;
        for i in 0..k {
            let int = SimAddr::new(50 + salt as u32, 7000 + salt + i);
            let rem = SimAddr::new(400, 600 + salt + i);
            n.translate_outbound(t, int, rem, false, r);
        }
    };
    noise(&mut na, &mut rng, t0 + super::MILLI, 0);
    noise(&mut nb, &mut rng, t0 + super::MILLI, 100);

    // Punch: jittered simultaneous open, LAB_VOLLEYS rounds.
    let t_punch = t0 + 200 * super::MILLI;
    let jitter_a = rng.gen_range(30) * super::MILLI;
    let jitter_b = rng.gen_range(30) * super::MILLI;

    for k in 0..LAB_VOLLEYS {
        let ta = t_punch + jitter_a + k as Time * LAB_INTERVAL;
        let tb = t_punch + jitter_b + k as Time * LAB_INTERVAL;
        let sprayed = if k == 0 { 0 } else { spray };

        // Phase 1: both sides emit this volley (their own mappings and
        // filter entries exist before either volley lands — within one
        // round the jitter is smaller than the one-way delay).
        let volley = |n: &mut NatBox, int: SimAddr, obs: SimAddr, t: Time, r: &mut Rng| {
            let mut probes = Vec::new();
            for d in 0..=sprayed {
                let target = SimAddr::new(obs.host, obs.port.wrapping_add(d));
                let src = n.translate_outbound(t, int, target, true, r);
                probes.push((target, src));
            }
            probes
        };
        let a_probes = volley(&mut na, a_int, b_obs, ta, &mut rng);
        let b_probes = volley(&mut nb, b_int, a_obs, tb, &mut rng);

        // Phase 2: arrivals. An admitted probe triggers an immediate
        // response from the receiver's internal endpoint back to the
        // probe's public source; the path validates if that response is
        // admitted by the prober's NAT.
        for (target, src) in &a_probes {
            let t_arr = ta + LAB_OWD;
            if nb.translate_inbound(t_arr, *src, *target).is_some() {
                let r_src = nb.translate_outbound(t_arr, b_int, *src, true, &mut rng);
                if na.translate_inbound(t_arr + LAB_OWD, r_src, *src).is_some() {
                    return true;
                }
            }
        }
        for (target, src) in &b_probes {
            let t_arr = tb + LAB_OWD;
            if na.translate_inbound(t_arr, *src, *target).is_some() {
                let r_src = na.translate_outbound(t_arr, a_int, *src, true, &mut rng);
                if nb.translate_inbound(t_arr + LAB_OWD, r_src, *src).is_some() {
                    return true;
                }
            }
        }

        // Tenant churn between volleys keeps sequential prediction honest.
        noise(&mut na, &mut rng, ta + LAB_OWD, 10 + k as u16);
        noise(&mut nb, &mut rng, tb + LAB_OWD, 110 + k as u16);
    }
    false
}

/// Measure the emergent punch-success matrix: `trials` punch trials per
/// unordered NAT-type pair. Returns `(a, b, measured_rate)` rows.
pub fn measure_punch_matrix(trials: u32, spray: u16, seed: u64) -> Vec<(NatType, NatType, f64)> {
    use NatType::*;
    let types = [FullCone, RestrictedCone, PortRestrictedCone, Symmetric];
    let mut rows = Vec::new();
    for (i, &a) in types.iter().enumerate() {
        for &b in &types[i..] {
            let mut ok = 0u32;
            for t in 0..trials {
                let s = seed
                    .wrapping_mul(0x100000001b3)
                    .wrapping_add((i as u64) << 32)
                    .wrapping_add((b as u64) << 16)
                    .wrapping_add(t as u64);
                if punch_trial(a, b, spray, s) {
                    ok += 1;
                }
            }
            rows.push((a, b, ok as f64 / trials as f64));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn addr(h: u32, p: u16) -> SimAddr {
        SimAddr::new(h, p)
    }

    /// A box that never misbehaves (classic-semantics tests).
    fn clean(nat_type: NatType, host: u32, base: u16) -> NatBox {
        NatBox::new(nat_type, host, base).with_misbehave(0.0)
    }

    #[test]
    fn full_cone_accepts_any_remote() {
        let mut rng = Rng::new(1);
        let mut nat = clean(NatType::FullCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, server, false, &mut rng);
        assert_eq!(pub_addr.host, 100);
        // Unrelated remote can reach the mapping.
        let stranger = addr(201, 9999);
        assert_eq!(nat.translate_inbound(1, stranger, pub_addr), Some(internal));
    }

    #[test]
    fn restricted_cone_filters_by_host() {
        let mut rng = Rng::new(2);
        let mut nat = clean(NatType::RestrictedCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, server, false, &mut rng);
        // Same host, different port: allowed (address-dependent only).
        assert_eq!(
            nat.translate_inbound(1, addr(200, 99), pub_addr),
            Some(internal)
        );
        // Different host: dropped.
        assert_eq!(nat.translate_inbound(1, addr(201, 53), pub_addr), None);
    }

    #[test]
    fn port_restricted_filters_by_host_and_port() {
        let mut rng = Rng::new(3);
        let mut nat = clean(NatType::PortRestrictedCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, server, false, &mut rng);
        assert_eq!(nat.translate_inbound(1, server, pub_addr), Some(internal));
        assert_eq!(nat.translate_inbound(1, addr(200, 99), pub_addr), None);
    }

    #[test]
    fn cone_mapping_is_endpoint_independent() {
        let mut rng = Rng::new(4);
        let mut nat = clean(NatType::PortRestrictedCone, 100, 20_000);
        let internal = addr(1, 5000);
        let p1 = nat.translate_outbound(0, internal, addr(200, 1), false, &mut rng);
        let p2 = nat.translate_outbound(1, internal, addr(201, 2), false, &mut rng);
        assert_eq!(p1, p2, "EIM: same public endpoint for all remotes");
    }

    #[test]
    fn symmetric_mapping_is_endpoint_dependent() {
        let mut rng = Rng::new(5);
        let mut nat = clean(NatType::Symmetric, 100, 20_000);
        let internal = addr(1, 5000);
        let p1 = nat.translate_outbound(0, internal, addr(200, 1), false, &mut rng);
        let p2 = nat.translate_outbound(1, internal, addr(201, 2), false, &mut rng);
        assert_ne!(p1, p2, "EDM: fresh public endpoint per remote");
        // Only the bound remote may answer.
        assert_eq!(nat.translate_inbound(2, addr(200, 1), p1), Some(internal));
        assert_eq!(nat.translate_inbound(2, addr(201, 2), p1), None);
    }

    #[test]
    fn mappings_expire() {
        let mut rng = Rng::new(6);
        let mut nat = clean(NatType::FullCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, server, false, &mut rng);
        assert_eq!(nat.mapping_count(), 1);
        // After TTL, inbound no longer resolves.
        let later = MAPPING_TTL + super::super::SECOND;
        assert_eq!(nat.translate_inbound(later, server, pub_addr), None);
        assert_eq!(nat.mapping_count(), 0);
    }

    #[test]
    fn keepalive_refreshes_mapping() {
        let mut rng = Rng::new(7);
        let mut nat = clean(NatType::FullCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub1 = nat.translate_outbound(0, internal, server, false, &mut rng);
        // Keepalive at 0.8 TTL.
        let t1 = MAPPING_TTL * 8 / 10;
        let pub2 = nat.translate_outbound(t1, internal, server, false, &mut rng);
        assert_eq!(pub1, pub2);
        // Mapping still live at 1.5 TTL (refreshed at t1).
        let t2 = MAPPING_TTL * 3 / 2;
        assert_eq!(nat.translate_inbound(t2, server, pub1), Some(internal));
    }

    #[test]
    fn filter_entries_expire_independently() {
        let mut rng = Rng::new(9);
        let mut nat = clean(NatType::PortRestrictedCone, 100, 20_000);
        let internal = addr(1, 5000);
        let old_peer = addr(200, 53);
        let fresh_peer = addr(201, 53);
        let pub_addr = nat.translate_outbound(0, internal, old_peer, false, &mut rng);
        nat.translate_outbound(0, internal, fresh_peer, false, &mut rng);
        // Keepalives to fresh_peer only; old_peer's entry goes idle.
        let step = FILTER_TTL / 2;
        for i in 1..=4u64 {
            nat.translate_outbound(i * step, internal, fresh_peer, false, &mut rng);
        }
        let t = 4 * step + 1;
        // Mapping is alive (refreshed via fresh_peer)…
        assert_eq!(nat.mapping_count(), 1);
        assert_eq!(
            nat.translate_inbound(t, fresh_peer, pub_addr),
            Some(internal)
        );
        // …but the idle peer's filter entry has expired on its own TTL.
        assert_eq!(nat.translate_inbound(t, old_peer, pub_addr), None);
    }

    #[test]
    fn alloc_port_wrap_skips_low_ports() {
        let mut rng = Rng::new(10);
        // Base near the top of the range: allocations must wrap to 1024,
        // never re-issue a taken port, never hand out ports below 1024.
        let mut nat = clean(NatType::FullCone, 100, u16::MAX - 1);
        let mut seen = std::collections::HashSet::new();
        for i in 0..8u32 {
            let p = nat.translate_outbound(0, addr(1, 5000 + i as u16), addr(200, 53), false, &mut rng);
            assert!(p.port >= 1024, "allocated reserved port {}", p.port);
            assert!(seen.insert(p.port), "duplicate port {}", p.port);
        }
    }

    #[test]
    fn broken_entries_drop_admitted_traffic() {
        let mut rng = Rng::new(11);
        // misbehave = 1.0: every face-directed entry is broken.
        let mut nat = NatBox::new(NatType::PortRestrictedCone, 100, 20_000).with_misbehave(1.0);
        let internal = addr(1, 5000);
        let peer_face = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, peer_face, true, &mut rng);
        // Class rules say admit (exact match) — the broken entry drops it.
        assert_eq!(nat.translate_inbound(1, peer_face, pub_addr), None);
        // Plain server flows (not faces) are never broken.
        let server = addr(201, 80);
        let pub2 = nat.translate_outbound(0, internal, server, false, &mut rng);
        assert_eq!(nat.translate_inbound(1, server, pub2), Some(internal));
    }

    #[test]
    fn punch_matrix_matches_ford() {
        use NatType::*;
        let types = [FullCone, RestrictedCone, PortRestrictedCone, Symmetric];
        for &a in &types {
            for &b in &types {
                let ok = NatType::punch_compatible(a, b);
                let expect_fail = matches!(
                    (a, b),
                    (Symmetric, Symmetric)
                        | (Symmetric, PortRestrictedCone)
                        | (PortRestrictedCone, Symmetric)
                );
                assert_eq!(ok, !expect_fail, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn bands_are_sane_and_symmetric() {
        use NatType::*;
        let types = [FullCone, RestrictedCone, PortRestrictedCone, Symmetric];
        for &a in &types {
            for &b in &types {
                let (lo, hi) = punch_success_band(a, b);
                assert!(lo >= 0.0 && hi <= 1.0 && lo < hi);
                assert_eq!(punch_success_band(a, b), punch_success_band(b, a));
                let p = punch_success_prob(a, b);
                assert!(p > lo && p < hi);
            }
        }
        // The ideal-theory oracle and the measured bands agree on shape:
        // Ford-compatible pairs sit high, sym↔sym sits near zero.
        assert!(punch_success_prob(FullCone, FullCone) > 0.8);
        assert!(punch_success_prob(Symmetric, Symmetric) < 0.3);
    }

    #[test]
    fn punch_trials_land_in_band_quick() {
        // Quick calibration check (the strict version with more trials is
        // in tests/nat_traversal.rs). 60 trials per pair keeps this under
        // a second even in debug builds.
        use NatType::*;
        for (a, b, rate) in measure_punch_matrix(60, 16, 42) {
            let (lo, hi) = punch_success_band(a, b);
            // Widen the band by the ~3σ sampling error of 60 trials.
            let slack = 0.18;
            assert!(
                rate >= (lo - slack).max(0.0) && rate <= (hi + slack).min(1.0),
                "{} vs {}: measured {rate:.2} outside band ({lo:.2}, {hi:.2})",
                a.label(),
                b.label()
            );
        }
    }

    #[test]
    fn sequential_symmetric_is_predictable_random_is_not() {
        let mut rng = Rng::new(12);
        let mut seq = clean(NatType::Symmetric, 100, 20_000)
            .with_port_alloc(PortAlloc::Sequential { stride: 1 });
        let p1 = seq.translate_outbound(0, addr(1, 5000), addr(200, 1), false, &mut rng);
        let p2 = seq.translate_outbound(0, addr(1, 5000), addr(200, 2), false, &mut rng);
        assert_eq!(p2.port, p1.port + 1, "sequential delta is the stride");

        let mut rnd =
            clean(NatType::Symmetric, 100, 20_000).with_port_alloc(PortAlloc::Random);
        let q1 = rnd.translate_outbound(0, addr(1, 5000), addr(200, 1), false, &mut rng);
        let q2 = rnd.translate_outbound(0, addr(1, 5000), addr(200, 2), false, &mut rng);
        assert!(q1.port.abs_diff(q2.port) > 16, "random ports far apart");
    }

    #[test]
    fn sym_alloc_mix_matches_fraction() {
        let n = 1000u64;
        let random = (0..n)
            .filter(|&i| sym_port_alloc(i) == PortAlloc::Random)
            .count();
        let frac = random as f64 / n as f64;
        assert!((frac - SYM_RANDOM_FRAC).abs() < 0.05, "frac = {frac}");
    }
}
