//! NAT boxes: translation + filtering per the RFC 4787 taxonomy.
//!
//! Each NAT owns a public host address and translates between the private
//! endpoints behind it and the outside world. Hole-punch outcomes emerge
//! from these semantics (see the pairing matrix test at the bottom, and the
//! `nat_traversal` bench reproducing the paper's ~70 % direct success rate).

use super::Time;
use crate::multiaddr::SimAddr;
use std::collections::HashMap;

/// Classical NAT behaviour classes.
///
/// Mapping = how external ports are allocated for internal endpoints.
/// Filtering = which inbound packets are accepted.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NatType {
    /// Endpoint-independent mapping + endpoint-independent filtering.
    FullCone,
    /// Endpoint-independent mapping + address-dependent filtering.
    RestrictedCone,
    /// Endpoint-independent mapping + address-and-port-dependent filtering.
    PortRestrictedCone,
    /// Address-and-port-dependent mapping (fresh public port per remote
    /// endpoint) + address-and-port-dependent filtering. Hole punching
    /// across two of these fails (unpredictable ports).
    Symmetric,
}

impl NatType {
    pub fn label(&self) -> &'static str {
        match self {
            NatType::FullCone => "full-cone",
            NatType::RestrictedCone => "restricted-cone",
            NatType::PortRestrictedCone => "port-restricted",
            NatType::Symmetric => "symmetric",
        }
    }

    /// Whether UDP hole punching between two NAT types succeeds, given both
    /// sides know each other's observed (public) endpoints and simultaneously
    /// send. Follows Ford et al. (2005) §4: endpoint-independent mapping on
    /// at least one path combined with compatible filtering is required.
    pub fn punch_compatible(a: NatType, b: NatType) -> bool {
        use NatType::*;
        match (a, b) {
            // Symmetric ↔ symmetric and symmetric ↔ port-restricted fail:
            // the symmetric side's punch allocates a fresh unpredictable
            // port, so the peer's packets target a stale mapping.
            (Symmetric, Symmetric) => false,
            (Symmetric, PortRestrictedCone) | (PortRestrictedCone, Symmetric) => false,
            // Everything else succeeds with coordinated simultaneous open.
            _ => true,
        }
    }
}

/// Lifetime of an idle UDP mapping (conservative consumer-router default).
pub const MAPPING_TTL: Time = 30 * super::SECOND;

#[derive(Clone, Debug)]
struct Mapping {
    public_port: u16,
    /// Remote endpoints this internal endpoint has sent to (for filtering).
    peers: HashMap<SimAddr, Time>,
    last_used: Time,
}

/// A NAT device translating for one or more private hosts.
pub struct NatBox {
    pub nat_type: NatType,
    pub public_host: u32,
    /// Endpoint-independent mappings: internal (host,port) → mapping.
    eim: HashMap<SimAddr, Mapping>,
    /// Endpoint-dependent mappings (symmetric): (internal, remote) → mapping.
    edm: HashMap<(SimAddr, SimAddr), Mapping>,
    /// Reverse: public port → internal endpoint (+ remote for symmetric).
    reverse: HashMap<u16, (SimAddr, Option<SimAddr>)>,
    next_port: u16,
    /// Whether hairpin (internal→internal via public addr) is supported.
    pub hairpin: bool,
}

impl NatBox {
    pub fn new(nat_type: NatType, public_host: u32, port_base: u16) -> NatBox {
        NatBox {
            nat_type,
            public_host,
            eim: HashMap::new(),
            edm: HashMap::new(),
            reverse: HashMap::new(),
            next_port: port_base,
            hairpin: false,
        }
    }

    fn alloc_port(&mut self, rng: &mut crate::util::Rng) -> u16 {
        // Symmetric NATs allocate unpredictably; cone NATs sequentially.
        match self.nat_type {
            NatType::Symmetric => loop {
                let p = 10_000 + (rng.gen_range(50_000) as u16);
                if !self.reverse.contains_key(&p) {
                    return p;
                }
            },
            _ => loop {
                let p = self.next_port;
                self.next_port = self.next_port.wrapping_add(1).max(1024);
                if !self.reverse.contains_key(&p) {
                    return p;
                }
            },
        }
    }

    /// Translate an outbound packet. Returns the public source address.
    pub fn translate_outbound(
        &mut self,
        now: Time,
        internal: SimAddr,
        remote: SimAddr,
        rng: &mut crate::util::Rng,
    ) -> SimAddr {
        self.expire(now);
        let public_host = self.public_host;
        match self.nat_type {
            NatType::Symmetric => {
                let key = (internal, remote);
                if let Some(m) = self.edm.get_mut(&key) {
                    m.last_used = now;
                    m.peers.insert(remote, now);
                    return SimAddr::new(public_host, m.public_port);
                }
                let port = self.alloc_port(rng);
                let mut peers = HashMap::new();
                peers.insert(remote, now);
                self.edm.insert(
                    key,
                    Mapping {
                        public_port: port,
                        peers,
                        last_used: now,
                    },
                );
                self.reverse.insert(port, (internal, Some(remote)));
                SimAddr::new(public_host, port)
            }
            _ => {
                if let Some(m) = self.eim.get_mut(&internal) {
                    m.last_used = now;
                    m.peers.insert(remote, now);
                    return SimAddr::new(public_host, m.public_port);
                }
                let port = self.alloc_port(rng);
                let mut peers = HashMap::new();
                peers.insert(remote, now);
                self.eim.insert(
                    internal,
                    Mapping {
                        public_port: port,
                        peers,
                        last_used: now,
                    },
                );
                self.reverse.insert(port, (internal, None));
                SimAddr::new(public_host, port)
            }
        }
    }

    /// Translate an inbound packet addressed to `public` from `remote`.
    /// Returns the internal destination if the filter admits it.
    pub fn translate_inbound(
        &mut self,
        now: Time,
        remote: SimAddr,
        public: SimAddr,
    ) -> Option<SimAddr> {
        self.expire(now);
        debug_assert_eq!(public.host, self.public_host);
        let (internal, bound_remote) = self.reverse.get(&public.port).copied()?;
        let mapping = match self.nat_type {
            NatType::Symmetric => self.edm.get_mut(&(internal, bound_remote?))?,
            _ => self.eim.get_mut(&internal)?,
        };
        let admitted = match self.nat_type {
            NatType::FullCone => true,
            NatType::RestrictedCone => mapping.peers.keys().any(|p| p.host == remote.host),
            NatType::PortRestrictedCone => mapping.peers.contains_key(&remote),
            NatType::Symmetric => mapping.peers.contains_key(&remote),
        };
        if admitted {
            mapping.last_used = now;
            Some(internal)
        } else {
            None
        }
    }

    /// Drop idle mappings.
    fn expire(&mut self, now: Time) {
        let ttl = MAPPING_TTL;
        let mut dead_ports = Vec::new();
        self.eim.retain(|_, m| {
            let live = now.saturating_sub(m.last_used) < ttl;
            if !live {
                dead_ports.push(m.public_port);
            }
            live
        });
        self.edm.retain(|_, m| {
            let live = now.saturating_sub(m.last_used) < ttl;
            if !live {
                dead_ports.push(m.public_port);
            }
            live
        });
        for p in dead_ports {
            self.reverse.remove(&p);
        }
    }

    /// Number of live mappings (diagnostics).
    pub fn mapping_count(&self) -> usize {
        self.eim.len() + self.edm.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn addr(h: u32, p: u16) -> SimAddr {
        SimAddr::new(h, p)
    }

    #[test]
    fn full_cone_accepts_any_remote() {
        let mut rng = Rng::new(1);
        let mut nat = NatBox::new(NatType::FullCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, server, &mut rng);
        assert_eq!(pub_addr.host, 100);
        // Unrelated remote can reach the mapping.
        let stranger = addr(201, 9999);
        assert_eq!(nat.translate_inbound(1, stranger, pub_addr), Some(internal));
    }

    #[test]
    fn restricted_cone_filters_by_host() {
        let mut rng = Rng::new(2);
        let mut nat = NatBox::new(NatType::RestrictedCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, server, &mut rng);
        // Same host, different port: allowed (address-dependent only).
        assert_eq!(
            nat.translate_inbound(1, addr(200, 99), pub_addr),
            Some(internal)
        );
        // Different host: dropped.
        assert_eq!(nat.translate_inbound(1, addr(201, 53), pub_addr), None);
    }

    #[test]
    fn port_restricted_filters_by_host_and_port() {
        let mut rng = Rng::new(3);
        let mut nat = NatBox::new(NatType::PortRestrictedCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, server, &mut rng);
        assert_eq!(nat.translate_inbound(1, server, pub_addr), Some(internal));
        assert_eq!(nat.translate_inbound(1, addr(200, 99), pub_addr), None);
    }

    #[test]
    fn cone_mapping_is_endpoint_independent() {
        let mut rng = Rng::new(4);
        let mut nat = NatBox::new(NatType::PortRestrictedCone, 100, 20_000);
        let internal = addr(1, 5000);
        let p1 = nat.translate_outbound(0, internal, addr(200, 1), &mut rng);
        let p2 = nat.translate_outbound(1, internal, addr(201, 2), &mut rng);
        assert_eq!(p1, p2, "EIM: same public endpoint for all remotes");
    }

    #[test]
    fn symmetric_mapping_is_endpoint_dependent() {
        let mut rng = Rng::new(5);
        let mut nat = NatBox::new(NatType::Symmetric, 100, 20_000);
        let internal = addr(1, 5000);
        let p1 = nat.translate_outbound(0, internal, addr(200, 1), &mut rng);
        let p2 = nat.translate_outbound(1, internal, addr(201, 2), &mut rng);
        assert_ne!(p1, p2, "EDM: fresh public endpoint per remote");
        // Only the bound remote may answer.
        assert_eq!(nat.translate_inbound(2, addr(200, 1), p1), Some(internal));
        assert_eq!(nat.translate_inbound(2, addr(201, 2), p1), None);
    }

    #[test]
    fn mappings_expire() {
        let mut rng = Rng::new(6);
        let mut nat = NatBox::new(NatType::FullCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub_addr = nat.translate_outbound(0, internal, server, &mut rng);
        assert_eq!(nat.mapping_count(), 1);
        // After TTL, inbound no longer resolves.
        let later = MAPPING_TTL + super::super::SECOND;
        assert_eq!(nat.translate_inbound(later, server, pub_addr), None);
        assert_eq!(nat.mapping_count(), 0);
    }

    #[test]
    fn keepalive_refreshes_mapping() {
        let mut rng = Rng::new(7);
        let mut nat = NatBox::new(NatType::FullCone, 100, 20_000);
        let internal = addr(1, 5000);
        let server = addr(200, 53);
        let pub1 = nat.translate_outbound(0, internal, server, &mut rng);
        // Keepalive at 0.8 TTL.
        let t1 = MAPPING_TTL * 8 / 10;
        let pub2 = nat.translate_outbound(t1, internal, server, &mut rng);
        assert_eq!(pub1, pub2);
        // Mapping still live at 1.5 TTL (refreshed at t1).
        let t2 = MAPPING_TTL * 3 / 2;
        assert_eq!(nat.translate_inbound(t2, server, pub1), Some(internal));
    }

    #[test]
    fn two_internal_hosts_get_distinct_ports() {
        let mut rng = Rng::new(8);
        let mut nat = NatBox::new(NatType::FullCone, 100, 20_000);
        let a = nat.translate_outbound(0, addr(1, 5000), addr(200, 1), &mut rng);
        let b = nat.translate_outbound(0, addr(2, 5000), addr(200, 1), &mut rng);
        assert_ne!(a.port, b.port);
    }

    #[test]
    fn punch_matrix_matches_ford() {
        use NatType::*;
        let types = [FullCone, RestrictedCone, PortRestrictedCone, Symmetric];
        for &a in &types {
            for &b in &types {
                let ok = NatType::punch_compatible(a, b);
                let expect_fail = matches!(
                    (a, b),
                    (Symmetric, Symmetric)
                        | (Symmetric, PortRestrictedCone)
                        | (PortRestrictedCone, Symmetric)
                );
                assert_eq!(ok, !expect_fail, "{a:?} vs {b:?}");
            }
        }
    }
}
